//! `vattn` — the command-line entry point.
//!
//! Subcommands:
//!   exp <id> [--n N] [--trials T] [--seed S] [--quick]   run an experiment (or `all`)
//!   list                                                  list experiments
//!   serve [--model tiny|small] [--mode dense|vattention] [--requests R]
//!         [--eps E] [--delta D] [--workers W] [--max-batch B]
//!         [--block-tokens T] [--kv-cap-mb M] [--kv-headroom H]
//!         [--prefix-cache] [--open-loop] [--rate R]
//!         [--reuse] [--reuse-max-age A] [--kv-quant int4|int8|f32]
//!         [--kv-spill PATH] [--kv-prefetch] [--kv-prefetch-depth N]
//!                                                         drive the streaming session on a trace
//!   serve --listen ADDR [--shards N] [--shard-queue-depth D] [engine flags]
//!                                                         network front-end: stream tokens over HTTP
//!   info                                                  build/config info
//!
//! `serve`, `list` and `info` have a closed flag vocabulary and reject
//! unknown `--flags` with a listing of the known ones (a typo like
//! `--worker 8` used to silently no-op). `exp` stays permissive because
//! each experiment defines its own knobs.

use vattn::util::cli::Args;

/// Everything `vattn serve` understands (options and bare flags alike).
const SERVE_KEYS: &[&str] = &[
    "model",
    "mode",
    "requests",
    "seed",
    "workers",
    "max-batch",
    "block-tokens",
    "kv-cap-mb",
    "kv-headroom",
    "prefix-cache",
    "open-loop",
    "rate",
    "ctx-min",
    "ctx-max",
    "eps",
    "delta",
    "reuse",
    "reuse-max-age",
    "kv-quant",
    "kv-spill",
    "kv-prefetch",
    "kv-prefetch-depth",
    "listen",
    "shards",
    "shard-queue-depth",
];

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => {
            reject_unknown(&args, &[]);
            println!("experiments:");
            for (id, desc, _) in vattn::experiments::registry() {
                println!("  {id:<12} {desc}");
            }
        }
        "exp" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            match vattn::experiments::run(id, &args) {
                Ok(out) => println!("{out}"),
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                }
            }
        }
        "serve" => {
            reject_unknown(&args, SERVE_KEYS);
            if let Err(e) = serve(&args) {
                eprintln!("error: {e:#}");
                std::process::exit(2);
            }
        }
        "info" => {
            reject_unknown(&args, &[]);
            println!(
                "vattn {} — vAttention: Verified Sparse Attention (reproduction)",
                vattn::version()
            );
            println!("experiments: {}", vattn::experiments::registry().len());
            println!("budget buckets: {:?}", vattn::runtime::BUDGET_BUCKETS);
        }
        _ => {
            println!("usage: vattn <list|exp <id>|serve|info> [options]");
            println!("  vattn exp all --quick              run every experiment (reduced trials)");
            println!("  vattn exp table1 --trials 20       single experiment");
            println!("  vattn serve --mode vattention --eps 0.1 --delta 0.1   streaming session demo");
            println!("  vattn serve --workers 8 --open-loop --rate 4  open-loop Poisson load");
            println!("  vattn serve --prefix-cache --kv-cap-mb 64     shared-prefix demand paging");
            println!("  vattn serve --reuse --reuse-max-age 32        cross-step heavy-hitter reuse");
            println!("  vattn serve --kv-quant int8 --kv-cap-mb 16    verified int8 KV (4x pool capacity)");
            println!("  vattn serve --kv-quant int4 --kv-cap-mb 16    verified bit-packed int4 KV (~7x pool capacity)");
            println!("  vattn serve --kv-spill /tmp/kv.spill --kv-cap-mb 8  spill-to-disk cold tier (no preemption replays)");
            println!("  vattn serve --kv-spill /tmp/kv.spill --kv-prefetch  overlap swap-ins with compute (async staging)");
            println!("  vattn serve --listen 127.0.0.1:8044 --shards 4      HTTP front-end (sharded, streaming)");
        }
    }
}

fn reject_unknown(args: &Args, known: &[&str]) {
    if let Err(e) = args.check_known(known) {
        eprintln!("error: {e}");
        std::process::exit(2);
    }
}

fn serve(args: &Args) -> anyhow::Result<()> {
    use vattn::metrics::EventLog;
    use vattn::model::{Model, ModelConfig};
    use vattn::server::{AttentionOpt, Engine, EngineConfig, GenOptions, Session, SubmitRequest};
    use vattn::util::threadpool::default_parallelism;
    use vattn::util::Rng;
    use vattn::workloads::traces::{generate_trace, to_requests, TraceConfig};

    let model_name = args.get_str("model", "tiny");
    let cfg = ModelConfig::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let mode_name = args.get_str("mode", "vattention");
    let n_req = args.get_usize("requests", 8);
    let seed = args.get_u64("seed", 42);
    let workers = args.get_usize("workers", default_parallelism());
    let open_loop = args.has_flag("open-loop");
    let eps = args.get_f64("eps", 0.1);
    let delta = args.get_f64("delta", eps);

    let trace_cfg = TraceConfig {
        rate: args.get_f64("rate", 2.0),
        num_requests: n_req,
        context_min: args.get_usize("ctx-min", 128),
        context_max: args.get_usize("ctx-max", 512),
        gen_min: 8,
        gen_max: 32,
    };
    let mut rng = Rng::new(seed);
    let trace = generate_trace(&trace_cfg, &mut rng);
    let requests = to_requests(&trace, cfg.vocab);

    // The per-request attention contract: every submitted request
    // carries its own (ε, δ) — this CLI just gives them all the same
    // one. With --reuse, the per-(layer, head) heavy-hitter selection
    // is cached across decode steps and re-scored only on certified
    // drift (token streams are unchanged; see docs/GUARANTEES.md §6).
    let reuse = args.has_flag("reuse");
    let attention = match mode_name {
        "dense" => {
            if reuse || args.get("reuse-max-age").is_some() {
                anyhow::bail!(
                    "--reuse/--reuse-max-age cache heavy-hitter selections and only apply \
                     to --mode vattention; dense attention has no selections to reuse"
                );
            }
            AttentionOpt::Dense
        }
        "vattention" => {
            let vcfg = vattn::experiments::common::vcfg(eps).with_guarantee(eps, delta);
            if reuse {
                let rcfg = vattn::policies::ReuseConfig {
                    max_age: args.get_usize("reuse-max-age", 32),
                    ..Default::default()
                };
                AttentionOpt::VerifiedReuse(vcfg, rcfg)
            } else {
                AttentionOpt::Verified(vcfg)
            }
        }
        other => anyhow::bail!("unknown mode '{other}' (dense|vattention)"),
    };

    // Physical KV storage: `--kv-quant int8` stores K/V rows quantized
    // (3.5–4x smaller blocks, so the same --kv-cap-mb holds ~4x more
    // tokens); `--kv-quant int4` bit-packs two codes per byte (~6–7.5x
    // smaller blocks). Verified requests fold the dequantization error
    // into their (ε, δ) budget automatically (docs/GUARANTEES.md §8–9).
    let kv_quant = args.get_str("kv-quant", "f32");
    let kv_dtype = vattn::kvcache::KvDtype::parse(kv_quant)
        .ok_or_else(|| anyhow::anyhow!("unknown --kv-quant '{kv_quant}' (int4|int8|f32)"))?;
    let mut builder = EngineConfig::builder()
        .max_batch(args.get_usize("max-batch", 4))
        .seed(seed)
        .workers(workers)
        .block_tokens(args.get_usize("block-tokens", 16))
        .kv_headroom_blocks(args.get_usize("kv-headroom", 0))
        .prefix_cache(args.has_flag("prefix-cache"))
        .kv_dtype(kv_dtype);
    let kv_cap_mb = args.get_usize("kv-cap-mb", 0);
    if kv_cap_mb > 0 {
        builder = builder.kv_capacity_bytes(kv_cap_mb << 20);
    }
    // File-backed cold tier: preemption swaps KV to disk instead of
    // replaying compute, and the prefix cache persists to
    // `<path>.prefix` so later runs warm-start from it.
    if let Some(path) = args.get("kv-spill") {
        builder = builder.kv_spill(path);
    }
    // Async swap-in staging: overlap cold-tier reads with compute by
    // kicking prefetches for suspended requests near the queue front.
    // Token streams are byte-identical with it on or off; it only
    // removes the blocking re-admission reads. Requires --kv-spill.
    if args.has_flag("kv-prefetch") {
        if args.get("kv-spill").is_none() {
            anyhow::bail!("--kv-prefetch stages cold-tier reads and requires --kv-spill PATH");
        }
        builder = builder.kv_prefetch(true);
    }
    builder = builder.kv_prefetch_depth(args.get_usize("kv-prefetch-depth", 2));

    // Network front-end: shard the engine config across N tick-threaded
    // sessions behind an HTTP listener. Attention mode comes from each
    // request's JSON body on this path ("mode":"verified", eps, delta),
    // so the CLI-level --mode only sets the trace-replay default above.
    if let Some(listen) = args.get("listen") {
        use vattn::metrics::RouterSummary;
        use vattn::server::{NetServer, RouterConfig};
        let shards = args.get_usize("shards", 1);
        let depth = args.get_usize("shard-queue-depth", 64);
        let rcfg = RouterConfig::new(builder.build()).shards(shards).queue_depth(depth);
        let backend = std::sync::Arc::new(Model::new(cfg, seed));
        let server = NetServer::start(backend, listen, rcfg)?;
        println!(
            "listening on http://{} ({shards} shard(s), queue depth {depth}, {workers} worker(s)/shard)",
            server.addr()
        );
        println!("routes: POST /v1/generate · DELETE /v1/requests/{{id}} · GET /v1/stats · GET /healthz");
        println!("press Enter (or close stdin) to drain and exit");
        let mut line = String::new();
        let _ = std::io::stdin().read_line(&mut line);
        println!("draining shards...");
        let final_stats = server.shutdown();
        println!("{}", RouterSummary::from_shards(&final_stats).render());
        return Ok(());
    }

    let engine = Engine::new(Model::new(cfg, seed), builder.build());
    let mut session: Session<Model> = engine.session();

    for ar in requests {
        let opts = GenOptions::new(ar.req.gen_len).seed(ar.req.id).attention(attention.clone());
        let mut sub = SubmitRequest::new(ar.req.prompt).options(opts);
        if open_loop {
            sub = sub.arrival(ar.arrival_s);
        }
        session.submit(sub);
    }

    let t0 = std::time::Instant::now();
    let mut log = EventLog::new();
    let mut rejected = 0usize;
    while !session.is_idle() {
        for ev in session.tick()? {
            if let vattn::server::Event::Rejected { id, reason, .. } = &ev {
                eprintln!("request {id} rejected: {reason}");
                rejected += 1;
            }
            log.record(&ev);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    if rejected > 0 && log.results().is_empty() {
        anyhow::bail!("all {rejected} request(s) rejected — see reasons above");
    }

    println!(
        "mode={mode_name} eps={eps} delta={delta} model={model_name} workers={} max_batch={} open_loop={open_loop}",
        engine.workers(),
        engine.cfg.max_batch
    );
    println!("{}", log.summary(wall).render());
    let stats = session.stats();
    println!("{}", vattn::metrics::PagingSummary::from(&stats).render());
    if stats.reuse.selects > 0 {
        println!("{}", vattn::metrics::ReuseSummary::from(&stats.reuse).render());
    }
    let mut results: Vec<_> = log.results().to_vec();
    results.sort_by_key(|r| r.id);
    for r in &results {
        println!(
            "  req {:>3}: {} tokens, wait {:>7.1}ms, ttft {:>7.1}ms, decode {:>7.1}ms, density {:.3}",
            r.id,
            r.tokens.len(),
            r.wait_s * 1e3,
            r.ttft_s * 1e3,
            r.decode_s * 1e3,
            r.mean_density
        );
    }
    // Persist the prefix radix (spill mode) so the next `vattn serve
    // --kv-spill PATH` warm-starts from this run's cached prompts.
    session.flush_prefix_cache()?;
    Ok(())
}
