//! `vattn` — the command-line entry point.
//!
//! Subcommands:
//!   exp <id> [--n N] [--trials T] [--seed S] [--quick]   run an experiment (or `all`)
//!   list                                                  list experiments
//!   serve [--model tiny|small] [--mode dense|vattention] [--requests R]
//!         [--workers W] [--max-batch B] [--block-tokens T] [--kv-cap-mb M]
//!         [--open-loop] [--rate R]
//!                                                         run the serving engine on a trace
//!   info                                                  build/config info

use vattn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => {
            println!("experiments:");
            for (id, desc, _) in vattn::experiments::registry() {
                println!("  {id:<12} {desc}");
            }
        }
        "exp" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            match vattn::experiments::run(id, &args) {
                Ok(out) => println!("{out}"),
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                }
            }
        }
        "serve" => {
            if let Err(e) = serve(&args) {
                eprintln!("error: {e:#}");
                std::process::exit(2);
            }
        }
        "info" => {
            println!(
                "vattn {} — vAttention: Verified Sparse Attention (reproduction)",
                vattn::version()
            );
            println!("experiments: {}", vattn::experiments::registry().len());
            println!("budget buckets: {:?}", vattn::runtime::BUDGET_BUCKETS);
        }
        _ => {
            println!("usage: vattn <list|exp <id>|serve|info> [options]");
            println!("  vattn exp all --quick              run every experiment (reduced trials)");
            println!("  vattn exp table1 --trials 20       single experiment");
            println!("  vattn serve --mode vattention      engine demo on a synthetic trace");
            println!("  vattn serve --workers 8 --open-loop --rate 4  open-loop Poisson load");
        }
    }
}

fn serve(args: &Args) -> anyhow::Result<()> {
    use vattn::metrics::ServeSummary;
    use vattn::model::{Model, ModelConfig, Sampler};
    use vattn::server::{AttentionMode, Engine, EngineConfig};
    use vattn::util::threadpool::default_parallelism;
    use vattn::util::Rng;
    use vattn::workloads::traces::{generate_trace, to_requests, TraceConfig};

    let model_name = args.get_str("model", "tiny");
    let cfg = ModelConfig::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let mode_name = args.get_str("mode", "vattention");
    let n_req = args.get_usize("requests", 8);
    let seed = args.get_u64("seed", 42);
    let workers = args.get_usize("workers", default_parallelism());
    let open_loop = args.has_flag("open-loop");

    let trace_cfg = TraceConfig {
        rate: args.get_f64("rate", 2.0),
        num_requests: n_req,
        context_min: args.get_usize("ctx-min", 128),
        context_max: args.get_usize("ctx-max", 512),
        gen_min: 8,
        gen_max: 32,
    };
    let mut rng = Rng::new(seed);
    let trace = generate_trace(&trace_cfg, &mut rng);
    let requests = to_requests(&trace, cfg.vocab);

    let mode = match mode_name {
        "dense" => AttentionMode::Dense,
        "vattention" => AttentionMode::Sparse(Box::new(|_l, _h| {
            Box::new(vattn::policies::VAttentionPolicy::oracle(
                vattn::experiments::common::vcfg(0.1),
            ))
        })),
        other => anyhow::bail!("unknown mode '{other}' (dense|vattention)"),
    };

    let kv_cap_mb = args.get_usize("kv-cap-mb", 0);
    let engine = Engine::new(
        Model::new(cfg, seed),
        EngineConfig {
            max_batch: args.get_usize("max-batch", 4),
            sampler: Sampler::Greedy,
            seed,
            workers,
            block_tokens: args.get_usize("block-tokens", 16),
            kv_capacity_bytes: if kv_cap_mb > 0 { Some(kv_cap_mb << 20) } else { None },
            ..Default::default()
        },
    );
    let t0 = std::time::Instant::now();
    let results = if open_loop {
        engine.serve_open_loop(requests, &mode)?
    } else {
        engine.serve(requests.into_iter().map(|r| r.req).collect(), &mode)?
    };
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "mode={mode_name} model={model_name} workers={} max_batch={} open_loop={open_loop}",
        engine.workers(),
        engine.cfg.max_batch
    );
    println!("{}", ServeSummary::from_results(&results, wall).render());
    for r in &results {
        println!(
            "  req {:>3}: {} tokens, wait {:>7.1}ms, ttft {:>7.1}ms, decode {:>7.1}ms, density {:.3}",
            r.id,
            r.tokens.len(),
            r.wait_s * 1e3,
            r.ttft_s * 1e3,
            r.decode_s * 1e3,
            r.mean_density
        );
    }
    Ok(())
}
