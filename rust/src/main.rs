//! `vattn` — the command-line entry point.
//!
//! Subcommands:
//!   exp <id> [--n N] [--trials T] [--seed S] [--quick]   run an experiment (or `all`)
//!   list                                                  list experiments
//!   serve [--model tiny|small] [--mode dense|vattention] [--requests R]
//!                                                         run the serving engine on a trace
//!   info                                                  build/config info

use vattn::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "list" => {
            println!("experiments:");
            for (id, desc, _) in vattn::experiments::registry() {
                println!("  {id:<12} {desc}");
            }
        }
        "exp" => {
            let id = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
            match vattn::experiments::run(id, &args) {
                Ok(out) => println!("{out}"),
                Err(e) => {
                    eprintln!("error: {e}");
                    std::process::exit(2);
                }
            }
        }
        "serve" => {
            if let Err(e) = serve(&args) {
                eprintln!("error: {e:#}");
                std::process::exit(2);
            }
        }
        "info" => {
            println!(
                "vattn {} — vAttention: Verified Sparse Attention (reproduction)",
                vattn::version()
            );
            println!("experiments: {}", vattn::experiments::registry().len());
            println!("budget buckets: {:?}", vattn::runtime::BUDGET_BUCKETS);
        }
        _ => {
            println!("usage: vattn <list|exp <id>|serve|info> [options]");
            println!("  vattn exp all --quick          run every experiment (reduced trials)");
            println!("  vattn exp table1 --trials 20   single experiment");
            println!("  vattn serve --mode vattention  engine demo on a synthetic trace");
        }
    }
}

fn serve(args: &Args) -> anyhow::Result<()> {
    use vattn::model::{Model, ModelConfig, Sampler};
    use vattn::server::{AttentionMode, Engine, EngineConfig, Request};
    use vattn::util::Rng;
    use vattn::workloads::traces::{generate_trace, TraceConfig};

    let model_name = args.get_str("model", "tiny");
    let cfg = ModelConfig::by_name(model_name)
        .ok_or_else(|| anyhow::anyhow!("unknown model '{model_name}'"))?;
    let mode_name = args.get_str("mode", "vattention");
    let n_req = args.get_usize("requests", 8);
    let seed = args.get_u64("seed", 42);

    let trace_cfg = TraceConfig {
        num_requests: n_req,
        context_min: args.get_usize("ctx-min", 128),
        context_max: args.get_usize("ctx-max", 512),
        gen_min: 8,
        gen_max: 32,
        ..Default::default()
    };
    let mut rng = Rng::new(seed);
    let trace = generate_trace(&trace_cfg, &mut rng);
    let requests: Vec<Request> = trace
        .iter()
        .map(|t| {
            let prompt: Vec<u32> =
                (0..t.context_len as u32).map(|i| (i * 31 + t.id as u32) % 250).collect();
            Request::new(t.id, prompt, t.gen_len)
        })
        .collect();

    let mode = match mode_name {
        "dense" => AttentionMode::Dense,
        "vattention" => AttentionMode::Sparse(Box::new(|_l, _h| {
            Box::new(vattn::policies::VAttentionPolicy::oracle(
                vattn::experiments::common::vcfg(0.1),
            ))
        })),
        other => anyhow::bail!("unknown mode '{other}' (dense|vattention)"),
    };

    let engine = Engine::new(
        Model::new(cfg, seed),
        EngineConfig { max_batch: args.get_usize("max-batch", 4), sampler: Sampler::Greedy, seed },
    );
    let t0 = std::time::Instant::now();
    let results = engine.serve(requests, &mode)?;
    let wall = t0.elapsed().as_secs_f64();

    let total_tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
    let mean_density: f64 =
        results.iter().map(|r| r.mean_density).sum::<f64>() / results.len() as f64;
    let total_bytes: usize = results.iter().map(|r| r.kv_bytes_read).sum();
    println!(
        "served {} requests, {} tokens in {:.2}s ({:.1} tok/s)",
        results.len(),
        total_tokens,
        wall,
        total_tokens as f64 / wall
    );
    println!("mode={mode_name} mean decode density={mean_density:.3} kv bytes read={total_bytes}");
    for r in &results {
        println!(
            "  req {:>3}: {} tokens, ttft {:>7.1}ms, decode {:>7.1}ms, density {:.3}",
            r.id,
            r.tokens.len(),
            r.ttft_s * 1e3,
            r.decode_s * 1e3,
            r.mean_density
        );
    }
    Ok(())
}
