//! Statistics utilities for the experiment harness: summary stats,
//! percentiles, correlations (Pearson/Spearman — the Fig. 1-right
//! correlation claim), histograms, and a QQ-based normality deviation
//! statistic for the Fig. 18 CLT-validity check. Serving-side TTFT /
//! TPOT / throughput reporting lives in `serving.rs`.

pub mod serving;

pub use serving::{
    ascii_histogram, summarize, EventLog, LatencySummary, PagingSummary, RequestTimeline,
    ReuseSummary, RouterSummary, ScenarioSummary, ServeSummary,
};

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100), linear interpolation.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    // total_cmp: a NaN latency sample must not panic the whole report
    // (NaNs sort to the top and only perturb the extreme percentiles).
    s.sort_by(f64::total_cmp);
    let rank = p / 100.0 * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Pearson correlation coefficient.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    if x.len() < 2 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (&a, &b) in x.iter().zip(y.iter()) {
        num += (a - mx) * (b - my);
        dx += (a - mx) * (a - mx);
        dy += (b - my) * (b - my);
    }
    if dx <= 0.0 || dy <= 0.0 {
        return 0.0;
    }
    num / (dx * dy).sqrt()
}

/// Spearman rank correlation.
pub fn spearman(x: &[f64], y: &[f64]) -> f64 {
    let rx = ranks(x);
    let ry = ranks(y);
    pearson(&rx, &ry)
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut order: Vec<usize> = (0..xs.len()).collect();
    order.sort_by(|&a, &b| xs[a].total_cmp(&xs[b]));
    let mut r = vec![0.0; xs.len()];
    let mut i = 0;
    while i < order.len() {
        // average ranks over ties
        let mut j = i;
        while j + 1 < order.len() && xs[order[j + 1]] == xs[order[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0;
        for &o in &order[i..=j] {
            r[o] = avg;
        }
        i = j + 1;
    }
    r
}

/// Fixed-width histogram over [lo, hi).
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if bins == 0 {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x >= lo && x < hi {
            // (x - lo) / w can round up to `bins` for x just below hi;
            // clamp so in-range samples land in the last bin.
            let b = (((x - lo) / w) as usize).min(bins - 1);
            h[b] += 1;
        }
    }
    h
}

/// QQ deviation: standardize the sample, compare its quantiles against
/// the standard normal quantiles, and return the max absolute gap.
/// Small (< ~0.15 for reasonable n) ⇒ the CLT normality assumption
/// holds — the Fig. 18 check.
pub fn qq_normal_deviation(xs: &[f64]) -> f64 {
    if xs.len() < 8 {
        return f64::NAN;
    }
    let m = mean(xs);
    let s = std(xs).max(1e-12);
    let mut z: Vec<f64> = xs.iter().map(|x| (x - m) / s).collect();
    z.sort_by(f64::total_cmp);
    let n = z.len();
    let mut worst = 0.0f64;
    // Compare only the central 98% (tail quantiles are noisy at any n).
    for (i, &zi) in z.iter().enumerate() {
        let p = (i as f64 + 0.5) / n as f64;
        if !(0.01..=0.99).contains(&p) {
            continue;
        }
        let q = crate::util::inv_normal_cdf(p);
        worst = worst.max((zi - q).abs());
    }
    worst
}

/// A labelled table printer for experiment outputs (paper-style rows).
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let line = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&line(&self.header, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&line(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals (table cells).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn mean_std_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert!((mean(&xs) - 3.0).abs() < 1e-12);
        assert!((std(&xs) - (2.0f64).sqrt()).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 3.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 100.0) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [3.0, 2.0, 1.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone_nonlinear() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [1.0, 8.0, 27.0, 64.0]; // monotone but nonlinear
        assert!((spearman(&x, &y) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn qq_accepts_normal_rejects_exponential() {
        let mut rng = Rng::new(1);
        let normal: Vec<f64> = (0..5000).map(|_| rng.normal()).collect();
        let expo: Vec<f64> = (0..5000).map(|_| rng.exp(1.0)).collect();
        let dn = qq_normal_deviation(&normal);
        let de = qq_normal_deviation(&expo);
        assert!(dn < 0.15, "normal dev {dn}");
        assert!(de > 0.3, "exponential dev {de}");
    }

    #[test]
    fn histogram_counts() {
        let h = histogram(&[0.1, 0.2, 0.9, 1.5], 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 1]);
    }

    #[test]
    fn histogram_edge_rounding_stays_in_bounds() {
        // With lo/hi/bins chosen so (x - lo) / w rounds up for x just
        // below hi, the index used to reach `bins` and panic; it must
        // clamp into the last bin instead.
        let hi = 0.3;
        let x = f64::from_bits(hi.to_bits() - 1); // largest f64 < hi
        let h = histogram(&[x], 0.0, hi, 3);
        assert_eq!(h.iter().sum::<usize>(), 1);
        assert_eq!(h[2], 1);
        // Degenerate bin count must not underflow the clamp.
        assert!(histogram(&[0.5], 0.0, 1.0, 0).is_empty());
    }

    #[test]
    fn nan_samples_do_not_panic_the_report() {
        // One poisoned latency sample used to panic percentile /
        // qq_normal_deviation via partial_cmp().unwrap(); total_cmp
        // keeps the report alive (NaNs sort above every number, so
        // central percentiles of mostly-clean data stay sane).
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        let mut many: Vec<f64> = (0..64).map(|i| i as f64).collect();
        many.push(f64::NAN);
        let d = qq_normal_deviation(&many);
        assert!(d.is_nan() || d.is_finite()); // no panic is the contract
        let r = spearman(&xs, &xs);
        assert!(r.is_finite());
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("a"));
    }
}
