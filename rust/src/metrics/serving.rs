//! Serving-side metrics: TTFT / TPOT / throughput summaries and ASCII
//! histograms over a batch of completed requests — the open-loop load
//! report printed by `vattn serve` and `bench_engine` — plus
//! [`EventLog`], the streaming-side recorder that derives the same
//! latency picture from per-event timestamps as a `Session` ticks.

use std::collections::BTreeMap;

use crate::kvcache::KvDtype;
use crate::metrics::{f, histogram, mean, percentile, Table};
use crate::policies::ReuseStats;
use crate::server::{Event, RequestId, RequestResult, SessionStats, ShardStats};

/// Percentile summary of one latency distribution (seconds).
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

/// Summarize a sample of latencies (empty ⇒ all zeros).
pub fn summarize(xs: &[f64]) -> LatencySummary {
    LatencySummary {
        p50: percentile(xs, 50.0),
        p90: percentile(xs, 90.0),
        p99: percentile(xs, 99.0),
        mean: mean(xs),
        max: xs.iter().cloned().fold(0.0, f64::max),
    }
}

/// Aggregate serving report for one engine run.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub requests: usize,
    pub tokens: usize,
    /// End-to-end wall clock of the serve call, seconds.
    pub wall_s: f64,
    /// Generated tokens per second of wall clock.
    pub throughput_tok_s: f64,
    /// Completed requests per second of wall clock.
    pub request_rate: f64,
    /// Time to first token from *arrival* (queue wait + prefill).
    pub ttft: LatencySummary,
    /// Mean time per output token.
    pub tpot: LatencySummary,
    /// Queue wait before admission.
    pub wait: LatencySummary,
    pub mean_density: f64,
    pub kv_bytes_read: usize,
    /// Decode-path KV append traffic (host tier), summed over requests.
    pub kv_bytes_written: usize,
    /// Prefill-phase KV gather traffic, summed over requests.
    pub kv_prefill_bytes_read: usize,
    /// Prefill-phase KV append traffic (prompt appends + prefix-fork
    /// copy-ins) — banked per request when prefill completes, so the
    /// summary covers *all* host-tier traffic, not just decode.
    pub kv_prefill_bytes_written: usize,
    ttft_samples: Vec<f64>,
    tpot_samples: Vec<f64>,
}

impl ServeSummary {
    pub fn from_results(results: &[RequestResult], wall_s: f64) -> ServeSummary {
        let ttft_samples: Vec<f64> = results.iter().map(|r| r.ttft_from_arrival_s()).collect();
        let tpot_samples: Vec<f64> = results.iter().map(|r| r.tpot_s()).collect();
        let waits: Vec<f64> = results.iter().map(|r| r.wait_s).collect();
        let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let n = results.len();
        let density = if n > 0 {
            results.iter().map(|r| r.mean_density).sum::<f64>() / n as f64
        } else {
            1.0
        };
        ServeSummary {
            requests: n,
            tokens,
            wall_s,
            throughput_tok_s: if wall_s > 0.0 { tokens as f64 / wall_s } else { 0.0 },
            request_rate: if wall_s > 0.0 { n as f64 / wall_s } else { 0.0 },
            ttft: summarize(&ttft_samples),
            tpot: summarize(&tpot_samples),
            wait: summarize(&waits),
            mean_density: density,
            kv_bytes_read: results.iter().map(|r| r.kv_bytes_read).sum(),
            kv_bytes_written: results.iter().map(|r| r.kv_bytes_written).sum(),
            kv_prefill_bytes_read: results.iter().map(|r| r.kv_prefill_bytes_read).sum(),
            kv_prefill_bytes_written: results.iter().map(|r| r.kv_prefill_bytes_written).sum(),
            ttft_samples,
            tpot_samples,
        }
    }

    /// Render the summary table plus TTFT/TPOT histograms.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "serving summary",
            &[
                "requests",
                "tokens",
                "wall s",
                "tok/s",
                "req/s",
                "density",
                "kv MiB read",
                "kv MiB written",
                "prefill MiB written",
            ],
        );
        t.row(vec![
            self.requests.to_string(),
            self.tokens.to_string(),
            f(self.wall_s, 2),
            f(self.throughput_tok_s, 1),
            f(self.request_rate, 2),
            f(self.mean_density, 3),
            f(self.kv_bytes_read as f64 / (1 << 20) as f64, 1),
            f(self.kv_bytes_written as f64 / (1 << 20) as f64, 1),
            f(self.kv_prefill_bytes_written as f64 / (1 << 20) as f64, 1),
        ]);
        let mut l = Table::new(
            "latency (ms)",
            &["metric", "p50", "p90", "p99", "mean", "max"],
        );
        for (name, s) in [("ttft", &self.ttft), ("tpot", &self.tpot), ("queue wait", &self.wait)] {
            l.row(vec![
                name.to_string(),
                f(s.p50 * 1e3, 1),
                f(s.p90 * 1e3, 1),
                f(s.p99 * 1e3, 1),
                f(s.mean * 1e3, 1),
                f(s.max * 1e3, 1),
            ]);
        }
        let mut out = t.render();
        out.push('\n');
        out.push_str(&l.render());
        out.push('\n');
        out.push_str(&ascii_histogram("ttft (ms)", &scale_ms(&self.ttft_samples), 8, 40));
        out.push_str(&ascii_histogram("tpot (ms)", &scale_ms(&self.tpot_samples), 8, 40));
        out
    }
}

fn scale_ms(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|x| x * 1e3).collect()
}

/// Fixed-width ASCII histogram (one line per bin, `#` bars).
pub fn ascii_histogram(title: &str, xs: &[f64], bins: usize, width: usize) -> String {
    let mut out = format!("## histogram: {title}\n");
    if xs.is_empty() || bins == 0 {
        out.push_str("(no samples)\n");
        return out;
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    // Widen a degenerate range so every sample lands in [lo, hi).
    let hi = if hi > lo { hi + (hi - lo) * 1e-9 } else { lo + 1.0 };
    let counts = histogram(xs, lo, hi, bins);
    let peak = counts.iter().copied().max().unwrap_or(1).max(1);
    let step = (hi - lo) / bins as f64;
    for (b, &c) in counts.iter().enumerate() {
        let bar = "#".repeat(c * width / peak);
        out.push_str(&format!(
            "{:>10.2} .. {:>10.2} |{:<w$}| {}\n",
            lo + b as f64 * step,
            lo + (b + 1) as f64 * step,
            bar,
            c,
            w = width
        ));
    }
    out
}

/// Memory-manager report for one serving run: how well demand paging
/// and prefix sharing did. Built from [`SessionStats`]; rendered by
/// `vattn serve` and written into `BENCH_engine.json` by `bench_engine`.
#[derive(Clone, Debug, Default)]
pub struct PagingSummary {
    /// Fraction of prompt blocks served from the prefix cache.
    pub prefix_hit_rate: f64,
    pub prefix_hit_blocks: u64,
    pub prefix_lookup_blocks: u64,
    /// Active requests forced back to the queue by pool exhaustion.
    pub preemptions: u64,
    /// Preemptions served by full recompute replay (0 in spill mode,
    /// where every preemption is a swap-out instead).
    pub preemption_replays: u64,
    /// Bytes swapped out to the file-backed cold tier (`--kv-spill`).
    pub spill_out_bytes: usize,
    /// Swap-out block writes to the cold tier.
    pub spill_out_ops: usize,
    /// Bytes swapped back in from the cold tier at re-admission.
    pub swap_in_bytes: usize,
    /// Swap-in block reads from the cold tier.
    pub swap_in_ops: usize,
    /// Swap-in reads that blocked the scheduler thread (synchronous
    /// `read_block` calls — the stall `--kv-prefetch` removes).
    pub blocking_swap_in_ops: usize,
    /// Cold-tier blocks handed to the async staging engine.
    pub prefetch_issued_ops: usize,
    /// Staged blocks consumed at resume (overlap that paid off).
    pub prefetch_hit_ops: usize,
    /// Staged blocks discarded (cancelled or failed before consume).
    pub prefetch_wasted_ops: usize,
    /// High-water mark of resident KV blocks (shared blocks count once).
    pub peak_blocks_in_use: usize,
    /// Pool capacity in blocks (`None` = unbounded).
    pub capacity_blocks: Option<usize>,
    /// Copy-on-write promotions that actually copied a block.
    pub cow_copies: u64,
    /// Session-default physical KV storage dtype.
    pub kv_dtype: KvDtype,
    /// Physical KV bytes per cached token at `kv_dtype`.
    pub bytes_per_token: usize,
    /// The same token's f32 footprint.
    pub bytes_per_token_fp32: usize,
}

impl From<&SessionStats> for PagingSummary {
    fn from(s: &SessionStats) -> PagingSummary {
        PagingSummary {
            prefix_hit_rate: s.prefix_hit_rate(),
            prefix_hit_blocks: s.prefix_hit_blocks,
            prefix_lookup_blocks: s.prefix_lookup_blocks,
            preemptions: s.preemptions,
            preemption_replays: s.preemption_replays,
            spill_out_bytes: s.spill_out_bytes,
            spill_out_ops: s.spill_out_ops,
            swap_in_bytes: s.swap_in_bytes,
            swap_in_ops: s.swap_in_ops,
            blocking_swap_in_ops: s.blocking_swap_in_ops,
            prefetch_issued_ops: s.prefetch_issued_ops,
            prefetch_hit_ops: s.prefetch_hit_ops,
            prefetch_wasted_ops: s.prefetch_wasted_ops,
            peak_blocks_in_use: s.peak_blocks_in_use,
            capacity_blocks: s.capacity_blocks,
            cow_copies: s.cow_copies,
            kv_dtype: s.kv_dtype,
            bytes_per_token: s.bytes_per_token,
            bytes_per_token_fp32: s.bytes_per_token_fp32,
        }
    }
}

impl PagingSummary {
    /// KV compression of the storage dtype against f32 (1.0 at f32 or
    /// when the bytes were never populated).
    pub fn compression_ratio(&self) -> f64 {
        crate::kvcache::store::compression_ratio(self.bytes_per_token_fp32, self.bytes_per_token)
    }

    /// Fraction of staged blocks that were consumed (0.0 with prefetch
    /// off or before any kick).
    pub fn prefetch_hit_rate(&self) -> f64 {
        if self.prefetch_issued_ops == 0 {
            0.0
        } else {
            self.prefetch_hit_ops as f64 / self.prefetch_issued_ops as f64
        }
    }

    /// Fraction of swap-ins that overlapped compute instead of blocking
    /// the scheduler (1.0 = every restore came from a staged buffer).
    pub fn swap_in_overlap_rate(&self) -> f64 {
        if self.swap_in_ops == 0 {
            0.0
        } else {
            1.0 - self.blocking_swap_in_ops as f64 / self.swap_in_ops as f64
        }
    }

    /// One-line table: KV paging counters for the run.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "kv paging",
            &[
                "prefix hit",
                "hit/lookup blocks",
                "preemptions",
                "replays",
                "spill MiB out/in",
                "prefetch hit/waste",
                "overlap",
                "peak blocks",
                "capacity",
                "cow",
                "kv dtype",
                "B/token",
                "compress",
            ],
        );
        t.row(vec![
            format!("{:.1}%", self.prefix_hit_rate * 100.0),
            format!("{}/{}", self.prefix_hit_blocks, self.prefix_lookup_blocks),
            self.preemptions.to_string(),
            self.preemption_replays.to_string(),
            format!(
                "{}/{}",
                f(self.spill_out_bytes as f64 / (1 << 20) as f64, 1),
                f(self.swap_in_bytes as f64 / (1 << 20) as f64, 1)
            ),
            format!("{}/{}", self.prefetch_hit_ops, self.prefetch_wasted_ops),
            format!("{:.0}%", self.swap_in_overlap_rate() * 100.0),
            self.peak_blocks_in_use.to_string(),
            self.capacity_blocks.map_or("unbounded".to_string(), |c| c.to_string()),
            self.cow_copies.to_string(),
            self.kv_dtype.name().to_string(),
            self.bytes_per_token.to_string(),
            format!("{:.2}x", self.compression_ratio()),
        ]);
        t.render()
    }
}

/// Temporal heavy-hitter reuse report for one serving run: how often
/// the drift certificate served the cached selection instead of
/// re-running the top-k scorer, and what forced the full re-scores.
/// Built from the [`ReuseStats`] aggregated in [`SessionStats`];
/// rendered by `vattn serve --reuse` and written into
/// `BENCH_engine.json` by `bench_engine`.
#[derive(Clone, Debug, Default)]
pub struct ReuseSummary {
    /// Policy `select` calls across all (request, layer, head) policies.
    pub selects: u64,
    /// Selects served from the cached heavy set.
    pub hits: u64,
    /// hits / selects (0 when reuse never ran).
    pub hit_rate: f64,
    /// Full top-k scans actually issued.
    pub scorer_calls: u64,
    /// selects / scorer_calls — how many times fewer scans than a
    /// reuse-free run (which scans once per select). ≥ 1 structurally.
    pub scorer_reduction: f64,
    /// Total full re-scores, split by cause below.
    pub refreshes: u64,
    pub refresh_cold: u64,
    pub refresh_max_age: u64,
    pub refresh_drift: u64,
    pub refresh_budget: u64,
    pub refresh_grown: u64,
    pub refresh_unsupported: u64,
    /// Uncached tokens the certificate exact-scored instead of pruning.
    pub survivors_scored: u64,
}

impl From<&ReuseStats> for ReuseSummary {
    fn from(s: &ReuseStats) -> ReuseSummary {
        ReuseSummary {
            selects: s.selects,
            hits: s.hits,
            hit_rate: s.hit_rate(),
            scorer_calls: s.scorer_calls,
            scorer_reduction: s.scorer_reduction(),
            refreshes: s.refreshes(),
            refresh_cold: s.refresh_cold,
            refresh_max_age: s.refresh_max_age,
            refresh_drift: s.refresh_drift,
            refresh_budget: s.refresh_budget,
            refresh_grown: s.refresh_grown,
            refresh_unsupported: s.refresh_unsupported,
            survivors_scored: s.survivors_scored,
        }
    }
}

impl From<&SessionStats> for ReuseSummary {
    fn from(s: &SessionStats) -> ReuseSummary {
        ReuseSummary::from(&s.reuse)
    }
}

impl ReuseSummary {
    /// One-line table: reuse counters for the run.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "temporal reuse",
            &[
                "hit rate",
                "hits/selects",
                "scorer calls",
                "reduction",
                "refreshes (cold/age/drift/budget/grown/opaque)",
                "survivors",
            ],
        );
        t.row(vec![
            format!("{:.1}%", self.hit_rate * 100.0),
            format!("{}/{}", self.hits, self.selects),
            self.scorer_calls.to_string(),
            format!("{:.1}x", self.scorer_reduction),
            format!(
                "{} ({}/{}/{}/{}/{}/{})",
                self.refreshes,
                self.refresh_cold,
                self.refresh_max_age,
                self.refresh_drift,
                self.refresh_budget,
                self.refresh_grown,
                self.refresh_unsupported
            ),
            self.survivors_scored.to_string(),
        ]);
        t.render()
    }
}

/// Timing of one request as observed through session events (all times
/// are the session clock, seconds since session creation).
#[derive(Clone, Debug, Default)]
pub struct RequestTimeline {
    pub admitted_s: Option<f64>,
    pub first_token_s: Option<f64>,
    pub last_token_s: Option<f64>,
    /// `Token` events observed so far.
    pub tokens: usize,
    pub finished_s: Option<f64>,
    /// Times this request was preempted (re-admissions follow).
    pub preemptions: usize,
    pub rejected: bool,
}

impl RequestTimeline {
    /// Admission → first token, if both were observed.
    pub fn ttft_s(&self) -> Option<f64> {
        Some(self.first_token_s? - self.admitted_s?)
    }

    /// Observed inter-token pacing: (last − first) / (tokens − 1).
    pub fn tpot_s(&self) -> Option<f64> {
        if self.tokens < 2 {
            return None;
        }
        Some((self.last_token_s? - self.first_token_s?) / (self.tokens - 1) as f64)
    }
}

/// Streaming-side metrics recorder: feed every `Event` a `Session::tick`
/// returns and read per-request timelines (or batch-level TTFT/TPOT
/// summaries) at any point — no need to wait for completion, which is
/// the whole point of the token-event interface.
#[derive(Debug, Default)]
pub struct EventLog {
    timelines: BTreeMap<RequestId, RequestTimeline>,
    results: Vec<RequestResult>,
}

impl EventLog {
    pub fn new() -> EventLog {
        EventLog::default()
    }

    pub fn record(&mut self, ev: &Event) {
        match ev {
            Event::Admitted { id, t_s } => {
                // Re-admissions after preemption must not move the
                // admission stamp, or TTFT (first token − admission)
                // could go negative for replayed requests.
                let t = self.entry(*id);
                if t.admitted_s.is_none() {
                    t.admitted_s = Some(*t_s);
                }
            }
            Event::Token { id, t_s, .. } => {
                let t = self.entry(*id);
                if t.first_token_s.is_none() {
                    t.first_token_s = Some(*t_s);
                }
                t.last_token_s = Some(*t_s);
                t.tokens += 1;
            }
            Event::Finished { id, result, t_s } => {
                self.entry(*id).finished_s = Some(*t_s);
                self.results.push(result.clone());
            }
            Event::Preempted { id, .. } => {
                self.entry(*id).preemptions += 1;
            }
            Event::Rejected { id, .. } => {
                self.entry(*id).rejected = true;
            }
        }
    }

    /// Total preemptions observed across all requests.
    pub fn preemptions(&self) -> usize {
        self.timelines.values().map(|t| t.preemptions).sum()
    }

    fn entry(&mut self, id: RequestId) -> &mut RequestTimeline {
        self.timelines.entry(id).or_default()
    }

    pub fn timeline(&self, id: RequestId) -> Option<&RequestTimeline> {
        self.timelines.get(&id)
    }

    /// Completion records collected from `Finished` events, in finish
    /// order.
    pub fn results(&self) -> &[RequestResult] {
        &self.results
    }

    /// Total `Token` events observed (finished or not).
    pub fn tokens(&self) -> usize {
        self.timelines.values().map(|t| t.tokens).sum()
    }

    /// Event-observed TTFT samples (admission → first token), in
    /// request-id order.
    pub fn ttft_samples(&self) -> Vec<f64> {
        self.timelines.values().filter_map(RequestTimeline::ttft_s).collect()
    }

    /// Event-observed TPOT samples, in request-id order.
    pub fn tpot_samples(&self) -> Vec<f64> {
        self.timelines.values().filter_map(RequestTimeline::tpot_s).collect()
    }

    pub fn ttft(&self) -> LatencySummary {
        summarize(&self.ttft_samples())
    }

    pub fn tpot(&self) -> LatencySummary {
        summarize(&self.tpot_samples())
    }

    /// The batch-style summary over all finished requests.
    pub fn summary(&self, wall_s: f64) -> ServeSummary {
        ServeSummary::from_results(&self.results, wall_s)
    }
}

/// Aggregate report over a sharded router run: per-shard request
/// accounting plus totals and the shed rate. Built from the
/// [`ShardStats`] the router's shards report at shutdown; printed by
/// `vattn serve --listen` and written into the `"serving"` block of
/// `BENCH_engine.json` by `bench_engine`.
#[derive(Clone, Debug, Default)]
pub struct RouterSummary {
    pub shards: usize,
    /// Requests routed to any shard (accepted + shed + rejected).
    pub received: u64,
    pub submitted: u64,
    /// Load-shed rejections (queue at depth; HTTP 429).
    pub shed: u64,
    /// Synchronous validation rejections (never queued).
    pub rejected: u64,
    pub completed: u64,
    pub failed: u64,
    pub cancelled: u64,
    /// Auto-cancels after a client disconnect.
    pub disconnected: u64,
    pub per_shard: Vec<ShardStats>,
}

impl RouterSummary {
    pub fn from_shards(stats: &[ShardStats]) -> RouterSummary {
        RouterSummary {
            shards: stats.len(),
            received: stats.iter().map(|s| s.received).sum(),
            submitted: stats.iter().map(|s| s.submitted).sum(),
            shed: stats.iter().map(|s| s.shed).sum(),
            rejected: stats.iter().map(|s| s.rejected).sum(),
            completed: stats.iter().map(|s| s.completed).sum(),
            failed: stats.iter().map(|s| s.failed).sum(),
            cancelled: stats.iter().map(|s| s.cancelled).sum(),
            disconnected: stats.iter().map(|s| s.disconnected).sum(),
            per_shard: stats.to_vec(),
        }
    }

    /// Fraction of routed requests shed by bounded admission.
    pub fn shed_rate(&self) -> f64 {
        if self.received > 0 {
            self.shed as f64 / self.received as f64
        } else {
            0.0
        }
    }

    /// Per-shard table plus a totals row.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "router",
            &[
                "shard",
                "received",
                "accepted",
                "shed",
                "rejected",
                "completed",
                "failed",
                "cancelled",
                "disconnects",
            ],
        );
        for s in &self.per_shard {
            t.row(vec![
                s.shard.to_string(),
                s.received.to_string(),
                s.submitted.to_string(),
                s.shed.to_string(),
                s.rejected.to_string(),
                s.completed.to_string(),
                s.failed.to_string(),
                s.cancelled.to_string(),
                s.disconnected.to_string(),
            ]);
        }
        t.row(vec![
            "total".to_string(),
            self.received.to_string(),
            self.submitted.to_string(),
            format!("{} ({:.1}%)", self.shed, self.shed_rate() * 100.0),
            self.rejected.to_string(),
            self.completed.to_string(),
            self.failed.to_string(),
            self.cancelled.to_string(),
            self.disconnected.to_string(),
        ]);
        t.render()
    }
}

/// Aggregate view of a scenario-matrix sweep (`workloads::scenario` +
/// `workloads::harness`). Plain counters so this layer stays free of a
/// `workloads` dependency: the sweep driver records one scenario at a
/// time with [`ScenarioSummary::record`] and renders a table at the end.
/// Written into the `"scenario_matrix"` block of `BENCH_engine.json`.
#[derive(Clone, Debug, Default)]
pub struct ScenarioSummary {
    /// Scenarios driven through the differential oracle.
    pub scenarios: usize,
    /// Scenarios whose oracle check failed.
    pub failures: usize,
    /// Requests submitted across all scenarios.
    pub requests: usize,
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub preemptions: u64,
    /// Scenarios that ran an empirical (ε, δ) coverage check.
    pub coverage_checked: usize,
    /// Worst observed coverage-violation rate across checked scenarios.
    pub coverage_violation_worst: f64,
}

impl ScenarioSummary {
    /// Fold one scenario's outcome in. A failed scenario contributes
    /// only to `scenarios`/`failures` (its per-request tallies are
    /// unreliable mid-abort).
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        passed: bool,
        requests: usize,
        completed: usize,
        cancelled: usize,
        failed: usize,
        preemptions: u64,
        coverage_violation_rate: Option<f64>,
    ) {
        self.scenarios += 1;
        if !passed {
            self.failures += 1;
            return;
        }
        self.requests += requests;
        self.completed += completed;
        self.cancelled += cancelled;
        self.failed += failed;
        self.preemptions += preemptions;
        if let Some(rate) = coverage_violation_rate {
            self.coverage_checked += 1;
            if rate > self.coverage_violation_worst {
                self.coverage_violation_worst = rate;
            }
        }
    }

    /// One-row table with the sweep totals.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "scenario matrix",
            &[
                "scenarios",
                "failures",
                "requests",
                "completed",
                "cancelled",
                "failed",
                "preemptions",
                "coverage checks",
                "worst violation rate",
            ],
        );
        t.row(vec![
            self.scenarios.to_string(),
            self.failures.to_string(),
            self.requests.to_string(),
            self.completed.to_string(),
            self.cancelled.to_string(),
            self.failed.to_string(),
            self.preemptions.to_string(),
            self.coverage_checked.to_string(),
            format!("{:.3}", self.coverage_violation_worst),
        ]);
        t.render()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(id: u64, n_tok: usize, wait: f64, ttft: f64, decode: f64) -> RequestResult {
        RequestResult {
            id,
            tokens: vec![0; n_tok],
            wait_s: wait,
            ttft_s: ttft,
            decode_s: decode,
            mean_density: 0.5,
            kv_bytes_read: 1024,
            kv_bytes_written: 256,
            kv_prefill_bytes_read: 64,
            kv_prefill_bytes_written: 4096,
        }
    }

    #[test]
    fn summary_aggregates_counts_and_latency() {
        let rs = vec![result(0, 10, 0.0, 0.1, 0.9), result(1, 20, 0.5, 0.2, 1.9)];
        let s = ServeSummary::from_results(&rs, 3.0);
        assert_eq!(s.requests, 2);
        assert_eq!(s.tokens, 30);
        assert!((s.throughput_tok_s - 10.0).abs() < 1e-9);
        assert!((s.mean_density - 0.5).abs() < 1e-12);
        assert_eq!(s.kv_bytes_read, 2048);
        assert_eq!(s.kv_bytes_written, 512);
        assert_eq!(s.kv_prefill_bytes_read, 128, "prefill reads are summed, not dropped");
        assert_eq!(s.kv_prefill_bytes_written, 8192, "prefill writes are summed, not dropped");
        // ttft from arrival includes queue wait: max = 0.5 + 0.2
        assert!((s.ttft.max - 0.7).abs() < 1e-9);
        // tpot divides decode time over tokens - 1 (first token is
        // prefill's): 0.9/9 and 1.9/19 -> both 0.1
        assert!((s.tpot.p50 - 0.1).abs() < 1e-9);
    }

    #[test]
    fn tpot_zero_for_single_token_generations() {
        let r = result(0, 1, 0.0, 0.1, 0.0);
        assert_eq!(r.tpot_s(), 0.0);
    }

    #[test]
    fn scenario_summary_folds_passes_and_failures() {
        let mut s = ScenarioSummary::default();
        s.record(true, 6, 5, 1, 0, 2, Some(0.1));
        s.record(true, 6, 6, 0, 0, 0, None);
        // Failed scenarios count only toward scenarios/failures.
        s.record(false, 6, 6, 0, 0, 9, Some(0.9));
        assert_eq!(s.scenarios, 3);
        assert_eq!(s.failures, 1);
        assert_eq!(s.requests, 12);
        assert_eq!(s.completed, 11);
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.coverage_checked, 1);
        assert!((s.coverage_violation_worst - 0.1).abs() < 1e-12);
        let out = s.render();
        assert!(out.contains("## scenario matrix"));
        assert!(out.contains("0.100"));
    }

    #[test]
    fn render_contains_tables_and_histograms() {
        let rs = vec![result(0, 5, 0.0, 0.05, 0.5)];
        let out = ServeSummary::from_results(&rs, 1.0).render();
        assert!(out.contains("## serving summary"));
        assert!(out.contains("## latency (ms)"));
        assert!(out.contains("## histogram: ttft (ms)"));
        assert!(out.contains("## histogram: tpot (ms)"));
    }

    #[test]
    fn histogram_handles_degenerate_and_empty() {
        let h = ascii_histogram("x", &[], 4, 10);
        assert!(h.contains("no samples"));
        let h = ascii_histogram("x", &[1.0, 1.0, 1.0], 4, 10);
        assert!(h.contains('#'), "{h}");
    }

    #[test]
    fn summarize_empty_is_zero() {
        let s = summarize(&[]);
        assert_eq!(s.p50, 0.0);
        assert_eq!(s.max, 0.0);
    }

    #[test]
    fn event_log_derives_ttft_and_tpot_from_timestamps() {
        let mut log = EventLog::new();
        log.record(&Event::Admitted { id: 0, t_s: 1.0 });
        log.record(&Event::Token { id: 0, token: 5, step: 0, t_s: 1.25 });
        log.record(&Event::Token { id: 0, token: 6, step: 1, t_s: 1.35 });
        log.record(&Event::Token { id: 0, token: 7, step: 2, t_s: 1.45 });
        log.record(&Event::Finished { id: 0, result: result(0, 3, 0.0, 0.25, 0.2), t_s: 1.45 });
        let t = log.timeline(0).unwrap();
        assert!((t.ttft_s().unwrap() - 0.25).abs() < 1e-9);
        assert!((t.tpot_s().unwrap() - 0.1).abs() < 1e-9);
        assert_eq!(t.finished_s, Some(1.45));
        assert_eq!(log.tokens(), 3);
        assert_eq!(log.results().len(), 1);
        assert!((log.ttft().p50 - 0.25).abs() < 1e-9);
        assert_eq!(log.summary(1.0).requests, 1);
    }

    #[test]
    fn event_log_counts_preemptions_per_request() {
        let mut log = EventLog::new();
        log.record(&Event::Admitted { id: 0, t_s: 0.1 });
        log.record(&Event::Token { id: 0, token: 9, step: 0, t_s: 0.15 });
        log.record(&Event::Preempted { id: 0, t_s: 0.2 });
        log.record(&Event::Admitted { id: 0, t_s: 0.3 });
        log.record(&Event::Preempted { id: 0, t_s: 0.4 });
        log.record(&Event::Preempted { id: 1, t_s: 0.4 });
        let t = log.timeline(0).unwrap();
        assert_eq!(t.preemptions, 2);
        assert_eq!(t.admitted_s, Some(0.1), "re-admission must not move the stamp");
        assert!(t.ttft_s().unwrap() > 0.0, "TTFT stays positive across replay");
        assert_eq!(log.timeline(1).unwrap().preemptions, 1);
        assert_eq!(log.preemptions(), 3);
    }

    #[test]
    fn paging_summary_renders_from_session_stats() {
        let stats = SessionStats {
            preemptions: 3,
            prefix_hit_blocks: 60,
            prefix_lookup_blocks: 80,
            prefix_blocks_held: 32,
            blocks_in_use: 32,
            peak_blocks_in_use: 96,
            capacity_blocks: Some(128),
            cow_copies: 1,
            spill_out_bytes: 3 << 20,
            spill_out_ops: 6,
            swap_in_bytes: 3 << 20,
            swap_in_ops: 6,
            blocking_swap_in_ops: 0,
            prefetch_issued_ops: 8,
            prefetch_hit_ops: 6,
            prefetch_wasted_ops: 2,
            prefetch_bytes: 3 << 20,
            preemption_replays: 2,
            kv_dtype: KvDtype::Int8,
            bytes_per_token: 288,
            bytes_per_token_fp32: 1024,
            ..Default::default()
        };
        let s = PagingSummary::from(&stats);
        assert!((s.prefix_hit_rate - 0.75).abs() < 1e-12);
        assert_eq!(s.spill_out_bytes, 3 << 20);
        assert_eq!(s.swap_in_ops, 6);
        assert_eq!(s.preemption_replays, 2);
        assert!((s.prefetch_hit_rate() - 0.75).abs() < 1e-12, "6 of 8 staged blocks consumed");
        assert!(
            (s.swap_in_overlap_rate() - 1.0).abs() < 1e-12,
            "0 blocking reads of 6 swap-ins = full overlap"
        );
        assert!((s.compression_ratio() - 1024.0 / 288.0).abs() < 1e-12);
        assert!(s.compression_ratio() >= 3.5);
        let out = s.render();
        assert!(out.contains("## kv paging"));
        assert!(out.contains("75.0%"), "{out}");
        assert!(out.contains("60/80"));
        assert!(out.contains("3.0/3.0"), "spill out/in MiB column: {out}");
        assert!(out.contains("6/2"), "prefetch hit/waste column: {out}");
        assert!(out.contains("100%"), "overlap column: {out}");
        assert!(out.contains("128"));
        assert!(out.contains("int8"), "{out}");
        assert!(out.contains("3.56x"), "{out}");
        let unbounded = PagingSummary::from(&SessionStats::default());
        assert!(unbounded.render().contains("unbounded"));
        assert_eq!(unbounded.prefix_hit_rate, 0.0);
        assert_eq!(unbounded.prefetch_hit_rate(), 0.0, "no kicks degrades to 0, not NaN");
        assert_eq!(unbounded.swap_in_overlap_rate(), 0.0, "no swap-ins degrades to 0, not NaN");
        assert_eq!(unbounded.compression_ratio(), 1.0, "unpopulated bytes degrade to 1x");
        assert!(unbounded.render().contains("f32"));
    }

    #[test]
    fn reuse_summary_derives_rates_and_renders() {
        let stats = ReuseStats {
            selects: 100,
            hits: 88,
            survivors_scored: 40,
            scorer_calls: 12,
            refresh_cold: 4,
            refresh_max_age: 2,
            refresh_drift: 3,
            refresh_budget: 1,
            refresh_grown: 2,
            refresh_unsupported: 0,
        };
        let s = ReuseSummary::from(&stats);
        assert!((s.hit_rate - 0.88).abs() < 1e-12);
        assert!((s.scorer_reduction - 100.0 / 12.0).abs() < 1e-12);
        assert_eq!(s.refreshes, 12);
        assert_eq!(s.hits + s.refreshes, s.selects);
        let out = s.render();
        assert!(out.contains("## temporal reuse"));
        assert!(out.contains("88.0%"), "{out}");
        assert!(out.contains("88/100"));
        // Reuse never ran: rates degrade gracefully.
        let idle = ReuseSummary::from(&ReuseStats::default());
        assert_eq!(idle.hit_rate, 0.0);
        assert_eq!(idle.scorer_reduction, 1.0);
    }

    #[test]
    fn event_log_partial_streams_and_rejections() {
        let mut log = EventLog::new();
        log.record(&Event::Admitted { id: 3, t_s: 0.5 });
        log.record(&Event::Token { id: 3, token: 1, step: 0, t_s: 0.75 });
        log.record(&Event::Rejected {
            id: 4,
            reason: crate::server::EngineError::UnknownRequest(4),
            t_s: 0.1,
        });
        let t = log.timeline(3).unwrap();
        assert!(t.ttft_s().is_some());
        assert!(t.tpot_s().is_none(), "one token is not enough for pacing");
        assert!(log.timeline(4).unwrap().rejected);
        assert!(log.tpot_samples().is_empty());
        assert_eq!(log.tokens(), 1);
    }

    #[test]
    fn router_summary_aggregates_and_renders() {
        let a = ShardStats {
            shard: 0,
            received: 10,
            submitted: 7,
            shed: 2,
            rejected: 1,
            completed: 6,
            failed: 0,
            cancelled: 1,
            disconnected: 0,
            ..ShardStats::default()
        };
        let b = ShardStats { shard: 1, received: 4, submitted: 4, completed: 4, ..ShardStats::default() };
        let s = RouterSummary::from_shards(&[a, b]);
        assert_eq!(s.shards, 2);
        assert_eq!(s.received, 14);
        assert_eq!(s.shed, 2);
        assert_eq!(s.completed, 10);
        assert!((s.shed_rate() - 2.0 / 14.0).abs() < 1e-12);
        let out = s.render();
        assert!(out.contains("total"));
        assert!(out.contains("14"));
        // Empty router: shed rate degrades to zero, not NaN.
        assert_eq!(RouterSummary::from_shards(&[]).shed_rate(), 0.0);
    }
}
