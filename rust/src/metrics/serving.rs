//! Serving-side metrics: TTFT / TPOT / throughput summaries and ASCII
//! histograms over a batch of completed requests — the open-loop load
//! report printed by `vattn serve` and `bench_engine`.

use crate::metrics::{f, histogram, mean, percentile, Table};
use crate::server::RequestResult;

/// Percentile summary of one latency distribution (seconds).
#[derive(Clone, Debug)]
pub struct LatencySummary {
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub mean: f64,
    pub max: f64,
}

/// Summarize a sample of latencies (empty ⇒ all zeros).
pub fn summarize(xs: &[f64]) -> LatencySummary {
    LatencySummary {
        p50: percentile(xs, 50.0),
        p90: percentile(xs, 90.0),
        p99: percentile(xs, 99.0),
        mean: mean(xs),
        max: xs.iter().cloned().fold(0.0, f64::max),
    }
}

/// Aggregate serving report for one engine run.
#[derive(Clone, Debug)]
pub struct ServeSummary {
    pub requests: usize,
    pub tokens: usize,
    /// End-to-end wall clock of the serve call, seconds.
    pub wall_s: f64,
    /// Generated tokens per second of wall clock.
    pub throughput_tok_s: f64,
    /// Completed requests per second of wall clock.
    pub request_rate: f64,
    /// Time to first token from *arrival* (queue wait + prefill).
    pub ttft: LatencySummary,
    /// Mean time per output token.
    pub tpot: LatencySummary,
    /// Queue wait before admission.
    pub wait: LatencySummary,
    pub mean_density: f64,
    pub kv_bytes_read: usize,
    ttft_samples: Vec<f64>,
    tpot_samples: Vec<f64>,
}

impl ServeSummary {
    pub fn from_results(results: &[RequestResult], wall_s: f64) -> ServeSummary {
        let ttft_samples: Vec<f64> = results.iter().map(|r| r.ttft_from_arrival_s()).collect();
        let tpot_samples: Vec<f64> = results.iter().map(|r| r.tpot_s()).collect();
        let waits: Vec<f64> = results.iter().map(|r| r.wait_s).collect();
        let tokens: usize = results.iter().map(|r| r.tokens.len()).sum();
        let n = results.len();
        let density = if n > 0 {
            results.iter().map(|r| r.mean_density).sum::<f64>() / n as f64
        } else {
            1.0
        };
        ServeSummary {
            requests: n,
            tokens,
            wall_s,
            throughput_tok_s: if wall_s > 0.0 { tokens as f64 / wall_s } else { 0.0 },
            request_rate: if wall_s > 0.0 { n as f64 / wall_s } else { 0.0 },
            ttft: summarize(&ttft_samples),
            tpot: summarize(&tpot_samples),
            wait: summarize(&waits),
            mean_density: density,
            kv_bytes_read: results.iter().map(|r| r.kv_bytes_read).sum(),
            ttft_samples,
            tpot_samples,
        }
    }

    /// Render the summary table plus TTFT/TPOT histograms.
    pub fn render(&self) -> String {
        let mut t = Table::new(
            "serving summary",
            &["requests", "tokens", "wall s", "tok/s", "req/s", "density", "kv MiB read"],
        );
        t.row(vec![
            self.requests.to_string(),
            self.tokens.to_string(),
            f(self.wall_s, 2),
            f(self.throughput_tok_s, 1),
            f(self.request_rate, 2),
            f(self.mean_density, 3),
            f(self.kv_bytes_read as f64 / (1 << 20) as f64, 1),
        ]);
        let mut l = Table::new(
            "latency (ms)",
            &["metric", "p50", "p90", "p99", "mean", "max"],
        );
        for (name, s) in [("ttft", &self.ttft), ("tpot", &self.tpot), ("queue wait", &self.wait)] {
            l.row(vec![
                name.to_string(),
                f(s.p50 * 1e3, 1),
                f(s.p90 * 1e3, 1),
                f(s.p99 * 1e3, 1),
                f(s.mean * 1e3, 1),
                f(s.max * 1e3, 1),
            ]);
        }
        let mut out = t.render();
        out.push('\n');
        out.push_str(&l.render());
        out.push('\n');
        out.push_str(&ascii_histogram("ttft (ms)", &scale_ms(&self.ttft_samples), 8, 40));
        out.push_str(&ascii_histogram("tpot (ms)", &scale_ms(&self.tpot_samples), 8, 40));
        out
    }
}

fn scale_ms(xs: &[f64]) -> Vec<f64> {
    xs.iter().map(|x| x * 1e3).collect()
}

/// Fixed-width ASCII histogram (one line per bin, `#` bars).
pub fn ascii_histogram(title: &str, xs: &[f64], bins: usize, width: usize) -> String {
    let mut out = format!("## histogram: {title}\n");
    if xs.is_empty() || bins == 0 {
        out.push_str("(no samples)\n");
        return out;
    }
    let lo = xs.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    // Widen a degenerate range so every sample lands in [lo, hi).
    let hi = if hi > lo { hi + (hi - lo) * 1e-9 } else { lo + 1.0 };
    let counts = histogram(xs, lo, hi, bins);
    let peak = counts.iter().copied().max().unwrap_or(1).max(1);
    let step = (hi - lo) / bins as f64;
    for (b, &c) in counts.iter().enumerate() {
        let bar = "#".repeat(c * width / peak);
        out.push_str(&format!(
            "{:>10.2} .. {:>10.2} |{:<w$}| {}\n",
            lo + b as f64 * step,
            lo + (b + 1) as f64 * step,
            bar,
            c,
            w = width
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(id: u64, n_tok: usize, wait: f64, ttft: f64, decode: f64) -> RequestResult {
        RequestResult {
            id,
            tokens: vec![0; n_tok],
            wait_s: wait,
            ttft_s: ttft,
            decode_s: decode,
            mean_density: 0.5,
            kv_bytes_read: 1024,
        }
    }

    #[test]
    fn summary_aggregates_counts_and_latency() {
        let rs = vec![result(0, 10, 0.0, 0.1, 0.9), result(1, 20, 0.5, 0.2, 1.9)];
        let s = ServeSummary::from_results(&rs, 3.0);
        assert_eq!(s.requests, 2);
        assert_eq!(s.tokens, 30);
        assert!((s.throughput_tok_s - 10.0).abs() < 1e-9);
        assert!((s.mean_density - 0.5).abs() < 1e-12);
        assert_eq!(s.kv_bytes_read, 2048);
        // ttft from arrival includes queue wait: max = 0.5 + 0.2
        assert!((s.ttft.max - 0.7).abs() < 1e-9);
        // tpot divides decode time over tokens - 1 (first token is
        // prefill's): 0.9/9 and 1.9/19 -> both 0.1
        assert!((s.tpot.p50 - 0.1).abs() < 1e-9);
    }

    #[test]
    fn tpot_zero_for_single_token_generations() {
        let r = result(0, 1, 0.0, 0.1, 0.0);
        assert_eq!(r.tpot_s(), 0.0);
    }

    #[test]
    fn render_contains_tables_and_histograms() {
        let rs = vec![result(0, 5, 0.0, 0.05, 0.5)];
        let out = ServeSummary::from_results(&rs, 1.0).render();
        assert!(out.contains("## serving summary"));
        assert!(out.contains("## latency (ms)"));
        assert!(out.contains("## histogram: ttft (ms)"));
        assert!(out.contains("## histogram: tpot (ms)"));
    }

    #[test]
    fn histogram_handles_degenerate_and_empty() {
        let h = ascii_histogram("x", &[], 4, 10);
        assert!(h.contains("no samples"));
        let h = ascii_histogram("x", &[1.0, 1.0, 1.0], 4, 10);
        assert!(h.contains('#'), "{h}");
    }

    #[test]
    fn summarize_empty_is_zero() {
        let s = summarize(&[]);
        assert_eq!(s.p50, 0.0);
        assert_eq!(s.max, 0.0);
    }
}
