//! Per-row symmetric int8 **and bit-packed int4** quantization with an
//! *exact* dequantization error bound — the storage layer under the
//! verified quantized KV tier.
//!
//! Every row is quantized against its own power-of-two scale: the
//! smallest `s = 2^e` with `max_i |x_i| / s ≤ Q` (`Q = 127` for int8,
//! `Q = 7` for int4). Power-of-two scales are what makes the advertised
//! bound exact rather than approximate: `x / s` and `s · q` are exact
//! f32 operations (pure exponent shifts / small-integer products), so
//! the only error is the rounding to the nearest code and
//!
//! ```text
//! |x_i − s·q_i| ≤ s / 2        per element, with equality only at ties,
//! ```
//!
//! which is [`QuantizedMat::max_abs_err`]'s / [`QuantizedMat4::max_abs_err`]'s
//! contract, asserted bitwise by `tests/proptests.rs`. A mantissa-bearing
//! scale (`max_abs / Q`) would buy back at most one bit of precision but
//! turns the bound into "scale/2 up to ulps", which is exactly the kind
//! of slack a *verified* error budget cannot absorb silently. The budget
//! math consumes the bound through [`KvQuantBounds`] →
//! `budget::QuantSlack` for both dtypes identically — int4's coarser
//! codes simply surface as ~16× larger scales, i.e. a wider deterministic
//! bias ρ, through the *same* formulas; the derivations live in
//! `docs/GUARANTEES.md` §8 (int8) and §9 (int4).
//!
//! The fused [`QuantizedMat::dot_row`] / [`QuantizedMat4::dot_row`]
//! kernels ([`crate::tensor::simd::dot_i8`] / [`crate::tensor::simd::dot_i4`])
//! replicate [`crate::tensor::dot`]'s accumulation order exactly, so
//! `dot_row(r, b)` is **bitwise equal** to `dot(&dequantize_row(r), b)`.
//! That equality is the bridge lemma that lets the KV store keep a
//! dequantized f32 working mirror (the "on-device tile" of the paper's
//! deployment) while the paged pool, snapshots and byte accounting all
//! operate on the quantized payload: any computation over the mirror is
//! bitwise the computation a fused dequantizing kernel would produce.
//!
//! Int4 packing: two codes per byte, **low nibble = even column**, row
//! stride `cols.div_ceil(2)` bytes. Codes are clamped to `[-7, 7]`
//! (the `-8` pattern is never produced), keeping the code range
//! symmetric so the `s/2` rounding bound holds on both sides.

/// Running dequantization-error bounds of one (K, V) quantized store
/// pair, maintained per (layer, head) slot as rows are appended. All
/// downstream slack terms derive from these two maxima; per-row scales
/// remain available on the [`QuantizedMat`] for finer-grained use.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KvQuantBounds {
    /// Largest per-row K scale observed (per-element error ≤ `/ 2`).
    pub k_scale_max: f32,
    /// Largest per-row V scale observed.
    pub v_scale_max: f32,
}

impl KvQuantBounds {
    /// Uniform bound on |dequantized logit − exact logit| for any cached
    /// key against `q_scaled`: every element of k̂ is within
    /// `k_scale_max/2` of k, so the dot product moves by at most
    /// `(k_scale_max/2)·‖q‖₁` (in real arithmetic; the f32 dot's own
    /// rounding is treated as exact throughout the budget math, as for
    /// every other logit in the repo).
    pub fn logit_err(&self, q_scaled: &[f32]) -> f32 {
        let l1: f32 = q_scaled.iter().map(|q| q.abs()).sum();
        0.5 * self.k_scale_max * l1
    }

    /// Per-element bound on |dequantized value − exact value|.
    pub fn value_err(&self) -> f32 {
        0.5 * self.v_scale_max
    }

    pub fn is_zero(&self) -> bool {
        self.k_scale_max == 0.0 && self.v_scale_max == 0.0
    }
}

/// Smallest power of two `s` with `max_abs / s ≤ qmax` (0 for an
/// all-zero row). Exponent floored at -126 so the scale is always a
/// normal f32.
fn pow2_scale_for(max_abs: f32, qmax: f64) -> f32 {
    if max_abs == 0.0 {
        return 0.0;
    }
    let e = ((max_abs as f64) / qmax).log2().ceil() as i32;
    (2.0f64).powi(e.max(-126)) as f32
}

/// int8 scale: smallest power of two with `max_abs / s ≤ 127`.
fn pow2_scale(max_abs: f32) -> f32 {
    pow2_scale_for(max_abs, 127.0)
}

/// int4 scale: smallest power of two with `max_abs / s ≤ 7`. Roughly
/// 16× the int8 scale for the same row — the wider ρ the §9 budget
/// derivation charges.
fn pow2_scale4(max_abs: f32) -> f32 {
    pow2_scale_for(max_abs, 7.0)
}

/// Dequantize one code against a row scale. Shared by the mirror
/// builder and the fused dot so both produce bitwise-identical values.
/// The product is exact f32 (power-of-two scale × 7-bit integer) except
/// when it overflows — a row whose max element sits near `f32::MAX` —
/// where clamping to the finite range can only move the value *toward*
/// the original (|x| ≤ f32::MAX), so the `scale/2` bound survives.
#[inline]
pub(crate) fn deq(scale: f32, code: i8) -> f32 {
    let x = scale * code as f32;
    if x.is_infinite() {
        f32::MAX.copysign(x)
    } else {
        x
    }
}

/// Sign-extended low nibble of a packed int4 byte (the even column).
#[inline]
pub(crate) fn nib_lo(b: u8) -> i8 {
    ((b << 4) as i8) >> 4
}

/// Sign-extended high nibble of a packed int4 byte (the odd column).
#[inline]
pub(crate) fn nib_hi(b: u8) -> i8 {
    (b as i8) >> 4
}

/// Pack one int4 code pair (each in `[-7, 7]`) into a byte.
#[inline]
fn pack_nibbles(lo: i8, hi: i8) -> u8 {
    ((lo as u8) & 0x0F) | ((hi as u8) << 4)
}

/// Quantize one row, appending `row.len()` codes to `codes`. Returns the
/// row's power-of-two scale. Deterministic: the same row always produces
/// the same bytes (asserted by `tests/proptests.rs`).
pub fn quantize_row_into(row: &[f32], codes: &mut Vec<i8>) -> f32 {
    let max_abs = row.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let scale = pow2_scale(max_abs);
    if scale == 0.0 {
        codes.resize(codes.len() + row.len(), 0);
        return 0.0;
    }
    for &x in row {
        // x/scale is an exact exponent shift with |x/scale| ≤ 127, so
        // the round lands in [-127, 127] and the cast cannot saturate.
        codes.push((x / scale).round() as i8);
    }
    scale
}

/// Row-major int8 matrix with one power-of-two scale per row — the
/// physical payload of a quantized KV slot. `rows × cols` codes plus
/// `rows` f32 scales: `cols + 4` bytes per row against the fp32 row's
/// `4·cols` (3.5–4× compression for the head dims in this repo).
#[derive(Clone, Debug, Default)]
pub struct QuantizedMat {
    cols: usize,
    data: Vec<i8>,
    scales: Vec<f32>,
    max_scale: f32,
}

impl QuantizedMat {
    pub fn new(cols: usize) -> QuantizedMat {
        QuantizedMat { cols, data: Vec::new(), scales: Vec::new(), max_scale: 0.0 }
    }

    pub fn rows(&self) -> usize {
        self.scales.len()
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Quantize and append one row; returns its scale.
    pub fn push_row(&mut self, row: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), self.cols);
        let s = quantize_row_into(row, &mut self.data);
        self.scales.push(s);
        self.max_scale = self.max_scale.max(s);
        s
    }

    pub fn scale(&self, r: usize) -> f32 {
        self.scales[r]
    }

    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Largest row scale so far (monotone under appends; the running
    /// input to [`KvQuantBounds`]).
    pub fn max_scale(&self) -> f32 {
        self.max_scale
    }

    /// The exact per-element dequantization error bound of row `r`:
    /// every element satisfies `|x − x̂| ≤ scale/2` (see module docs for
    /// why this is exact, not approximate).
    pub fn max_abs_err(&self, r: usize) -> f32 {
        0.5 * self.scales[r]
    }

    pub fn row_codes(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Append row `r`'s dequantized values to `out`.
    pub fn dequantize_row_into(&self, r: usize, out: &mut Vec<f32>) {
        let s = self.scales[r];
        out.extend(self.row_codes(r).iter().map(|&c| deq(s, c)));
    }

    pub fn dequantize_row(&self, r: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.cols);
        self.dequantize_row_into(r, &mut out);
        out
    }

    /// Fused dequantize-and-dot of row `r` against `b` — bitwise equal
    /// to `tensor::dot(&self.dequantize_row(r), b)`: same dequantized
    /// values (shared [`deq`]), same accumulation order
    /// ([`crate::tensor::simd::dot_i8`] pairs with
    /// [`crate::tensor::simd::dot`]).
    pub fn dot_row(&self, r: usize, b: &[f32]) -> f32 {
        crate::tensor::simd::dot_i8(self.row_codes(r), self.scales[r], b)
    }

    /// Physical payload bytes: one code per element plus one f32 scale
    /// per row.
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Raw payload of rows [lo, hi) — codes and scales, byte-for-byte.
    pub fn raw_rows(&self, lo: usize, hi: usize) -> (&[i8], &[f32]) {
        (&self.data[lo * self.cols..hi * self.cols], &self.scales[lo..hi])
    }

    /// Append rows from a raw payload (as produced by
    /// [`QuantizedMat::raw_rows`]) without requantizing — the
    /// byte-for-byte copy behind prefix-fork snapshots, so a forked
    /// request's store is bit-identical to its donor's.
    pub fn extend_raw(&mut self, codes: &[i8], scales: &[f32]) {
        debug_assert_eq!(codes.len(), scales.len() * self.cols);
        self.data.extend_from_slice(codes);
        self.scales.extend_from_slice(scales);
        for &s in scales {
            self.max_scale = self.max_scale.max(s);
        }
    }

    pub fn clear(&mut self) {
        self.data.clear();
        self.scales.clear();
        self.max_scale = 0.0;
    }
}

/// Quantize one row to int4, appending `row.len().div_ceil(2)` packed
/// bytes to `packed`. Returns the row's power-of-two scale.
/// Deterministic, like the int8 path.
pub fn quantize_row4_into(row: &[f32], packed: &mut Vec<u8>) -> f32 {
    let max_abs = row.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let scale = pow2_scale4(max_abs);
    if scale == 0.0 {
        packed.resize(packed.len() + row.len().div_ceil(2), 0);
        return 0.0;
    }
    for pair in row.chunks(2) {
        // x/scale is an exact exponent shift with |x/scale| ≤ 7, so the
        // round lands in [-7, 7] and both nibbles carry real codes.
        let lo = (pair[0] / scale).round() as i8;
        let hi = if pair.len() == 2 { (pair[1] / scale).round() as i8 } else { 0 };
        packed.push(pack_nibbles(lo, hi));
    }
    scale
}

/// Row-major **bit-packed int4** matrix with one power-of-two scale per
/// row — the physical payload of an int4 KV slot. Two codes per byte
/// (low nibble = even column): `cols.div_ceil(2) + 4` bytes per row
/// against the fp32 row's `4·cols` (~6–7.5× compression at this repo's
/// head dims). Same exact `scale/2` per-element bound as
/// [`QuantizedMat`], just at the int4 code range `[-7, 7]`.
#[derive(Clone, Debug, Default)]
pub struct QuantizedMat4 {
    cols: usize,
    /// Packed row stride in bytes.
    stride: usize,
    data: Vec<u8>,
    scales: Vec<f32>,
    max_scale: f32,
}

impl QuantizedMat4 {
    pub fn new(cols: usize) -> QuantizedMat4 {
        QuantizedMat4 {
            cols,
            stride: cols.div_ceil(2),
            data: Vec::new(),
            scales: Vec::new(),
            max_scale: 0.0,
        }
    }

    pub fn rows(&self) -> usize {
        self.scales.len()
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Quantize and append one row; returns its scale.
    pub fn push_row(&mut self, row: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), self.cols);
        let s = quantize_row4_into(row, &mut self.data);
        self.scales.push(s);
        self.max_scale = self.max_scale.max(s);
        s
    }

    pub fn scale(&self, r: usize) -> f32 {
        self.scales[r]
    }

    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Largest row scale so far (monotone under appends; the running
    /// input to [`KvQuantBounds`] — the bounds formulas are shared with
    /// int8, only this maximum is wider).
    pub fn max_scale(&self) -> f32 {
        self.max_scale
    }

    /// The exact per-element dequantization error bound of row `r`:
    /// `|x − x̂| ≤ scale/2`, same derivation as int8 (module docs).
    pub fn max_abs_err(&self, r: usize) -> f32 {
        0.5 * self.scales[r]
    }

    /// Packed bytes of row `r` (`cols.div_ceil(2)` of them).
    pub fn row_packed(&self, r: usize) -> &[u8] {
        &self.data[r * self.stride..(r + 1) * self.stride]
    }

    /// Sign-extended code of (row `r`, column `c`).
    pub fn code(&self, r: usize, c: usize) -> i8 {
        let byte = self.data[r * self.stride + c / 2];
        if c % 2 == 0 {
            nib_lo(byte)
        } else {
            nib_hi(byte)
        }
    }

    /// Append row `r`'s dequantized values to `out`.
    pub fn dequantize_row_into(&self, r: usize, out: &mut Vec<f32>) {
        let s = self.scales[r];
        for c in 0..self.cols {
            out.push(deq(s, self.code(r, c)));
        }
    }

    pub fn dequantize_row(&self, r: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.cols);
        self.dequantize_row_into(r, &mut out);
        out
    }

    /// Fused in-register unpack-dequantize-dot of row `r` against `b` —
    /// bitwise equal to `tensor::dot(&self.dequantize_row(r), b)` (the
    /// bridge lemma at int4: [`crate::tensor::simd::dot_i4`] pairs with
    /// [`crate::tensor::simd::dot`]).
    pub fn dot_row(&self, r: usize, b: &[f32]) -> f32 {
        crate::tensor::simd::dot_i4(self.row_packed(r), self.cols, self.scales[r], b)
    }

    /// Physical payload bytes: the packed codes plus one f32 scale per
    /// row.
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Raw payload of rows [lo, hi) — packed bytes and scales.
    pub fn raw_rows(&self, lo: usize, hi: usize) -> (&[u8], &[f32]) {
        (&self.data[lo * self.stride..hi * self.stride], &self.scales[lo..hi])
    }

    /// Append rows from a raw payload (as produced by
    /// [`QuantizedMat4::raw_rows`]) without requantizing — byte-for-byte,
    /// so prefix forks and spill round-trips are bit-identical.
    pub fn extend_raw(&mut self, packed: &[u8], scales: &[f32]) {
        debug_assert_eq!(packed.len(), scales.len() * self.stride);
        self.data.extend_from_slice(packed);
        self.scales.extend_from_slice(scales);
        for &s in scales {
            self.max_scale = self.max_scale.max(s);
        }
    }

    pub fn clear(&mut self) {
        self.data.clear();
        self.scales.clear();
        self.max_scale = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;
    use crate::util::Rng;

    fn is_pow2(x: f32) -> bool {
        // Normal f32 power of two: zero mantissa bits.
        x > 0.0 && (x.to_bits() & 0x007f_ffff) == 0
    }

    #[test]
    fn scales_are_powers_of_two_and_codes_fit() {
        let mut rng = Rng::new(1);
        let mut m = QuantizedMat::new(32);
        for _ in 0..50 {
            let row: Vec<f32> = (0..32).map(|_| rng.normal32(0.0, 3.0)).collect();
            let s = m.push_row(&row);
            assert!(is_pow2(s), "scale {s} not a power of two");
        }
        assert!(m.data.iter().all(|&c| (-127..=127).contains(&(c as i32))));
        assert_eq!(m.rows(), 50);
        assert_eq!(m.payload_bytes(), 50 * (32 + 4));
    }

    #[test]
    fn roundtrip_error_within_half_scale_exact() {
        let mut rng = Rng::new(2);
        let mut m = QuantizedMat::new(16);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for _ in 0..40 {
            rows.push((0..16).map(|_| rng.normal32(0.0, 2.0)).collect());
        }
        rows.push(vec![0.0; 16]); // zero row: scale 0, exact
        rows.push(vec![-3.25; 16]); // constant row
        rows.push(vec![f32::MAX; 16]); // max-magnitude row (overflow clamp)
        for row in &rows {
            m.push_row(row);
        }
        for (r, row) in rows.iter().enumerate() {
            let bound = m.max_abs_err(r);
            let back = m.dequantize_row(r);
            for (c, (&x, &x_hat)) in row.iter().zip(back.iter()).enumerate() {
                assert!(x_hat.is_finite());
                assert!(
                    (x - x_hat).abs() <= bound,
                    "row {r} col {c}: |{x} - {x_hat}| > {bound}"
                );
            }
        }
        // Zero row is exact with a zero bound.
        let zr = rows.len() - 3;
        assert_eq!(m.scale(zr), 0.0);
        assert_eq!(m.dequantize_row(zr), vec![0.0; 16]);
    }

    #[test]
    fn exact_tie_rounds_within_bound() {
        // x = scale·(m + 0.5) sits exactly on a quantization tie; the
        // error must be exactly scale/2, never over.
        let mut m = QuantizedMat::new(4);
        // max element 127 pins the scale at exactly 1.0.
        let row = vec![127.0, 2.5, -3.5, 0.5];
        let s = m.push_row(&row);
        assert_eq!(s, 1.0);
        let back = m.dequantize_row(0);
        for (&x, &x_hat) in row.iter().zip(back.iter()) {
            assert!((x - x_hat).abs() <= 0.5, "|{x} - {x_hat}| > 0.5");
        }
    }

    #[test]
    fn quantization_is_deterministic() {
        let mut rng = Rng::new(3);
        let row: Vec<f32> = (0..24).map(|_| rng.normal32(0.0, 1.0)).collect();
        let mut a = QuantizedMat::new(24);
        let mut b = QuantizedMat::new(24);
        a.push_row(&row);
        b.push_row(&row);
        assert_eq!(a.row_codes(0), b.row_codes(0));
        assert_eq!(a.scale(0).to_bits(), b.scale(0).to_bits());
    }

    #[test]
    fn fused_dot_is_bitwise_equal_to_dequantize_then_dot() {
        let mut rng = Rng::new(4);
        let mut m = QuantizedMat::new(37); // odd width exercises the tail loop
        for _ in 0..20 {
            let row: Vec<f32> = (0..37).map(|_| rng.normal32(0.0, 2.0)).collect();
            m.push_row(&row);
        }
        let q: Vec<f32> = (0..37).map(|_| rng.normal32(0.0, 1.0)).collect();
        for r in 0..20 {
            let fused = m.dot_row(r, &q);
            let two_step = dot(&m.dequantize_row(r), &q);
            assert_eq!(fused.to_bits(), two_step.to_bits(), "row {r} diverged");
        }
    }

    #[test]
    fn raw_copy_reproduces_payload_byte_for_byte() {
        let mut rng = Rng::new(5);
        let mut src = QuantizedMat::new(8);
        for _ in 0..12 {
            let row: Vec<f32> = (0..8).map(|_| rng.normal32(0.0, 1.0)).collect();
            src.push_row(&row);
        }
        let (codes, scales) = src.raw_rows(4, 8);
        let mut dst = QuantizedMat::new(8);
        dst.extend_raw(codes, scales);
        assert_eq!(dst.rows(), 4);
        for r in 0..4 {
            assert_eq!(dst.row_codes(r), src.row_codes(4 + r));
            assert_eq!(dst.scale(r).to_bits(), src.scale(4 + r).to_bits());
            assert_eq!(dst.dequantize_row(r), src.dequantize_row(4 + r));
        }
        assert!(dst.max_scale() <= src.max_scale());
    }

    #[test]
    fn int4_scales_are_powers_of_two_and_codes_fit() {
        let mut rng = Rng::new(6);
        let mut m = QuantizedMat4::new(32);
        for _ in 0..50 {
            let row: Vec<f32> = (0..32).map(|_| rng.normal32(0.0, 3.0)).collect();
            let s = m.push_row(&row);
            assert!(is_pow2(s), "scale {s} not a power of two");
        }
        for r in 0..50 {
            for c in 0..32 {
                assert!((-7..=7).contains(&(m.code(r, c) as i32)), "code out of int4 range");
            }
        }
        assert_eq!(m.rows(), 50);
        assert_eq!(m.payload_bytes(), 50 * (16 + 4));
    }

    #[test]
    fn int4_roundtrip_error_within_half_scale_exact() {
        let mut rng = Rng::new(7);
        let mut m = QuantizedMat4::new(15); // odd width: padded last nibble
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for _ in 0..40 {
            rows.push((0..15).map(|_| rng.normal32(0.0, 2.0)).collect());
        }
        rows.push(vec![0.0; 15]);
        rows.push(vec![-3.25; 15]);
        rows.push(vec![f32::MAX; 15]);
        for row in &rows {
            m.push_row(row);
        }
        for (r, row) in rows.iter().enumerate() {
            let bound = m.max_abs_err(r);
            let back = m.dequantize_row(r);
            for (c, (&x, &x_hat)) in row.iter().zip(back.iter()).enumerate() {
                assert!(x_hat.is_finite());
                assert!(
                    (x - x_hat).abs() <= bound,
                    "row {r} col {c}: |{x} - {x_hat}| > {bound}"
                );
            }
        }
        let zr = rows.len() - 3;
        assert_eq!(m.scale(zr), 0.0);
        assert_eq!(m.dequantize_row(zr), vec![0.0; 15]);
    }

    #[test]
    fn int4_exact_tie_rounds_within_bound() {
        // max element 7 pins the int4 scale at exactly 1.0.
        let mut m = QuantizedMat4::new(4);
        let row = vec![7.0, 2.5, -3.5, 0.5];
        let s = m.push_row(&row);
        assert_eq!(s, 1.0);
        let back = m.dequantize_row(0);
        for (&x, &x_hat) in row.iter().zip(back.iter()) {
            assert!((x - x_hat).abs() <= 0.5, "|{x} - {x_hat}| > 0.5");
        }
    }

    #[test]
    fn int4_fused_dot_is_bitwise_equal_to_dequantize_then_dot() {
        let mut rng = Rng::new(8);
        let mut m = QuantizedMat4::new(37); // odd width exercises the tail
        for _ in 0..20 {
            let row: Vec<f32> = (0..37).map(|_| rng.normal32(0.0, 2.0)).collect();
            m.push_row(&row);
        }
        let q: Vec<f32> = (0..37).map(|_| rng.normal32(0.0, 1.0)).collect();
        for r in 0..20 {
            let fused = m.dot_row(r, &q);
            let two_step = dot(&m.dequantize_row(r), &q);
            assert_eq!(fused.to_bits(), two_step.to_bits(), "row {r} diverged");
        }
    }

    #[test]
    fn int4_raw_copy_reproduces_payload_byte_for_byte() {
        let mut rng = Rng::new(9);
        let mut src = QuantizedMat4::new(9); // odd width: padded stride
        for _ in 0..12 {
            let row: Vec<f32> = (0..9).map(|_| rng.normal32(0.0, 1.0)).collect();
            src.push_row(&row);
        }
        let (packed, scales) = src.raw_rows(4, 8);
        let mut dst = QuantizedMat4::new(9);
        dst.extend_raw(packed, scales);
        assert_eq!(dst.rows(), 4);
        for r in 0..4 {
            assert_eq!(dst.row_packed(r), src.row_packed(4 + r));
            assert_eq!(dst.scale(r).to_bits(), src.scale(4 + r).to_bits());
            assert_eq!(dst.dequantize_row(r), src.dequantize_row(4 + r));
        }
    }

    #[test]
    fn int4_nibble_packing_is_lossless_over_the_code_range() {
        for lo in -7i8..=7 {
            for hi in -7i8..=7 {
                let b = pack_nibbles(lo, hi);
                assert_eq!((nib_lo(b), nib_hi(b)), (lo, hi));
            }
        }
    }

    #[test]
    fn int4_scale_is_wider_than_int8_for_the_same_row() {
        // Same max_abs: int4's 7-code range forces a scale 16× the int8
        // one (both are powers of two) — the wider ρ §9 charges.
        let s8 = pow2_scale(5.0);
        let s4 = pow2_scale4(5.0);
        assert_eq!(s4, 16.0 * s8);
    }

    #[test]
    fn bounds_logit_err_scales_with_q_l1_norm() {
        let b = KvQuantBounds { k_scale_max: 0.25, v_scale_max: 0.5 };
        let q = vec![1.0, -2.0, 0.5];
        assert!((b.logit_err(&q) - 0.5 * 0.25 * 3.5).abs() < 1e-7);
        assert!((b.value_err() - 0.25).abs() < 1e-7);
        assert!(!b.is_zero());
        assert!(KvQuantBounds::default().is_zero());
    }
}
