//! Per-row symmetric int8 quantization with an *exact* dequantization
//! error bound — the kernel layer under the verified quantized KV tier.
//!
//! Every row is quantized against its own power-of-two scale: the
//! smallest `s = 2^e` with `max_i |x_i| / s ≤ 127`. Power-of-two scales
//! are what makes the advertised bound exact rather than approximate:
//! `x / s` and `s · q` are exact f32 operations (pure exponent shifts /
//! small-integer products), so the only error is the rounding to the
//! nearest code and
//!
//! ```text
//! |x_i − s·q_i| ≤ s / 2        per element, with equality only at ties,
//! ```
//!
//! which is [`QuantizedMat::max_abs_err`]'s contract, asserted bitwise by
//! `tests/proptests.rs`. A mantissa-bearing scale (`max_abs / 127`)
//! would buy back at most one bit of precision but turns the bound into
//! "scale/2 up to ulps", which is exactly the kind of slack a *verified*
//! error budget cannot absorb silently. The budget math consumes the
//! bound through [`KvQuantBounds`] → `budget::QuantSlack`; the
//! derivation lives in `docs/GUARANTEES.md` §8.
//!
//! The fused [`QuantizedMat::dot_row`] replicates [`crate::tensor::dot`]'s
//! accumulation order exactly, so `dot_row(r, b)` is **bitwise equal** to
//! `dot(&dequantize_row(r), b)`. That equality is the bridge lemma that
//! lets the KV store keep a dequantized f32 working mirror (the
//! "on-device tile" of the paper's deployment) while the paged pool,
//! snapshots and byte accounting all operate on the int8 payload: any
//! computation over the mirror is bitwise the computation a fused
//! dequantizing kernel would produce.

/// Running dequantization-error bounds of one (K, V) quantized store
/// pair, maintained per (layer, head) slot as rows are appended. All
/// downstream slack terms derive from these two maxima; per-row scales
/// remain available on the [`QuantizedMat`] for finer-grained use.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KvQuantBounds {
    /// Largest per-row K scale observed (per-element error ≤ `/ 2`).
    pub k_scale_max: f32,
    /// Largest per-row V scale observed.
    pub v_scale_max: f32,
}

impl KvQuantBounds {
    /// Uniform bound on |dequantized logit − exact logit| for any cached
    /// key against `q_scaled`: every element of k̂ is within
    /// `k_scale_max/2` of k, so the dot product moves by at most
    /// `(k_scale_max/2)·‖q‖₁` (in real arithmetic; the f32 dot's own
    /// rounding is treated as exact throughout the budget math, as for
    /// every other logit in the repo).
    pub fn logit_err(&self, q_scaled: &[f32]) -> f32 {
        let l1: f32 = q_scaled.iter().map(|q| q.abs()).sum();
        0.5 * self.k_scale_max * l1
    }

    /// Per-element bound on |dequantized value − exact value|.
    pub fn value_err(&self) -> f32 {
        0.5 * self.v_scale_max
    }

    pub fn is_zero(&self) -> bool {
        self.k_scale_max == 0.0 && self.v_scale_max == 0.0
    }
}

/// Smallest power of two `s` with `max_abs / s ≤ 127` (0 for an all-zero
/// row). Exponent floored at -126 so the scale is always a normal f32.
fn pow2_scale(max_abs: f32) -> f32 {
    if max_abs == 0.0 {
        return 0.0;
    }
    let e = ((max_abs as f64) / 127.0).log2().ceil() as i32;
    (2.0f64).powi(e.max(-126)) as f32
}

/// Dequantize one code against a row scale. Shared by the mirror
/// builder and the fused dot so both produce bitwise-identical values.
/// The product is exact f32 (power-of-two scale × 7-bit integer) except
/// when it overflows — a row whose max element sits near `f32::MAX` —
/// where clamping to the finite range can only move the value *toward*
/// the original (|x| ≤ f32::MAX), so the `scale/2` bound survives.
#[inline]
fn deq(scale: f32, code: i8) -> f32 {
    let x = scale * code as f32;
    if x.is_infinite() {
        f32::MAX.copysign(x)
    } else {
        x
    }
}

/// Quantize one row, appending `row.len()` codes to `codes`. Returns the
/// row's power-of-two scale. Deterministic: the same row always produces
/// the same bytes (asserted by `tests/proptests.rs`).
pub fn quantize_row_into(row: &[f32], codes: &mut Vec<i8>) -> f32 {
    let max_abs = row.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
    let scale = pow2_scale(max_abs);
    if scale == 0.0 {
        codes.resize(codes.len() + row.len(), 0);
        return 0.0;
    }
    for &x in row {
        // x/scale is an exact exponent shift with |x/scale| ≤ 127, so
        // the round lands in [-127, 127] and the cast cannot saturate.
        codes.push((x / scale).round() as i8);
    }
    scale
}

/// Row-major int8 matrix with one power-of-two scale per row — the
/// physical payload of a quantized KV slot. `rows × cols` codes plus
/// `rows` f32 scales: `cols + 4` bytes per row against the fp32 row's
/// `4·cols` (3.5–4× compression for the head dims in this repo).
#[derive(Clone, Debug, Default)]
pub struct QuantizedMat {
    cols: usize,
    data: Vec<i8>,
    scales: Vec<f32>,
    max_scale: f32,
}

impl QuantizedMat {
    pub fn new(cols: usize) -> QuantizedMat {
        QuantizedMat { cols, data: Vec::new(), scales: Vec::new(), max_scale: 0.0 }
    }

    pub fn rows(&self) -> usize {
        self.scales.len()
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Quantize and append one row; returns its scale.
    pub fn push_row(&mut self, row: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), self.cols);
        let s = quantize_row_into(row, &mut self.data);
        self.scales.push(s);
        self.max_scale = self.max_scale.max(s);
        s
    }

    pub fn scale(&self, r: usize) -> f32 {
        self.scales[r]
    }

    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Largest row scale so far (monotone under appends; the running
    /// input to [`KvQuantBounds`]).
    pub fn max_scale(&self) -> f32 {
        self.max_scale
    }

    /// The exact per-element dequantization error bound of row `r`:
    /// every element satisfies `|x − x̂| ≤ scale/2` (see module docs for
    /// why this is exact, not approximate).
    pub fn max_abs_err(&self, r: usize) -> f32 {
        0.5 * self.scales[r]
    }

    pub fn row_codes(&self, r: usize) -> &[i8] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Append row `r`'s dequantized values to `out`.
    pub fn dequantize_row_into(&self, r: usize, out: &mut Vec<f32>) {
        let s = self.scales[r];
        out.extend(self.row_codes(r).iter().map(|&c| deq(s, c)));
    }

    pub fn dequantize_row(&self, r: usize) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.cols);
        self.dequantize_row_into(r, &mut out);
        out
    }

    /// Fused dequantize-and-dot of row `r` against `b` — bitwise equal
    /// to `tensor::dot(&self.dequantize_row(r), b)`: same dequantized
    /// values (shared `deq`), same 8-wide unrolled accumulation order.
    pub fn dot_row(&self, r: usize, b: &[f32]) -> f32 {
        let codes = self.row_codes(r);
        let s = self.scales[r];
        debug_assert_eq!(codes.len(), b.len());
        let n = codes.len();
        let chunks = n / 8;
        let mut acc = [0.0f32; 8];
        for i in 0..chunks {
            let o = i * 8;
            acc[0] += deq(s, codes[o]) * b[o];
            acc[1] += deq(s, codes[o + 1]) * b[o + 1];
            acc[2] += deq(s, codes[o + 2]) * b[o + 2];
            acc[3] += deq(s, codes[o + 3]) * b[o + 3];
            acc[4] += deq(s, codes[o + 4]) * b[o + 4];
            acc[5] += deq(s, codes[o + 5]) * b[o + 5];
            acc[6] += deq(s, codes[o + 6]) * b[o + 6];
            acc[7] += deq(s, codes[o + 7]) * b[o + 7];
        }
        let mut sum =
            (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]));
        for i in chunks * 8..n {
            sum += deq(s, codes[i]) * b[i];
        }
        sum
    }

    /// Physical payload bytes: one code per element plus one f32 scale
    /// per row.
    pub fn payload_bytes(&self) -> usize {
        self.data.len() + self.scales.len() * 4
    }

    /// Raw payload of rows [lo, hi) — codes and scales, byte-for-byte.
    pub fn raw_rows(&self, lo: usize, hi: usize) -> (&[i8], &[f32]) {
        (&self.data[lo * self.cols..hi * self.cols], &self.scales[lo..hi])
    }

    /// Append rows from a raw payload (as produced by
    /// [`QuantizedMat::raw_rows`]) without requantizing — the
    /// byte-for-byte copy behind prefix-fork snapshots, so a forked
    /// request's store is bit-identical to its donor's.
    pub fn extend_raw(&mut self, codes: &[i8], scales: &[f32]) {
        debug_assert_eq!(codes.len(), scales.len() * self.cols);
        self.data.extend_from_slice(codes);
        self.scales.extend_from_slice(scales);
        for &s in scales {
            self.max_scale = self.max_scale.max(s);
        }
    }

    pub fn clear(&mut self) {
        self.data.clear();
        self.scales.clear();
        self.max_scale = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::dot;
    use crate::util::Rng;

    fn is_pow2(x: f32) -> bool {
        // Normal f32 power of two: zero mantissa bits.
        x > 0.0 && (x.to_bits() & 0x007f_ffff) == 0
    }

    #[test]
    fn scales_are_powers_of_two_and_codes_fit() {
        let mut rng = Rng::new(1);
        let mut m = QuantizedMat::new(32);
        for _ in 0..50 {
            let row: Vec<f32> = (0..32).map(|_| rng.normal32(0.0, 3.0)).collect();
            let s = m.push_row(&row);
            assert!(is_pow2(s), "scale {s} not a power of two");
        }
        assert!(m.data.iter().all(|&c| (-127..=127).contains(&(c as i32))));
        assert_eq!(m.rows(), 50);
        assert_eq!(m.payload_bytes(), 50 * (32 + 4));
    }

    #[test]
    fn roundtrip_error_within_half_scale_exact() {
        let mut rng = Rng::new(2);
        let mut m = QuantizedMat::new(16);
        let mut rows: Vec<Vec<f32>> = Vec::new();
        for _ in 0..40 {
            rows.push((0..16).map(|_| rng.normal32(0.0, 2.0)).collect());
        }
        rows.push(vec![0.0; 16]); // zero row: scale 0, exact
        rows.push(vec![-3.25; 16]); // constant row
        rows.push(vec![f32::MAX; 16]); // max-magnitude row (overflow clamp)
        for row in &rows {
            m.push_row(row);
        }
        for (r, row) in rows.iter().enumerate() {
            let bound = m.max_abs_err(r);
            let back = m.dequantize_row(r);
            for (c, (&x, &x_hat)) in row.iter().zip(back.iter()).enumerate() {
                assert!(x_hat.is_finite());
                assert!(
                    (x - x_hat).abs() <= bound,
                    "row {r} col {c}: |{x} - {x_hat}| > {bound}"
                );
            }
        }
        // Zero row is exact with a zero bound.
        let zr = rows.len() - 3;
        assert_eq!(m.scale(zr), 0.0);
        assert_eq!(m.dequantize_row(zr), vec![0.0; 16]);
    }

    #[test]
    fn exact_tie_rounds_within_bound() {
        // x = scale·(m + 0.5) sits exactly on a quantization tie; the
        // error must be exactly scale/2, never over.
        let mut m = QuantizedMat::new(4);
        // max element 127 pins the scale at exactly 1.0.
        let row = vec![127.0, 2.5, -3.5, 0.5];
        let s = m.push_row(&row);
        assert_eq!(s, 1.0);
        let back = m.dequantize_row(0);
        for (&x, &x_hat) in row.iter().zip(back.iter()) {
            assert!((x - x_hat).abs() <= 0.5, "|{x} - {x_hat}| > 0.5");
        }
    }

    #[test]
    fn quantization_is_deterministic() {
        let mut rng = Rng::new(3);
        let row: Vec<f32> = (0..24).map(|_| rng.normal32(0.0, 1.0)).collect();
        let mut a = QuantizedMat::new(24);
        let mut b = QuantizedMat::new(24);
        a.push_row(&row);
        b.push_row(&row);
        assert_eq!(a.row_codes(0), b.row_codes(0));
        assert_eq!(a.scale(0).to_bits(), b.scale(0).to_bits());
    }

    #[test]
    fn fused_dot_is_bitwise_equal_to_dequantize_then_dot() {
        let mut rng = Rng::new(4);
        let mut m = QuantizedMat::new(37); // odd width exercises the tail loop
        for _ in 0..20 {
            let row: Vec<f32> = (0..37).map(|_| rng.normal32(0.0, 2.0)).collect();
            m.push_row(&row);
        }
        let q: Vec<f32> = (0..37).map(|_| rng.normal32(0.0, 1.0)).collect();
        for r in 0..20 {
            let fused = m.dot_row(r, &q);
            let two_step = dot(&m.dequantize_row(r), &q);
            assert_eq!(fused.to_bits(), two_step.to_bits(), "row {r} diverged");
        }
    }

    #[test]
    fn raw_copy_reproduces_payload_byte_for_byte() {
        let mut rng = Rng::new(5);
        let mut src = QuantizedMat::new(8);
        for _ in 0..12 {
            let row: Vec<f32> = (0..8).map(|_| rng.normal32(0.0, 1.0)).collect();
            src.push_row(&row);
        }
        let (codes, scales) = src.raw_rows(4, 8);
        let mut dst = QuantizedMat::new(8);
        dst.extend_raw(codes, scales);
        assert_eq!(dst.rows(), 4);
        for r in 0..4 {
            assert_eq!(dst.row_codes(r), src.row_codes(4 + r));
            assert_eq!(dst.scale(r).to_bits(), src.scale(4 + r).to_bits());
            assert_eq!(dst.dequantize_row(r), src.dequantize_row(4 + r));
        }
        assert!(dst.max_scale() <= src.max_scale());
    }

    #[test]
    fn bounds_logit_err_scales_with_q_l1_norm() {
        let b = KvQuantBounds { k_scale_max: 0.25, v_scale_max: 0.5 };
        let q = vec![1.0, -2.0, 0.5];
        assert!((b.logit_err(&q) - 0.5 * 0.25 * 3.5).abs() < 1e-7);
        assert!((b.value_err() - 0.25).abs() < 1e-7);
        assert!(!b.is_zero());
        assert!(KvQuantBounds::default().is_zero());
    }
}
