//! Minimal dense f32 tensor ops: a row-major 2-D matrix plus the handful
//! of BLAS-1/2/3 primitives the attention stack and the rust-native
//! transformer need. Hot kernels live in [`simd`] (explicit 8-lane
//! accumulators with an optional runtime-detected AVX2 arm); the
//! wrappers here keep the classic call sites (`dot`, `axpy`,
//! `softmax_inplace`) stable. See DESIGN.md §Kernel layer for the
//! oracle-pairing rule and why one kernel is fixed per process.
//!
//! [`quant`] adds the per-row symmetric int8 and bit-packed int4
//! kernels (power-of-two scales, exact `scale/2` error bound, fused
//! dequant-dot) behind the verified quantized KV tier.

pub mod quant;
pub mod simd;

pub use quant::{KvQuantBounds, QuantizedMat, QuantizedMat4};

use crate::util::Rng;

/// Row-major 2-D f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(rows * cols, data.len(), "shape/data mismatch");
        Mat { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Gaussian-initialized matrix (mean 0, given std), seeded.
    pub fn randn(rows: usize, cols: usize, std: f32, rng: &mut Rng) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal32(0.0, std));
        }
        Mat { rows, cols, data }
    }

    /// Reshape in place to (rows × cols), zero-filled, reusing the
    /// existing allocation — the scratch-buffer primitive behind
    /// `KvCache::gather_into` (no fresh `Vec` on the decode hot path).
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }

    /// C = self · other  (self: m×k, other: k×n). Straightforward ikj
    /// loop with row-major accumulation; good enough for the model sizes
    /// here (the PJRT path carries the big matmuls).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let arow = self.row(i);
            let orow = out.row_mut(i);
            for (p, &a) in arow.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[p * n..(p + 1) * n];
                axpy(a, brow, orow);
            }
        }
        out
    }

    /// y = self · x for a vector x (len = cols).
    pub fn matvec(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.cols, x.len());
        (0..self.rows).map(|r| dot(self.row(r), x)).collect()
    }

    /// yᵀ = xᵀ · self for a vector x (len = rows). Cache-friendly: walks
    /// rows and accumulates, instead of striding columns.
    pub fn vecmat(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(self.rows, x.len());
        let mut out = vec![0.0f32; self.cols];
        for (r, &xv) in x.iter().enumerate() {
            if xv != 0.0 {
                axpy(xv, self.row(r), &mut out);
            }
        }
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }
}

/// Dot product. This is the single hottest kernel in the repo (score
/// computation reads all keys); it dispatches to the [`simd`] layer,
/// whose every arm is bitwise-equal to the historical 8-wide unrolled
/// kernel (kept there as `dot_oracle` and proptested against).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// y += alpha * x. Per-element independent, so vectorization cannot
/// change results; dispatches to the [`simd`] layer.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    simd::axpy(alpha, x, y);
}

/// y *= alpha.
#[inline]
pub fn scale(alpha: f32, y: &mut [f32]) {
    for v in y.iter_mut() {
        *v *= alpha;
    }
}

/// Euclidean norm.
#[inline]
pub fn norm2(x: &[f32]) -> f32 {
    dot(x, x).sqrt()
}

/// Relative L2 error ||a-b|| / ||b|| (the paper's error metric; `b` is the
/// exact quantity). Returns 0 when both are ~zero.
pub fn rel_l2_error(approx: &[f32], exact: &[f32]) -> f64 {
    assert_eq!(approx.len(), exact.len());
    let mut num = 0.0f64;
    let mut den = 0.0f64;
    for (&a, &e) in approx.iter().zip(exact.iter()) {
        num += ((a - e) as f64).powi(2);
        den += (e as f64).powi(2);
    }
    if den < 1e-30 {
        if num < 1e-30 {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (num / den).sqrt()
    }
}

/// Numerically stable in-place softmax.
pub fn softmax_inplace(x: &mut [f32]) {
    if x.is_empty() {
        return;
    }
    let m = simd::max_fold(x);
    let mut sum = 0.0f32;
    for v in x.iter_mut() {
        *v = (*v - m).exp();
        sum += *v;
    }
    let inv = 1.0 / sum;
    for v in x.iter_mut() {
        *v *= inv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resize_reuses_allocation_and_zeroes() {
        let mut m = Mat::from_vec(2, 3, vec![1.0; 6]);
        let cap = m.data.capacity();
        m.resize(1, 2);
        assert_eq!((m.rows, m.cols), (1, 2));
        assert_eq!(m.data, vec![0.0, 0.0]);
        assert_eq!(m.data.capacity(), cap, "shrinking must keep the buffer");
        m.resize(3, 2);
        assert_eq!(m.row(2), &[0.0, 0.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let i = Mat::from_fn(2, 2, |r, c| if r == c { 1.0 } else { 0.0 });
        assert_eq!(a.matmul(&i), a);
        assert_eq!(i.matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        let b = Mat::from_vec(3, 2, vec![7., 8., 9., 10., 11., 12.]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58., 64., 139., 154.]);
    }

    #[test]
    fn matvec_vecmat_consistent_with_matmul() {
        let mut rng = Rng::new(1);
        let a = Mat::randn(5, 7, 1.0, &mut rng);
        let x: Vec<f32> = (0..7).map(|i| i as f32 * 0.3 - 1.0).collect();
        let y = a.matvec(&x);
        let xm = Mat::from_vec(7, 1, x.clone());
        let ym = a.matmul(&xm);
        for i in 0..5 {
            assert!((y[i] - ym.data[i]).abs() < 1e-5);
        }
        let z: Vec<f32> = (0..5).map(|i| 0.5 - i as f32 * 0.2).collect();
        let w = a.vecmat(&z);
        let zm = Mat::from_vec(1, 5, z.clone());
        let wm = zm.matmul(&a);
        for i in 0..7 {
            assert!((w[i] - wm.data[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn dot_matches_naive_all_lengths() {
        let mut rng = Rng::new(2);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 64, 100, 128, 1000] {
            let a: Vec<f32> = (0..n).map(|_| rng.normal32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.normal32(0.0, 1.0)).collect();
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let fast = dot(&a, &b);
            assert!((naive - fast).abs() < 1e-3 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn softmax_sums_to_one_and_is_stable() {
        let mut x = vec![1000.0, 1001.0, 999.0];
        softmax_inplace(&mut x);
        let s: f32 = x.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(x.iter().all(|v| v.is_finite()));
        assert!(x[1] > x[0] && x[0] > x[2]);
    }

    #[test]
    fn softmax_uniform() {
        let mut x = vec![3.0; 8];
        softmax_inplace(&mut x);
        for &v in &x {
            assert!((v - 0.125).abs() < 1e-6);
        }
    }

    #[test]
    fn rel_error_basics() {
        assert_eq!(rel_l2_error(&[1.0, 0.0], &[1.0, 0.0]), 0.0);
        let e = rel_l2_error(&[1.1], &[1.0]);
        assert!((e - 0.1).abs() < 1e-6);
        assert_eq!(rel_l2_error(&[0.0], &[0.0]), 0.0);
        assert!(rel_l2_error(&[1.0], &[0.0]).is_infinite());
    }

    #[test]
    fn transpose_round_trip() {
        let mut rng = Rng::new(3);
        let a = Mat::randn(4, 6, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn norm2_known() {
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-6);
    }
}
