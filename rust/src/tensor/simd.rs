//! Explicit lane-parallel kernels for the four decode hot loops: `dot`,
//! the fused int8/int4 dequant-dots, the softmax max-fold, and the
//! budget-stats moment pass — plus the naive sequential references the
//! speedup gate measures against.
//!
//! # Kernel pairing and the bridge lemma
//!
//! Every kernel here is written as a fixed-width `[f32; 8]` (or `[f64; 4]`)
//! lane-array loop: lane `j` of chunk `o` performs exactly the FP ops the
//! pre-existing 8-wide unrolled scalar kernel performed for element
//! `o + j`, and the horizontal reduction uses the identical tree
//! `(acc0+acc1) + (acc2+acc3) + ((acc4+acc5) + (acc6+acc7))` followed by
//! the identical scalar tail. The lane-array form is therefore **bitwise
//! equal** to the original kernel on every input — it is the same
//! computation, spelled so LLVM reliably vectorizes it on stable Rust.
//!
//! The fused [`dot_i8`] / [`dot_i4`] kernels replicate [`dot`]'s
//! accumulation order with the shared [`crate::tensor::quant`]
//! dequantizer in the load position, which preserves the PR 5 bridge
//! lemma end-to-end: `fused(r, b) ≡ dot(dequantize(r), b)` bitwise, so
//! the paged store can keep serving from its dequantized mirror while
//! benches and future device paths run the fused form.
//!
//! # One kernel per process
//!
//! An optional AVX2 path (runtime-detected, `core::arch::x86_64`) covers
//! [`dot`], [`axpy`] and the fused dequant-dots. It deliberately uses
//! separate multiply and add (`vmulps` + `vaddps`, **no FMA**): per lane
//! those are the same two IEEE-754 operations the lane-array loop
//! performs, and the horizontal reduction re-uses the same tree over the
//! extracted lanes — so the AVX2 and lane-array kernels are also bitwise
//! equal by construction. That equality is asserted by proptests; as
//! belt-and-braces for the engine's byte-identical-stream invariant, the
//! implementation choice is still made **once per process**
//! ([`kernel_name`] reports it) so every worker thread, shard and replay
//! of a request runs the same code path.
//!
//! The `*_seq_ref` functions are `#[inline(never)]` single-accumulator
//! sequential loops: a cross-iteration FP dependency chain LLVM must not
//! (and cannot, FP adds being non-associative) vectorize. They are the
//! honest "scalar" baseline for the CI-gated `bench_decode_speedup` /
//! `bench_engine` `"kernels"` comparison, and double as value oracles
//! (within accumulation-order tolerance) in the property suite.
#![deny(unsafe_op_in_unsafe_fn)]

use std::sync::OnceLock;

use super::quant::{deq, nib_hi, nib_lo};

/// The process-wide kernel choice. Both variants are bitwise-identical
/// on every input (module docs); fixing one per process is defense in
/// depth for stream determinism, not a correctness requirement.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kernel {
    /// Portable `[f32; 8]` lane arrays (stable Rust, LLVM-vectorized).
    Lanes,
    /// Runtime-detected AVX2 (`vmulps`/`vaddps`, no FMA).
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    Avx2,
}

static KERNEL: OnceLock<Kernel> = OnceLock::new();

#[cfg(target_arch = "x86_64")]
fn detect() -> Kernel {
    if std::arch::is_x86_feature_detected!("avx2") {
        Kernel::Avx2
    } else {
        Kernel::Lanes
    }
}

#[cfg(not(target_arch = "x86_64"))]
fn detect() -> Kernel {
    Kernel::Lanes
}

#[inline]
fn kernel() -> Kernel {
    *KERNEL.get_or_init(detect)
}

/// Name of the kernel implementation this process fixed at first use —
/// surfaced in `BENCH_engine.json`'s `"kernels"` block.
pub fn kernel_name() -> &'static str {
    match kernel() {
        Kernel::Lanes => "lanes",
        Kernel::Avx2 => "avx2",
    }
}

/// The shared horizontal reduction: the exact tree the original 8-wide
/// unrolled kernels used. Every dot-family kernel (lane-array, AVX2,
/// fused int8/int4) must reduce through this function.
#[inline]
fn reduce8(acc: &[f32; 8]) -> f32 {
    (acc[0] + acc[1]) + (acc[2] + acc[3]) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

// ───────────────────────────── dot ─────────────────────────────

/// Dot product — dispatched lane-array / AVX2 kernel. Bitwise equal to
/// [`dot_oracle`] on every input.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if kernel() == Kernel::Avx2 {
        // SAFETY: dispatch verified AVX2 support at process start.
        return unsafe { dot_avx2(a, b) };
    }
    dot_lanes(a, b)
}

/// Portable lane-array dot kernel.
#[inline]
pub fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        let o = i * 8;
        let (a8, b8) = (&a[o..o + 8], &b[o..o + 8]);
        for j in 0..8 {
            acc[j] += a8[j] * b8[j];
        }
    }
    let mut s = reduce8(&acc);
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// AVX2 dot kernel: per lane, the same multiply then add as
/// [`dot_lanes`] (no FMA — fusing would change the rounding and break
/// bitwise pairing), then the same [`reduce8`] tree and scalar tail.
///
/// # Safety
/// The CPU must support AVX2 (`is_x86_feature_detected!("avx2")`).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot_avx2(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = a.len();
    let chunks = n / 8;
    // SAFETY: all pointer reads are within `chunks * 8 <= n` elements.
    let mut acc = unsafe { _mm256_setzero_ps() };
    for i in 0..chunks {
        let o = i * 8;
        let va = unsafe { _mm256_loadu_ps(a.as_ptr().add(o)) };
        let vb = unsafe { _mm256_loadu_ps(b.as_ptr().add(o)) };
        acc = unsafe { _mm256_add_ps(acc, _mm256_mul_ps(va, vb)) };
    }
    let mut lanes = [0.0f32; 8];
    // SAFETY: `lanes` is 8 f32s; unaligned store is permitted.
    unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), acc) };
    let mut s = reduce8(&lanes);
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// Verbatim copy of the pre-SIMD `tensor::dot` (8 named accumulators) —
/// the proptest oracle the dispatched kernel must match bitwise.
pub fn dot_oracle(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        let o = i * 8;
        acc[0] += a[o] * b[o];
        acc[1] += a[o + 1] * b[o + 1];
        acc[2] += a[o + 2] * b[o + 2];
        acc[3] += a[o + 3] * b[o + 3];
        acc[4] += a[o + 4] * b[o + 4];
        acc[5] += a[o + 5] * b[o + 5];
        acc[6] += a[o + 6] * b[o + 6];
        acc[7] += a[o + 7] * b[o + 7];
    }
    let mut s = reduce8(&acc);
    for i in chunks * 8..n {
        s += a[i] * b[i];
    }
    s
}

/// Naive sequential dot: one accumulator, a strict cross-iteration FP
/// dependency chain. The speedup-gate baseline.
#[inline(never)]
pub fn dot_seq_ref(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for i in 0..a.len().min(b.len()) {
        s += a[i] * b[i];
    }
    s
}

// ─────────────────────── fused int8 dequant-dot ───────────────────────

/// Fused int8 dequantize-and-dot: lane `j` computes
/// `deq(scale, codes[o+j]) * b[o+j]`, exactly [`dot`]'s accumulation
/// with the shared dequantizer in the load position — bitwise equal to
/// `dot(&dequantized_row, b)` (the bridge lemma).
#[inline]
pub fn dot_i8(codes: &[i8], scale: f32, b: &[f32]) -> f32 {
    debug_assert_eq!(codes.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if kernel() == Kernel::Avx2 {
        // SAFETY: dispatch verified AVX2 support at process start.
        return unsafe { dot_i8_avx2(codes, scale, b) };
    }
    dot_i8_lanes(codes, scale, b)
}

/// Portable lane-array fused int8 kernel.
#[inline]
pub fn dot_i8_lanes(codes: &[i8], scale: f32, b: &[f32]) -> f32 {
    let n = codes.len();
    let chunks = n / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        let o = i * 8;
        let (c8, b8) = (&codes[o..o + 8], &b[o..o + 8]);
        for j in 0..8 {
            acc[j] += deq(scale, c8[j]) * b8[j];
        }
    }
    let mut s = reduce8(&acc);
    for i in chunks * 8..n {
        s += deq(scale, codes[i]) * b[i];
    }
    s
}

/// AVX2 fused int8 kernel: dequantizes each 8-code group into a lane
/// buffer with the shared scalar dequantizer (keeping its overflow
/// clamp bit-identical), then runs the same vector multiply-add as
/// [`dot_avx2`].
///
/// # Safety
/// The CPU must support AVX2.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot_i8_avx2(codes: &[i8], scale: f32, b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = codes.len();
    let chunks = n / 8;
    let mut acc = unsafe { _mm256_setzero_ps() };
    let mut da = [0.0f32; 8];
    for i in 0..chunks {
        let o = i * 8;
        for j in 0..8 {
            da[j] = deq(scale, codes[o + j]);
        }
        // SAFETY: `da` holds 8 f32s; b reads stay within `chunks*8 <= n`.
        let va = unsafe { _mm256_loadu_ps(da.as_ptr()) };
        let vb = unsafe { _mm256_loadu_ps(b.as_ptr().add(o)) };
        acc = unsafe { _mm256_add_ps(acc, _mm256_mul_ps(va, vb)) };
    }
    let mut lanes = [0.0f32; 8];
    // SAFETY: `lanes` is 8 f32s.
    unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), acc) };
    let mut s = reduce8(&lanes);
    for i in chunks * 8..n {
        s += deq(scale, codes[i]) * b[i];
    }
    s
}

/// Sequential fused int8 reference (speedup baseline).
#[inline(never)]
pub fn dot_i8_seq_ref(codes: &[i8], scale: f32, b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for i in 0..codes.len().min(b.len()) {
        s += deq(scale, codes[i]) * b[i];
    }
    s
}

// ─────────────────────── fused int4 dequant-dot ───────────────────────

/// Fused bit-packed int4 dequantize-and-dot: unpacks two codes per byte
/// in-register (low nibble = even column) and accumulates exactly as
/// [`dot`] does — bitwise equal to unpack-then-[`dot`]. `cols` is the
/// logical row width; `packed` holds `cols.div_ceil(2)` bytes.
#[inline]
pub fn dot_i4(packed: &[u8], cols: usize, scale: f32, b: &[f32]) -> f32 {
    debug_assert_eq!(packed.len(), cols.div_ceil(2));
    debug_assert_eq!(cols, b.len());
    #[cfg(target_arch = "x86_64")]
    if kernel() == Kernel::Avx2 {
        // SAFETY: dispatch verified AVX2 support at process start.
        return unsafe { dot_i4_avx2(packed, cols, scale, b) };
    }
    dot_i4_lanes(packed, cols, scale, b)
}

/// Portable lane-array fused int4 kernel: each 8-column chunk reads 4
/// packed bytes and sign-extends both nibbles in-register.
#[inline]
pub fn dot_i4_lanes(packed: &[u8], cols: usize, scale: f32, b: &[f32]) -> f32 {
    let chunks = cols / 8;
    let mut acc = [0.0f32; 8];
    for i in 0..chunks {
        let o = i * 8;
        let by = &packed[o / 2..o / 2 + 4];
        let b8 = &b[o..o + 8];
        let c8 = [
            nib_lo(by[0]),
            nib_hi(by[0]),
            nib_lo(by[1]),
            nib_hi(by[1]),
            nib_lo(by[2]),
            nib_hi(by[2]),
            nib_lo(by[3]),
            nib_hi(by[3]),
        ];
        for j in 0..8 {
            acc[j] += deq(scale, c8[j]) * b8[j];
        }
    }
    let mut s = reduce8(&acc);
    for c in chunks * 8..cols {
        let byte = packed[c / 2];
        let code = if c % 2 == 0 { nib_lo(byte) } else { nib_hi(byte) };
        s += deq(scale, code) * b[c];
    }
    s
}

/// AVX2 fused int4 kernel — same nibble unpack into a lane buffer, same
/// vector multiply-add as [`dot_avx2`].
///
/// # Safety
/// The CPU must support AVX2.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn dot_i4_avx2(packed: &[u8], cols: usize, scale: f32, b: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let chunks = cols / 8;
    let mut acc = unsafe { _mm256_setzero_ps() };
    let mut da = [0.0f32; 8];
    for i in 0..chunks {
        let o = i * 8;
        let by = &packed[o / 2..o / 2 + 4];
        for j in 0..4 {
            da[2 * j] = deq(scale, nib_lo(by[j]));
            da[2 * j + 1] = deq(scale, nib_hi(by[j]));
        }
        // SAFETY: `da` holds 8 f32s; b reads stay within `chunks*8 <= cols`.
        let va = unsafe { _mm256_loadu_ps(da.as_ptr()) };
        let vb = unsafe { _mm256_loadu_ps(b.as_ptr().add(o)) };
        acc = unsafe { _mm256_add_ps(acc, _mm256_mul_ps(va, vb)) };
    }
    let mut lanes = [0.0f32; 8];
    // SAFETY: `lanes` is 8 f32s.
    unsafe { _mm256_storeu_ps(lanes.as_mut_ptr(), acc) };
    let mut s = reduce8(&lanes);
    for c in chunks * 8..cols {
        let byte = packed[c / 2];
        let code = if c % 2 == 0 { nib_lo(byte) } else { nib_hi(byte) };
        s += deq(scale, code) * b[c];
    }
    s
}

/// Sequential fused int4 reference (speedup baseline).
#[inline(never)]
pub fn dot_i4_seq_ref(packed: &[u8], cols: usize, scale: f32, b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for c in 0..cols.min(b.len()) {
        let byte = packed[c / 2];
        let code = if c % 2 == 0 { nib_lo(byte) } else { nib_hi(byte) };
        s += deq(scale, code) * b[c];
    }
    s
}

// ──────────────────────── softmax max-fold ────────────────────────

/// Max over a slice (`NEG_INFINITY` when empty) with 8 independent lane
/// maxima. max is associative and commutative over the finite logits
/// this repo produces, so the value equals the sequential fold for every
/// input without NaNs — asserted against [`max_fold_seq_ref`]. Kept
/// lane-array-only: the per-lane `max` has no cross-lane dependency, so
/// LLVM vectorizes this form directly and an intrinsic arm would add
/// unsafe surface for no spread.
#[inline]
pub fn max_fold(xs: &[f32]) -> f32 {
    let n = xs.len();
    let chunks = n / 8;
    let mut m = [f32::NEG_INFINITY; 8];
    for i in 0..chunks {
        let x8 = &xs[i * 8..i * 8 + 8];
        for j in 0..8 {
            m[j] = m[j].max(x8[j]);
        }
    }
    let mut best = f32::NEG_INFINITY;
    for &lane in &m {
        best = best.max(lane);
    }
    for &x in &xs[chunks * 8..] {
        best = best.max(x);
    }
    best
}

/// Sequential max fold — the exact expression the softmax / dense-SDPA
/// code used before this pass.
#[inline(never)]
pub fn max_fold_seq_ref(xs: &[f32]) -> f32 {
    xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b))
}

// ───────────────────────────── axpy ─────────────────────────────

/// y += alpha · x. Per-element independent (no cross-iteration FP
/// dependency), so the vector form is trivially bitwise-equal to the
/// scalar loop.
#[inline]
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    if kernel() == Kernel::Avx2 {
        // SAFETY: dispatch verified AVX2 support at process start.
        unsafe { axpy_avx2(alpha, x, y) };
        return;
    }
    axpy_lanes(alpha, x, y);
}

/// Portable axpy (the pre-SIMD `tensor::axpy` loop, which LLVM already
/// vectorizes; kept as the named lane kernel for pairing tests).
#[inline]
pub fn axpy_lanes(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

/// AVX2 axpy: `vmulps` + `vaddps` per lane — the same two IEEE ops per
/// element as the scalar loop (no FMA).
///
/// # Safety
/// The CPU must support AVX2.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
pub unsafe fn axpy_avx2(alpha: f32, x: &[f32], y: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = x.len().min(y.len());
    let chunks = n / 8;
    // SAFETY: broadcast of a scalar; loads/stores below stay within
    // `chunks * 8 <= n` elements of both slices.
    let va = unsafe { _mm256_set1_ps(alpha) };
    for i in 0..chunks {
        let o = i * 8;
        let vx = unsafe { _mm256_loadu_ps(x.as_ptr().add(o)) };
        let vy = unsafe { _mm256_loadu_ps(y.as_ptr().add(o)) };
        let r = unsafe { _mm256_add_ps(vy, _mm256_mul_ps(va, vx)) };
        unsafe { _mm256_storeu_ps(y.as_mut_ptr().add(o), r) };
    }
    for i in chunks * 8..n {
        y[i] += alpha * x[i];
    }
}

/// Sequential axpy reference (speedup baseline; also the oracle — the
/// kernel must match it bitwise since every element is independent).
#[inline(never)]
pub fn axpy_seq_ref(alpha: f32, x: &[f32], y: &mut [f32]) {
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

// ───────────────────── budget stats moment pass ─────────────────────

/// The `estimate_stats_impl` inner loop for one base-sample row: charge
/// `r_c = w · v_c` into the per-column running sums `sum_vec[c] += r_c`,
/// `sum_vec2[c] += r_c²`, and return the row's `‖r⃗‖² = Σ_c r_c²`.
///
/// Split on the dependency structure: the per-column updates touch only
/// their own accumulator slots (column-parallel — vectorizing cannot
/// reorder any FP op, so the pass is bitwise-identical to the original
/// interleaved loop), while the `‖r⃗‖²` sum is a cross-column dependency
/// chain and is kept scalar **in column order on purpose** —
/// reassociating it would change `range_n`, hence budgets, hence token
/// streams.
#[inline]
pub fn weighted_moments(w: f64, row: &[f32], sum_vec: &mut [f64], sum_vec2: &mut [f64]) -> f64 {
    debug_assert_eq!(row.len(), sum_vec.len());
    debug_assert_eq!(row.len(), sum_vec2.len());
    for ((&vc, sv), sv2) in row.iter().zip(sum_vec.iter_mut()).zip(sum_vec2.iter_mut()) {
        let r = w * vc as f64;
        *sv += r;
        *sv2 += r * r;
    }
    let mut rn2 = 0.0f64;
    for &vc in row {
        let r = w * vc as f64;
        rn2 += r * r;
    }
    rn2
}

/// The original interleaved loop, verbatim — the oracle
/// [`weighted_moments`] must match bitwise on all three outputs.
#[inline(never)]
pub fn weighted_moments_seq_ref(
    w: f64,
    row: &[f32],
    sum_vec: &mut [f64],
    sum_vec2: &mut [f64],
) -> f64 {
    let mut rn2 = 0.0f64;
    for (c, &vc) in row.iter().enumerate() {
        let r = w * vc as f64;
        sum_vec[c] += r;
        sum_vec2[c] += r * r;
        rn2 += r * r;
    }
    rn2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn randv(n: usize, rng: &mut Rng) -> Vec<f32> {
        (0..n).map(|_| rng.normal32(0.0, 1.0)).collect()
    }

    /// Widths covering every lane-body count {0, 1, 2+} × tail {0..7}.
    const WIDTHS: [usize; 14] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 23, 24, 31, 64, 100];

    #[test]
    fn dispatched_dot_is_bitwise_equal_to_oracle() {
        let mut rng = Rng::new(11);
        for n in WIDTHS {
            let (a, b) = (randv(n, &mut rng), randv(n, &mut rng));
            assert_eq!(dot(&a, &b).to_bits(), dot_oracle(&a, &b).to_bits(), "n={n}");
            assert_eq!(dot_lanes(&a, &b).to_bits(), dot_oracle(&a, &b).to_bits(), "n={n}");
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx2_dot_is_bitwise_equal_to_lanes_when_available() {
        if !std::arch::is_x86_feature_detected!("avx2") {
            return;
        }
        let mut rng = Rng::new(12);
        for n in WIDTHS {
            let (a, b) = (randv(n, &mut rng), randv(n, &mut rng));
            // SAFETY: guarded by the runtime feature check above.
            let v = unsafe { dot_avx2(&a, &b) };
            assert_eq!(v.to_bits(), dot_lanes(&a, &b).to_bits(), "n={n}");
        }
    }

    #[test]
    fn dot_matches_sequential_reference_within_tolerance() {
        let mut rng = Rng::new(13);
        for n in WIDTHS {
            let (a, b) = (randv(n, &mut rng), randv(n, &mut rng));
            let (fast, slow) = (dot(&a, &b), dot_seq_ref(&a, &b));
            assert!(
                (fast - slow).abs() <= 1e-4 * (1.0 + slow.abs()),
                "n={n}: {fast} vs {slow}"
            );
        }
    }

    #[test]
    fn max_fold_equals_sequential_fold() {
        let mut rng = Rng::new(14);
        for n in WIDTHS {
            let xs = randv(n, &mut rng);
            assert_eq!(max_fold(&xs).to_bits(), max_fold_seq_ref(&xs).to_bits(), "n={n}");
        }
    }

    #[test]
    fn axpy_is_bitwise_equal_to_reference() {
        let mut rng = Rng::new(15);
        for n in WIDTHS {
            let x = randv(n, &mut rng);
            let y0 = randv(n, &mut rng);
            let mut fast = y0.clone();
            let mut slow = y0;
            axpy(0.37, &x, &mut fast);
            axpy_seq_ref(0.37, &x, &mut slow);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&fast), bits(&slow), "n={n}");
        }
    }

    #[test]
    fn weighted_moments_matches_interleaved_oracle_bitwise() {
        let mut rng = Rng::new(16);
        for n in WIDTHS {
            let row = randv(n, &mut rng);
            let mut sv_a = vec![0.1f64; n];
            let mut sv2_a = vec![0.2f64; n];
            let mut sv_b = sv_a.clone();
            let mut sv2_b = sv2_a.clone();
            let rn2_a = weighted_moments(1.7, &row, &mut sv_a, &mut sv2_a);
            let rn2_b = weighted_moments_seq_ref(1.7, &row, &mut sv_b, &mut sv2_b);
            assert_eq!(rn2_a.to_bits(), rn2_b.to_bits(), "n={n}");
            let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&sv_a), bits(&sv_b), "n={n}");
            assert_eq!(bits(&sv2_a), bits(&sv2_b), "n={n}");
        }
    }

    #[test]
    fn kernel_choice_is_fixed_and_named() {
        let first = kernel_name();
        assert!(first == "lanes" || first == "avx2");
        assert_eq!(first, kernel_name(), "kernel choice must be stable per process");
    }
}
