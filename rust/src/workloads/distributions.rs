//! Score-profile-controlled KV synthesis, plus the seeded arrival-time
//! samplers the scenario fuzz matrix enumerates over.
//!
//! Given a target logit profile, keys are constructed as
//! `k_i = l_i · q̂ / ‖q̂‖ + orthogonal noise`, so ⟨k_i, q_scaled⟩ = l_i up
//! to noise — letting us dial the attention-score distribution exactly
//! (sharp, power-law, flat, or a planted mixture). Values carry a shared
//! mean direction plus noise, matching the anisotropy of real value
//! embeddings (and keeping ‖N‖₂ non-degenerate, which mean-zero random
//! values would destroy).

use crate::tensor::Mat;
use crate::util::Rng;

// ───────────────────────── arrival processes ─────────────────────────
//
// Every sampler takes an **explicit u64 seed** — never a caller-owned
// `&mut Rng` — so an arrival pattern is a pure function of its
// parameters. That is what makes `workloads::scenario` enumeration
// bit-reproducible across platforms and runs: two scenarios that share
// an arrival seed share arrival times exactly, regardless of what else
// either run sampled first. (The trailing `ln` in the exponential draw
// is the one libm call; it is pinned to 1e-12 relative tolerance in the
// regression test below, while the underlying u64/f64 draws are pinned
// exactly.)

/// Closed-loop batch: everything arrives at t = 0.
pub fn batch_arrivals(n: usize) -> Vec<f64> {
    vec![0.0; n]
}

/// Open-loop Poisson process: i.i.d. exponential inter-arrival gaps at
/// `rate` requests/second, from a dedicated RNG seeded with `seed`.
/// Returns `n` non-decreasing arrival times (seconds from start).
pub fn poisson_arrivals(rate: f64, n: usize, seed: u64) -> Vec<f64> {
    let rate = rate.max(1e-12);
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            t += rng.exp(rate);
            t
        })
        .collect()
}

/// Bursty spike over a Poisson background: `spike_n` of the `n`
/// arrivals land at exactly `spike_at` (a thundering herd), the rest
/// follow `poisson_arrivals(rate, _, seed)`. Output is sorted, so the
/// spike interleaves with the background at its timestamp.
pub fn bursty_arrivals(rate: f64, n: usize, spike_at: f64, spike_n: usize, seed: u64) -> Vec<f64> {
    let spike_n = spike_n.min(n);
    let mut out = poisson_arrivals(rate, n - spike_n, seed);
    out.resize(n, spike_at);
    out.sort_by(|a, b| a.partial_cmp(b).expect("arrival times are finite"));
    out
}

/// Attention-score regimes from Fig. 2 (top panes).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ScoreProfile {
    /// A few tokens dominate: `heavy` tokens get logit `boost`, the rest
    /// are noise. Top-k's best case.
    Sharp { heavy: usize, boost: f32 },
    /// Power-law decaying logits with exponent `alpha` (Tactic's model).
    PowerLaw { alpha: f32 },
    /// Near-uniform logits: random sampling's best case.
    Flat,
    /// Sharp head + heavy tail: the mixed regime where the hybrid wins.
    Mixed { heavy: usize, boost: f32, alpha: f32 },
}

/// One synthetic attention head: KV cache + a scaled query.
pub struct HeadSample {
    pub k: Mat,
    pub v: Mat,
    /// Query pre-scaled by 1/√d.
    pub q_scaled: Vec<f32>,
}

/// Build a head of `n` tokens, dim `d`, with the given score profile.
pub fn synthesize_head(n: usize, d: usize, profile: ScoreProfile, rng: &mut Rng) -> HeadSample {
    // Random unit query direction; the scaled query has norm ~1 so logits
    // are exactly the profile values.
    let mut q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
    let qn = crate::tensor::norm2(&q);
    for x in q.iter_mut() {
        *x /= qn;
    }

    let logits = profile_logits(n, profile, rng);

    // Keys: l_i * q + noise orthogonalized against q.
    let noise_std = 0.4;
    let mut k = Mat::zeros(n, d);
    for i in 0..n {
        let mut noise: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, noise_std)).collect();
        let proj = crate::tensor::dot(&noise, &q);
        for c in 0..d {
            noise[c] -= proj * q[c];
            k.set(i, c, logits[i] * q[c] + noise[c]);
        }
    }

    // Values: shared mean direction + per-token noise + a component
    // correlated with the token's *score rank*. The rank-correlated term
    // is what makes deterministic truncation (top-k) biased: dropping the
    // tail systematically tilts the renormalized output toward the
    // high-score tokens' value direction — the failure mode Fig. 2 (and
    // §3) attributes to top-k on non-sharp heads. Unbiased sampling is
    // immune by construction.
    let mean_dir: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
    let corr_dir: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
    let mean_logit = logits.iter().sum::<f32>() / n as f32;
    let mut v = Mat::zeros(n, d);
    for i in 0..n {
        let tilt = 0.8 * (logits[i] - mean_logit).clamp(-2.0, 2.0);
        for c in 0..d {
            v.set(i, c, mean_dir[c] + tilt * corr_dir[c] + rng.normal32(0.0, 0.7));
        }
    }

    HeadSample { k, v, q_scaled: q }
}

/// Target logits for a profile, shuffled so position carries no signal
/// (except that the heavy tokens of `Sharp`/`Mixed` stay identifiable by
/// magnitude, not index).
pub fn profile_logits(n: usize, profile: ScoreProfile, rng: &mut Rng) -> Vec<f32> {
    let mut logits: Vec<f32> = match profile {
        ScoreProfile::Sharp { heavy, boost } => (0..n)
            .map(|i| if i < heavy { boost + rng.normal32(0.0, 0.3) } else { rng.normal32(0.0, 0.5) })
            .collect(),
        ScoreProfile::PowerLaw { alpha } => (0..n)
            .map(|i| {
                // logit = -alpha * ln(rank): attention scores ∝ rank^-alpha
                let rank = (i + 1) as f32;
                -alpha * rank.ln() + rng.normal32(0.0, 0.2) + 6.0
            })
            .collect(),
        ScoreProfile::Flat => (0..n).map(|_| rng.normal32(0.0, 0.25)).collect(),
        ScoreProfile::Mixed { heavy, boost, alpha } => (0..n)
            .map(|i| {
                if i < heavy {
                    boost + rng.normal32(0.0, 0.3)
                } else {
                    let rank = (i - heavy + 1) as f32;
                    -alpha * rank.ln() + rng.normal32(0.0, 0.3) + 2.0
                }
            })
            .collect(),
    };
    rng.shuffle(&mut logits);
    logits
}

/// Effective support size of the attention distribution: #tokens needed
/// to reach `p` cumulative mass (the Fig. 2 top-pane statistic).
pub fn coverage_count(scores: &[f32], p: f64) -> usize {
    let mut sorted: Vec<f32> = scores.to_vec();
    sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let mut cum = 0.0f64;
    for (i, &s) in sorted.iter().enumerate() {
        cum += s as f64;
        if cum >= p {
            return i + 1;
        }
    }
    sorted.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::attention_scores;

    #[test]
    fn sharp_profile_concentrates_mass() {
        let mut rng = Rng::new(1);
        let h = synthesize_head(2000, 32, ScoreProfile::Sharp { heavy: 8, boost: 8.0 }, &mut rng);
        let scores = attention_scores(&h.k, &h.q_scaled);
        let c90 = coverage_count(&scores, 0.9);
        assert!(c90 <= 16, "sharp head needed {c90} tokens for 90% mass");
    }

    #[test]
    fn flat_profile_spreads_mass() {
        let mut rng = Rng::new(2);
        let h = synthesize_head(2000, 32, ScoreProfile::Flat, &mut rng);
        let scores = attention_scores(&h.k, &h.q_scaled);
        let c90 = coverage_count(&scores, 0.9);
        assert!(c90 > 1000, "flat head reached 90% mass with {c90} tokens");
    }

    #[test]
    fn power_law_in_between() {
        let mut rng = Rng::new(3);
        let h = synthesize_head(2000, 32, ScoreProfile::PowerLaw { alpha: 1.0 }, &mut rng);
        let scores = attention_scores(&h.k, &h.q_scaled);
        let c90 = coverage_count(&scores, 0.9);
        assert!(c90 > 16 && c90 < 1900, "power-law coverage {c90}");
    }

    #[test]
    fn logits_realized_accurately() {
        // The construction should realize ⟨k_i, q⟩ = l_i exactly (noise is
        // orthogonal to q).
        let mut rng = Rng::new(4);
        let h = synthesize_head(100, 16, ScoreProfile::Flat, &mut rng);
        let logits = crate::attention::logits_all(&h.k, &h.q_scaled);
        for &l in &logits {
            assert!(l.abs() < 2.0, "flat logit out of range: {l}");
        }
    }

    #[test]
    fn coverage_count_basics() {
        assert_eq!(coverage_count(&[0.5, 0.3, 0.2], 0.5), 1);
        assert_eq!(coverage_count(&[0.5, 0.3, 0.2], 0.79), 2);
        assert_eq!(coverage_count(&[0.5, 0.3, 0.2], 0.99), 3);
    }

    #[test]
    fn deterministic_given_seed() {
        let h1 = synthesize_head(50, 8, ScoreProfile::Flat, &mut Rng::new(7));
        let h2 = synthesize_head(50, 8, ScoreProfile::Flat, &mut Rng::new(7));
        assert_eq!(h1.k.data, h2.k.data);
        assert_eq!(h1.v.data, h2.v.data);
    }

    // ───────────────── arrival-sampler regression pins ─────────────────

    /// Pinned values computed by an independent (integer-exact)
    /// re-implementation of splitmix64 + xoshiro256** + the exponential
    /// transform. The u64/f64 draws underlying these times are exact
    /// dyadic rationals; only the final `ln` goes through libm, hence
    /// the relative tolerance instead of bit equality.
    #[test]
    fn poisson_arrivals_pinned_values() {
        // First raw draws of Rng::new(42), pinned exactly: any change to
        // the seed-expansion or generator breaks these before it breaks
        // the (tolerance-padded) arrival times.
        assert_eq!(Rng::new(42).next_u64(), 1546998764402558742u64);
        let mut r = Rng::new(42);
        let f: Vec<f64> = (0..4).map(|_| r.f64()).collect();
        assert_eq!(f, vec![0.08386297105988216, 0.3789802506626686, 0.6800434110281394, 0.9246929453253876]);

        let pinned = [1.239285554529295, 1.7244211466927506, 1.917220468243946, 1.9563672420325569];
        let got = poisson_arrivals(2.0, 4, 42);
        assert_eq!(got.len(), pinned.len());
        for (g, p) in got.iter().zip(pinned.iter()) {
            assert!((g / p - 1.0).abs() < 1e-12, "arrival {g} vs pinned {p}");
        }

        let pinned7 = [
            0.0023723449126377425,
            0.010888581882966084,
            0.01205389510486719,
            0.012181116482374292,
            0.01224232811350639,
            0.013149519475341934,
        ];
        for (g, p) in poisson_arrivals(150.0, 6, 7).iter().zip(pinned7.iter()) {
            assert!((g / p - 1.0).abs() < 1e-12, "arrival {g} vs pinned {p}");
        }
    }

    #[test]
    fn arrival_samplers_are_pure_functions_of_the_seed() {
        assert_eq!(poisson_arrivals(3.0, 16, 9), poisson_arrivals(3.0, 16, 9));
        assert_ne!(poisson_arrivals(3.0, 16, 9), poisson_arrivals(3.0, 16, 10));
        assert_eq!(bursty_arrivals(3.0, 16, 0.5, 5, 9), bursty_arrivals(3.0, 16, 0.5, 5, 9));
    }

    #[test]
    fn batch_arrivals_all_zero() {
        assert_eq!(batch_arrivals(4), vec![0.0; 4]);
        assert!(batch_arrivals(0).is_empty());
    }

    #[test]
    fn poisson_arrivals_sorted_and_rate_scaled() {
        let xs = poisson_arrivals(5.0, 2000, 2);
        assert!(xs.windows(2).all(|w| w[0] <= w[1]));
        let mean = xs.last().unwrap() / xs.len() as f64;
        assert!((mean - 0.2).abs() < 0.03, "mean inter-arrival {mean}");
    }

    #[test]
    fn bursty_arrivals_contain_the_spike() {
        let xs = bursty_arrivals(2.0, 12, 0.25, 4, 11);
        assert_eq!(xs.len(), 12);
        assert!(xs.windows(2).all(|w| w[0] <= w[1]), "bursty arrivals unsorted");
        assert_eq!(xs.iter().filter(|&&t| t == 0.25).count(), 4);
        // spike_n > n clamps instead of panicking
        assert_eq!(bursty_arrivals(2.0, 3, 0.1, 9, 1), vec![0.1; 3]);
    }
}
