//! Serving traces: Poisson arrivals with configurable context-length and
//! generation-length distributions, for the engine benchmarks (Fig. 5 and
//! the end-to-end example), plus materialization of a trace into engine
//! requests for the open-loop load mode.

use crate::server::{ArrivingRequest, Request};
use crate::util::Rng;

/// One request in a workload trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub id: u64,
    /// Arrival time in seconds from trace start.
    pub arrival_s: f64,
    /// Prompt/context length in tokens.
    pub context_len: usize,
    /// Number of tokens to generate.
    pub gen_len: usize,
}

/// Trace generator configuration.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Mean arrival rate (requests/second).
    pub rate: f64,
    pub num_requests: usize,
    pub context_min: usize,
    pub context_max: usize,
    pub gen_min: usize,
    pub gen_max: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            rate: 2.0,
            num_requests: 32,
            context_min: 512,
            context_max: 2048,
            gen_min: 16,
            gen_max: 64,
        }
    }
}

/// Generate a Poisson-arrival trace with log-uniform context lengths
/// (long-context serving traffic is heavy-tailed in context size).
pub fn generate_trace(cfg: &TraceConfig, rng: &mut Rng) -> Vec<TraceRequest> {
    let mut t = 0.0f64;
    (0..cfg.num_requests)
        .map(|i| {
            t += rng.exp(cfg.rate);
            let lc = (cfg.context_min as f64).ln();
            let hc = (cfg.context_max as f64).ln();
            let context_len = (lc + (hc - lc) * rng.f64()).exp() as usize;
            let gen_len = rng.range(cfg.gen_min, cfg.gen_max + 1);
            TraceRequest { id: i as u64, arrival_s: t, context_len, gen_len }
        })
        .collect()
}

/// [`generate_trace`] from an explicit seed: the trace is a pure
/// function of `(cfg, seed)`, with a dedicated RNG that shares no state
/// with the caller. Prefer this entry point in benches and the scenario
/// matrix so traces stay reproducible independent of surrounding draws.
pub fn generate_trace_seeded(cfg: &TraceConfig, seed: u64) -> Vec<TraceRequest> {
    generate_trace(cfg, &mut Rng::new(seed))
}

/// Deterministic synthetic prompt for a trace request — keyed off the
/// request id so regenerating a trace reproduces identical streams.
pub fn synthetic_prompt(id: u64, len: usize, vocab: usize) -> Vec<u32> {
    let v = vocab.max(1) as u32;
    (0..len as u32)
        .map(|i| i.wrapping_mul(131).wrapping_add((id as u32).wrapping_mul(7)) % v)
        .collect()
}

/// Materialize engine requests (with arrival times) from a trace.
pub fn to_requests(trace: &[TraceRequest], vocab: usize) -> Vec<ArrivingRequest> {
    trace
        .iter()
        .map(|t| {
            ArrivingRequest::at(
                t.arrival_s,
                Request::new(t.id, synthetic_prompt(t.id, t.context_len, vocab), t.gen_len),
            )
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_is_sorted_and_in_bounds() {
        let cfg = TraceConfig::default();
        let mut rng = Rng::new(1);
        let trace = generate_trace(&cfg, &mut rng);
        assert_eq!(trace.len(), cfg.num_requests);
        let mut prev = 0.0;
        for r in &trace {
            assert!(r.arrival_s >= prev);
            prev = r.arrival_s;
            assert!(r.context_len >= cfg.context_min && r.context_len <= cfg.context_max);
            assert!(r.gen_len >= cfg.gen_min && r.gen_len <= cfg.gen_max);
        }
    }

    #[test]
    fn to_requests_preserves_trace_shape() {
        let cfg = TraceConfig { num_requests: 8, ..Default::default() };
        let mut rng = Rng::new(3);
        let trace = generate_trace(&cfg, &mut rng);
        let reqs = to_requests(&trace, 250);
        assert_eq!(reqs.len(), 8);
        for (t, r) in trace.iter().zip(reqs.iter()) {
            assert_eq!(r.req.id, t.id);
            assert_eq!(r.req.prompt.len(), t.context_len);
            assert_eq!(r.req.gen_len, t.gen_len);
            assert!((r.arrival_s - t.arrival_s).abs() < 1e-12);
            assert!(r.req.prompt.iter().all(|&tok| tok < 250));
        }
        // regenerating the same trace gives identical prompts
        let again = to_requests(&trace, 250);
        assert_eq!(reqs[3].req.prompt, again[3].req.prompt);
    }

    #[test]
    fn seeded_trace_matches_explicit_rng() {
        let cfg = TraceConfig { num_requests: 12, ..Default::default() };
        let a = generate_trace_seeded(&cfg, 7);
        let b = generate_trace(&cfg, &mut Rng::new(7));
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_s.to_bits(), y.arrival_s.to_bits());
            assert_eq!((x.context_len, x.gen_len), (y.context_len, y.gen_len));
        }
    }

    #[test]
    fn mean_interarrival_matches_rate() {
        let cfg = TraceConfig { rate: 5.0, num_requests: 2000, ..Default::default() };
        let mut rng = Rng::new(2);
        let trace = generate_trace(&cfg, &mut rng);
        let total = trace.last().unwrap().arrival_s;
        let mean = total / cfg.num_requests as f64;
        assert!((mean - 0.2).abs() < 0.03, "mean inter-arrival {mean}");
    }
}
