//! Differential oracle for the scenario fuzz matrix.
//!
//! [`run_scenario`] materializes a [`Scenario`] into a concrete
//! workload (prompts, seeded arrivals, per-request `GenOptions`, fault
//! plan), runs it on the **reference configuration** — one worker, a
//! direct `Session::tick` loop, ample pool, every non-semantic feature
//! off — and on the **scenario configuration**, then checks one
//! property that every PR since the seed has re-asserted piecemeal:
//!
//! * every completed request's token stream is **byte-identical** to
//!   its reference stream; cancelled / failed requests produced a
//!   strict prefix of it;
//! * after drain + `flush_prefix_cache`, pools and spill slots are
//!   **quiescent** ([`crate::server::Session::kv_quiescent`]) — no
//!   leaked blocks, no orphaned cold-tier slots;
//! * `preemption_replays` is consistent with the spill mode (spill on →
//!   zero replays; spill off → one replay per preemption);
//! * scenarios serving verified requests additionally re-prove the
//!   empirical (ε, δ) coverage bound at the policy level.
//!
//! Dtype and attention axes are *semantic* (they change the streamed
//! tokens), so the reference run keeps them as per-request options over
//! an f32-sized ample pool — exactly the narrower-override invariant
//! `tests/kv_quant.rs` pins. Everything else (batching, arrival timing,
//! pool pressure, spill, prefix cache, sharding, worker count) must not
//! move a single byte.
//!
//! Direct-topology scenarios run twice and must reproduce outcomes and
//! scheduling counters exactly — this is what `EngineConfig::
//! virtual_clock` buys: Poisson-arrival admission is a pure function of
//! the tick count, so even preemption patterns replay bit-identically.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::Result;

use super::distributions::{batch_arrivals, bursty_arrivals, poisson_arrivals};
use super::scenario::{Arrival, Fault, OptionsAxis, PromptShape, Resources, Scenario, Topology};
use crate::kvcache::{KvCache, KvDtype};
use crate::model::{Model, ModelConfig, StepOut};
use crate::server::{
    Backend, EngineConfig, Event, GenOptions, Router, RouterConfig, SelectFn, Session,
    SessionStats, StreamEvent,
};
use crate::util::Rng;

/// Requests per scenario.
const N_REQ: usize = 6;
/// Tokens each request generates.
const GEN_LEN: usize = 10;
/// Engine seed shared by the reference and scenario runs (request
/// streams are forked from it per request-seed tag).
const ENGINE_SEED: u64 = 5;
/// Model weight seed.
const MODEL_SEED: u64 = 42;
/// Paged-KV block granularity for both runs.
const BLOCK_TOKENS: usize = 8;
/// Prompt token planted to make [`PoisonBackend`] fail a step. Outside
/// the `% 250` range normal prompts draw from, inside the tiny model's
/// 256-token vocab — so the reference backend serves it fine.
const POISON_TOKEN: u32 = 251;
/// Position the poison token is planted at. Every prompt in the matrix
/// is ≥ 16 tokens and generation starts at the prompt's end, so
/// position 5 is a prefill-only position for *all* requests: the
/// backend can never see a *generated* token there, which is what makes
/// gating the fault on `(token, pos)` collision-free even if the model
/// happens to sample token 251 during decode.
const POISON_POS: usize = 5;
/// Cancel-storm targets cancel once their stream reaches this length.
const CANCEL_AT: usize = 3;
/// Requests the cancel storm targets.
const STORM_TARGETS: [usize; 3] = [1, 3, 5];
/// Requests whose prompts carry the poison token under
/// `Fault::BackendError`.
const POISONED: [usize; 2] = [2, 4];

// ───────────────────────── poison backend ─────────────────────────

/// Backend wrapper that fails `step` whenever it is fed `poison` at
/// position `POISON_POS` — deterministic mid-prefill backend errors
/// for `Fault::BackendError`. With `poison` outside the prompt alphabet
/// (e.g. `u32::MAX`) it is a transparent pass-through.
pub struct PoisonBackend<B: Backend> {
    inner: B,
    poison: u32,
}

impl<B: Backend> PoisonBackend<B> {
    pub fn new(inner: B, poison: u32) -> Self {
        PoisonBackend { inner, poison }
    }

    /// Pass-through: never fails.
    pub fn benign(inner: B) -> Self {
        PoisonBackend { inner, poison: u32::MAX }
    }
}

impl<B: Backend> Backend for PoisonBackend<B> {
    fn config(&self) -> &ModelConfig {
        self.inner.config()
    }

    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut SelectFn>,
    ) -> Result<StepOut> {
        if token == self.poison && pos == POISON_POS {
            anyhow::bail!("injected fault: poison token {token} at pos {pos}");
        }
        self.inner.step(token, pos, cache, select)
    }
}

// ───────────────────────── workload build ─────────────────────────

/// How one request ended.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Finished; carries the full stream.
    Completed(Vec<u32>),
    /// Cancelled mid-stream; carries the prefix streamed before.
    Cancelled(Vec<u32>),
    /// Terminated by the engine (backend fault); carries the prefix.
    Failed(Vec<u32>),
    /// Load-shed / drain rejection before any streaming (router only).
    Shed,
}

/// A scenario ground to concrete requests.
struct Workload {
    prompts: Vec<Vec<u32>>,
    arrivals: Vec<f64>,
    opts: Vec<GenOptions>,
    /// Engine-wide dtype of the *scenario* pool (reference always f32).
    pool_dtype: KvDtype,
    storm: BTreeSet<usize>,
    poisoned: BTreeSet<usize>,
}

fn prompt_tokens(scenario: &Scenario, i: usize) -> Vec<u32> {
    let unique = |i: usize, len: usize| -> Vec<u32> {
        (0..len as u32).map(|j| (j * 131 + i as u32 * 97 + 13) % 250).collect()
    };
    match scenario.prompt {
        PromptShape::Unique => unique(i, 20 + 3 * i),
        PromptShape::SharedPrefix => {
            // Two full blocks of shared prefix + a per-request suffix.
            let mut p: Vec<u32> = (0..(2 * BLOCK_TOKENS) as u32).map(|j| (j * 37 + 5) % 250).collect();
            p.extend((0..(6 + i) as u32).map(|j| (j * 53 + i as u32 * 19 + 2) % 250));
            p
        }
        PromptShape::Coherent => {
            // Identical rows except the final token: maximal radix
            // collisions and copy-on-write promotions.
            let mut p: Vec<u32> = (0..23u32).map(|j| (j * 41 + 7) % 250).collect();
            p.push(i as u32 % 250);
            p
        }
    }
}

fn build_workload(scenario: &Scenario, base_seed: u64) -> Workload {
    let seed = scenario.seed(base_seed);
    let mut prompts: Vec<Vec<u32>> = (0..N_REQ).map(|i| prompt_tokens(scenario, i)).collect();
    let poisoned: BTreeSet<usize> = if scenario.fault == Fault::BackendError {
        for &i in &POISONED {
            // Poison rides inside the prompt's first block: prefill
            // hits it mid-chunk, and the (token, pos) pair can never
            // collide with a decode step (see POISON_POS).
            prompts[i][POISON_POS] = POISON_TOKEN;
        }
        POISONED.iter().copied().collect()
    } else {
        BTreeSet::new()
    };
    let arrivals = match scenario.arrival {
        Arrival::Batch => batch_arrivals(N_REQ),
        Arrival::Poisson => poisson_arrivals(150.0, N_REQ, seed ^ 0xA1),
        Arrival::Burst => bursty_arrivals(150.0, N_REQ, 0.008, N_REQ / 2, seed ^ 0xB2),
    };
    let (eps, delta) = (0.25, 0.2);
    let opt_for = |i: usize| -> GenOptions {
        let base = GenOptions::new(GEN_LEN).seed(1000 + i as u64);
        match scenario.options {
            OptionsAxis::Dense => base.dense(),
            OptionsAxis::Verified => base.verified(eps, delta),
            OptionsAxis::VerifiedReuse => base.verified_reuse(eps, delta),
            OptionsAxis::Int8 => base.kv_dtype(KvDtype::Int8),
            OptionsAxis::Int4 => base.kv_dtype(KvDtype::Int4),
            OptionsAxis::Mixed => match i % 3 {
                0 => base, // inherit the pool's f32
                1 => base.kv_dtype(KvDtype::Int8),
                _ => base.kv_dtype(KvDtype::Int4),
            },
        }
    };
    let pool_dtype = match scenario.options {
        OptionsAxis::Int8 => KvDtype::Int8,
        OptionsAxis::Int4 => KvDtype::Int4,
        _ => KvDtype::F32,
    };
    let storm = if scenario.fault == Fault::CancelStorm {
        STORM_TARGETS.iter().copied().collect()
    } else {
        BTreeSet::new()
    };
    Workload {
        prompts,
        arrivals,
        opts: (0..N_REQ).map(opt_for).collect(),
        pool_dtype,
        storm,
        poisoned,
    }
}

/// Pool capacity in bytes for `blocks` blocks at the scenario's pool
/// dtype (a quantized pool packs more tokens into the same bytes, so
/// over-commitment is defined in blocks, not bytes).
fn cap_bytes(mcfg: &ModelConfig, dtype: KvDtype, blocks: usize) -> usize {
    blocks * BLOCK_TOKENS * dtype.kv_bytes_per_token(mcfg)
}

/// Over-commitment level in blocks: `ForcePreempt` squeezes to the
/// point where three active requests cannot coexist (preemption is
/// guaranteed); plain over-commitment leaves room to sometimes squeak
/// through.
fn capacity_blocks(scenario: &Scenario) -> Option<usize> {
    match (scenario.resources, scenario.fault) {
        (Resources::Ample, _) => None,
        (_, Fault::ForcePreempt) => Some(8),
        (Resources::OverCommitted | Resources::SpillOn | Resources::SpillPrefetch, _) => Some(12),
    }
}

static SPILL_TAG: AtomicU64 = AtomicU64::new(0);

fn fresh_spill_path(scenario: &Scenario) -> PathBuf {
    let tag = SPILL_TAG.fetch_add(1, Ordering::Relaxed);
    std::env::temp_dir().join(format!(
        "vattn_scenario_{}_{:x}_{}.spill",
        std::process::id(),
        scenario.code(),
        tag
    ))
}

fn cleanup_spill(path: &Path, shards: usize) {
    let base = path.display().to_string();
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(format!("{base}.prefix"));
    for i in 0..shards {
        let _ = std::fs::remove_file(format!("{base}.shard{i}"));
        let _ = std::fs::remove_file(format!("{base}.shard{i}.prefix"));
    }
}

fn scenario_engine_config(scenario: &Scenario, w: &Workload, spill: Option<&Path>) -> EngineConfig {
    let mut b = EngineConfig::builder()
        .max_batch(3)
        .seed(ENGINE_SEED)
        .workers(if scenario.topology == Topology::Direct { 4 } else { 2 })
        .prefill_chunk(BLOCK_TOKENS)
        .block_tokens(BLOCK_TOKENS)
        .kv_dtype(w.pool_dtype)
        .prefix_cache(true)
        // Router shards own wall-clock tick threads; the virtual clock
        // is for the deterministic direct loop.
        .virtual_clock(scenario.topology == Topology::Direct);
    if let Some(blocks) = capacity_blocks(scenario) {
        b = b.kv_capacity_bytes(cap_bytes(&ModelConfig::tiny(), w.pool_dtype, blocks));
    }
    if let Some(p) = spill {
        b = b.kv_spill(p);
        if scenario.resources == Resources::SpillPrefetch {
            b = b.kv_prefetch(true);
        }
    }
    b.build()
}

fn reference_engine_config() -> EngineConfig {
    EngineConfig::builder()
        .max_batch(N_REQ)
        .seed(ENGINE_SEED)
        .workers(1)
        .prefill_chunk(BLOCK_TOKENS)
        .block_tokens(BLOCK_TOKENS)
        .virtual_clock(true)
        .build()
}

// ───────────────────────── runners ─────────────────────────

struct RunOut {
    outcomes: BTreeMap<usize, Outcome>,
    stats: SessionStats,
}

/// Drive one `Session::tick` loop to quiescence, applying the fault
/// plan, asserting gapless streams / replay-consistent `Finished`
/// records, and checking end-of-run quiescence.
fn run_direct(w: &Workload, cfg: EngineConfig, poison: u32) -> Result<RunOut, String> {
    let backend = PoisonBackend::new(Model::new(ModelConfig::tiny(), MODEL_SEED), poison);
    let spill_on = cfg.kv_spill.is_some();
    let mut session = Session::new(backend, cfg);
    let mut ids = Vec::with_capacity(N_REQ);
    for i in 0..N_REQ {
        ids.push(session.submit(
            crate::server::SubmitRequest::new(w.prompts[i].clone())
                .arrival(w.arrivals[i])
                .options(w.opts[i].clone()),
        ));
    }
    let index_of: BTreeMap<_, _> = ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();

    let mut streams: Vec<Vec<u32>> = vec![Vec::new(); N_REQ];
    let mut outcomes: BTreeMap<usize, Outcome> = BTreeMap::new();
    let mut rounds = 0usize;
    while !session.is_idle() {
        rounds += 1;
        if rounds > 100_000 {
            return Err("direct drive loop did not converge in 100k ticks".into());
        }
        let events = session.tick().map_err(|e| format!("tick failed: {e}"))?;
        for ev in events {
            match ev {
                Event::Token { id, token, step, .. } => {
                    let i = index_of[&id];
                    if streams[i].len() != step {
                        return Err(format!(
                            "request {i}: token step {step} after {} streamed (gap)",
                            streams[i].len()
                        ));
                    }
                    streams[i].push(token);
                }
                Event::Finished { id, result, .. } => {
                    let i = index_of[&id];
                    if result.tokens != streams[i] {
                        return Err(format!(
                            "request {i}: Finished record diverged from its Token stream"
                        ));
                    }
                    outcomes.insert(i, Outcome::Completed(streams[i].clone()));
                }
                Event::Rejected { id, reason, .. } => {
                    let i = index_of[&id];
                    if !w.poisoned.contains(&i) {
                        return Err(format!("request {i} rejected without a fault plan: {reason}"));
                    }
                    outcomes.insert(i, Outcome::Failed(streams[i].clone()));
                }
                Event::Admitted { .. } | Event::Preempted { .. } => {}
            }
        }
        // Cancel storm: fire once a target's stream reaches CANCEL_AT.
        for &i in &w.storm {
            if !outcomes.contains_key(&i)
                && streams[i].len() >= CANCEL_AT
                && session.cancel(ids[i]).is_ok()
            {
                outcomes.insert(i, Outcome::Cancelled(streams[i].clone()));
            }
        }
    }
    session.flush_prefix_cache().map_err(|e| format!("flush_prefix_cache: {e}"))?;
    if !session.kv_quiescent() {
        return Err(format!(
            "pool/spill not quiescent after drain+flush: {} blocks in use, {:?} spill slots",
            session.kv_blocks_in_use(),
            session.spill_live_blocks()
        ));
    }
    if session.prefix_blocks_held() != 0 {
        return Err("prefix cache still holds blocks after flush".into());
    }
    let stats = session.stats();
    check_replay_consistency(&stats, spill_on)?;
    Ok(RunOut { outcomes, stats })
}

/// Drive the in-process sharded router: submit in id order (arrival
/// gaps realized as wall sleeps), collect every request's stream on its
/// own thread, apply the cancel storm from the collectors, then drain
/// with `shutdown` and assert per-shard quiescence.
fn run_router(
    w: &Workload,
    cfg: EngineConfig,
    shards: usize,
    poison: u32,
) -> Result<RunOut, String> {
    let backend = Arc::new(PoisonBackend::new(Model::new(ModelConfig::tiny(), MODEL_SEED), poison));
    let spill_on = cfg.kv_spill.is_some();
    let router = Router::new(backend, RouterConfig::new(cfg).shards(shards).queue_depth(64));

    let mut outcomes: BTreeMap<usize, Outcome> = BTreeMap::new();
    let results: Vec<Result<(usize, Outcome), String>> = std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(N_REQ);
        let started = std::time::Instant::now();
        for i in 0..N_REQ {
            // Realize the arrival process as wall-clock submit gaps
            // (the router has no arrival-time API; ordering is what
            // the oracle relies on, not exact spacing).
            let gap = w.arrivals[i] - started.elapsed().as_secs_f64();
            if gap > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(gap.min(0.05)));
            }
            let (gid, rx) = router.submit(w.prompts[i].clone(), w.opts[i].clone());
            let storm_target = w.storm.contains(&i);
            let router = &router;
            handles.push(scope.spawn(move || -> Result<(usize, Outcome), String> {
                let mut stream: Vec<u32> = Vec::new();
                let mut cancel_sent = false;
                loop {
                    let ev = rx
                        .recv_timeout(std::time::Duration::from_secs(30))
                        .map_err(|_| format!("request {i}: stream stalled or disconnected"))?;
                    match ev {
                        StreamEvent::Accepted { .. } => {}
                        StreamEvent::Token { step, token, .. } => {
                            if stream.len() != step {
                                return Err(format!(
                                    "request {i}: token step {step} after {} streamed (gap)",
                                    stream.len()
                                ));
                            }
                            stream.push(token);
                            if storm_target && !cancel_sent && stream.len() >= CANCEL_AT {
                                cancel_sent = true;
                                router.cancel(gid);
                            }
                        }
                        StreamEvent::Finished { result, .. } => {
                            if result.tokens != stream {
                                return Err(format!(
                                    "request {i}: Finished record diverged from its Token stream"
                                ));
                            }
                            return Ok((i, Outcome::Completed(stream)));
                        }
                        StreamEvent::Cancelled { .. } => return Ok((i, Outcome::Cancelled(stream))),
                        StreamEvent::Failed { .. } => return Ok((i, Outcome::Failed(stream))),
                        StreamEvent::Rejected { error, .. } => {
                            let status = error.kind.http_status();
                            if status == 429 || status == 503 {
                                return Ok((i, Outcome::Shed));
                            }
                            return Err(format!(
                                "request {i}: rejected with non-shed error {status}: {}",
                                error.message
                            ));
                        }
                    }
                }
            }));
        }
        handles.into_iter().map(|h| h.join().expect("collector panicked")).collect()
    });
    for r in results {
        let (i, outcome) = r?;
        outcomes.insert(i, outcome);
    }

    let shard_stats = router.shutdown();
    if shard_stats.len() != shards {
        return Err(format!("expected {shards} shard reports, got {}", shard_stats.len()));
    }
    let mut merged = SessionStats::default();
    for s in &shard_stats {
        if s.outstanding != 0 || s.waiting != 0 || s.active != 0 {
            return Err(format!("shard {} drained with work outstanding", s.shard));
        }
        if s.kv_blocks_in_use != 0 || s.prefix_blocks_held != 0 {
            return Err(format!(
                "shard {} leaked blocks after drain: {} in use, {} prefix-held",
                s.shard, s.kv_blocks_in_use, s.prefix_blocks_held
            ));
        }
        if s.spill_live_blocks.unwrap_or(0) != 0 {
            return Err(format!(
                "shard {} leaked {} spill slots after drain",
                s.shard,
                s.spill_live_blocks.unwrap_or(0)
            ));
        }
        check_replay_consistency(&s.session, spill_on)
            .map_err(|e| format!("shard {}: {e}", s.shard))?;
        merged.preemptions += s.session.preemptions;
        merged.preemption_replays += s.session.preemption_replays;
        merged.prefix_hit_blocks += s.session.prefix_hit_blocks;
        merged.spill_out_ops += s.session.spill_out_ops;
        merged.swap_in_ops += s.session.swap_in_ops;
    }
    Ok(RunOut { outcomes, stats: merged })
}

/// Spill mode never replays (preemption is swap-out/swap-in); replay
/// mode replays exactly once per preemption.
fn check_replay_consistency(stats: &SessionStats, spill_on: bool) -> Result<(), String> {
    if spill_on {
        if stats.preemption_replays != 0 {
            return Err(format!(
                "{} compute replays with a spill store configured",
                stats.preemption_replays
            ));
        }
    } else if stats.preemption_replays != stats.preemptions {
        return Err(format!(
            "replays ({}) != preemptions ({}) without a spill store",
            stats.preemption_replays, stats.preemptions
        ));
    }
    Ok(())
}

// ───────────────────────── (ε, δ) coverage ─────────────────────────

/// Policy-level empirical coverage re-proof (the `budget_coverage.rs`
/// recipe at fuzz-matrix scale): over seeded trials, the Hoeffding
/// denominator budget's sample must violate the ε bound in ≤ ~δ of
/// trials. Returns the violation rate.
pub fn empirical_coverage(eps: f64, delta: f64, trials: usize, seed: u64) -> f64 {
    use crate::attention::{exact_num_den, weighted_num_den, Selection};
    use crate::budget::{self, Bound, Verify};
    use crate::policies::sink_window_indices;
    use crate::tensor::{dot, Mat};

    let (n, d) = (512usize, 16usize);
    let mut meta = Rng::new(seed);
    let mut violations = 0usize;
    for t in 0..trials {
        let mut rng = meta.fork(t as u64);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
        let i_f = sink_window_indices(n, 16, 16);
        let m_ref = i_f.iter().map(|&i| dot(k.row(i), &q)).fold(f32::NEG_INFINITY, f32::max);
        let base = budget::draw_base_sample(n, &i_f, 0.1, &mut rng);
        let stats = budget::estimate_stats(&k, &v, &q, &i_f, &base, m_ref);
        let b = budget::budget_for(&stats, Verify::Denominator, eps, delta, Bound::Hoeffding)
            .max(base.len())
            .min(stats.n_s);
        let dyn_idx = rng.sample_excluding(n, b, &i_f);
        let sel = Selection::compose(i_f, dyn_idx, b as f32 / stats.n_s as f32);
        let (_, d_hat) = weighted_num_den(&k, &v, &q, &sel, m_ref);
        let (_, d_exact) = exact_num_den(&k, &v, &q, m_ref);
        if ((d_hat - d_exact) / d_exact).abs() > eps {
            violations += 1;
        }
    }
    violations as f64 / trials as f64
}

// ───────────────────────── the oracle ─────────────────────────

/// One scenario's oracle verdict.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub scenario: Scenario,
    pub requests: usize,
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub shed: usize,
    pub preemptions: u64,
    /// Present for verified scenarios: empirical (ε, δ) violation rate.
    pub coverage_violation_rate: Option<f64>,
}

/// Run `scenario` through the differential oracle. `Ok` carries the
/// outcome tallies; `Err` is the first property violation, prefixed
/// with the scenario label.
pub fn run_scenario(scenario: Scenario, base_seed: u64) -> Result<ScenarioReport, String> {
    run_scenario_inner(scenario, base_seed)
        .map_err(|e| format!("[{}] {e}", scenario.label()))
}

fn run_scenario_inner(scenario: Scenario, base_seed: u64) -> Result<ScenarioReport, String> {
    let w = build_workload(&scenario, base_seed);

    // Reference: benign backend, batch arrivals, no faults, ample f32
    // pool, single worker, direct loop. Same prompts, same options.
    let ref_arrivals = batch_arrivals(N_REQ);
    let ref_run = {
        let clean = Workload {
            prompts: w.prompts.clone(),
            arrivals: ref_arrivals.clone(),
            opts: w.opts.clone(),
            pool_dtype: KvDtype::F32,
            storm: BTreeSet::new(),
            poisoned: BTreeSet::new(),
        };
        run_direct(&clean, reference_engine_config(), u32::MAX)
            .map_err(|e| format!("reference run: {e}"))?
    };
    for i in 0..N_REQ {
        match ref_run.outcomes.get(&i) {
            Some(Outcome::Completed(s)) if s.len() == GEN_LEN => {}
            other => return Err(format!("reference request {i} did not complete: {other:?}")),
        }
    }

    let poison = if scenario.fault == Fault::BackendError { POISON_TOKEN } else { u32::MAX };
    let needs_spill =
        matches!(scenario.resources, Resources::SpillOn | Resources::SpillPrefetch);
    let shards = match scenario.topology {
        Topology::Direct => 0,
        Topology::Router { shards } => shards,
    };

    let run_once = || -> Result<RunOut, String> {
        let spill_path = needs_spill.then(|| fresh_spill_path(&scenario));
        let cfg = scenario_engine_config(&scenario, &w, spill_path.as_deref());
        let out = match scenario.topology {
            Topology::Direct => run_direct(&w, cfg, poison),
            Topology::Router { shards } => run_router(&w, cfg, shards, poison),
        };
        if let Some(p) = spill_path {
            cleanup_spill(&p, shards);
        }
        out
    };

    let run = run_once()?;
    compare_to_reference(&w, &run, &ref_run)?;

    // One over-committed session serving all six requests cannot avoid
    // preempting; router shards may legitimately serialize instead
    // (affinity can isolate requests), so the count assert is
    // direct-only — shard runs still check replay consistency.
    if scenario.fault == Fault::ForcePreempt
        && scenario.topology == Topology::Direct
        && run.stats.preemptions == 0
    {
        return Err("forced-preemption scenario ran without a single preemption".into());
    }
    if scenario.topology == Topology::Direct {
        // Re-run: with the virtual clock, the whole schedule — not just
        // the streams — must reproduce bit-identically.
        let again = run_once()?;
        if again.outcomes != run.outcomes {
            return Err("direct scenario re-run changed request outcomes".into());
        }
        if (again.stats.preemptions, again.stats.preemption_replays)
            != (run.stats.preemptions, run.stats.preemption_replays)
        {
            return Err(format!(
                "direct scenario re-run changed scheduling counters: {:?} vs {:?}",
                (again.stats.preemptions, again.stats.preemption_replays),
                (run.stats.preemptions, run.stats.preemption_replays)
            ));
        }
    }

    let coverage = matches!(scenario.options, OptionsAxis::Verified | OptionsAxis::VerifiedReuse)
        .then(|| empirical_coverage(0.2, 0.15, 12, scenario.seed(base_seed) ^ 0xC07E4A6E));

    let mut report = ScenarioReport {
        scenario,
        requests: N_REQ,
        completed: 0,
        cancelled: 0,
        failed: 0,
        shed: 0,
        preemptions: run.stats.preemptions,
        coverage_violation_rate: coverage,
    };
    for outcome in run.outcomes.values() {
        match outcome {
            Outcome::Completed(_) => report.completed += 1,
            Outcome::Cancelled(_) => report.cancelled += 1,
            Outcome::Failed(_) => report.failed += 1,
            Outcome::Shed => report.shed += 1,
        }
    }
    if let Some(rate) = report.coverage_violation_rate {
        if rate > 0.15 + 0.1 {
            return Err(format!("(ε,δ) coverage violated: empirical rate {rate} > δ + slack"));
        }
    }
    Ok(report)
}

/// The differential heart: every scenario outcome against the
/// reference stream for the same request index.
fn compare_to_reference(w: &Workload, run: &RunOut, reference: &RunOut) -> Result<(), String> {
    for i in 0..N_REQ {
        let ref_stream = match &reference.outcomes[&i] {
            Outcome::Completed(s) => s,
            _ => unreachable!("reference outcomes were checked complete"),
        };
        let outcome = run
            .outcomes
            .get(&i)
            .ok_or_else(|| format!("request {i} has no terminal outcome"))?;
        match outcome {
            Outcome::Completed(s) => {
                if s != ref_stream {
                    return Err(format!(
                        "request {i}: stream diverged from reference\n  got {s:?}\n  ref {ref_stream:?}"
                    ));
                }
            }
            Outcome::Cancelled(s) => {
                if !w.storm.contains(&i) {
                    return Err(format!("request {i} cancelled outside the storm set"));
                }
                if !ref_stream.starts_with(s) {
                    return Err(format!("request {i}: cancelled stream is not a reference prefix"));
                }
            }
            Outcome::Failed(s) => {
                if !w.poisoned.contains(&i) {
                    return Err(format!("request {i} failed outside the poison set"));
                }
                if !ref_stream.starts_with(s) {
                    return Err(format!("request {i}: failed stream is not a reference prefix"));
                }
            }
            Outcome::Shed => {
                return Err(format!("request {i} shed under a drain-free scenario"));
            }
        }
    }
    Ok(())
}
