//! Synthetic workloads calibrated to the regimes the paper evaluates on.
//!
//! We do not have the Llama/Mistral/DeepSeek KV caches or the RULER /
//! LongBench corpora in this environment (see DESIGN.md §3). What the
//! estimators and policies actually see, however, is (K, V, q) — so we
//! generate KV caches whose *attention-score distributions* span the
//! sharp → flat spectrum of Fig. 2, and plant retrieval/aggregation
//! structure that mirrors what the RULER-HARD tasks test:
//!
//! * needle tasks (`niah_*`) reward heavy-hitter recall — a handful of
//!   tokens carry the answer;
//! * aggregation tasks (`fwe`, `vt`, `cwe`) encode the answer in the
//!   *total mass* of a large group of medium-score tokens — exactly the
//!   long-tail regime where deterministic top-k fails and unbiased
//!   sampling wins.

pub mod distributions;
pub mod harness;
pub mod scenario;
pub mod tasks;
pub mod traces;

pub use distributions::{
    batch_arrivals, bursty_arrivals, poisson_arrivals, synthesize_head, HeadSample, ScoreProfile,
};
pub use harness::{run_scenario, PoisonBackend, ScenarioReport};
pub use scenario::{axes_covered, matrix, sample, Scenario};
pub use tasks::{Task, TaskInstance, TaskKind};
