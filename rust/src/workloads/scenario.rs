//! Scenario-generator DSL: serving scenarios as enumerable data.
//!
//! Borrowed from ruler's `enumo` workload compositors (Set / Append /
//! Cross / Plug / Filter — SNIPPETS.md Snippet 2), specialized from
//! strings-with-holes to typed serving-scenario templates: a
//! [`Template`] is a scenario with a hole (`None`) per unfilled axis,
//! and a [`Gen`] expression composes sets of templates into the cross
//! products the fuzz matrix sweeps. Everything here is pure data — no
//! wall clock, no global RNG. Randomness enters only through explicit
//! u64 seeds ([`Scenario::seed`], [`sample`]), so the same matrix
//! enumerates bit-identically on every platform and run.
//!
//! The six axes (ROADMAP item 5):
//!
//! | axis        | values                                                     |
//! |-------------|------------------------------------------------------------|
//! | arrival     | batch (t=0) / Poisson / bursty spike                       |
//! | prompt      | unique / shared-prefix / adversarially-coherent            |
//! | options     | dense / verified / verified-reuse / int8 / int4 / mixed    |
//! | resources   | ample / over-committed / + spill / + spill with prefetch   |
//! | fault       | none / cancel storm / backend step errors / forced preempt |
//! | topology    | direct `Session::tick` / router at shards {1, 4}           |
//!
//! `workloads::harness` turns a [`Scenario`] into a concrete workload
//! and runs it through the differential oracle; this module only
//! decides *what* to run.

use crate::util::Rng;

// ───────────────────────────── axes ─────────────────────────────

/// When requests become visible to the scheduler.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Arrival {
    /// Closed loop: everything at t = 0.
    Batch,
    /// Open loop: Poisson arrivals (seeded, virtual-clock replayed).
    Poisson,
    /// Poisson background plus a thundering-herd spike.
    Burst,
}

/// How prompts relate to each other across the batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PromptShape {
    /// Pairwise-unrelated prompts: no prefix sharing possible.
    Unique,
    /// A common prefix spanning whole blocks + per-request suffixes:
    /// the prefix cache's intended diet.
    SharedPrefix,
    /// Adversarially coherent: prompts identical except the final
    /// token, maximizing radix collisions and copy-on-write promotions.
    Coherent,
}

/// Per-request `GenOptions` the scenario assigns.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum OptionsAxis {
    Dense,
    /// Verified sparse attention at a per-request (ε, δ) contract.
    Verified,
    /// Verified with cross-step heavy-hitter reuse.
    VerifiedReuse,
    /// Engine-wide int8 KV (pool sized at int8).
    Int8,
    /// Engine-wide bit-packed int4 KV.
    Int4,
    /// f32 pool with per-request narrower dtype overrides cycling
    /// f32 / int8 / int4 across the batch.
    Mixed,
}

/// KV memory regime.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Resources {
    /// Unbounded pool: no preemption possible.
    Ample,
    /// Pool capped below the batch's worst case: demand paging must
    /// preempt (deterministic replay path).
    OverCommitted,
    /// Same cap with a file-backed cold tier: preemption is swap-out /
    /// swap-in, never replay.
    SpillOn,
    /// Cold tier plus the async prefetch pipeline: queue-front victims
    /// are staged by the spill-io thread so resume consumes completed
    /// reads instead of blocking on `read_exact_at`.
    SpillPrefetch,
}

/// Injected failure mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Fault {
    None,
    /// Cancel a fixed subset of requests mid-stream.
    CancelStorm,
    /// Poisoned prompt tokens make the backend error inside `step` for
    /// a fixed subset of requests.
    BackendError,
    /// Pool capped so tightly that LIFO preemption is guaranteed.
    ForcePreempt,
}

/// Where the requests are served.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Topology {
    /// One `Session`, driven by a direct tick loop.
    Direct,
    /// The sharded router (in-process, own tick threads per shard).
    Router { shards: usize },
}

/// Axis selector, for [`Gen::Plug`] and hole inspection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Axis {
    Arrival,
    Prompt,
    Options,
    Resources,
    Fault,
    Topology,
}

pub const AXES: [Axis; 6] =
    [Axis::Arrival, Axis::Prompt, Axis::Options, Axis::Resources, Axis::Fault, Axis::Topology];

// ─────────────────────── templates & scenarios ───────────────────────

/// A scenario with holes: `None` axes are unfilled. The DSL composes
/// templates; a fully-ground template becomes a [`Scenario`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Template {
    pub arrival: Option<Arrival>,
    pub prompt: Option<PromptShape>,
    pub options: Option<OptionsAxis>,
    pub resources: Option<Resources>,
    pub fault: Option<Fault>,
    pub topology: Option<Topology>,
}

impl Template {
    pub fn new() -> Template {
        Template::default()
    }

    pub fn arrival(mut self, v: Arrival) -> Self {
        self.arrival = Some(v);
        self
    }

    pub fn prompt(mut self, v: PromptShape) -> Self {
        self.prompt = Some(v);
        self
    }

    pub fn options(mut self, v: OptionsAxis) -> Self {
        self.options = Some(v);
        self
    }

    pub fn resources(mut self, v: Resources) -> Self {
        self.resources = Some(v);
        self
    }

    pub fn fault(mut self, v: Fault) -> Self {
        self.fault = Some(v);
        self
    }

    pub fn topology(mut self, v: Topology) -> Self {
        self.topology = Some(v);
        self
    }

    /// True when `axis` is unfilled.
    pub fn has_hole(&self, axis: Axis) -> bool {
        match axis {
            Axis::Arrival => self.arrival.is_none(),
            Axis::Prompt => self.prompt.is_none(),
            Axis::Options => self.options.is_none(),
            Axis::Resources => self.resources.is_none(),
            Axis::Fault => self.fault.is_none(),
            Axis::Topology => self.topology.is_none(),
        }
    }

    /// Merge two templates whose filled axes are disjoint; `None` if
    /// any axis is filled on both sides (even with equal values — the
    /// compositors are responsible for keeping factors disjoint).
    pub fn merge(&self, other: &Template) -> Option<Template> {
        fn join<T: Copy>(a: Option<T>, b: Option<T>) -> Result<Option<T>, ()> {
            match (a, b) {
                (Some(_), Some(_)) => Err(()),
                (Some(x), None) | (None, Some(x)) => Ok(Some(x)),
                (None, None) => Ok(None),
            }
        }
        Some(Template {
            arrival: join(self.arrival, other.arrival).ok()?,
            prompt: join(self.prompt, other.prompt).ok()?,
            options: join(self.options, other.options).ok()?,
            resources: join(self.resources, other.resources).ok()?,
            fault: join(self.fault, other.fault).ok()?,
            topology: join(self.topology, other.topology).ok()?,
        })
    }

    /// Ground the template into a scenario; `None` while holes remain.
    pub fn ground(&self) -> Option<Scenario> {
        Some(Scenario {
            arrival: self.arrival?,
            prompt: self.prompt?,
            options: self.options?,
            resources: self.resources?,
            fault: self.fault?,
            topology: self.topology?,
        })
    }
}

/// One fully-ground serving scenario: a point in the 6-axis space.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Scenario {
    pub arrival: Arrival,
    pub prompt: PromptShape,
    pub options: OptionsAxis,
    pub resources: Resources,
    pub fault: Fault,
    pub topology: Topology,
}

impl Scenario {
    /// Small per-axis value codes (stable across enumeration order; do
    /// not reorder existing variants without re-pinning seeds).
    pub fn axis_codes(&self) -> [u64; 6] {
        let arrival = match self.arrival {
            Arrival::Batch => 0,
            Arrival::Poisson => 1,
            Arrival::Burst => 2,
        };
        let prompt = match self.prompt {
            PromptShape::Unique => 0,
            PromptShape::SharedPrefix => 1,
            PromptShape::Coherent => 2,
        };
        let options = match self.options {
            OptionsAxis::Dense => 0,
            OptionsAxis::Verified => 1,
            OptionsAxis::VerifiedReuse => 2,
            OptionsAxis::Int8 => 3,
            OptionsAxis::Int4 => 4,
            OptionsAxis::Mixed => 5,
        };
        let resources = match self.resources {
            Resources::Ample => 0,
            Resources::OverCommitted => 1,
            Resources::SpillOn => 2,
            Resources::SpillPrefetch => 3,
        };
        let fault = match self.fault {
            Fault::None => 0,
            Fault::CancelStorm => 1,
            Fault::BackendError => 2,
            Fault::ForcePreempt => 3,
        };
        let topology = match self.topology {
            Topology::Direct => 0,
            Topology::Router { shards } => 100 + shards as u64,
        };
        [arrival, prompt, options, resources, fault, topology]
    }

    /// Stable scalar code: a base-256 packing of the axis codes. Unique
    /// per scenario, independent of enumeration order.
    pub fn code(&self) -> u64 {
        self.axis_codes().iter().fold(0u64, |acc, &c| (acc << 8) | (c & 0xFF))
    }

    /// Deterministic per-scenario seed: every random choice a scenario
    /// makes (arrival gaps, storm targets, request seeds) forks from
    /// this, so a scenario's workload is a pure function of
    /// `(base_seed, scenario)`.
    pub fn seed(&self, base_seed: u64) -> u64 {
        base_seed ^ self.code().wrapping_mul(0x9E3779B97F4A7C15)
    }

    /// Compact display label, e.g. `poisson/shared/int8/spill/cancel/router4`.
    pub fn label(&self) -> String {
        let arrival = match self.arrival {
            Arrival::Batch => "batch",
            Arrival::Poisson => "poisson",
            Arrival::Burst => "burst",
        };
        let prompt = match self.prompt {
            PromptShape::Unique => "unique",
            PromptShape::SharedPrefix => "shared",
            PromptShape::Coherent => "coherent",
        };
        let options = match self.options {
            OptionsAxis::Dense => "dense",
            OptionsAxis::Verified => "verified",
            OptionsAxis::VerifiedReuse => "reuse",
            OptionsAxis::Int8 => "int8",
            OptionsAxis::Int4 => "int4",
            OptionsAxis::Mixed => "mixed",
        };
        let resources = match self.resources {
            Resources::Ample => "ample",
            Resources::OverCommitted => "overcommit",
            Resources::SpillOn => "spill",
            Resources::SpillPrefetch => "prefetch",
        };
        let fault = match self.fault {
            Fault::None => "clean",
            Fault::CancelStorm => "cancel",
            Fault::BackendError => "bkerr",
            Fault::ForcePreempt => "preempt",
        };
        let topology = match self.topology {
            Topology::Direct => "direct".to_string(),
            Topology::Router { shards } => format!("router{shards}"),
        };
        format!("{arrival}/{prompt}/{options}/{resources}/{fault}/{topology}")
    }
}

// ───────────────────────── compositors ─────────────────────────

/// Template predicates for [`Gen::Filter`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pred {
    /// Drop combinations whose semantics are contradictory (currently:
    /// forced preemption on an ample pool — nothing can force it).
    Compatible,
    /// Keep templates whose fault axis is filled with a real fault.
    Faulty,
    /// Keep templates with `Fault::None` (or the fault axis unfilled).
    Clean,
}

impl Pred {
    pub fn eval(&self, t: &Template) -> bool {
        match self {
            Pred::Compatible => {
                !(t.fault == Some(Fault::ForcePreempt) && t.resources == Some(Resources::Ample))
            }
            Pred::Faulty => matches!(t.fault, Some(f) if f != Fault::None),
            Pred::Clean => t.fault.is_none() || t.fault == Some(Fault::None),
        }
    }
}

/// The compositor language (ruler's enumo shapes, typed):
///
/// * `Set` — a literal list of templates;
/// * `Append` — union of sub-generators;
/// * `Cross` — pairwise [`Template::merge`] of two generators over
///   disjoint axes (the workload cross product);
/// * `Plug` — fill one named hole of every `base` template with each
///   value the `fill` generator provides for that axis (templates
///   without the hole pass through once, unchanged — enumo's
///   "plug into terms containing the hole");
/// * `Filter` — keep templates satisfying a [`Pred`].
#[derive(Clone, Debug)]
pub enum Gen {
    Set(Vec<Template>),
    Append(Vec<Gen>),
    Cross(Box<Gen>, Box<Gen>),
    Plug { base: Box<Gen>, hole: Axis, fill: Box<Gen> },
    Filter(Box<Gen>, Pred),
}

impl Gen {
    /// One-axis value set: the building block for `Cross`/`Plug`.
    pub fn arrivals(vs: &[Arrival]) -> Gen {
        Gen::Set(vs.iter().map(|&v| Template::new().arrival(v)).collect())
    }

    pub fn prompts(vs: &[PromptShape]) -> Gen {
        Gen::Set(vs.iter().map(|&v| Template::new().prompt(v)).collect())
    }

    pub fn options(vs: &[OptionsAxis]) -> Gen {
        Gen::Set(vs.iter().map(|&v| Template::new().options(v)).collect())
    }

    pub fn resources(vs: &[Resources]) -> Gen {
        Gen::Set(vs.iter().map(|&v| Template::new().resources(v)).collect())
    }

    pub fn faults(vs: &[Fault]) -> Gen {
        Gen::Set(vs.iter().map(|&v| Template::new().fault(v)).collect())
    }

    pub fn topologies(vs: &[Topology]) -> Gen {
        Gen::Set(vs.iter().map(|&v| Template::new().topology(v)).collect())
    }

    pub fn cross(self, other: Gen) -> Gen {
        Gen::Cross(Box::new(self), Box::new(other))
    }

    pub fn plug(self, hole: Axis, fill: Gen) -> Gen {
        Gen::Plug { base: Box::new(self), hole, fill: Box::new(fill) }
    }

    pub fn filter(self, pred: Pred) -> Gen {
        Gen::Filter(Box::new(self), pred)
    }

    /// Expand to the template list, in deterministic (structural) order.
    pub fn expand(&self) -> Vec<Template> {
        match self {
            Gen::Set(ts) => ts.clone(),
            Gen::Append(gs) => gs.iter().flat_map(|g| g.expand()).collect(),
            Gen::Cross(a, b) => {
                let (ta, tb) = (a.expand(), b.expand());
                ta.iter().flat_map(|x| tb.iter().filter_map(move |y| x.merge(y))).collect()
            }
            Gen::Plug { base, hole, fill } => {
                let fills: Vec<Template> = fill.expand();
                base.expand()
                    .into_iter()
                    .flat_map(|t| -> Vec<Template> {
                        if !t.has_hole(*hole) {
                            return vec![t];
                        }
                        fills
                            .iter()
                            .filter(|f| !f.has_hole(*hole))
                            .filter_map(|f| t.merge(f))
                            .collect()
                    })
                    .collect()
            }
            Gen::Filter(g, pred) => g.expand().into_iter().filter(|t| pred.eval(t)).collect(),
        }
    }

    /// Expand and ground; templates with remaining holes are an
    /// authoring bug, so this panics on them.
    pub fn scenarios(&self) -> Vec<Scenario> {
        self.expand()
            .iter()
            .map(|t| t.ground().unwrap_or_else(|| panic!("template with holes: {t:?}")))
            .collect()
    }
}

// ───────────────────────── the matrix ─────────────────────────

/// Topologies every scenario sweep covers.
pub const TOPOLOGIES: [Topology; 3] =
    [Topology::Direct, Topology::Router { shards: 1 }, Topology::Router { shards: 4 }];

/// The canonical fuzz matrix, built *with* the DSL (so the compositors
/// are load-bearing, not decorative):
///
/// * fault-free branch — the full 6-axis cross product with
///   `Fault::None` plugged in: 3·3·6·4·3 = 648 scenarios;
/// * faulty branch — every real fault crossed with a reduced slice of
///   the other axes (batch arrivals, 2 prompt shapes, 3 option modes),
///   filtered for compatibility: 3·2·3·4·3 − 18 = 198 scenarios.
///
/// Total: 846 distinct scenarios covering every value of every axis.
pub fn matrix() -> Vec<Scenario> {
    let all_arrivals = [Arrival::Batch, Arrival::Poisson, Arrival::Burst];
    let all_prompts = [PromptShape::Unique, PromptShape::SharedPrefix, PromptShape::Coherent];
    let all_options = [
        OptionsAxis::Dense,
        OptionsAxis::Verified,
        OptionsAxis::VerifiedReuse,
        OptionsAxis::Int8,
        OptionsAxis::Int4,
        OptionsAxis::Mixed,
    ];
    let all_resources = [
        Resources::Ample,
        Resources::OverCommitted,
        Resources::SpillOn,
        Resources::SpillPrefetch,
    ];

    let clean = Gen::arrivals(&all_arrivals)
        .cross(Gen::prompts(&all_prompts))
        .cross(Gen::options(&all_options))
        .cross(Gen::resources(&all_resources))
        .cross(Gen::topologies(&TOPOLOGIES))
        .plug(Axis::Fault, Gen::faults(&[Fault::None]));

    let faulty = Gen::faults(&[Fault::CancelStorm, Fault::BackendError, Fault::ForcePreempt])
        .cross(Gen::arrivals(&[Arrival::Batch]))
        .cross(Gen::prompts(&[PromptShape::Unique, PromptShape::SharedPrefix]))
        .cross(Gen::options(&[OptionsAxis::Dense, OptionsAxis::Int8, OptionsAxis::Verified]))
        .cross(Gen::resources(&all_resources))
        .cross(Gen::topologies(&TOPOLOGIES))
        .filter(Pred::Compatible);

    Gen::Append(vec![clean, faulty]).scenarios()
}

/// Deterministic sample of `n` scenarios that still spans every axis
/// value present in `all`: a seeded shuffle ordered so that scenarios
/// contributing a not-yet-covered axis value are taken first, then the
/// remainder fills up to `n`. Pure function of `(all, n, seed)` — the
/// CI matrix is this with the pinned seed in `tests/scenario_matrix.rs`.
pub fn sample(all: &[Scenario], n: usize, seed: u64) -> Vec<Scenario> {
    use std::collections::HashSet;
    let mut order: Vec<usize> = (0..all.len()).collect();
    Rng::new(seed).shuffle(&mut order);

    let mut covered: HashSet<(usize, u64)> = HashSet::new();
    let mut picked: Vec<usize> = Vec::new();
    let mut rest: Vec<usize> = Vec::new();
    for &i in &order {
        let codes = all[i].axis_codes();
        let mut novel = false;
        for (axis, &c) in codes.iter().enumerate() {
            novel |= covered.insert((axis, c));
        }
        if novel {
            picked.push(i);
        } else {
            rest.push(i);
        }
    }
    picked.extend(rest);
    picked.truncate(n.min(all.len()));
    picked.into_iter().map(|i| all[i]).collect()
}

/// Count axes on which `scenarios` exercises ≥ 2 distinct values — the
/// "spans all 6 axes" acceptance statistic.
pub fn axes_covered(scenarios: &[Scenario]) -> usize {
    use std::collections::HashSet;
    let mut per_axis: [HashSet<u64>; 6] = Default::default();
    for s in scenarios {
        for (axis, &c) in s.axis_codes().iter().enumerate() {
            per_axis[axis].insert(c);
        }
    }
    per_axis.iter().filter(|vs| vs.len() >= 2).count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn cross_is_a_cross_product() {
        let g = Gen::arrivals(&[Arrival::Batch, Arrival::Poisson])
            .cross(Gen::prompts(&[PromptShape::Unique, PromptShape::SharedPrefix]));
        let ts = g.expand();
        assert_eq!(ts.len(), 4);
        let set: HashSet<_> = ts.iter().map(|t| (t.arrival.unwrap(), t.prompt.unwrap())).collect();
        assert_eq!(set.len(), 4);
    }

    #[test]
    fn merge_rejects_conflicts() {
        let a = Template::new().arrival(Arrival::Batch);
        let b = Template::new().arrival(Arrival::Poisson).prompt(PromptShape::Unique);
        assert!(a.merge(&b).is_none(), "conflicting axis must not merge");
        let c = Template::new().prompt(PromptShape::Coherent);
        let m = a.merge(&c).unwrap();
        assert_eq!(m.arrival, Some(Arrival::Batch));
        assert_eq!(m.prompt, Some(PromptShape::Coherent));
    }

    #[test]
    fn plug_fills_only_holes() {
        let base = Gen::Set(vec![
            Template::new().arrival(Arrival::Batch), // fault hole: plugged twice
            Template::new().arrival(Arrival::Poisson).fault(Fault::None), // no hole: passes once
        ]);
        let g = base.plug(Axis::Fault, Gen::faults(&[Fault::CancelStorm, Fault::BackendError]));
        let ts = g.expand();
        assert_eq!(ts.len(), 3);
        assert_eq!(ts.iter().filter(|t| t.arrival == Some(Arrival::Batch)).count(), 2);
        assert!(ts.iter().any(|t| t.fault == Some(Fault::None)));
    }

    #[test]
    fn filter_compatible_drops_forced_preempt_on_ample() {
        let g = Gen::faults(&[Fault::ForcePreempt])
            .cross(Gen::resources(&[Resources::Ample, Resources::OverCommitted]))
            .filter(Pred::Compatible);
        let ts = g.expand();
        assert_eq!(ts.len(), 1);
        assert_eq!(ts[0].resources, Some(Resources::OverCommitted));
    }

    #[test]
    fn matrix_shape_and_coverage() {
        let all = matrix();
        assert_eq!(all.len(), 846, "648 clean + 198 faulty");
        let set: HashSet<_> = all.iter().copied().collect();
        assert_eq!(set.len(), all.len(), "matrix has duplicate scenarios");
        assert_eq!(axes_covered(&all), 6);
        // Every declared axis value appears somewhere.
        let mut values: [HashSet<u64>; 6] = Default::default();
        for s in &all {
            for (axis, &c) in s.axis_codes().iter().enumerate() {
                values[axis].insert(c);
            }
        }
        assert_eq!(values.map(|v| v.len()), [3, 3, 6, 4, 4, 3]);
        // The incompatible combo never appears.
        assert!(!all
            .iter()
            .any(|s| s.fault == Fault::ForcePreempt && s.resources == Resources::Ample));
    }

    #[test]
    fn codes_and_seeds_are_stable_and_distinct() {
        let all = matrix();
        let codes: HashSet<u64> = all.iter().map(|s| s.code()).collect();
        assert_eq!(codes.len(), all.len(), "scenario codes collide");
        let s = all[0];
        assert_eq!(s.seed(7), s.seed(7));
        assert_ne!(s.seed(7), s.seed(8));
        assert_ne!(s.seed(7), all[1].seed(7));
        // Pin one code so accidental variant reordering is caught.
        let probe = Scenario {
            arrival: Arrival::Poisson,
            prompt: PromptShape::SharedPrefix,
            options: OptionsAxis::Int8,
            resources: Resources::SpillOn,
            fault: Fault::None,
            topology: Topology::Router { shards: 4 },
        };
        assert_eq!(probe.code(), 0x010103020068);
        assert_eq!(probe.label(), "poisson/shared/int8/spill/clean/router4");
    }

    #[test]
    fn sample_is_deterministic_and_spans_all_axes() {
        let all = matrix();
        let a = sample(&all, 44, 1234);
        let b = sample(&all, 44, 1234);
        assert_eq!(a, b);
        assert_eq!(a.len(), 44);
        let distinct: HashSet<_> = a.iter().copied().collect();
        assert_eq!(distinct.len(), a.len(), "sample repeats a scenario");
        assert_eq!(axes_covered(&a), 6, "sample must span all six axes");
        // The coverage-first ordering guarantees every axis *value* too.
        let mut values: [HashSet<u64>; 6] = Default::default();
        for s in &a {
            for (axis, &c) in s.axis_codes().iter().enumerate() {
                values[axis].insert(c);
            }
        }
        assert_eq!(values.map(|v| v.len()), [3, 3, 6, 4, 4, 3]);
        assert_ne!(sample(&all, 44, 1234), sample(&all, 44, 99));
    }
}
