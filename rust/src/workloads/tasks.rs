//! RULER-proxy retrieval/aggregation tasks over synthetic KV caches.
//!
//! Each task plants an *answer* into the KV structure and scores a
//! sparse-attention method by whether the attention output still decodes
//! to that answer (dense attention decodes correctly by construction).
//!
//! * `NiahSingle` / `NiahMultikey{2,3}` — needle-in-a-haystack: one
//!   high-logit needle carries the answer value; multikey variants add
//!   decoy needles at nearby logits (approximate top-k confusers).
//! * `NiahMultivalue` — several needles, *all* must be aggregated.
//! * `Fwe` / `Vt` / `Qa` — aggregation: competing token groups encode
//!   candidate answers; the correct one has the largest *total* mass but
//!   individually weaker tokens than a sharper decoy group, so truncating
//!   the tail (top-k) flips the argmax while unbiased sampling keeps it.
//! * `Cwe` — 10-way aggregation with tiny margins (hard for everyone,
//!   matching its near-zero scores in Table 4).

use crate::tensor::Mat;
use crate::util::Rng;

/// The seven RULER32K-HARD proxies plus the easy single-needle task.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TaskKind {
    NiahSingle,
    NiahMultikey2,
    NiahMultikey3,
    NiahMultivalue,
    Vt,
    Fwe,
    Qa1,
    Qa2,
    Cwe,
}

impl TaskKind {
    pub fn name(&self) -> &'static str {
        match self {
            TaskKind::NiahSingle => "niah_single",
            TaskKind::NiahMultikey2 => "niah_multikey_2",
            TaskKind::NiahMultikey3 => "niah_multikey_3",
            TaskKind::NiahMultivalue => "niah_multivalue",
            TaskKind::Vt => "vt",
            TaskKind::Fwe => "fwe",
            TaskKind::Qa1 => "qa_1",
            TaskKind::Qa2 => "qa_2",
            TaskKind::Cwe => "cwe",
        }
    }

    /// The RULER32K-HARD subset (Table 1 / Tables 7–8).
    pub fn hard_suite() -> Vec<TaskKind> {
        vec![
            TaskKind::Qa1,
            TaskKind::Qa2,
            TaskKind::Vt,
            TaskKind::Fwe,
            TaskKind::NiahMultikey2,
            TaskKind::NiahMultikey3,
            TaskKind::NiahMultivalue,
        ]
    }
}

/// Static description of a generated task instance.
pub struct TaskInstance {
    pub kind: TaskKind,
    pub k: Mat,
    pub v: Mat,
    pub q_scaled: Vec<f32>,
    /// Candidate answer directions (unit vectors in value space).
    pub codebook: Mat,
    /// Index of the correct answer in the codebook.
    pub answer: usize,
    /// For multivalue: per-slot answers (slot s lives in value dims
    /// [s*slot_d, (s+1)*slot_d)); empty for single-answer tasks.
    pub slot_answers: Vec<usize>,
    pub slot_d: usize,
}

impl TaskInstance {
    /// Decode an attention output back to an answer id: nearest codebook
    /// direction by inner product.
    pub fn decode(&self, out: &[f32]) -> usize {
        let mut best = 0;
        let mut best_s = f32::NEG_INFINITY;
        for a in 0..self.codebook.rows {
            let s = crate::tensor::dot(self.codebook.row(a), out);
            if s > best_s {
                best_s = s;
                best = a;
            }
        }
        best
    }

    /// Decode one slot of a multivalue output.
    fn decode_slot(&self, out: &[f32], slot: usize) -> usize {
        let lo = slot * self.slot_d;
        let hi = lo + self.slot_d;
        let mut best = 0;
        let mut best_s = f32::NEG_INFINITY;
        for a in 0..self.codebook.rows {
            let s = crate::tensor::dot(&self.codebook.row(a)[lo..hi], &out[lo..hi]);
            if s > best_s {
                best_s = s;
                best = a;
            }
        }
        best
    }

    /// Score an attention output: 1.0 if it decodes to the planted
    /// answer(s), else 0.0.
    pub fn score(&self, out: &[f32]) -> f64 {
        if self.slot_answers.is_empty() {
            if self.decode(out) == self.answer {
                1.0
            } else {
                0.0
            }
        } else {
            let ok = self
                .slot_answers
                .iter()
                .enumerate()
                .all(|(s, &a)| self.decode_slot(out, s) == a);
            if ok {
                1.0
            } else {
                0.0
            }
        }
    }
}

/// Task generator with a model-regime difficulty dial (see `table1`):
/// `sharpness` scales needle boosts (lower = flatter = harder), matching
/// how different base models separate needle logits differently.
pub struct Task {
    pub kind: TaskKind,
    pub n: usize,
    pub d: usize,
    pub n_answers: usize,
    pub sharpness: f32,
}

impl Task {
    pub fn new(kind: TaskKind, n: usize, d: usize) -> Task {
        Task { kind, n, d, n_answers: 8, sharpness: 1.0 }
    }

    pub fn generate(&self, rng: &mut Rng) -> TaskInstance {
        match self.kind {
            TaskKind::NiahSingle => self.gen_niah(rng, 0, 7.0),
            TaskKind::NiahMultikey2 => self.gen_niah(rng, 4, 5.6),
            TaskKind::NiahMultikey3 => self.gen_niah(rng, 6, 5.4),
            TaskKind::NiahMultivalue => self.gen_multivalue(rng),
            TaskKind::Vt => self.gen_aggregate(rng, 4, 0.14, 1.9),
            TaskKind::Fwe => self.gen_aggregate(rng, 3, 0.16, 1.85),
            TaskKind::Qa1 => self.gen_aggregate(rng, 4, 0.20, 1.6),
            TaskKind::Qa2 => self.gen_aggregate(rng, 6, 0.12, 1.7),
            TaskKind::Cwe => self.gen_aggregate(rng, 10, 0.045, 1.5),
        }
    }

    fn base_kv(&self, rng: &mut Rng) -> (Mat, Mat, Vec<f32>, Mat) {
        let (n, d) = (self.n, self.d);
        let mut q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
        let qn = crate::tensor::norm2(&q);
        for x in q.iter_mut() {
            *x /= qn;
        }
        // Background keys: small random logits + orthogonal noise.
        let mut k = Mat::zeros(n, d);
        for i in 0..n {
            let l = rng.normal32(0.0, 0.5);
            let mut noise: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 0.4)).collect();
            let proj = crate::tensor::dot(&noise, &q);
            for c in 0..d {
                noise[c] -= proj * q[c];
                k.set(i, c, l * q[c] + noise[c]);
            }
        }
        // Background values: isotropic noise (no answer signal).
        let mut v = Mat::zeros(n, d);
        for i in 0..n {
            for c in 0..d {
                v.set(i, c, rng.normal32(0.0, 0.5));
            }
        }
        // Answer codebook: orthonormal-ish random unit directions.
        let mut codebook = Mat::zeros(self.n_answers, d);
        for a in 0..self.n_answers {
            let mut dir: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
            let nn = crate::tensor::norm2(&dir);
            for c in 0..d {
                codebook.set(a, c, dir[c] / nn);
            }
            let _ = &mut dir;
        }
        (k, v, q, codebook)
    }

    fn plant_key(&self, k: &mut Mat, q: &[f32], i: usize, logit: f32, rng: &mut Rng) {
        let d = self.d;
        let mut noise: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 0.2)).collect();
        let proj = crate::tensor::dot(&noise, q);
        for c in 0..d {
            noise[c] -= proj * q[c];
            k.set(i, c, logit * q[c] + noise[c]);
        }
    }

    /// Needle task: the true needle carries `boost·sharpness`; `decoys`
    /// decoy needles carry wrong answers at ~85% of the boost.
    fn gen_niah(&self, rng: &mut Rng, decoys: usize, boost: f32) -> TaskInstance {
        let (mut k, mut v, q, codebook) = self.base_kv(rng);
        let boost = boost * self.sharpness;
        let answer = rng.below(self.n_answers);
        let spots = rng.sample_distinct(self.n - 256, decoys + 1);
        // true needle
        let ni = spots[0] + 128; // keep out of sink/window by default
        self.plant_key(&mut k, &q, ni, boost, rng);
        for c in 0..self.d {
            v.set(ni, c, codebook.get(answer, c) * 3.0);
        }
        // decoys: *distinct* wrong answers at slightly lower logits (two
        // decoys sharing an answer could out-mass the true needle).
        for (j, &s) in spots.iter().skip(1).enumerate() {
            let di = s + 128;
            let wrong = (answer + 1 + j) % self.n_answers;
            self.plant_key(&mut k, &q, di, boost * 0.82, rng);
            for c in 0..self.d {
                v.set(di, c, codebook.get(wrong, c) * 3.0);
            }
        }
        TaskInstance { kind: self.kind, k, v, q_scaled: q, codebook, answer, slot_answers: vec![], slot_d: 0 }
    }

    /// Multivalue: 4 slots, each with its own needle; all must decode.
    /// The codebook is built slot-orthonormal (Gram–Schmidt within each
    /// slot's dims) so slot decoding is unambiguous.
    fn gen_multivalue(&self, rng: &mut Rng) -> TaskInstance {
        let (mut k, mut v, q, mut codebook) = self.base_kv(rng);
        let slots = 4;
        let slot_d = self.d / slots;
        assert!(self.n_answers <= slot_d, "slot dims must fit the codebook");
        // Re-generate the codebook with orthonormal sub-vectors per slot.
        for s in 0..slots {
            let lo = s * slot_d;
            let mut basis: Vec<Vec<f32>> = Vec::new();
            for a in 0..self.n_answers {
                let mut dir: Vec<f32> = (0..slot_d).map(|_| rng.normal32(0.0, 1.0)).collect();
                for b in &basis {
                    let proj = crate::tensor::dot(&dir, b);
                    for (x, &bv) in dir.iter_mut().zip(b.iter()) {
                        *x -= proj * bv;
                    }
                }
                let nn = crate::tensor::norm2(&dir).max(1e-6);
                for x in dir.iter_mut() {
                    *x /= nn;
                }
                for c in 0..slot_d {
                    codebook.set(a, lo + c, dir[c]);
                }
                basis.push(dir);
            }
        }
        let boost = 6.5 * self.sharpness;
        let spots = rng.sample_distinct(self.n - 256, slots);
        let mut slot_answers = Vec::with_capacity(slots);
        for (s, &pos) in spots.iter().enumerate() {
            let i = pos + 128;
            let a = rng.below(self.n_answers);
            slot_answers.push(a);
            self.plant_key(&mut k, &q, i, boost + rng.normal32(0.0, 0.3), rng);
            // value: answer direction restricted to the slot's dims
            for c in 0..self.d {
                v.set(i, c, 0.0);
            }
            for c in s * slot_d..(s + 1) * slot_d {
                v.set(i, c, codebook.get(a, c) * 4.0);
            }
        }
        TaskInstance {
            kind: self.kind,
            k,
            v,
            q_scaled: q,
            codebook,
            answer: slot_answers[0],
            slot_answers,
            slot_d,
        }
    }

    /// Aggregation task: `groups` token groups, one per candidate answer.
    /// The *correct* group has the largest total attention mass but is
    /// spread over many weak tokens; one decoy group is sharp (fewer,
    /// stronger tokens) so that truncating the tail flips the argmax.
    ///
    /// `margin` controls the mass gap; `decoy_sharpness` the decoy logit
    /// advantage.
    fn gen_aggregate(&self, rng: &mut Rng, groups: usize, margin: f32, decoy_sharpness: f32) -> TaskInstance {
        let (mut k, mut v, q, codebook) = self.base_kv(rng);
        let groups = groups.min(self.n_answers);
        let answer = rng.below(groups);
        let decoy = (answer + 1) % groups;
        // Group geometry: correct group is wide (many weak tokens), decoy
        // narrow (few strong tokens), other groups weaker fillers. The
        // sizes/sharpness are calibrated so that (a) dense attention keeps
        // a clear margin (wide·e^b > narrow·e^{b+ds} requires ds < ln 8),
        // and (b) deterministic top-k flips to the decoy whenever its
        // budget B satisfies B − narrow < narrow·e^{ds} — i.e. truncation
        // loses the answer group's tail mass. With wide = 600 the flip
        // point lands around 10–15% density at n = 4096, which is where
        // the paper's hard tasks separate methods.
        // Per-instance difficulty jitter: the flip point then varies
        // across instances, so truncating methods get *partial* credit at
        // a given density (as on real benchmarks) instead of a cliff.
        let base_wide = (self.n / 7).max(220).min(600);
        let wide = ((base_wide as f32) * (0.55 + 0.65 * rng.f32())) as usize;
        let decoy_sharpness = decoy_sharpness * (0.85 + 0.25 * rng.f32());
        let narrow = (wide as f32 / 8.0) as usize;
        let filler = 60;
        let base_logit = 2.0;
        let total: usize = wide + narrow + filler * (groups.saturating_sub(2));
        let spots = rng.sample_distinct(self.n - 256, total);
        let mut cursor = 0;
        for g in 0..groups {
            let (count, logit) = if g == answer {
                (wide, base_logit)
            } else if g == decoy {
                // fewer tokens, individually sharper, less total mass
                (narrow, base_logit + decoy_sharpness)
            } else {
                (filler, base_logit - 0.7)
            };
            for _ in 0..count {
                let i = spots[cursor] + 128;
                cursor += 1;
                self.plant_key(&mut k, &q, i, logit + rng.normal32(0.0, 0.15), rng);
                for c in 0..self.d {
                    v.set(i, c, codebook.get(g, c) * 2.5);
                }
            }
            let _ = margin; // margin is expressed through the group sizes
        }
        TaskInstance { kind: self.kind, k, v, q_scaled: q, codebook, answer, slot_answers: vec![], slot_d: 0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::dense_sdpa;

    fn dense_accuracy(kind: TaskKind, trials: usize, seed: u64) -> f64 {
        let task = Task::new(kind, 4096, 48);
        let mut rng = Rng::new(seed);
        let mut acc = 0.0;
        for t in 0..trials {
            let inst = task.generate(&mut rng.fork(t as u64));
            let out = dense_sdpa(&inst.k, &inst.v, &inst.q_scaled).out;
            acc += inst.score(&out);
        }
        acc / trials as f64
    }

    #[test]
    fn dense_solves_niah_single() {
        assert!(dense_accuracy(TaskKind::NiahSingle, 20, 1) >= 0.95);
    }

    #[test]
    fn dense_solves_multikey() {
        assert!(dense_accuracy(TaskKind::NiahMultikey2, 20, 2) >= 0.9);
        assert!(dense_accuracy(TaskKind::NiahMultikey3, 20, 3) >= 0.9);
    }

    #[test]
    fn dense_solves_multivalue() {
        assert!(dense_accuracy(TaskKind::NiahMultivalue, 20, 4) >= 0.9);
    }

    #[test]
    fn dense_solves_aggregates() {
        assert!(dense_accuracy(TaskKind::Fwe, 20, 5) >= 0.9);
        assert!(dense_accuracy(TaskKind::Vt, 20, 6) >= 0.9);
        assert!(dense_accuracy(TaskKind::Qa1, 20, 7) >= 0.85);
    }

    #[test]
    fn truncated_topk_fails_aggregates() {
        // The defining property: oracle top-k with a small budget flips
        // the answer toward the sharp decoy group.
        use crate::attention::sparse_sdpa;
        use crate::policies::{IndexPolicy, OracleTopKPolicy, PolicyCtx, SizeSpec};
        let task = Task::new(TaskKind::Fwe, 4096, 48);
        let mut rng = Rng::new(8);
        let mut dense_ok = 0.0;
        let mut topk_ok = 0.0;
        let trials = 15;
        for t in 0..trials {
            let inst = task.generate(&mut rng.fork(t));
            let dense = dense_sdpa(&inst.k, &inst.v, &inst.q_scaled).out;
            dense_ok += inst.score(&dense);
            let mut pol = OracleTopKPolicy {
                sink: SizeSpec::Abs(16),
                window: SizeSpec::Abs(16),
                heavy: SizeSpec::Abs(64), // enough for decoy, not for answer group
            };
            let mut fork = rng.fork(1000 + t);
            let mut ctx = PolicyCtx { k: &inst.k, v: &inst.v, q_scaled: &inst.q_scaled, rng: &mut fork, step: 0 };
            let sel = pol.select(&mut ctx);
            let out = sparse_sdpa(&inst.k, &inst.v, &inst.q_scaled, &sel);
            topk_ok += inst.score(&out);
        }
        let dense_acc = dense_ok / trials as f64;
        let topk_acc = topk_ok / trials as f64;
        assert!(dense_acc >= 0.9, "dense {dense_acc}");
        assert!(topk_acc <= dense_acc - 0.3, "top-k should collapse: {topk_acc} vs {dense_acc}");
    }

    #[test]
    fn needle_not_in_sink_or_window() {
        let task = Task::new(TaskKind::NiahSingle, 2048, 32);
        let mut rng = Rng::new(9);
        for t in 0..10 {
            let inst = task.generate(&mut rng.fork(t));
            // find the planted needle = argmax logit
            let logits = crate::attention::logits_all(&inst.k, &inst.q_scaled);
            let ni = (0..2048)
                .max_by(|&a, &b| logits[a].partial_cmp(&logits[b]).unwrap())
                .unwrap();
            assert!(ni >= 128 && ni < 2048 - 128, "needle at {ni}");
        }
    }

    #[test]
    fn hard_suite_has_seven_tasks() {
        assert_eq!(TaskKind::hard_suite().len(), 7);
    }
}
