//! # vAttention: Verified Sparse Attention — reproduction library
//!
//! A three-layer reproduction of "vAttention: Verified Sparse Attention"
//! (Desai, Agrawal, et al., 2025):
//!
//! * **L3 (this crate)** — the serving coordinator: paged KV cache
//!   management, index-selection policies (vAttention + all evaluated
//!   baselines), the verified budget machinery, a parallel
//!   continuous-batching engine with open-loop trace serving, and the
//!   experiment harness reproducing every table/figure.
//! * **L2** — `python/compile/model.py`: JAX transformer blocks lowered
//!   AOT to HLO text under `artifacts/`, executed from rust via PJRT.
//! * **L1** — `python/compile/kernels/`: Pallas kernels (sparse SDPA with
//!   importance weights, dense SDPA), validated against pure-jnp oracles.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

// The serving API is consumed by doc readers first; a broken intra-doc
// link is a build failure (CI runs a blocking `cargo doc --no-deps`).
#![deny(rustdoc::broken_intra_doc_links)]

pub mod attention;
pub mod budget;
pub mod experiments;
pub mod kvcache;
pub mod metrics;
pub mod model;
pub mod policies;
pub mod runtime;
pub mod server;
pub mod sim;
pub mod tensor;
pub mod workloads;
pub mod util;

pub fn version() -> &'static str {
    "0.1.0"
}
