//! Fig. 1 (right) — the verification claim: the user-specified tolerance
//! ε correlates near-perfectly with the observed mean attention error
//! under the verified denominator-only approximation.

use super::common::*;
use crate::metrics::{f, pearson, spearman, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{synthesize_head, ScoreProfile};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 8192);
    let d = args.get_usize("d", 32);
    let trials = args.get_usize("trials", 6);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    let eps_grid = [0.01, 0.02, 0.05, 0.1, 0.15, 0.2, 0.3, 0.4];
    // Heads whose residual genuinely matters: flat and shallow-power-law
    // tails (on sharply-dominated heads the guarantee is nearly free at
    // every ε, so the dial has nothing to control — cf. Fig 2 top-left).
    let heads: Vec<_> = (0..4)
        .map(|i| {
            let profile = if i % 2 == 0 {
                ScoreProfile::Flat
            } else {
                ScoreProfile::PowerLaw { alpha: 0.35 }
            };
            synthesize_head(n, d, profile, &mut rng)
        })
        .collect();

    let mut t = Table::new(
        "Fig 1 (right): user ε vs observed mean attention error (verified-D)",
        &["epsilon", "mean layer err", "mean density"],
    );
    let mut errs = Vec::new();
    let mut denss = Vec::new();
    for &eps in &eps_grid {
        let mut err = 0.0;
        let mut den = 0.0;
        for head in &heads {
            let mut cfg = vcfg(eps);
            cfg.floor_at_base = false;
            cfg.sink = crate::policies::SizeSpec::Abs(64);
            cfg.window = crate::policies::SizeSpec::Abs(64);
            cfg.heavy = crate::policies::SizeSpec::Frac(0.01);
            let mut pol = crate::policies::VAttentionPolicy::oracle(cfg);
            let pt = eval_head(&mut pol, head, trials, &mut rng);
            err += pt.err;
            den += pt.density;
        }
        err /= heads.len() as f64;
        den /= heads.len() as f64;
        t.row(vec![f(eps, 3), f(err, 4), f(den, 3)]);
        errs.push(err);
        denss.push(den);
    }
    let eps_v: Vec<f64> = eps_grid.to_vec();
    let r = pearson(&eps_v, &errs);
    let rho = spearman(&eps_v, &errs);

    let mut out = t.render();
    out.push_str(&format!("\nPearson r(eps, err) = {r:.4}   Spearman rho = {rho:.4}\n"));
    out.push_str("paper: near-perfect correlation (Fig. 1 right) — expect r > 0.9\n");

    let json = Json::obj()
        .field("experiment", Json::str("fig1_correlation"))
        .field("epsilon", Json::arr_f64(eps_v))
        .field("mean_error", Json::arr_f64(errs))
        .field("mean_density", Json::arr_f64(denss))
        .field("pearson", Json::num(r))
        .field("spearman", Json::num(rho));
    write_results("fig1_correlation", &out, &json);
    out
}
