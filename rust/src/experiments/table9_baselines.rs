//! Table 9 — the approximate-top-k family at a fixed 512-token budget:
//! H2O, StreamingLLM, InfLLM, DoubleSparsity, Quest, PQCache,
//! HashAttention vs oracle-top and the full model, on a task mix.
//!
//! Expected shape: oracle ≈ full > HashAttention ≳ Quest/DS/PQCache >
//! InfLLM > H2O > StreamingLLM.

use super::common::*;
use crate::metrics::{f, Table};
use crate::policies::*;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workloads::TaskKind;

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 4096);
    let d = args.get_usize("d", 48);
    let trials = args.get_usize("trials", 10);
    let seed = args.get_u64("seed", 42);
    let budget = args.get_usize("budget", 512);

    let tasks = [
        TaskKind::NiahSingle,
        TaskKind::NiahMultikey2,
        TaskKind::Qa1,
        TaskKind::Fwe,
        TaskKind::Vt,
    ];
    // Multi-turn emulation: history-based policies (H2O, SnapKV — and the
    // irreversible-compression family generally) accumulate relevance
    // from *past* queries. The paper's critique is exactly that relevance
    // shifts between turns, so we warm every policy with a few unrelated
    // queries before the scored one (stateless policies are unaffected).
    let history_turns = args.get_usize("history", 4);

    // (label, factory) — budget-matched at `budget` tokens (plus the
    // shared 128+128 sink/window, as in the paper's protocol).
    type Factory<'a> = Box<dyn Fn() -> Box<dyn IndexPolicy> + 'a>;
    let abs = SizeSpec::Abs(budget);
    let entries: Vec<(&str, Factory, usize)> = vec![
        ("Full Model", Box::new(|| make_policy("oracle-top-p", 0.999999, seed)), 0),
        ("Oracle(top)", Box::new(move || Box::new(OracleTopKPolicy { sink: SizeSpec::Abs(128), window: SizeSpec::Abs(128), heavy: abs })), 0),
        ("H2O", Box::new(move || Box::new(H2OPolicy::new(abs))), 0),
        ("StreamLLM", Box::new(move || Box::new(SinkWindowPolicy::new(128, budget))), 0),
        ("InfLLM", Box::new(move || Box::new(HeavyHitterPolicy::new(Box::new(scorers::BlockMeanScorer::new(16)), abs))), 256),
        ("DS", Box::new(move || Box::new(HeavyHitterPolicy::new(Box::new(scorers::DoubleSparsityScorer { channels: 8 }), abs))), 32),
        ("Quest", Box::new(move || Box::new(HeavyHitterPolicy::new(Box::new(scorers::QuestScorer::new(16)), abs))), 32),
        ("PQCache", Box::new(move || Box::new(HeavyHitterPolicy::new(Box::new(scorers::PqScorer::new(8, 16, seed)), abs))), 32),
        ("HashAttention", Box::new(move || Box::new(HeavyHitterPolicy::new(Box::new(scorers::HashSignScorer::new(32, seed)), abs))), 32),
    ];

    let mut hdr: Vec<&str> = vec!["method", "aux bits/tok"];
    hdr.extend(tasks.iter().map(|k| k.name()));
    hdr.push("Average");
    let mut t = Table::new(
        &format!("Table 9: approximate-top-k family @ {budget} tokens"),
        &hdr,
    );
    let mut json_rows = Vec::new();
    let mut out = String::new();
    for (label, factory, aux_bits) in &entries {
        let mut scores = Vec::new();
        for &kind in &tasks {
            let pt = eval_task_with_history(factory.as_ref(), kind, n, d, trials, seed, history_turns);
            scores.push(pt.quality);
        }
        let avg = scores.iter().sum::<f64>() / scores.len() as f64;
        let mut row = vec![label.to_string(), aux_bits.to_string()];
        row.extend(scores.iter().map(|&s| f(s, 1)));
        row.push(f(avg, 2));
        t.row(row);
        json_rows.push(
            Json::obj()
                .field("method", Json::str(*label))
                .field("scores", Json::arr_f64(scores))
                .field("average", Json::num(avg)),
        );
    }
    out.push_str(&t.render());
    out.push_str(
        "\npaper Table 9 averages: Full 63.6, Oracle 63.4, HashAttention 64.2,\n\
         Quest 62.4, DS 61.9, InfLLM 48.2, H2O 43.5, StreamLLM 33.3 — expect\n\
         the same ordering (oracle/hash near full; static patterns collapse).\n",
    );

    let json = Json::obj()
        .field("experiment", Json::str("table9"))
        .field("budget", Json::num(budget as f64))
        .field("rows", Json::Arr(json_rows));
    write_results("table9", &out, &json);
    out
}

/// eval_task variant that feeds `history` unrelated queries to the
/// (stateful) policy before the scored query.
fn eval_task_with_history(
    factory: &dyn Fn() -> Box<dyn IndexPolicy>,
    kind: TaskKind,
    n: usize,
    d: usize,
    trials: usize,
    seed: u64,
    history: usize,
) -> EvalPoint {
    use crate::attention::{dense_sdpa, sparse_sdpa};
    use crate::util::Rng;
    use crate::workloads::Task;
    let task = Task::new(kind, n, d);
    let mut rng = Rng::new(seed ^ (kind as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let (mut acc, mut den, mut err) = (0.0, 0.0, 0.0);
    for t in 0..trials {
        let inst = task.generate(&mut rng.fork(t as u64));
        let exact = dense_sdpa(&inst.k, &inst.v, &inst.q_scaled).out;
        let mut policy = factory();
        let mut fork = rng.fork(1_000_000 + t as u64);
        // unrelated turns: random unit queries over the same cache
        for h in 0..history {
            let mut q: Vec<f32> = (0..d).map(|_| fork.normal32(0.0, 1.0)).collect();
            let qa = crate::tensor::norm2(&q);
            for x in q.iter_mut() {
                *x /= qa;
            }
            let mut ctx = PolicyCtx { k: &inst.k, v: &inst.v, q_scaled: &q, rng: &mut fork, step: h };
            let _ = policy.select(&mut ctx);
        }
        let mut ctx = PolicyCtx {
            k: &inst.k,
            v: &inst.v,
            q_scaled: &inst.q_scaled,
            rng: &mut fork,
            step: history,
        };
        let sel = policy.select(&mut ctx);
        den += sel.density(inst.k.rows);
        let approx = sparse_sdpa(&inst.k, &inst.v, &inst.q_scaled, &sel);
        err += crate::tensor::rel_l2_error(&approx, &exact);
        acc += inst.score(&approx);
    }
    let tf = trials as f64;
    EvalPoint { density: den / tf, err: err / tf, quality: acc / tf * 100.0 }
}
