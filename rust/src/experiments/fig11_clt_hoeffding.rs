//! Figs. 11–15 + App. E — CLT vs Hoeffding budgets: sample-size
//! requirements and empirical failure rates at (ε=0.1, δ=0.2) with 5%
//! oracle top-k, across three score regimes standing in for early /
//! middle / late layers.

use super::common::write_results;
use crate::attention::{exact_num_den, weighted_num_den, Selection};
use crate::budget::{self, Bound};
use crate::metrics::{f, mean, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{synthesize_head, HeadSample, ScoreProfile};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 8192);
    let d = args.get_usize("d", 32);
    let trials = args.get_usize("trials", 60);
    let eps = args.get_f64("eps", 0.1);
    let delta = args.get_f64("delta", 0.2);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    // "Layers": early = sharp heads, middle = power-law, late = flat-ish.
    let regimes: [(&str, ScoreProfile); 3] = [
        ("layer-1 (sharp)", ScoreProfile::Sharp { heavy: 16, boost: 7.0 }),
        ("layer-16 (power-law)", ScoreProfile::PowerLaw { alpha: 1.0 }),
        ("layer-32 (flat)", ScoreProfile::Flat),
    ];

    let mut t = Table::new(
        &format!("Figs 11-15: CLT vs Hoeffding denominator budgets (eps={eps}, delta={delta}, 5% top-k)"),
        &["regime", "CLT budget", "CLT fail%", "Hoeff budget", "Hoeff fail%", "ratio"],
    );
    let mut json_rows = Vec::new();
    for (name, profile) in regimes {
        let head = synthesize_head(n, d, profile, &mut rng);
        let (b_clt, fail_clt) = measure(&head, eps, delta, Bound::Clt, trials, &mut rng);
        let (b_hoef, fail_hoef) = measure(&head, eps, delta, Bound::Hoeffding, trials, &mut rng);
        let ratio = if b_clt > 0.0 { b_hoef / b_clt } else { f64::NAN };
        t.row(vec![
            name.to_string(),
            f(b_clt, 0),
            f(fail_clt * 100.0, 1),
            f(b_hoef, 0),
            f(fail_hoef * 100.0, 1),
            f(ratio, 2),
        ]);
        json_rows.push(
            Json::obj()
                .field("regime", Json::str(name))
                .field("clt_budget", Json::num(b_clt))
                .field("clt_fail", Json::num(fail_clt))
                .field("hoeffding_budget", Json::num(b_hoef))
                .field("hoeffding_fail", Json::num(fail_hoef)),
        );
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\npaper App E: Hoeffding needs ~2.8x more samples than CLT for the same\n\
         guarantee; CLT failure rate stays near/below delta={delta}, Hoeffding\n\
         near zero. Expect the same pattern.\n",
    ));
    let json = Json::obj()
        .field("experiment", Json::str("fig11_clt_hoeffding"))
        .field("rows", Json::Arr(json_rows));
    write_results("fig11_clt_hoeffding", &out, &json);
    out
}

/// Returns (mean budget, empirical failure rate of |D̂−D| > ε·D).
fn measure(
    head: &HeadSample,
    eps: f64,
    delta: f64,
    bound: Bound,
    trials: usize,
    rng: &mut Rng,
) -> (f64, f64) {
    let n = head.k.rows;
    // deterministic 5% oracle top-k + sink/window
    let logits = crate::attention::logits_all(&head.k, &head.q_scaled);
    let mut i_f = crate::policies::sink_window_indices(n, 128, 128);
    let top = crate::policies::top_indices_excluding(&logits, n / 20, &i_f);
    i_f.extend(top);
    i_f.sort_unstable();
    let m_ref = i_f.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let n_s = n - i_f.len();

    let (_, d_exact) = exact_num_den(&head.k, &head.v, &head.q_scaled, m_ref);
    let sel_f = Selection::deterministic(i_f.clone());
    let (_, d_f) = weighted_num_den(&head.k, &head.v, &head.q_scaled, &sel_f, m_ref);

    let mut budgets = Vec::new();
    let mut failures = 0usize;
    for t in 0..trials {
        let mut fork = rng.fork(t as u64);
        let base = budget::draw_base_sample(n, &i_f, 0.025, &mut fork);
        let stats = budget::estimate_stats(&head.k, &head.v, &head.q_scaled, &i_f, &base, m_ref);
        // Raw bound (no base floor) — the quantity Figs 11-15 plot.
        let b = budget::budget_denominator(&stats, eps, delta, bound).max(8).min(n_s);
        budgets.push(b as f64);
        // Draw the actual sample; form D̂ = D_f + scaled residual sum.
        let dyn_idx = fork.sample_excluding(n, b, &i_f);
        let sel = Selection::sampled(dyn_idx, b as f32 / n_s as f32);
        let (_, d_dyn) = weighted_num_den(&head.k, &head.v, &head.q_scaled, &sel, m_ref);
        let d_hat = d_f + d_dyn;
        if (d_hat - d_exact).abs() > eps * d_exact {
            failures += 1;
        }
    }
    (mean(&budgets), failures as f64 / trials as f64)
}
