//! Table 10 — the MagicPig evaluation-setup ablation.
//!
//! Setup A (authors'): the *question* is processed with dense attention,
//! so by the time sparse decoding starts, the needle's signal already
//! sits in the local window of the query. Setup B (this paper's): only
//! the context gets dense attention; the question is processed sparsely
//! and retrieval must actually work. We emulate the setups by where the
//! needle signal lives relative to the always-kept window: Setup A ⇒
//! needle duplicated near the sequence end (inside the window), Setup B
//! ⇒ needle only at its original position. Also compares the
//! theory-faithful simpleLSH variant against raw angular LSH.

use super::common::write_results;
use crate::attention::{dense_sdpa, sparse_sdpa};
use crate::metrics::{f, Table};
use crate::policies::{IndexPolicy, MagicPigPolicy, PolicyCtx, SizeSpec};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{Task, TaskKind};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 4096);
    let d = args.get_usize("d", 48);
    let trials = args.get_usize("trials", 12);
    let seed = args.get_u64("seed", 42);

    let kinds = [TaskKind::NiahSingle, TaskKind::NiahMultikey2, TaskKind::NiahMultikey3];
    let variants: [(&str, bool, bool); 4] = [
        // (label, setup_a, simple_lsh)
        ("A + raw-LSH (authors')", true, false),
        ("A + simpleLSH", true, true),
        ("B + raw-LSH", false, false),
        ("B + simpleLSH (ours)", false, true),
    ];

    let mut hdr: Vec<&str> = vec!["setup"];
    hdr.extend(kinds.iter().map(|k| k.name()));
    let mut t = Table::new("Table 10: MagicPig under evaluation setups A vs B (K=8, L=75)", &hdr);
    let mut json_rows = Vec::new();
    for (label, setup_a, simple) in variants {
        let mut row = vec![label.to_string()];
        let mut scores = Vec::new();
        for &kind in &kinds {
            let task = Task::new(kind, n, d);
            let mut rng = Rng::new(seed ^ kind as u64);
            let mut acc = 0.0;
            for tr in 0..trials {
                let mut inst = task.generate(&mut rng.fork(tr as u64));
                // Real key distributions give needles their inner-product
                // advantage partly through *norm*, not pure angle (the
                // orthogonality problem MagicPig's App. B.5 discussion is
                // about). Emulate: pad every needle key with a large
                // component orthogonal to q — the logit is unchanged
                // (dense attention still solves the task) but the lifted
                // cosine collapses, so angular LSH struggles to retrieve
                // it.
                {
                    let logits = crate::attention::logits_all(&inst.k, &inst.q_scaled);
                    let mut order: Vec<usize> = (0..inst.k.rows).collect();
                    order.sort_by(|&a, &b| logits[b].partial_cmp(&logits[a]).unwrap());
                    let mut fork = rng.fork(9_000 + tr as u64);
                    for &ni in order.iter().take(10) {
                        let d_ = inst.k.cols;
                        let mut u: Vec<f32> = (0..d_).map(|_| fork.normal32(0.0, 1.0)).collect();
                        let proj = crate::tensor::dot(&u, &inst.q_scaled);
                        for (c, x) in u.iter_mut().enumerate() {
                            *x -= proj * inst.q_scaled[c];
                        }
                        let un = crate::tensor::norm2(&u).max(1e-6);
                        let kn = crate::tensor::norm2(inst.k.row(ni));
                        for c in 0..d_ {
                            let cur = inst.k.get(ni, c);
                            inst.k.set(ni, c, cur + 4.0 * kn * u[c] / un);
                        }
                    }
                }
                if setup_a {
                    // Setup A: dense question processing has already
                    // surfaced the needle — emulate by copying the
                    // needle's KV into the kept window region.
                    let logits = crate::attention::logits_all(&inst.k, &inst.q_scaled);
                    let ni = (0..inst.k.rows)
                        .max_by(|&a, &b| logits[a].partial_cmp(&logits[b]).unwrap())
                        .unwrap();
                    let last = inst.k.rows - 4;
                    let krow = inst.k.row(ni).to_vec();
                    let vrow = inst.v.row(ni).to_vec();
                    inst.k.row_mut(last).copy_from_slice(&krow);
                    inst.v.row_mut(last).copy_from_slice(&vrow);
                }
                let mut pol = MagicPigPolicy::new(8, 75, seed.wrapping_add(tr as u64));
                pol.simple_lsh = simple;
                pol.sink = SizeSpec::Abs(128);
                pol.window = SizeSpec::Abs(128);
                let mut fork = rng.fork(500 + tr as u64);
                let mut ctx = PolicyCtx {
                    k: &inst.k,
                    v: &inst.v,
                    q_scaled: &inst.q_scaled,
                    rng: &mut fork,
                    step: 0,
                };
                let sel = pol.select(&mut ctx);
                let approx = sparse_sdpa(&inst.k, &inst.v, &inst.q_scaled, &sel);
                let _dense = dense_sdpa(&inst.k, &inst.v, &inst.q_scaled);
                acc += inst.score(&approx);
            }
            let q = acc / trials as f64 * 100.0;
            row.push(f(q, 1));
            scores.push(q);
        }
        t.row(row);
        json_rows.push(
            Json::obj()
                .field("setup", Json::str(label))
                .field("scores", Json::arr_f64(scores)),
        );
    }

    let mut out = t.render();
    out.push_str(
        "\npaper Table 10: MagicPig scores 100/98/98 under setup A but collapses\n\
         (e.g. 46/12) under setup B on multikey tasks — dense question\n\
         processing masks retrieval failures. Expect A-rows >> B-rows here.\n",
    );
    let json = Json::obj()
        .field("experiment", Json::str("table10"))
        .field("rows", Json::Arr(json_rows));
    write_results("table10", &out, &json);
    out
}
