//! Fig. 2 — the motivation study: attention-score coverage and the
//! quality–efficiency trade-off of oracle-top / random-sample / MagicPig
//! / the top+sample hybrid across score-distribution regimes.
//!
//! Paper setup: a GSM-Infinite sample of length 25K, three head regimes
//! (sharp / intermediate / flat). Expected shape: oracle-top wins when
//! mass is concentrated, random sampling wins on flat tails, MagicPig is
//! inconsistent, and the hybrid is consistently near the best — the
//! observation vAttention builds on.

use super::common::*;
use crate::metrics::{f, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{distributions::coverage_count, synthesize_head, ScoreProfile};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 8192);
    let d = args.get_usize("d", 32);
    let trials = args.get_usize("trials", 4);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    let regimes: [(&str, ScoreProfile); 3] = [
        ("sharp", ScoreProfile::Sharp { heavy: 16, boost: 8.0 }),
        ("power-law", ScoreProfile::PowerLaw { alpha: 1.2 }),
        ("flat", ScoreProfile::Flat),
    ];
    let methods = ["oracle-top-k", "random-sample", "magicpig", "hybrid"];
    let budgets = [0.01, 0.02, 0.05, 0.10, 0.20];

    let mut out = String::new();
    let mut json_regimes = Vec::new();

    // ── top pane: coverage counts ──
    let mut cov_table = Table::new(
        "Fig 2 (top): tokens needed for p-coverage of attention mass",
        &["regime", "p=0.5", "p=0.7", "p=0.9", "p=0.99"],
    );
    let mut heads = Vec::new();
    for (name, profile) in regimes.iter() {
        let head = synthesize_head(n, d, *profile, &mut rng);
        let scores = crate::attention::attention_scores(&head.k, &head.q_scaled);
        cov_table.row(vec![
            name.to_string(),
            coverage_count(&scores, 0.5).to_string(),
            coverage_count(&scores, 0.7).to_string(),
            coverage_count(&scores, 0.9).to_string(),
            coverage_count(&scores, 0.99).to_string(),
        ]);
        heads.push((name, head));
    }
    out.push_str(&cov_table.render());
    out.push('\n');

    // ── bottom pane: relative error vs budget per regime ──
    for (name, head) in &heads {
        let mut t = Table::new(
            &format!("Fig 2 (bottom): rel. attention error vs density — {name} head"),
            &["method", "2%", "5%", "10%", "20%", "best@10%"],
        );
        let mut json_methods = Vec::new();
        let mut best_at_10 = ("-", f64::INFINITY);
        let mut rows: Vec<(&str, Vec<EvalPoint>)> = Vec::new();
        for m in methods {
            let mut pts = Vec::new();
            for &b in &budgets {
                // MagicPig's knob is its (K, L) grid index: pick the grid
                // point whose retrieved density is closest to b, matching
                // the paper's best-configuration protocol.
                // Fig. 2 uses the *pure* estimators (no sink/window
                // anchors) — the §3 ablation isolates the selection
                // mechanisms themselves.
                let pt = if m == "magicpig" {
                    let mut best: Option<EvalPoint> = None;
                    for knob in knob_sweep("magicpig") {
                        let grid = [(12, 16), (10, 16), (8, 16), (8, 32), (6, 32), (6, 64), (4, 64), (4, 128)];
                        let (kb, lt) = grid[(knob as usize).min(grid.len() - 1)];
                        let mut pol = crate::policies::MagicPigPolicy::new(kb, lt, seed);
                        pol.sink = crate::policies::SizeSpec::Abs(0);
                        pol.window = crate::policies::SizeSpec::Abs(0);
                        let mut p = eval_head(&mut pol, head, trials, &mut rng);
                        // constrain to roughly the target density
                        if (p.density - b).abs() > 0.75 * b {
                            continue;
                        }
                        if best.map(|bb| p.err < bb.err).unwrap_or(true) {
                            p.density = b;
                            best = Some(p);
                        }
                    }
                    best.unwrap_or(EvalPoint { density: b, err: f64::NAN, quality: f64::NAN })
                } else {
                    let mut pol: Box<dyn crate::policies::IndexPolicy> = match m {
                        "oracle-top-k" => Box::new(crate::policies::OracleTopKPolicy {
                            sink: crate::policies::SizeSpec::Abs(0),
                            window: crate::policies::SizeSpec::Abs(0),
                            heavy: crate::policies::SizeSpec::Frac(b),
                        }),
                        "random-sample" => Box::new(crate::policies::RandomSamplePolicy::pure(b)),
                        "hybrid" => Box::new(crate::policies::HybridTopSamplePolicy::new(b)),
                        _ => make_policy(m, b, seed),
                    };
                    eval_head(pol.as_mut(), head, trials, &mut rng)
                };
                pts.push(pt);
            }
            if pts[2].err < best_at_10.1 {
                best_at_10 = (m, pts[2].err);
            }
            rows.push((m, pts));
        }
        for (m, pts) in &rows {
            t.row(vec![
                m.to_string(),
                f(pts[0].err, 4),
                f(pts[1].err, 4),
                f(pts[2].err, 4),
                f(pts[3].err, 4),
                if *m == best_at_10.0 { "<BEST".into() } else { "".into() },
            ]);
            json_methods.push(
                Json::obj()
                    .field("method", Json::str(*m))
                    .field("errors", Json::arr_f64(pts.iter().map(|p| p.err)))
                    .field("densities", Json::arr_f64(pts.iter().map(|p| p.density))),
            );
        }
        out.push_str(&t.render());
        out.push('\n');
        json_regimes.push(
            Json::obj()
                .field("regime", Json::str(**name))
                .field("methods", Json::Arr(json_methods)),
        );
    }

    let json = Json::obj()
        .field("experiment", Json::str("fig2"))
        .field("n", Json::num(n as f64))
        .field("regimes", Json::Arr(json_regimes));
    write_results("fig2", &out, &json);
    out
}
