//! Table 1 (and Tables 4/5/7/8 detail) — RULER32K-HARD at 10% sparsity
//! across three model regimes.
//!
//! The three base models are emulated as *sharpness regimes* of the task
//! generator (DESIGN.md §3): Llama-like (sharp logit separation),
//! DeepSeek-distill-like (intermediate), Mistral-like (flat). Expected
//! ordering per column: SDPA ≥ vAttention(oracle) ≥ oracle-top-k, and
//! vAttention(HAT) recovering most of HAT's gap to full attention.

use super::common::*;
use crate::metrics::{f, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workloads::TaskKind;

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 4096);
    let d = args.get_usize("d", 48);
    let trials = args.get_usize("trials", 10);
    let seed = args.get_u64("seed", 42);
    let detail = args.has_flag("detail");

    // (name, sharpness) regimes standing in for the three models.
    let regimes: [(&str, f32); 3] =
        [("llama-like", 1.0), ("dpsk-like", 0.85), ("mistral-like", 0.7)];

    // Method → (name, knob targeting ~10% density).
    let methods: [(&str, &str, f64); 5] = [
        ("SDPA", "oracle-top-p", 0.999999),
        ("oracle-top-k", "oracle-top-k", 0.10),
        ("vAttention(oracle-top-k)", "vattention-oracle", 0.025),
        ("HAT", "hashattention", 0.10),
        ("vAttention(HAT)", "vattention-hat", 0.025),
    ];

    let suite = TaskKind::hard_suite();
    let mut out = String::new();
    let mut json_rows = Vec::new();

    let mut t = Table::new(
        "Table 1: RULER-HARD proxy average @ ~10% density",
        &["method", regimes[0].0, regimes[1].0, regimes[2].0],
    );
    let mut detail_tables: Vec<Table> = regimes
        .iter()
        .map(|(rn, _)| {
            let mut hdr: Vec<&str> = vec!["method"];
            hdr.extend(suite.iter().map(|k| k.name()));
            Table::new(&format!("Table 7/8-style detail — {rn}"), &hdr)
        })
        .collect();

    for (label, method, knob) in methods {
        let mut cells = vec![label.to_string()];
        let mut per_regime = Vec::new();
        for (ri, (_, sharp)) in regimes.iter().enumerate() {
            let mut scores = Vec::new();
            for &kind in &suite {
                let pt = eval_task(&|| make_policy(method, knob, seed), kind, n, d, *sharp, trials, seed);
                scores.push(pt.quality);
            }
            let avg = scores.iter().sum::<f64>() / scores.len() as f64;
            cells.push(f(avg, 2));
            per_regime.push(avg);
            if detail {
                let mut row = vec![label.to_string()];
                row.extend(scores.iter().map(|&s| f(s, 1)));
                detail_tables[ri].row(row);
            }
        }
        t.row(cells);
        json_rows.push(
            Json::obj()
                .field("method", Json::str(label))
                .field("scores", Json::arr_f64(per_regime)),
        );
    }

    out.push_str(&t.render());
    out.push_str(
        "\npaper Table 1 (Llama/Dpsk/Mistral): SDPA 88.7/65.4/64.1, oracle-top-k\n\
         87.2/64.9/64.4, vAtt(oracle) 88.6/65.2/64.1, HAT 81.9/60.7/54.7,\n\
         vAtt(HAT) 86.6/65.1/56.9 — expect the same ordering & gap closure.\n\n",
    );
    if detail {
        for dt in detail_tables {
            out.push_str(&dt.render());
            out.push('\n');
        }
    }

    let json = Json::obj()
        .field("experiment", Json::str("table1"))
        .field("rows", Json::Arr(json_rows));
    write_results("table1", &out, &json);
    out
}
