//! Table 12 — the wider baseline × density grid: DoubleSparsity,
//! MagicPig, OracleTopK, OracleTopP, PQCache, vAttention(OracleTopK) at
//! densities {2%, 5%, 10%, 20%} across model regimes.

use super::common::*;
use crate::metrics::{f, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workloads::TaskKind;

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 4096);
    let d = args.get_usize("d", 48);
    let trials = args.get_usize("trials", 6);
    let seed = args.get_u64("seed", 42);

    // Regimes standing in for the model zoo (capability via sharpness).
    let regimes: [(&str, f32); 3] =
        [("qwen-like (sharp)", 1.1), ("llama8b-like", 1.0), ("llama1b-like (weak)", 0.6)];
    let suite = [TaskKind::NiahSingle, TaskKind::NiahMultikey2, TaskKind::Qa1, TaskKind::Fwe];
    let densities = [0.02, 0.05, 0.10, 0.20];

    // method → knob at each target density
    let configs: Vec<(&str, &str, [f64; 4])> = vec![
        ("DoubleSparsity", "double-sparsity", [0.02, 0.05, 0.10, 0.20]),
        ("MagicPig", "magicpig", [0.0, 1.0, 3.0, 5.0]),
        ("OracleTopK", "oracle-top-k", [0.02, 0.05, 0.10, 0.20]),
        ("OracleTopP", "oracle-top-p", [0.6, 0.8, 0.9, 0.97]),
        ("PQCache", "pqcache", [0.02, 0.05, 0.10, 0.20]),
        ("vAttention(OracleTopK)", "vattention-oracle", [0.2, 0.1, 0.05, 0.02]),
    ];

    let mut out = String::new();
    let mut json_regimes = Vec::new();
    for (regime, sharp) in regimes {
        let mut t = Table::new(
            &format!("Table 12 — {regime}"),
            &["method", "2%", "5%", "10%", "20%", "dense"],
        );
        // dense reference
        let dense = {
            let mut acc = 0.0;
            for &kind in &suite {
                acc += eval_task(&|| make_policy("oracle-top-p", 0.999999, seed), kind, n, d, sharp, trials, seed).quality;
            }
            acc / suite.len() as f64
        };
        let mut json_rows = Vec::new();
        for (label, method, knobs) in &configs {
            let mut cells = vec![label.to_string()];
            let mut vals = Vec::new();
            for (di, &knob) in knobs.iter().enumerate() {
                let _ = densities[di];
                let mut acc = 0.0;
                for &kind in &suite {
                    acc += eval_task(&|| make_policy(method, knob, seed), kind, n, d, sharp, trials, seed).quality;
                }
                let v = acc / suite.len() as f64;
                cells.push(f(v, 1));
                vals.push(v);
            }
            cells.push("-".into());
            t.row(cells);
            json_rows.push(
                Json::obj()
                    .field("method", Json::str(*label))
                    .field("scores", Json::arr_f64(vals)),
            );
        }
        t.row(vec!["dense".into(), "-".into(), "-".into(), "-".into(), "-".into(), f(dense, 1)]);
        out.push_str(&t.render());
        out.push('\n');
        json_regimes.push(
            Json::obj()
                .field("regime", Json::str(regime))
                .field("dense", Json::num(dense))
                .field("rows", Json::Arr(json_rows)),
        );
    }
    out.push_str(
        "paper Table 12: vAttention(OracleTopK) ~= dense at every density while\n\
         DoubleSparsity/MagicPig collapse at low density; OracleTopP strong but\n\
         needs more tokens. Expect the same ordering.\n",
    );
    let json = Json::obj()
        .field("experiment", Json::str("table12"))
        .field("regimes", Json::Arr(json_regimes));
    write_results("table12", &out, &json);
    out
}
