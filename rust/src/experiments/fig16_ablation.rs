//! Figs. 16/17 + Fig. 10 + App. F — (ε, δ) ablations for the verified
//! denominator-only and numerator-only recipes: density and layer error
//! across the grid, with the ε↔error correlation per δ, plus the Fig. 10
//! denominator-only quality check on QA tasks.

use super::common::*;
use crate::budget::Verify;
use crate::metrics::{f, pearson, Table};
use crate::policies::VAttentionPolicy;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{synthesize_head, ScoreProfile, TaskKind};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 8192);
    let d = args.get_usize("d", 32);
    let trials = args.get_usize("trials", 4);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    let eps_grid = [0.025, 0.05, 0.1, 0.2, 0.3];
    let delta_grid = [0.05, 0.1, 0.2];
    // Shallow-tail head: the residual carries real mass, so (ε, δ)
    // actually govern the budget (on sharply-dominated heads the
    // guarantee is free at every ε and the grid is flat — cf. fig1-corr).
    let head = synthesize_head(n, d, ScoreProfile::PowerLaw { alpha: 0.3 }, &mut rng);

    let mut out = String::new();
    let mut json_parts = Vec::new();
    for (verify, label, fig) in [
        (Verify::Denominator, "denominator-verified", "Fig 16"),
        (Verify::Numerator, "numerator-verified", "Fig 17"),
    ] {
        let mut t = Table::new(
            &format!("{fig}: {label} — density / layer error over (eps, delta)"),
            &["eps", "delta", "density", "layer err"],
        );
        let mut json_rows = Vec::new();
        let mut corr_per_delta = Vec::new();
        for &delta in &delta_grid {
            let mut errs = Vec::new();
            for &eps in &eps_grid {
                let mut cfg = vcfg(eps);
                cfg.delta = delta;
                cfg.verify = verify;
                cfg.sink = crate::policies::SizeSpec::Abs(64);
                cfg.window = crate::policies::SizeSpec::Abs(64);
                cfg.heavy = crate::policies::SizeSpec::Frac(0.01);
                cfg.base_rate = 0.05;
                cfg.floor_at_base = false; // as in App. F plots
                let mut pol = VAttentionPolicy::oracle(cfg);
                let pt = eval_head(&mut pol, &head, trials, &mut rng);
                t.row(vec![f(eps, 3), f(delta, 2), f(pt.density, 3), f(pt.err, 4)]);
                errs.push(pt.err);
                json_rows.push(
                    Json::obj()
                        .field("eps", Json::num(eps))
                        .field("delta", Json::num(delta))
                        .field("density", Json::num(pt.density))
                        .field("error", Json::num(pt.err)),
                );
            }
            let r = pearson(&eps_grid.to_vec(), &errs);
            corr_per_delta.push((delta, r));
        }
        out.push_str(&t.render());
        for (delta, r) in &corr_per_delta {
            out.push_str(&format!("  corr(eps, err) at delta={delta}: r={r:.3}\n"));
        }
        out.push('\n');
        json_parts.push(
            Json::obj()
                .field("mode", Json::str(label))
                .field("rows", Json::Arr(json_rows))
                .field(
                    "correlations",
                    Json::arr(corr_per_delta.iter().map(|(dl, r)| {
                        Json::obj().field("delta", Json::num(*dl)).field("r", Json::num(*r))
                    })),
                ),
        );
    }

    // ── Fig. 10: denominator-only quality on QA tasks ──
    let mut t = Table::new(
        "Fig 10: denominator-only guarantee — quality on QA proxies",
        &["eps", "density", "quality%", "layer err"],
    );
    let mut json_f10 = Vec::new();
    for &eps in &eps_grid {
        let (mut den, mut q, mut e) = (0.0, 0.0, 0.0);
        for kind in [TaskKind::Qa1, TaskKind::Qa2] {
            let pt = eval_task(
                &|| {
                    let mut cfg = vcfg(eps);
                    cfg.verify = Verify::Denominator;
                    Box::new(VAttentionPolicy::oracle(cfg))
                },
                kind,
                4096,
                48,
                1.0,
                trials.max(6),
                seed,
            );
            den += pt.density / 2.0;
            q += pt.quality / 2.0;
            e += pt.err / 2.0;
        }
        t.row(vec![f(eps, 3), f(den, 3), f(q, 1), f(e, 4)]);
        json_f10.push(
            Json::obj()
                .field("eps", Json::num(eps))
                .field("density", Json::num(den))
                .field("quality", Json::num(q))
                .field("error", Json::num(e)),
        );
    }
    out.push_str(&t.render());
    out.push_str(
        "\npaper Figs 10/16/17: strong (near-linear) eps-error correlation for\n\
         reasonable delta; density spans a wide range; numerator mode needs\n\
         larger eps (guarantee lives in d dimensions).\n",
    );

    let json = Json::obj()
        .field("experiment", Json::str("fig16_ablation"))
        .field("modes", Json::Arr(json_parts))
        .field("fig10", Json::Arr(json_f10));
    write_results("fig16_ablation", &out, &json);
    out
}
