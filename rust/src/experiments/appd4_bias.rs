//! App. D.4 — why unbiasedness matters: error propagation through depth
//! modeled as an n-step walk. With per-step bias μ and noise σ the MSE
//! grows as n²μ² + nσ² — bias compounds quadratically, variance
//! linearly. We simulate both and fit the exponents.

use super::common::write_results;
use crate::metrics::{f, mean, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;

pub fn run(args: &Args) -> String {
    let trials = args.get_usize("trials", 4000);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    let depths = [1usize, 2, 4, 8, 16, 32];
    let eps = 0.05;

    let mut t = Table::new(
        "App D.4: MSE growth over depth — all-bias vs all-variance errors",
        &["depth", "MSE (bias)", "MSE (variance)", "ratio"],
    );
    let mut bias_mse = Vec::new();
    let mut var_mse = Vec::new();
    for &n in &depths {
        // all-bias: each step adds +eps
        let mb = (n as f64 * eps).powi(2);
        // all-variance: each step adds ±eps with mean 0 (simulated)
        let mut sq = Vec::with_capacity(trials);
        for _ in 0..trials {
            let mut s = 0.0f64;
            for _ in 0..n {
                s += if rng.f64() < 0.5 { eps } else { -eps };
            }
            sq.push(s * s);
        }
        let mv = mean(&sq);
        t.row(vec![n.to_string(), f(mb, 5), f(mv, 5), f(mb / mv, 1)]);
        bias_mse.push(mb);
        var_mse.push(mv);
    }
    // growth exponents from log-log endpoints
    let slope = |ys: &[f64]| {
        ((ys[ys.len() - 1] / ys[0]).ln()) / ((depths[depths.len() - 1] as f64 / depths[0] as f64).ln())
    };
    let sb = slope(&bias_mse);
    let sv = slope(&var_mse);

    let mut out = t.render();
    out.push_str(&format!(
        "\nfitted growth exponents: bias {sb:.2} (theory 2), variance {sv:.2} (theory 1)\n\
         => unbiased sampling (vAttention) compounds errors linearly; biased\n\
         truncation (top-k) compounds quadratically with depth.\n",
    ));
    let json = Json::obj()
        .field("experiment", Json::str("appd4_bias"))
        .field("depths", Json::arr_f64(depths.iter().map(|&d| d as f64)))
        .field("bias_mse", Json::arr_f64(bias_mse))
        .field("variance_mse", Json::arr_f64(var_mse))
        .field("bias_exponent", Json::num(sb))
        .field("variance_exponent", Json::num(sv));
    write_results("appd4_bias", &out, &json);
    out
}
