//! Fig. 19 + App. I — parameter sensitivity: starting from the natural
//! config (sink=window=128, f_t=f_b=0.05, ε=δ=0.05), vary one parameter
//! at a time and trace (density, layer error). Expected: zero sink or
//! window is catastrophic; small-but-nonzero values are stable; ε/δ
//! trace out the error-density curve.

use super::common::*;
use crate::metrics::{f, Table};
use crate::policies::{SizeSpec, VAttentionPolicy};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{synthesize_head, ScoreProfile};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 8192);
    let d = args.get_usize("d", 32);
    let trials = args.get_usize("trials", 4);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    // A head with genuine sink/local structure so removing them hurts:
    // sinks = first tokens with elevated logits, window = recent tokens
    // with elevated logits.
    let mut head = synthesize_head(n, d, ScoreProfile::Mixed { heavy: 10, boost: 5.5, alpha: 0.8 }, &mut rng);
    for i in 0..4 {
        boost_token(&mut head, i, 6.0);
    }
    for i in n - 48..n {
        boost_token(&mut head, i, 4.0);
    }

    let sweeps: Vec<(&str, Vec<f64>)> = vec![
        ("sink_size", vec![0.0, 2.0, 8.0, 32.0, 128.0]),
        ("window_size", vec![0.0, 8.0, 64.0, 128.0]),
        ("heavy_size", vec![0.0, 0.005, 0.025, 0.05, 0.1]),
        ("base_rate", vec![0.005, 0.01, 0.025, 0.05, 0.1]),
        ("epsilon", vec![0.025, 0.05, 0.1, 0.2, 0.4]),
        ("delta", vec![0.025, 0.05, 0.1, 0.2, 0.4]),
    ];

    let mut out = String::new();
    let mut json_sweeps = Vec::new();
    for (param, values) in sweeps {
        let mut t = Table::new(
            &format!("Fig 19 sensitivity — varying {param}"),
            &["value", "density", "layer err"],
        );
        let mut json_rows = Vec::new();
        for &val in &values {
            let mut cfg = vcfg(0.05);
            cfg.heavy = SizeSpec::Frac(0.05);
            cfg.base_rate = 0.05;
            match param {
                "sink_size" => cfg.sink = SizeSpec::Abs(val as usize),
                "window_size" => cfg.window = SizeSpec::Abs(val as usize),
                "heavy_size" => cfg.heavy = SizeSpec::Frac(val),
                "base_rate" => cfg.base_rate = val.max(1e-4),
                "epsilon" => cfg.eps = val,
                "delta" => cfg.delta = val,
                _ => unreachable!(),
            }
            let mut pol = VAttentionPolicy::oracle(cfg);
            let pt = eval_head(&mut pol, &head, trials, &mut rng);
            t.row(vec![f(val, 3), f(pt.density, 3), f(pt.err, 4)]);
            json_rows.push(
                Json::obj()
                    .field("value", Json::num(val))
                    .field("density", Json::num(pt.density))
                    .field("error", Json::num(pt.err)),
            );
        }
        out.push_str(&t.render());
        out.push('\n');
        json_sweeps.push(
            Json::obj()
                .field("param", Json::str(param))
                .field("rows", Json::Arr(json_rows)),
        );
    }
    out.push_str(
        "paper Fig 19: sink >= 2 and window >= 64 stable; zero sink/window blows\n\
         up the error; base rate >= 0.025 and heavy >= 0.025 stable; eps/delta\n\
         move the operating point along the error-density curve.\n",
    );
    let json = Json::obj()
        .field("experiment", Json::str("fig19_sensitivity"))
        .field("sweeps", Json::Arr(json_sweeps));
    write_results("fig19_sensitivity", &out, &json);
    out
}

/// Raise token i's logit by `boost` (in-place key edit along q).
fn boost_token(head: &mut crate::workloads::HeadSample, i: usize, boost: f32) {
    let q = head.q_scaled.clone();
    for (c, &qc) in q.iter().enumerate() {
        let cur = head.k.get(i, c);
        head.k.set(i, c, cur + boost * qc);
    }
}
