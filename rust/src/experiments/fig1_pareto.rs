//! Fig. 1 (middle) / Fig. 4 / Figs. 6–7 — the Pareto study: quality and
//! attention error vs density for every method, per task family, plus
//! the benchmark-mix aggregate.
//!
//! Expected shape (paper): vAttention(oracle) dominates, beating even
//! oracle top-p at matched density; vAttention(HAT) lifts HashAttention
//! substantially; plain top-k methods trail on the aggregation tasks.

use super::common::*;
use crate::metrics::{f, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::workloads::TaskKind;

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 4096);
    let d = args.get_usize("d", 48);
    let trials = args.get_usize("trials", 8);
    let seed = args.get_u64("seed", 42);
    let quick = args.has_flag("quick");

    // Task families standing in for the benchmark suites (DESIGN.md §3):
    // RULER-needle ≈ retrieval; RULER-aggregate ≈ vt/fwe; LongBench-QA ≈ qa.
    let families: Vec<(&str, Vec<TaskKind>)> = vec![
        ("ruler-needle", vec![TaskKind::NiahSingle, TaskKind::NiahMultikey2, TaskKind::NiahMultivalue]),
        ("ruler-aggregate", vec![TaskKind::Vt, TaskKind::Fwe]),
        ("qa-mix", vec![TaskKind::Qa1, TaskKind::Qa2]),
    ];
    let methods: Vec<&str> = if quick {
        vec!["oracle-top-k", "oracle-top-p", "vattention-oracle"]
    } else {
        vec![
            "oracle-top-k",
            "oracle-top-p",
            "hashattention",
            "magicpig",
            "vattention-oracle",
            "vattention-hat",
        ]
    };

    let mut out = String::new();
    let mut json_fams = Vec::new();
    for (fam, kinds) in &families {
        let mut t = Table::new(
            &format!("Fig 1/4 Pareto — {fam}: (density → quality%, error)"),
            &["method", "knob", "density", "quality%", "rel-err"],
        );
        let mut json_methods = Vec::new();
        for m in &methods {
            let mut curve = Vec::new();
            for knob in knob_sweep(m) {
                // average the family's tasks at this knob
                let (mut den, mut qual, mut err) = (0.0, 0.0, 0.0);
                for &kind in kinds {
                    let pt = eval_task(
                        &|| make_policy(m, knob, seed),
                        kind,
                        n,
                        d,
                        1.0,
                        trials,
                        seed,
                    );
                    den += pt.density;
                    qual += pt.quality;
                    err += pt.err;
                }
                let kf = kinds.len() as f64;
                let pt = EvalPoint { density: den / kf, quality: qual / kf, err: err / kf };
                t.row(vec![
                    m.to_string(),
                    f(knob, 3),
                    f(pt.density, 3),
                    f(pt.quality, 1),
                    f(pt.err, 4),
                ]);
                curve.push(pt);
            }
            json_methods.push(
                Json::obj()
                    .field("method", Json::str(*m))
                    .field("density", Json::arr_f64(curve.iter().map(|p| p.density)))
                    .field("quality", Json::arr_f64(curve.iter().map(|p| p.quality)))
                    .field("error", Json::arr_f64(curve.iter().map(|p| p.err))),
            );
        }
        out.push_str(&t.render());
        out.push('\n');
        json_fams.push(
            Json::obj()
                .field("family", Json::str(*fam))
                .field("methods", Json::Arr(json_methods)),
        );
    }

    let json = Json::obj()
        .field("experiment", Json::str("fig1_pareto"))
        .field("families", Json::Arr(json_fams));
    write_results("fig1_pareto", &out, &json);
    out
}
