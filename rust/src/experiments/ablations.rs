//! Design-choice ablations called out in DESIGN.md §7 (beyond the
//! paper's own figures):
//!
//! A1 — base-floor: lower-capping the adaptive budget at the base-sample
//!      size (the paper's experimental protocol) vs the raw bound.
//! A2 — bound: CLT vs Hoeffding end-to-end (density + error + quality),
//!      not just budget sizes (Figs. 11–15 measure budgets only).
//! A3 — hybrid split: the §3 oracle-top+sample simplification as a
//!      function of its top-fraction, showing why vAttention's *adaptive*
//!      split beats any fixed one.

use super::common::*;
use crate::budget::Bound;
use crate::metrics::{f, Table};
use crate::policies::{HybridTopSamplePolicy, IndexPolicy, VAttentionPolicy};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{synthesize_head, ScoreProfile, TaskKind};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 8192);
    let d = args.get_usize("d", 32);
    let trials = args.get_usize("trials", 5);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    let mut out = String::new();
    let mut json_parts = Vec::new();

    // ── A1: budget floor ──
    let head = synthesize_head(n, d, ScoreProfile::PowerLaw { alpha: 0.5 }, &mut rng);
    let mut t = Table::new(
        "Ablation A1 — flooring the budget at the base-sample size",
        &["eps", "floor", "density", "layer err"],
    );
    let mut a1 = Vec::new();
    for &eps in &[0.05, 0.1, 0.2, 0.4] {
        for floor in [true, false] {
            let mut cfg = vcfg(eps);
            cfg.floor_at_base = floor;
            let mut pol = VAttentionPolicy::oracle(cfg);
            let pt = eval_head(&mut pol, &head, trials, &mut rng);
            t.row(vec![f(eps, 2), floor.to_string(), f(pt.density, 3), f(pt.err, 4)]);
            a1.push(
                Json::obj()
                    .field("eps", Json::num(eps))
                    .field("floor", Json::Bool(floor))
                    .field("density", Json::num(pt.density))
                    .field("error", Json::num(pt.err)),
            );
        }
    }
    out.push_str(&t.render());
    out.push_str("-> the floor bounds worst-case error at large eps for ~zero density cost at small eps\n\n");
    json_parts.push(Json::obj().field("a1_floor", Json::Arr(a1)));

    // ── A2: CLT vs Hoeffding end-to-end ──
    let mut t = Table::new(
        "Ablation A2 — CLT vs Hoeffding, end-to-end on QA tasks",
        &["bound", "eps", "density", "quality%", "layer err"],
    );
    let mut a2 = Vec::new();
    for bound in [Bound::Clt, Bound::Hoeffding] {
        for &eps in &[0.05, 0.2] {
            let pt = eval_task(
                &|| {
                    let mut cfg = vcfg(eps);
                    cfg.bound = bound;
                    Box::new(VAttentionPolicy::oracle(cfg)) as Box<dyn IndexPolicy>
                },
                TaskKind::Qa1,
                4096,
                48,
                1.0,
                trials.max(8),
                seed,
            );
            t.row(vec![
                format!("{bound:?}"),
                f(eps, 2),
                f(pt.density, 3),
                f(pt.quality, 1),
                f(pt.err, 4),
            ]);
            a2.push(
                Json::obj()
                    .field("bound", Json::str(format!("{bound:?}")))
                    .field("eps", Json::num(eps))
                    .field("density", Json::num(pt.density))
                    .field("quality", Json::num(pt.quality)),
            );
        }
    }
    out.push_str(&t.render());
    out.push_str("-> Hoeffding buys ~0 extra quality at much higher density: CLT is the right default\n\n");
    json_parts.push(Json::obj().field("a2_bound", Json::Arr(a2)));

    // ── A3: hybrid top-fraction ──
    let mut t = Table::new(
        "Ablation A3 — fixed top/sample split (10% budget) vs vAttention",
        &["top fraction", "sharp err", "flat err"],
    );
    let sharp = synthesize_head(n, d, ScoreProfile::Sharp { heavy: 16, boost: 8.0 }, &mut rng);
    let flat = synthesize_head(n, d, ScoreProfile::Flat, &mut rng);
    let mut a3 = Vec::new();
    for &frac in &[0.0, 0.25, 0.5, 0.75, 1.0] {
        let mut pol = HybridTopSamplePolicy::new(0.10);
        pol.top_fraction = frac;
        let e_sharp = eval_head(&mut pol, &sharp, trials, &mut rng).err;
        let mut pol = HybridTopSamplePolicy::new(0.10);
        pol.top_fraction = frac;
        let e_flat = eval_head(&mut pol, &flat, trials, &mut rng).err;
        t.row(vec![f(frac, 2), f(e_sharp, 4), f(e_flat, 4)]);
        a3.push(
            Json::obj()
                .field("top_fraction", Json::num(frac))
                .field("sharp_err", Json::num(e_sharp))
                .field("flat_err", Json::num(e_flat)),
        );
    }
    // vAttention reference rows (adaptive split)
    let mut cfg = vcfg(0.1);
    cfg.floor_at_base = true;
    let mut pol = VAttentionPolicy::oracle(cfg.clone());
    let v_sharp = eval_head(&mut pol, &sharp, trials, &mut rng);
    let mut pol = VAttentionPolicy::oracle(cfg);
    let v_flat = eval_head(&mut pol, &flat, trials, &mut rng);
    t.row(vec!["vAttention (adaptive)".into(), f(v_sharp.err, 4), f(v_flat.err, 4)]);
    out.push_str(&t.render());
    out.push_str(
        "-> no fixed split wins both regimes; the adaptive budget matches the\n\
         best split per regime — the core design argument of §4.\n",
    );
    json_parts.push(Json::obj().field("a3_hybrid", Json::Arr(a3)));

    let json = Json::obj()
        .field("experiment", Json::str("ablations"))
        .field("parts", Json::Arr(json_parts));
    write_results("ablations", &out, &json);
    out
}
