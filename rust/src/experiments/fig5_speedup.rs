//! Fig. 5 — decode speedup vs density with a CPU-hosted KV cache.
//!
//! Two measurements:
//!  1. *Measured*: wall-clock of one attention layer over a host-resident
//!     cache on this machine, dense vs density-ρ gathers (memory-bound,
//!     so time ≈ ρ × dense ± selection overhead).
//!  2. *Modeled*: the `sim::DecodeLatencyModel` extrapolation to
//!     Llama-2-7B / Llama-3-8B shapes over a PCIe-class link, the
//!     configuration the paper actually measures.
//! Expected shape: near-linear speedup in 1/ρ at long context.

use super::common::write_results;
use crate::attention::{dense_sdpa, sparse_sdpa, Selection};
use crate::metrics::{f, Table};
use crate::model::ModelConfig;
use crate::sim::DecodeLatencyModel;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::{timer, Rng};
use crate::workloads::{synthesize_head, ScoreProfile};

pub fn run(args: &Args) -> String {
    let d = args.get_usize("d", 128);
    let n = args.get_usize("n", 32_768);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    let densities = [0.02, 0.05, 0.10, 0.20, 0.50, 1.00];

    // ── 1. measured on this host ──
    let head = synthesize_head(n, d, ScoreProfile::PowerLaw { alpha: 1.0 }, &mut rng);
    let mut t1 = Table::new(
        &format!("Fig 5 (measured, this host): single-head attention at n={n}"),
        &["density", "time/step", "speedup"],
    );
    let budget = std::time::Duration::from_millis(300);
    let dense_stats = timer::bench("dense", 1, budget, 3, || {
        dense_sdpa(&head.k, &head.v, &head.q_scaled)
    });
    let mut measured = Vec::new();
    for &rho in &densities {
        let b = ((n as f64 * rho) as usize).max(1);
        let stats = if rho >= 1.0 {
            dense_stats.clone()
        } else {
            let mut fork = rng.fork(b as u64);
            timer::bench(&format!("rho={rho}"), 1, budget, 3, || {
                // selection + gather-read + weighted attention (the full
                // sparse hot path)
                let idx = fork.sample_distinct(n, b);
                let sel = Selection::sampled(idx, rho as f32);
                sparse_sdpa(&head.k, &head.v, &head.q_scaled, &sel)
            })
        };
        let speedup = dense_stats.p50_s / stats.p50_s;
        t1.row(vec![f(rho, 2), timer::fmt_time(stats.p50_s), f(speedup, 2)]);
        measured.push((rho, stats.p50_s, speedup));
    }

    // ── 2. modeled at paper shapes ──
    let mut t2 = Table::new(
        "Fig 5 (modeled, Llama-8B shape over PCIe link): speedup vs density",
        &["context", "rho=0.02", "rho=0.05", "rho=0.10", "rho=0.20"],
    );
    let model = DecodeLatencyModel::for_model(ModelConfig::llama8b_shape());
    let contexts = [8_192usize, 16_384, 32_768, 65_536, 131_072];
    let mut modeled = Vec::new();
    for &ctx in &contexts {
        let row: Vec<f64> = [0.02, 0.05, 0.10, 0.20].iter().map(|&r| model.speedup(ctx, r)).collect();
        t2.row(vec![
            format!("{}K", ctx / 1024),
            f(row[0], 2),
            f(row[1], 2),
            f(row[2], 2),
            f(row[3], 2),
        ]);
        modeled.push((ctx, row));
    }

    let mut out = t1.render();
    out.push('\n');
    out.push_str(&t2.render());
    out.push_str("\npaper: near-linear speedup (10% density → ~8-10x at 128K ctx)\n");

    let json = Json::obj()
        .field("experiment", Json::str("fig5_speedup"))
        .field(
            "measured",
            Json::arr(measured.iter().map(|(r, t, s)| {
                Json::obj()
                    .field("density", Json::num(*r))
                    .field("p50_s", Json::num(*t))
                    .field("speedup", Json::num(*s))
            })),
        )
        .field(
            "modeled",
            Json::arr(modeled.iter().map(|(c, row)| {
                Json::obj()
                    .field("context", Json::num(*c as f64))
                    .field("speedups", Json::arr_f64(row.clone()))
            })),
        );
    write_results("fig5_speedup", &out, &json);
    out
}
