//! Shared harness for the experiment suite: policy construction by name,
//! evaluation loops (attention error on synthetic heads, task accuracy on
//! the RULER proxies), and results-file output.

use crate::attention::{dense_sdpa, sparse_sdpa};
use crate::policies::*;
use crate::tensor::rel_l2_error;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{HeadSample, Task, TaskKind};

/// Where experiment outputs are written.
pub fn results_dir() -> std::path::PathBuf {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("results");
    let _ = std::fs::create_dir_all(&dir);
    dir
}

pub fn write_results(name: &str, text: &str, json: &Json) {
    let dir = results_dir();
    let _ = std::fs::write(dir.join(format!("{name}.txt")), text);
    let _ = std::fs::write(dir.join(format!("{name}.json")), json.to_string());
}

/// A (density, error) or (density, quality) measurement.
#[derive(Clone, Copy, Debug)]
pub struct EvalPoint {
    pub density: f64,
    pub err: f64,
    pub quality: f64,
}

/// Evaluate a policy on one head: relative attention error and density,
/// averaged over `trials` fresh selections.
pub fn eval_head(policy: &mut dyn IndexPolicy, head: &HeadSample, trials: usize, rng: &mut Rng) -> EvalPoint {
    let exact = dense_sdpa(&head.k, &head.v, &head.q_scaled).out;
    let mut err = 0.0;
    let mut den = 0.0;
    for t in 0..trials {
        let mut fork = rng.fork(t as u64);
        let mut ctx = PolicyCtx {
            k: &head.k,
            v: &head.v,
            q_scaled: &head.q_scaled,
            rng: &mut fork,
            step: t,
        };
        let sel = policy.select(&mut ctx);
        den += sel.density(head.k.rows);
        let approx = sparse_sdpa(&head.k, &head.v, &head.q_scaled, &sel);
        err += rel_l2_error(&approx, &exact);
    }
    EvalPoint { density: den / trials as f64, err: err / trials as f64, quality: f64::NAN }
}

/// Evaluate a policy factory on a task: accuracy, mean density, and mean
/// attention error over `trials` instances.
pub fn eval_task(
    factory: &dyn Fn() -> Box<dyn IndexPolicy>,
    kind: TaskKind,
    n: usize,
    d: usize,
    sharpness: f32,
    trials: usize,
    seed: u64,
) -> EvalPoint {
    let mut task = Task::new(kind, n, d);
    task.sharpness = sharpness;
    let mut rng = Rng::new(seed ^ (kind as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut acc = 0.0;
    let mut den = 0.0;
    let mut err = 0.0;
    for t in 0..trials {
        let inst = task.generate(&mut rng.fork(t as u64));
        let exact = dense_sdpa(&inst.k, &inst.v, &inst.q_scaled).out;
        let mut policy = factory();
        let mut fork = rng.fork(1_000_000 + t as u64);
        let mut ctx = PolicyCtx {
            k: &inst.k,
            v: &inst.v,
            q_scaled: &inst.q_scaled,
            rng: &mut fork,
            step: 0,
        };
        let sel = policy.select(&mut ctx);
        den += sel.density(inst.k.rows);
        let approx = sparse_sdpa(&inst.k, &inst.v, &inst.q_scaled, &sel);
        err += rel_l2_error(&approx, &exact);
        acc += inst.score(&approx);
    }
    let tf = trials as f64;
    EvalPoint { density: den / tf, err: err / tf, quality: acc / tf * 100.0 }
}

/// Named policy configurations used across the comparison experiments.
/// `knob` is the method's own quality/efficiency dial.
pub fn make_policy(method: &str, knob: f64, seed: u64) -> Box<dyn IndexPolicy> {
    match method {
        "oracle-top-k" => Box::new(OracleTopKPolicy::with_fraction(knob)),
        "oracle-top-p" => Box::new(OracleTopPPolicy::new(knob)),
        "random-sample" => Box::new(RandomSamplePolicy::with_fraction(knob)),
        "hybrid" => Box::new(HybridTopSamplePolicy::new(knob)),
        "streaming-llm" => Box::new(SinkWindowPolicy::new(128, (knob * 1000.0) as usize)),
        "hashattention" => Box::new(HeavyHitterPolicy::new(
            Box::new(scorers::HashSignScorer::new(32, seed)),
            SizeSpec::Frac(knob),
        )),
        "double-sparsity" => Box::new(HeavyHitterPolicy::new(
            Box::new(scorers::DoubleSparsityScorer { channels: 8 }),
            SizeSpec::Frac(knob),
        )),
        "quest" => Box::new(HeavyHitterPolicy::new(
            Box::new(scorers::QuestScorer::new(16)),
            SizeSpec::Frac(knob),
        )),
        "pqcache" => Box::new(HeavyHitterPolicy::new(
            Box::new(scorers::PqScorer::new(8, 16, seed)),
            SizeSpec::Frac(knob),
        )),
        "infllm" => Box::new(HeavyHitterPolicy::new(
            Box::new(scorers::BlockMeanScorer::new(16)),
            SizeSpec::Frac(knob),
        )),
        "h2o" => Box::new(H2OPolicy::new(SizeSpec::Frac(knob))),
        "snapkv" => Box::new(SnapKvPolicy::new(SizeSpec::Frac(knob), 8)),
        "magicpig" => {
            // knob indexes the (K, L) grid of Table 3 (extended on the
            // sparse end so the density sweep has low-density points).
            let grid =
                [(12, 16), (10, 16), (8, 16), (8, 32), (6, 32), (6, 64), (4, 64), (4, 128)];
            let (k, l) = grid[(knob as usize).min(grid.len() - 1)];
            let mut p = MagicPigPolicy::new(k, l, seed);
            p.max_budget = None;
            Box::new(p)
        }
        "vattention-oracle" => Box::new(VAttentionPolicy::oracle(vcfg(knob))),
        "vattention-hat" => Box::new(VAttentionPolicy::new(
            vcfg(knob),
            Box::new(scorers::HashSignScorer::new(32, seed)),
        )),
        _ => panic!("unknown method '{method}'"),
    }
}

/// vAttention config with ε = δ = knob and the paper's natural fractions,
/// denominator guarantee (the practical default across the evaluation —
/// see Fig. 10 / App. F: numerator guarantees on synthetic mean-plus-noise
/// values need larger ε to leave the saturated regime).
pub fn vcfg(knob: f64) -> VAttentionConfig {
    VAttentionConfig {
        sink: SizeSpec::Abs(128),
        window: SizeSpec::Abs(128),
        heavy: SizeSpec::Frac(0.05),
        base_rate: 0.025,
        eps: knob,
        delta: knob,
        verify: crate::budget::Verify::Denominator,
        bound: crate::budget::Bound::Clt,
        floor_at_base: true,
    }
}

/// The standard knob sweeps per method (densities roughly 2%–25%).
pub fn knob_sweep(method: &str) -> Vec<f64> {
    match method {
        "oracle-top-k" | "hashattention" | "double-sparsity" | "quest" | "pqcache" | "infllm"
        | "h2o" | "snapkv" => vec![0.01, 0.02, 0.05, 0.10, 0.15, 0.20],
        "random-sample" | "hybrid" => vec![0.02, 0.05, 0.10, 0.15, 0.20],
        "oracle-top-p" => vec![0.5, 0.7, 0.8, 0.9, 0.95, 0.99],
        "magicpig" => vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0],
        "vattention-oracle" | "vattention-hat" => vec![0.3, 0.2, 0.1, 0.05, 0.025, 0.01],
        _ => vec![0.05, 0.1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::ScoreProfile;

    #[test]
    fn eval_head_full_policy_zero_error() {
        let mut rng = Rng::new(1);
        let head = crate::workloads::synthesize_head(512, 16, ScoreProfile::Flat, &mut rng);
        let mut pol = OracleTopPPolicy::new(0.999999);
        let pt = eval_head(&mut pol, &head, 2, &mut rng);
        assert!(pt.err < 0.05, "err={}", pt.err);
    }

    #[test]
    fn make_policy_all_methods_construct_and_run() {
        let mut rng = Rng::new(2);
        let head = crate::workloads::synthesize_head(
            600,
            16,
            ScoreProfile::Mixed { heavy: 8, boost: 6.0, alpha: 0.8 },
            &mut rng,
        );
        for m in [
            "oracle-top-k",
            "oracle-top-p",
            "random-sample",
            "hybrid",
            "streaming-llm",
            "hashattention",
            "double-sparsity",
            "quest",
            "pqcache",
            "infllm",
            "h2o",
            "snapkv",
            "magicpig",
            "vattention-oracle",
            "vattention-hat",
        ] {
            let knob = knob_sweep(m)[2.min(knob_sweep(m).len() - 1)];
            let mut pol = make_policy(m, knob, 7);
            let pt = eval_head(pol.as_mut(), &head, 1, &mut rng);
            assert!(pt.density > 0.0 && pt.density <= 1.0, "{m}: density {}", pt.density);
            assert!(pt.err.is_finite(), "{m}: err {}", pt.err);
        }
    }

    #[test]
    fn eval_task_dense_like_policy_scores_high() {
        let pt = eval_task(
            &|| make_policy("oracle-top-p", 0.9999, 1) as Box<dyn IndexPolicy>,
            TaskKind::NiahSingle,
            2048,
            48,
            1.0,
            5,
            3,
        );
        assert!(pt.quality >= 80.0, "quality={}", pt.quality);
    }
}
