//! Fig. 18 + App. H — validity of the CLT assumption: the denominator
//! estimator D̂ across resamples should be normally distributed. We
//! compute the standardized QQ deviation against the normal quantiles
//! and a coarse histogram, at several sampling rates.

use super::common::write_results;
use crate::attention::{weighted_num_den, Selection};
use crate::metrics::{f, histogram, mean, qq_normal_deviation, std, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{synthesize_head, ScoreProfile};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 32_768);
    let d = args.get_usize("d", 32);
    let resamples = args.get_usize("resamples", 400);
    let seed = args.get_u64("seed", 42);
    let mut rng = Rng::new(seed);

    let head = synthesize_head(n, d, ScoreProfile::PowerLaw { alpha: 0.9 }, &mut rng);
    let rates = [0.005, 0.01, 0.02, 0.05];

    // The estimator samples the *residual* population — heavy hitters are
    // removed deterministically first (Algorithm 1). Sampling over the
    // raw cache would mix in the dominant terms and break normality; the
    // paper's QQ plots are over the residual estimator.
    let logits = crate::attention::logits_all(&head.k, &head.q_scaled);
    let mut i_f = crate::policies::sink_window_indices(n, 128, 128);
    let top = crate::policies::top_indices_excluding(&logits, n / 20, &i_f);
    i_f.extend(top);
    i_f.sort_unstable();
    let m_ref = i_f.iter().map(|&i| logits[i]).fold(f32::NEG_INFINITY, f32::max);
    let n_s = n - i_f.len();

    let mut t = Table::new(
        &format!("Fig 18: normality of the D-hat estimator ({resamples} resamples, n={n})"),
        &["sample rate", "mean D-hat", "std", "QQ max dev", "normal?"],
    );
    let mut json_rows = Vec::new();
    for &rate in &rates {
        let b = ((rate * n as f64) as usize).min(n_s);
        let mut estimates = Vec::with_capacity(resamples);
        for t_i in 0..resamples {
            let mut fork = rng.fork(t_i as u64);
            let idx = fork.sample_excluding(n, b, &i_f);
            let sel = Selection::sampled(idx, b as f32 / n_s as f32);
            let (_, d_hat) = weighted_num_den(&head.k, &head.v, &head.q_scaled, &sel, m_ref);
            estimates.push(d_hat);
        }
        let dev = qq_normal_deviation(&estimates);
        let normalish = dev < 0.25;
        t.row(vec![
            f(rate, 3),
            f(mean(&estimates), 1),
            f(std(&estimates), 1),
            f(dev, 3),
            if normalish { "yes".into() } else { "no".into() },
        ]);
        let h = histogram(
            &estimates,
            mean(&estimates) - 4.0 * std(&estimates),
            mean(&estimates) + 4.0 * std(&estimates),
            16,
        );
        json_rows.push(
            Json::obj()
                .field("rate", Json::num(rate))
                .field("qq_max_dev", Json::num(dev))
                .field("histogram", Json::arr(h.into_iter().map(|c| Json::num(c as f64)))),
        );
    }
    let mut out = t.render();
    out.push_str(
        "\npaper Fig 18: histograms + QQ plots show D-hat is very close to normal\n\
         at all sampling rates, validating the CLT budget rule.\n",
    );
    let json = Json::obj()
        .field("experiment", Json::str("fig18_qq"))
        .field("rows", Json::Arr(json_rows));
    write_results("fig18_qq", &out, &json);
    out
}
