//! Table 2 + Figs. 8/9 — long generation "in the wild": run the engine
//! with vAttention at its natural config and verify (a) generation
//! quality matches dense (token agreement as the AIME-accuracy proxy),
//! (b) density adapts per step and stays low, (c) attention error stays
//! bounded as the sequence grows into the thousands of tokens.

use super::common::write_results;
use crate::kvcache::KvCache;
use crate::metrics::{f, mean, Table};
use crate::model::{Model, ModelConfig, Sampler};
use crate::policies::{IndexPolicy, PolicyCtx, SizeSpec, VAttentionPolicy};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;

pub fn run(args: &Args) -> String {
    let steps = args.get_usize("steps", 1200);
    let prompt_len = args.get_usize("prompt", 96);
    let seed = args.get_u64("seed", 42);
    let eps = args.get_f64("eps", 0.05);

    let cfg = ModelConfig::tiny();
    let model = Model::new(cfg.clone(), seed);
    let sampler = Sampler::Greedy;
    let mut rng = Rng::new(seed);
    let prompt: Vec<u32> = (0..prompt_len as u32).map(|t| (t * 31 + 7) % 250).collect();

    // ── dense rollout (reference) ──
    let mut dense_cache = KvCache::new(&cfg);
    let mut dense_tokens = Vec::new();
    let out = model.prefill(&prompt, &mut dense_cache);
    let mut tok = sampler.sample(&out.logits, &mut rng.fork(1));
    for s in 0..steps {
        dense_tokens.push(tok);
        let out = model.decode_step(tok, prompt_len + s, &mut dense_cache, None);
        tok = sampler.sample(&out.logits, &mut rng.fork(2 + s as u64));
    }

    // ── vAttention rollout (natural config, per paper Table 2) ──
    let mut vcfg = super::common::vcfg(eps);
    vcfg.sink = SizeSpec::Abs(128);
    vcfg.window = SizeSpec::Abs(128);
    vcfg.heavy = SizeSpec::Frac(0.025);
    vcfg.base_rate = 0.025;
    let lh = cfg.n_layers * cfg.n_heads;
    let mut policies: Vec<VAttentionPolicy> =
        (0..lh).map(|_| VAttentionPolicy::oracle(vcfg.clone())).collect();
    let mut cache = KvCache::new(&cfg);
    let mut v_tokens = Vec::new();
    let mut densities = Vec::new();
    let mut errors = Vec::new();
    let out = model.prefill(&prompt, &mut cache);
    let mut tok = sampler.sample(&out.logits, &mut rng.fork(1));
    let mut step_rng = Rng::new(seed ^ 0xABCD);
    for s in 0..steps {
        v_tokens.push(tok);
        let n_heads = cfg.n_heads;
        let mut select = |l: usize,
                          h: usize,
                          k: &crate::tensor::Mat,
                          v: &crate::tensor::Mat,
                          q: &[f32],
                          _qb: Option<crate::tensor::quant::KvQuantBounds>| {
            let mut ctx = PolicyCtx { k, v, q_scaled: q, rng: &mut step_rng, step: s };
            policies[l * n_heads + h].select(&mut ctx)
        };
        let out = model.decode_step(tok, prompt_len + s, &mut cache, Some(&mut select));
        densities.push(out.mean_density);
        // Attention-error probe every 100 steps: compare the sparse
        // logits against a dense step on a cloned position.
        if s % 100 == 0 {
            let dense_out = model.decode_step(tok, prompt_len + s, &mut dense_cache_probe(&model, &prompt, &v_tokens), None);
            errors.push(crate::tensor::rel_l2_error(&out.logits, &dense_out.logits));
        }
        tok = sampler.sample(&out.logits, &mut rng.fork(2 + s as u64));
    }

    // Token-agreement "accuracy" proxy + density evolution.
    let agree = dense_tokens.iter().zip(v_tokens.iter()).filter(|(a, b)| a == b).count();
    let agreement = agree as f64 / steps as f64 * 100.0;
    let early = mean(&densities[..steps / 4]);
    let late = mean(&densities[steps - steps / 4..]);

    let mut t = Table::new("Table 2 proxy: long generation with vAttention (natural config)", &["metric", "value"]);
    t.row(vec!["steps".into(), steps.to_string()]);
    t.row(vec!["token agreement vs dense %".into(), f(agreement, 2)]);
    t.row(vec!["mean density (first quarter)".into(), f(early, 3)]);
    t.row(vec!["mean density (last quarter)".into(), f(late, 3)]);
    t.row(vec!["mean logits rel-err (probes)".into(), f(mean(&errors), 4)]);
    let mut out_s = t.render();
    out_s.push_str(
        "\npaper Table 2: vAttention matches dense avg@4 (36.7 vs 36.7) at ~10-15%\n\
         density over 32K-token generations; Fig 8/9: density *decreases* with\n\
         sequence length (fixed sink/window shrink relatively; adaptive budget\n\
         tracks the distribution).\n",
    );

    let json = Json::obj()
        .field("experiment", Json::str("table2_longgen"))
        .field("agreement_pct", Json::num(agreement))
        .field("density", Json::arr_f64(densities.iter().copied().step_by(10)))
        .field("probe_errors", Json::arr_f64(errors.clone()));
    write_results("table2_longgen", &out_s, &json);
    out_s
}

/// Rebuild a dense cache that matches the sparse rollout's token history
/// (probe helper — dense reference for the current prefix).
fn dense_cache_probe(model: &Model, prompt: &[u32], generated: &[u32]) -> KvCache {
    let mut cache = KvCache::new(&model.cfg);
    model.prefill(prompt, &mut cache);
    for (i, &t) in generated[..generated.len().saturating_sub(1)].iter().enumerate() {
        model.decode_step(t, prompt.len() + i, &mut cache, None);
    }
    cache
}
