//! Table 11 + App. G — how large must the base sample be? Estimation
//! error of the denominator variance σ² and the numerator trace Tr(Σ)
//! from base samples at rates {2.5%, 5%, 10%}, on three task types.

use super::common::write_results;
use crate::budget::{draw_base_sample, estimate_stats};
use crate::metrics::{f, mean, Table};
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::Rng;
use crate::workloads::{Task, TaskKind};

pub fn run(args: &Args) -> String {
    let n = args.get_usize("n", 8192);
    let d = args.get_usize("d", 48);
    let trials = args.get_usize("trials", 12);
    let seed = args.get_u64("seed", 42);

    let kinds = [TaskKind::NiahMultikey2, TaskKind::Qa1, TaskKind::Vt];
    let rates = [0.025, 0.05, 0.10];

    let mut out = String::new();
    let mut json_tasks = Vec::new();
    for kind in kinds {
        let mut t = Table::new(
            &format!("Table 11 — base-sample estimation error, task {}", kind.name()),
            &["base rate", "~tokens", "sigma^2 err %", "Tr(Sigma) err %"],
        );
        let task = Task::new(kind, n, d);
        let mut rng = Rng::new(seed ^ kind as u64);
        let mut json_rows = Vec::new();
        for &rate in &rates {
            let mut sig_errs = Vec::new();
            let mut tr_errs = Vec::new();
            let mut tokens = 0usize;
            for tr in 0..trials {
                let inst = task.generate(&mut rng.fork(tr as u64));
                // deterministic set: sink/window 128 + oracle top 5%
                let logits = crate::attention::logits_all(&inst.k, &inst.q_scaled);
                let mut i_f = crate::policies::sink_window_indices(n, 128, 128);
                let top = crate::policies::top_indices_excluding(&logits, n / 20, &i_f);
                i_f.extend(top);
                i_f.sort_unstable();
                let m_ref = i_f
                    .iter()
                    .map(|&i| logits[i])
                    .fold(f32::NEG_INFINITY, f32::max);
                // ground truth over the *full* residual
                let all_res: Vec<usize> = {
                    let set: std::collections::HashSet<usize> = i_f.iter().copied().collect();
                    (0..n).filter(|i| !set.contains(i)).collect()
                };
                let truth = estimate_stats(&inst.k, &inst.v, &inst.q_scaled, &i_f, &all_res, m_ref);
                // estimate from the base sample
                let mut fork = rng.fork(1000 + tr as u64);
                let base = draw_base_sample(n, &i_f, rate, &mut fork);
                tokens = base.len();
                let est = estimate_stats(&inst.k, &inst.v, &inst.q_scaled, &i_f, &base, m_ref);
                if truth.sigma2_d > 1e-12 {
                    sig_errs.push((est.sigma2_d - truth.sigma2_d).abs() / truth.sigma2_d * 100.0);
                }
                if truth.trace_sigma_n > 1e-12 {
                    tr_errs.push(
                        (est.trace_sigma_n - truth.trace_sigma_n).abs() / truth.trace_sigma_n
                            * 100.0,
                    );
                }
            }
            let se = mean(&sig_errs);
            let te = mean(&tr_errs);
            t.row(vec![f(rate, 3), tokens.to_string(), f(se, 2), f(te, 2)]);
            json_rows.push(
                Json::obj()
                    .field("rate", Json::num(rate))
                    .field("sigma2_err_pct", Json::num(se))
                    .field("trace_err_pct", Json::num(te)),
            );
        }
        out.push_str(&t.render());
        out.push('\n');
        json_tasks.push(
            Json::obj()
                .field("task", Json::str(kind.name()))
                .field("rows", Json::Arr(json_rows)),
        );
    }
    out.push_str(
        "paper Table 11: ~3-5% error at 2.5% rate, improving with rate — tiny\n\
         base samples estimate the needed statistics well.\n",
    );
    let json = Json::obj()
        .field("experiment", Json::str("table11"))
        .field("tasks", Json::Arr(json_tasks));
    write_results("table11", &out, &json);
    out
}
