//! The experiment harness: one module per paper table/figure (see the
//! DESIGN.md §7 index), a registry, and the CLI entry point.
//!
//! Every experiment prints the paper-style rows/series and writes
//! `results/<id>.{txt,json}`. Absolute numbers differ from the paper
//! (synthetic substrate — DESIGN.md §3); the *shape* — who wins, by
//! roughly what factor, where crossovers fall — is the reproduction
//! target, and each module's header documents the expected shape.

pub mod ablations;
pub mod appd4_bias;
pub mod common;
pub mod fig11_clt_hoeffding;
pub mod fig16_ablation;
pub mod fig18_qq;
pub mod fig19_sensitivity;
pub mod fig1_correlation;
pub mod fig1_pareto;
pub mod fig2_motivation;
pub mod fig5_speedup;
pub mod table10_magicpig;
pub mod table11_bootstrap;
pub mod table12_wider;
pub mod table1_hard;
pub mod table2_longgen;
pub mod table9_baselines;

use crate::util::cli::Args;

type ExpFn = fn(&Args) -> String;

/// (id, description, runner) for every reproduced table/figure.
pub fn registry() -> Vec<(&'static str, &'static str, ExpFn)> {
    vec![
        ("fig2", "motivation: coverage + error vs budget per score regime", fig2_motivation::run),
        ("fig1", "pareto: quality/error vs density, all methods (also fig4/6/7)", fig1_pareto::run),
        ("fig1-corr", "correlation of user eps with observed error", fig1_correlation::run),
        ("fig5", "decode speedup vs density, CPU-hosted KV", fig5_speedup::run),
        ("table1", "RULER-HARD proxy @10% sparsity across model regimes (also tables 4/5/7/8)", table1_hard::run),
        ("table2", "long generation with natural config (also figs 8/9)", table2_longgen::run),
        ("table9", "approximate-top-k family @512 budget", table9_baselines::run),
        ("table10", "MagicPig setup A vs B ablation", table10_magicpig::run),
        ("table11", "base-sample estimation error of sigma^2 / Tr(Sigma)", table11_bootstrap::run),
        ("fig11", "CLT vs Hoeffding budgets + failure rates (figs 11-15)", fig11_clt_hoeffding::run),
        ("fig16", "(eps, delta) ablation for verified-D/N + fig10 quality", fig16_ablation::run),
        ("fig18", "QQ normality of the denominator estimator", fig18_qq::run),
        ("fig19", "parameter sensitivity sweeps", fig19_sensitivity::run),
        ("table12", "wider baseline x density grid", table12_wider::run),
        ("appd4", "bias vs variance error propagation", appd4_bias::run),
        ("ablations", "design-choice ablations: budget floor, bound, fixed-vs-adaptive split", ablations::run),
    ]
}

/// Run one experiment by id (or "all"). Returns the rendered output.
pub fn run(id: &str, args: &Args) -> Result<String, String> {
    if id == "all" {
        let mut out = String::new();
        for (name, _, f) in registry() {
            eprintln!("[exp] running {name} ...");
            out.push_str(&format!("\n================ {name} ================\n"));
            out.push_str(&f(args));
        }
        return Ok(out);
    }
    for (name, _, f) in registry() {
        if name == id {
            return Ok(f(args));
        }
    }
    Err(format!(
        "unknown experiment '{id}'. available: {}",
        registry().iter().map(|(n, _, _)| *n).collect::<Vec<_>>().join(", ")
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_ids_unique() {
        let ids: Vec<_> = registry().iter().map(|(n, _, _)| *n).collect();
        let set: std::collections::HashSet<_> = ids.iter().collect();
        assert_eq!(ids.len(), set.len());
        assert!(ids.len() >= 15);
    }

    #[test]
    fn unknown_id_is_error() {
        let args = Args::default();
        assert!(run("nope", &args).is_err());
    }
}
