//! The real PJRT-backed artifact registry (`pjrt` feature only): compiles
//! every `*.hlo.txt` once on the CPU PJRT client and executes them on
//! the serving hot path.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A compiled-artifact registry over a PJRT client.
pub struct Runtime {
    pub client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub dir: PathBuf,
}

impl Runtime {
    /// Load and compile every `*.hlo.txt` under `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = HashMap::new();
        let entries = std::fs::read_dir(&dir)
            .with_context(|| format!("artifact dir {dir:?} (run `make artifacts` first)"))?;
        for entry in entries {
            let path = entry?.path();
            let name = path.file_name().unwrap().to_string_lossy().to_string();
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                let proto = xla::HloModuleProto::from_text_file(&path)
                    .map_err(|e| anyhow!("parse {name}: {e:?}"))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client.compile(&comp).map_err(|e| anyhow!("compile {name}: {e:?}"))?;
                exes.insert(stem.to_string(), exe);
            }
        }
        if exes.is_empty() {
            return Err(anyhow!("no .hlo.txt artifacts in {dir:?}"));
        }
        Ok(Runtime { client, exes, dir })
    }

    pub fn has(&self, name: &str) -> bool {
        self.exes.contains_key(name)
    }

    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.exes.keys().cloned().collect();
        v.sort();
        v
    }

    /// Upload an f32 tensor to the device.
    pub fn upload(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("upload {dims:?}: {e:?}"))
    }

    /// Execute an artifact on device buffers; returns the flattened
    /// tuple elements as literals (artifacts are lowered with
    /// return_tuple=True).
    pub fn execute(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact '{name}' (have: {:?})", self.names()))?;
        let out = exe.execute_b(args).map_err(|e| anyhow!("execute {name}: {e:?}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("download {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple {name}: {e:?}"))
    }

    /// Convenience: execute and read a single f32 output tensor.
    pub fn execute_1(&self, name: &str, args: &[&xla::PjRtBuffer]) -> Result<Vec<f32>> {
        let parts = self.execute(name, args)?;
        parts
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{name}: empty tuple"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{name}: to_vec: {e:?}"))
    }
}
