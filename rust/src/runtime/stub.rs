//! Offline stand-ins for the PJRT runtime (default build, without the
//! `pjrt` feature). They present the same API surface so the engine,
//! examples and tests compile unchanged; constructors report the missing
//! runtime instead of executing anything.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use crate::kvcache::KvCache;
use crate::model::{ModelConfig, StepOut, Weights};

const NO_PJRT: &str = "built without the `pjrt` feature — rebuild with `--features pjrt` \
                       (requires a local `xla` crate and xla_extension; see DESIGN.md §8)";

/// Placeholder for a device-resident buffer.
pub struct PjrtBuffer;

/// Placeholder artifact registry; `load` always fails.
pub struct Runtime {
    pub dir: PathBuf,
}

impl Runtime {
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let _ = dir.as_ref();
        bail!(NO_PJRT)
    }

    pub fn has(&self, _name: &str) -> bool {
        false
    }

    pub fn names(&self) -> Vec<String> {
        Vec::new()
    }

    pub fn upload(&self, _data: &[f32], _dims: &[usize]) -> Result<PjrtBuffer> {
        Err(anyhow!(NO_PJRT))
    }

    pub fn execute_1(&self, _name: &str, _args: &[&PjrtBuffer]) -> Result<Vec<f32>> {
        Err(anyhow!(NO_PJRT))
    }
}

/// Placeholder artifact-backed transformer; `new` always fails, and the
/// `Backend` impl over it is never reachable in the default build.
pub struct PjrtModel {
    pub cfg: ModelConfig,
}

impl PjrtModel {
    pub fn new(_rt: Runtime, cfg: ModelConfig, _weights: &Weights) -> Result<PjrtModel> {
        let _ = cfg;
        Err(anyhow!(NO_PJRT))
    }

    pub fn decode_step(
        &self,
        _token: u32,
        _pos: usize,
        _cache: &mut KvCache,
        _select: Option<&mut crate::model::SelectFn>,
    ) -> Result<StepOut> {
        Err(anyhow!(NO_PJRT))
    }
}
