//! The PJRT-executed transformer: rust drives the per-layer artifacts
//! (`qkv` → host-side index selection + gather → `attn_b{B}` → `ffn` →
//! `logits`), with all weights resident on the device.

use anyhow::{anyhow, Result};

use super::{bucket_for, Runtime, BUDGET_BUCKETS};
use crate::attention::Selection;
use crate::kvcache::KvCache;
use crate::model::{rope_phases, ModelConfig, StepOut, Weights};
use crate::tensor::Mat;

/// Device-resident weight buffers for one layer.
struct LayerBufs {
    w_ln_attn: xla::PjRtBuffer,
    wq: xla::PjRtBuffer,
    wk: xla::PjRtBuffer,
    wv: xla::PjRtBuffer,
    wo: xla::PjRtBuffer,
    w_ln_ffn: xla::PjRtBuffer,
    w_gate: xla::PjRtBuffer,
    w_up: xla::PjRtBuffer,
    w_down: xla::PjRtBuffer,
}

/// A transformer whose compute runs through the AOT artifacts while the
/// KV cache (and index selection) stay on the rust side.
pub struct PjrtModel {
    pub cfg: ModelConfig,
    rt: Runtime,
    layers: Vec<LayerBufs>,
    w_ln_f: xla::PjRtBuffer,
    w_emb: xla::PjRtBuffer,
    /// Host copy of the embedding for token lookup.
    emb_host: Mat,
}

// SAFETY CLAIM, NOT VERIFIED: these impls assert that the CPU PJRT
// client, its compiled executables and the device-resident buffers are
// internally synchronized (the PJRT C API documents its CPU client as
// thread-safe), and the engine never mutates a PjrtModel after
// construction — worker threads only call `decode_step(&self, ..)`
// through a shared Arc. Whoever wires up the `xla` dependency (see
// Cargo.toml [features]) must confirm the bound crate's thread-safety
// before running the engine with `workers > 1`, or serialize execution
// behind a Mutex here; until then keep `workers: 1` on PJRT engines.
unsafe impl Send for PjrtModel {}
unsafe impl Sync for PjrtModel {}

impl PjrtModel {
    /// Upload `weights` once and bind to the artifact runtime.
    pub fn new(rt: Runtime, cfg: ModelConfig, weights: &Weights) -> Result<PjrtModel> {
        let d = cfg.d_model;
        let f = cfg.d_ff;
        let mut layers = Vec::with_capacity(cfg.n_layers);
        for lw in &weights.layers {
            layers.push(LayerBufs {
                w_ln_attn: rt.upload(&lw.w_ln_attn, &[d])?,
                wq: rt.upload(&lw.wq.data, &[d, d])?,
                wk: rt.upload(&lw.wk.data, &[d, d])?,
                wv: rt.upload(&lw.wv.data, &[d, d])?,
                wo: rt.upload(&lw.wo.data, &[d, d])?,
                w_ln_ffn: rt.upload(&lw.w_ln_ffn, &[d])?,
                w_gate: rt.upload(&lw.w_gate.data, &[d, f])?,
                w_up: rt.upload(&lw.w_up.data, &[d, f])?,
                w_down: rt.upload(&lw.w_down.data, &[f, d])?,
            });
        }
        let w_ln_f = rt.upload(&weights.w_ln_f, &[d])?;
        let w_emb = rt.upload(&weights.w_emb.data, &[cfg.vocab, d])?;
        Ok(PjrtModel {
            cfg,
            rt,
            layers,
            w_ln_f,
            w_emb,
            emb_host: weights.w_emb.clone(),
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// One decode step through the artifacts. `select` picks attention
    /// indices per (layer, head); `None` = dense attention over the whole
    /// cache (bucketed; contexts beyond the largest bucket must be
    /// served sparsely — exactly the regime the paper targets).
    pub fn decode_step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        mut select: Option<&mut crate::model::SelectFn>,
    ) -> Result<StepOut> {
        let cfg = &self.cfg;
        let (h, dh, d) = (cfg.n_heads, cfg.d_head(), cfg.d_model);
        let (cos, sin) = rope_phases(pos, dh);
        let cos_b = self.rt.upload(&cos, &[dh / 2])?;
        let sin_b = self.rt.upload(&sin, &[dh / 2])?;
        let mut x = self.emb_host.row(token as usize % cfg.vocab).to_vec();
        let mut densities: Vec<f64> = Vec::new();
        // Scratch gather buffers, reshaped in place per head — the
        // decode hot path allocates zero fresh `Mat`s per (layer, head).
        let mut gk = Mat::zeros(0, 0);
        let mut gv = Mat::zeros(0, 0);

        for (l, lb) in self.layers.iter().enumerate() {
            // ── qkv artifact ──
            let x_b = self.rt.upload(&x, &[1, d])?;
            let parts = self.rt.execute(
                "qkv",
                &[&x_b, &lb.w_ln_attn, &lb.wq, &lb.wk, &lb.wv, &cos_b, &sin_b],
            )?;
            let mut it = parts.into_iter();
            let q = it.next().ok_or_else(|| anyhow!("qkv: missing q"))?.to_vec::<f32>()
                .map_err(|e| anyhow!("{e:?}"))?;
            let k = it.next().ok_or_else(|| anyhow!("qkv: missing k"))?.to_vec::<f32>()
                .map_err(|e| anyhow!("{e:?}"))?;
            let v = it.next().ok_or_else(|| anyhow!("qkv: missing v"))?.to_vec::<f32>()
                .map_err(|e| anyhow!("{e:?}"))?;

            // Append to the host cache, then select + gather per head.
            for head in 0..h {
                cache.append(l, head, &k[head * dh..(head + 1) * dh], &v[head * dh..(head + 1) * dh]);
            }
            let n = cache.len(l);
            // Select per head first, then size the bucket to the largest
            // selection (dense mode selects everything).
            let mut sels: Vec<Selection> = Vec::with_capacity(h);
            for head in 0..h {
                let qh = &q[head * dh..(head + 1) * dh];
                let sel = match select.as_mut() {
                    Some(f) => {
                        let (kc, vc) = cache.head(l, head);
                        let qb = cache.quant_bounds(l, head);
                        f(l, head, kc, vc, qh, qb)
                    }
                    None => Selection::deterministic((0..n).collect()),
                };
                sels.push(sel);
            }
            let max_len = sels.iter().map(|s| s.len()).max().unwrap_or(0);
            let bucket = self.attn_bucket(max_len, select.is_some())?;
            let mut kg = vec![0.0f32; h * bucket * dh];
            let mut vg = vec![0.0f32; h * bucket * dh];
            let mut log_invp = vec![0.0f32; h * bucket];
            let mut mask = vec![0.0f32; h * bucket];

            for (head, sel) in sels.iter_mut().enumerate() {
                if sel.len() > bucket {
                    sel.truncate(bucket);
                }
                densities.push(sel.density(n));
                cache.gather_into(l, head, &sel.idx, &mut gk, &mut gv);
                let base = head * bucket;
                kg[base * dh..(base + sel.len()) * dh].copy_from_slice(&gk.data);
                vg[base * dh..(base + sel.len()) * dh].copy_from_slice(&gv.data);
                for (j, &p) in sel.prob.iter().enumerate() {
                    log_invp[base + j] = -(p.ln());
                    mask[base + j] = 1.0;
                }
            }

            // ── attn artifact (bucketed) ──
            let q_b = self.rt.upload(&q, &[h, dh])?;
            let kg_b = self.rt.upload(&kg, &[h, bucket, dh])?;
            let vg_b = self.rt.upload(&vg, &[h, bucket, dh])?;
            let lp_b = self.rt.upload(&log_invp, &[h, bucket])?;
            let mk_b = self.rt.upload(&mask, &[h, bucket])?;
            let attn_out = self.rt.execute_1(
                &format!("attn_b{bucket}"),
                &[&q_b, &kg_b, &vg_b, &lp_b, &mk_b, &lb.wo],
            )?;
            for (xi, &ai) in x.iter_mut().zip(attn_out.iter()) {
                *xi += ai;
            }

            // ── ffn artifact ──
            let x_b = self.rt.upload(&x, &[1, d])?;
            let ffn_out = self
                .rt
                .execute_1("ffn", &[&x_b, &lb.w_ln_ffn, &lb.w_gate, &lb.w_up, &lb.w_down])?;
            for (xi, &fi) in x.iter_mut().zip(ffn_out.iter()) {
                *xi += fi;
            }
        }

        // ── logits artifact ──
        let x_b = self.rt.upload(&x, &[1, d])?;
        let logits = self.rt.execute_1("logits", &[&x_b, &self.w_ln_f, &self.w_emb])?;
        let mean_density = if densities.is_empty() {
            1.0
        } else {
            densities.iter().sum::<f64>() / densities.len() as f64
        };
        Ok(StepOut { logits, mean_density })
    }

    /// Pick the attention bucket for a cache of size n. Sparse mode uses
    /// the smallest bucket that fits the selection (callers truncate);
    /// dense mode needs a bucket ≥ n.
    fn attn_bucket(&self, n: usize, sparse: bool) -> Result<usize> {
        if sparse {
            // Sparse selections are capped to the largest bucket.
            Ok(bucket_for(n).unwrap_or(*BUDGET_BUCKETS.last().unwrap()))
        } else {
            bucket_for(n).ok_or_else(|| {
                anyhow!(
                    "dense attention over n={n} exceeds the largest artifact bucket \
                     ({}); serve long contexts with a sparse policy",
                    BUDGET_BUCKETS.last().unwrap()
                )
            })
        }
    }
}
