//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them once on the CPU PJRT client,
//! and executes them on the serving hot path. Python never runs here.
//!
//! Weights are uploaded once as device-resident `PjRtBuffer`s; per-token
//! activations are the only recurring host→device traffic, plus the
//! *gathered* KV rows for the attention artifact — which is exactly the
//! paper's CPU-offload data movement (density × cache bytes).
//!
//! The real runtime needs the external `xla` crate (bound to an
//! xla_extension install), which the offline build environment cannot
//! provide. It is therefore gated behind the `pjrt` cargo feature; the
//! default build uses the API-compatible stubs in `stub.rs`, which keep
//! the engine, examples and tests compiling and report the missing
//! runtime at load time.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub mod pjrt_model;
#[cfg(feature = "pjrt")]
pub use pjrt::Runtime;
#[cfg(feature = "pjrt")]
pub use pjrt_model::PjrtModel;

#[cfg(not(feature = "pjrt"))]
pub mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::{PjrtModel, Runtime};

/// Budget buckets every adaptive budget is rounded up to — must match
/// `aot.BUDGET_BUCKETS`.
pub const BUDGET_BUCKETS: [usize; 5] = [128, 256, 512, 1024, 2048];

/// Round a selection size up to its artifact bucket (None if it exceeds
/// the largest bucket — callers then truncate or fall back).
pub fn bucket_for(b: usize) -> Option<usize> {
    BUDGET_BUCKETS.iter().copied().find(|&cap| cap >= b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_rounding() {
        assert_eq!(bucket_for(1), Some(128));
        assert_eq!(bucket_for(128), Some(128));
        assert_eq!(bucket_for(129), Some(256));
        assert_eq!(bucket_for(2048), Some(2048));
        assert_eq!(bucket_for(2049), None);
    }

    // PJRT-touching tests live in rust/tests/runtime_pjrt.rs (they need
    // artifacts built, the `pjrt` feature, and a working xla_extension
    // install; without those they skip).
}
