//! Timing helpers + the hand-rolled bench harness used by `rust/benches`
//! (criterion is not available offline).

use std::time::{Duration, Instant};

/// Simple scope timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }
    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }
    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

/// Benchmark statistics from repeated timed runs.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  min {:>12}",
            self.name,
            self.iters,
            fmt_time(self.mean_s),
            fmt_time(self.p50_s),
            fmt_time(self.p95_s),
            fmt_time(self.min_s),
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Run `f` repeatedly: warm up for `warmup` iterations, then time
/// iterations until `budget` wall-clock elapses (at least `min_iters`).
/// Returns robust statistics. `f` should return something observable so
/// the optimizer cannot delete the work; we black-box it here.
pub fn bench<T>(name: &str, warmup: usize, budget: Duration, min_iters: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < min_iters {
        let t = Instant::now();
        black_box(f());
        samples.push(t.elapsed().as_secs_f64());
        if samples.len() > 100_000 {
            break;
        }
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    BenchStats {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: samples[0],
        p50_s: samples[n / 2],
        p95_s: samples[((n as f64 * 0.95) as usize).min(n - 1)],
    }
}

/// Prevent the optimizer from eliding benchmarked work.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_iters() {
        let stats = bench("noop", 2, Duration::from_millis(5), 10, || 1 + 1);
        assert!(stats.iters >= 10);
        assert!(stats.mean_s >= 0.0);
        assert!(stats.min_s <= stats.p50_s);
        assert!(stats.p50_s <= stats.p95_s + 1e-12);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}
