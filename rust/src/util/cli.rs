//! Minimal argument parser (clap is not available offline). Supports
//! `--key value`, `--key=value`, bare flags and positional args.

use std::collections::HashMap;

/// Parsed command line: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Validate every provided `--key` (option or bare flag) against a
    /// closed set. Typos like `--worker 8` for `--workers 8` used to
    /// no-op silently; commands with a fixed vocabulary call this and
    /// fail loudly instead, listing what they do understand. Both
    /// listings are sorted and deduplicated, so the message is
    /// deterministic regardless of argument order or repetition
    /// (options live in a `HashMap`, and a repeated bare flag would
    /// otherwise be listed twice).
    pub fn check_known(&self, known: &[&str]) -> Result<(), String> {
        let mut unknown: Vec<&str> = self
            .options
            .keys()
            .map(String::as_str)
            .chain(self.flags.iter().map(String::as_str))
            .filter(|k| !known.contains(k))
            .collect();
        if unknown.is_empty() {
            return Ok(());
        }
        unknown.sort_unstable();
        unknown.dedup();
        let mut known: Vec<&str> = known.to_vec();
        known.sort_unstable();
        known.dedup();
        let fmt = |keys: &[&str]| {
            keys.iter().map(|k| format!("--{k}")).collect::<Vec<_>>().join(", ")
        };
        Err(format!(
            "unknown option{} {}; known options: {}",
            if unknown.len() > 1 { "s" } else { "" },
            fmt(&unknown),
            if known.is_empty() { "(none)".to_string() } else { fmt(&known) }
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("exp fig2 --seed 42 --eps=0.05 --verbose");
        assert_eq!(a.positional, vec!["exp", "fig2"]);
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get_f64("eps", 0.1), 0.05);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_usize("n", 32768), 32768);
        assert_eq!(a.get_str("mode", "dense"), "dense");
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --slow");
        assert!(a.has_flag("fast") && a.has_flag("slow"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn negative_number_value() {
        let a = parse("--shift -3"); // "-3" does not start with --, so value
        assert_eq!(a.get("shift"), Some("-3"));
    }

    #[test]
    fn check_known_accepts_exact_vocabulary() {
        let a = parse("serve --workers 8 --open-loop --rate 4");
        assert!(a.check_known(&["workers", "open-loop", "rate", "mode"]).is_ok());
    }

    #[test]
    fn check_known_rejects_typoed_option_with_listing() {
        let a = parse("serve --worker 8"); // typo for --workers
        let err = a.check_known(&["workers", "mode"]).unwrap_err();
        assert!(err.contains("unknown option --worker"), "{err}");
        assert!(err.contains("--workers"), "listing must name the real key: {err}");
        assert!(err.contains("--mode"), "{err}");
    }

    #[test]
    fn check_known_rejects_typoed_flag_and_pluralizes() {
        let a = parse("serve --open-lop --quiet");
        let err = a.check_known(&["open-loop"]).unwrap_err();
        assert!(err.contains("unknown options"), "{err}");
        assert!(err.contains("--open-lop") && err.contains("--quiet"), "{err}");
    }

    #[test]
    fn check_known_ignores_positionals() {
        let a = parse("exp fig2 extra");
        assert!(a.check_known(&[]).is_ok());
    }

    #[test]
    fn check_known_listing_is_sorted_and_deduplicated() {
        // --alpha appears twice as a bare flag; --zeta as an option and
        // (by overwrite) again: neither may be listed more than once,
        // and both listings must come out in sorted order.
        let a = parse("serve --zeta 1 --alpha --zeta=2 --alpha");
        let err =
            a.check_known(&["workers", "listen", "shards", "shard-queue-depth"]).unwrap_err();
        assert!(err.contains("unknown options --alpha, --zeta;"), "{err}");
        assert_eq!(err.matches("--alpha").count(), 1, "deduplicated: {err}");
        let pos = |k: &str| err.find(k).unwrap_or_else(|| panic!("missing {k}: {err}"));
        assert!(
            pos("--listen") < pos("--shard-queue-depth")
                && pos("--shard-queue-depth") < pos("--shards")
                && pos("--shards") < pos("--workers"),
            "known listing must be sorted: {err}"
        );
    }

    #[test]
    fn check_known_accepts_serve_net_flags() {
        let a = parse("serve --listen 127.0.0.1:8044 --shards 4 --shard-queue-depth 32");
        assert!(a.check_known(&["listen", "shards", "shard-queue-depth"]).is_ok());
    }
}
