//! Minimal argument parser (clap is not available offline). Supports
//! `--key value`, `--key=value`, bare flags and positional args.

use std::collections::HashMap;

/// Parsed command line: positionals plus `--key value` options.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: HashMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(stripped.to_string(), v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn positional_and_options() {
        let a = parse("exp fig2 --seed 42 --eps=0.05 --verbose");
        assert_eq!(a.positional, vec!["exp", "fig2"]);
        assert_eq!(a.get("seed"), Some("42"));
        assert_eq!(a.get_f64("eps", 0.1), 0.05);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_usize("n", 32768), 32768);
        assert_eq!(a.get_str("mode", "dense"), "dense");
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--fast --slow");
        assert!(a.has_flag("fast") && a.has_flag("slow"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn negative_number_value() {
        let a = parse("--shift -3"); // "-3" does not start with --, so value
        assert_eq!(a.get("shift"), Some("-3"));
    }
}
