//! Tiny JSON writer + parser (no serde offline). The writer emits the
//! subset the results files need; the parser handles full JSON — it
//! exists for the network front-end (`server::net`), whose request
//! bodies arrive as JSON over a raw socket.

use std::fmt::Write as _;

/// A JSON value builder. Construct with the helper ctors and serialize
/// with `to_string()`.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn arr_f64(items: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(items.into_iter().map(Json::Num).collect())
    }
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }
    /// Chainable field insertion (only valid on `Obj`).
    pub fn field(mut self, key: &str, value: Json) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value));
        } else {
            panic!("Json::field on non-object");
        }
        self
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    /// Parse a JSON document. Rejects trailing garbage and nesting
    /// deeper than 64 levels (a hand-rolled recursive-descent parser on
    /// network input must bound its own stack).
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { bytes: s.as_bytes(), pos: 0, depth: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(format!("trailing data at byte {}", p.pos));
        }
        Ok(v)
    }

    /// Object field lookup (`None` on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric field as usize (must be a non-negative integer).
    pub fn as_usize(&self) -> Option<usize> {
        let x = self.as_f64()?;
        (x >= 0.0 && x == x.trunc() && x < 2f64.powi(53)).then_some(x as usize)
    }

    /// Numeric field as u64 (must be a non-negative integer).
    pub fn as_u64(&self) -> Option<u64> {
        let x = self.as_f64()?;
        (x >= 0.0 && x == x.trunc() && x < 2f64.powi(53)).then_some(x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&pad);
                    Json::Str(k.clone()).write(out, indent);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                if !fields.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} levels"));
        }
        let v = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected byte 0x{b:02x} at {}", self.pos)),
            None => Err("unexpected end of input".to_string()),
        };
        self.depth -= 1;
        v
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            fields.push((k, v));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("unterminated escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or("truncated \\u escape")?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| "non-ascii \\u escape".to_string())?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                            self.pos += 4;
                            // Surrogates (paired or lone) fall back to
                            // U+FFFD: the request bodies we parse never
                            // carry astral-plane text, and replacement
                            // beats rejecting the whole request.
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input was &str, so the
                    // bytes are valid; find the char boundary).
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xc0) == 0x80 {
                        self.pos += 1;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| "invalid utf-8 in string".to_string())?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9') | Some(b'.') | Some(b'e') | Some(b'E') | Some(b'+') | Some(b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).expect("ascii digits");
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let j = Json::obj()
            .field("name", Json::str("fig2"))
            .field("density", Json::num(0.1))
            .field("errors", Json::arr_f64(vec![0.5, 0.25]))
            .field("ok", Json::Bool(true));
        let s = j.to_string();
        assert!(s.contains("\"name\": \"fig2\""));
        assert!(s.contains("[0.5,0.25]"));
        assert!(s.contains("\"ok\": true"));
    }

    #[test]
    fn escapes() {
        let s = Json::str("a\"b\\c\nd").to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn ints_have_no_decimal() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let j = Json::obj()
            .field("prompt", Json::arr_f64(vec![1.0, 2.0, 3.0]))
            .field("gen_len", Json::num(16))
            .field("mode", Json::str("verified"))
            .field("eps", Json::num(0.1))
            .field("stream", Json::Bool(true))
            .field("note", Json::Null);
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("gen_len").unwrap().as_usize(), Some(16));
        assert_eq!(parsed.get("mode").unwrap().as_str(), Some("verified"));
        assert_eq!(parsed.get("eps").unwrap().as_f64(), Some(0.1));
        assert_eq!(parsed.get("stream").unwrap().as_bool(), Some(true));
        let prompt: Vec<usize> =
            parsed.get("prompt").unwrap().as_arr().unwrap().iter().map(|v| v.as_usize().unwrap()).collect();
        assert_eq!(prompt, vec![1, 2, 3]);
        assert!(matches!(parsed.get("note"), Some(Json::Null)));
        assert!(parsed.get("missing").is_none());
    }

    #[test]
    fn parse_escapes_and_whitespace() {
        let parsed = Json::parse(" { \"a\\n\\\"b\" : [ -1.5e2 , \"\\u0041\" ] } ").unwrap();
        assert_eq!(parsed.get("a\n\"b").unwrap().as_arr().unwrap()[0].as_f64(), Some(-150.0));
        assert_eq!(parsed.get("a\n\"b").unwrap().as_arr().unwrap()[1].as_str(), Some("A"));
    }

    #[test]
    fn parse_rejects_malformed_input() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("{\"a\": 1,}").is_err());
        assert!(Json::parse("[1 2]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 trailing").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("--1").is_err());
        let deep = "[".repeat(80) + &"]".repeat(80);
        assert!(Json::parse(&deep).is_err(), "depth limit must hold");
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(Json::Num(3.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(0.0).as_u64(), Some(0));
        assert_eq!(Json::Str("3".into()).as_usize(), None);
    }
}
