//! Tiny JSON *writer* (no parser needed — results files only). Handles
//! the subset we emit: objects, arrays, strings, numbers, bools.

use std::fmt::Write as _;

/// A JSON value builder. Construct with the helper ctors and serialize
/// with `to_string()`.
#[derive(Clone, Debug)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn arr(items: impl IntoIterator<Item = Json>) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn arr_f64(items: impl IntoIterator<Item = f64>) -> Json {
        Json::Arr(items.into_iter().map(Json::Num).collect())
    }
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }
    /// Chainable field insertion (only valid on `Obj`).
    pub fn field(mut self, key: &str, value: Json) -> Json {
        if let Json::Obj(ref mut fields) = self {
            fields.push((key.to_string(), value));
        } else {
            panic!("Json::field on non-object");
        }
        self
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0);
        s
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out, indent);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                let pad = "  ".repeat(indent + 1);
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('\n');
                    out.push_str(&pad);
                    Json::Str(k.clone()).write(out, indent);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                if !fields.is_empty() {
                    out.push('\n');
                    out.push_str(&"  ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_shapes() {
        let j = Json::obj()
            .field("name", Json::str("fig2"))
            .field("density", Json::num(0.1))
            .field("errors", Json::arr_f64(vec![0.5, 0.25]))
            .field("ok", Json::Bool(true));
        let s = j.to_string();
        assert!(s.contains("\"name\": \"fig2\""));
        assert!(s.contains("[0.5,0.25]"));
        assert!(s.contains("\"ok\": true"));
    }

    #[test]
    fn escapes() {
        let s = Json::str("a\"b\\c\nd").to_string();
        assert_eq!(s, "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn non_finite_to_null() {
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn ints_have_no_decimal() {
        assert_eq!(Json::num(3.0).to_string(), "3");
        assert_eq!(Json::num(3.5).to_string(), "3.5");
    }
}
