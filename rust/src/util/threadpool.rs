//! Fixed-size thread pool over std channels (tokio is not available
//! offline). Used by the serving engine for request handling and by the
//! experiment harness for parallel sweeps.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A basic work-stealing-free thread pool: one shared queue, N workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("vattn-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker hung up");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker died")).collect()
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default parallelism for experiment sweeps.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }
}
