//! Fixed-size thread pool over std channels (tokio is not available
//! offline). Used by the serving engine for request handling and by the
//! experiment harness for parallel sweeps.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A basic work-stealing-free thread pool: one shared queue, N workers.
pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    pub fn new(n: usize) -> ThreadPool {
        let n = n.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("vattn-worker-{i}"))
                    .spawn(move || loop {
                        let job = {
                            let guard = rx.lock().unwrap();
                            guard.recv()
                        };
                        match job {
                            Ok(job) => job(),
                            Err(_) => break, // all senders dropped
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers }
    }

    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(job))
            .expect("worker hung up");
    }

    /// Map `f` over `items` in parallel, preserving order.
    pub fn map<T, R, F>(&self, items: Vec<T>, f: F) -> Vec<R>
    where
        T: Send + 'static,
        R: Send + 'static,
        F: Fn(T) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let n = items.len();
        let (tx, rx): (Sender<(usize, R)>, Receiver<(usize, R)>) = channel();
        for (i, item) in items.into_iter().enumerate() {
            let tx = tx.clone();
            let f = Arc::clone(&f);
            self.execute(move || {
                let r = f(item);
                let _ = tx.send((i, r));
            });
        }
        drop(tx);
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter().map(|o| o.expect("worker died")).collect()
    }

    pub fn num_workers(&self) -> usize {
        self.workers.len()
    }

    /// Run `f(0..n)` on the pool's *persistent* workers while borrowing
    /// from the caller's stack — the scoped-threadpool pattern, so hot
    /// paths (e.g. chunked dense SDPA) stop paying a thread spawn per
    /// call. Blocks until every task has finished; a panicking task is
    /// re-raised here after the rest complete.
    ///
    /// SAFETY of the internal lifetime erasure (borrows ride into the
    /// 'static job queue as raw addresses): the closure and output slots
    /// outlive this call, every task sends a completion message *after*
    /// it finishes (or unwinds), and we do not return until all `n`
    /// messages arrive — so no task can touch the borrowed data after
    /// `scoped_map` returns, and each task writes a distinct output slot.
    pub fn scoped_map<'env, R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send + 'env,
        F: Fn(usize) -> R + Sync + 'env,
    {
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let out_addr = out.as_mut_ptr() as usize;
        let f_addr = &f as *const F as usize;
        let (tx, rx) = channel::<std::thread::Result<()>>();
        for i in 0..n {
            let tx = tx.clone();
            self.execute(move || {
                let res = std::panic::catch_unwind(|| {
                    let f = unsafe { &*(f_addr as *const F) };
                    let r = f(i);
                    // Distinct index ⇒ distinct slot; the slot holds
                    // None (trivial drop), so a raw overwrite is fine.
                    unsafe { (out_addr as *mut Option<R>).add(i).write(Some(r)) };
                });
                let _ = tx.send(res);
            });
        }
        drop(tx);
        let mut first_panic = None;
        for _ in 0..n {
            match rx.recv().expect("worker hung up mid-scope") {
                Ok(()) => {}
                Err(p) if first_panic.is_none() => first_panic = Some(p),
                Err(_) => {}
            }
        }
        if let Some(p) = first_panic {
            std::panic::resume_unwind(p);
        }
        out.into_iter().map(|o| o.expect("every task completed")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default parallelism for experiment sweeps.
pub fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn executes_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn map_preserves_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map((0..50).collect::<Vec<_>>(), |x| x * 2);
        assert_eq!(out, (0..50).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn map_empty() {
        let pool = ThreadPool::new(2);
        let out: Vec<i32> = pool.map(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn scoped_map_borrows_the_stack_and_preserves_order() {
        let pool = ThreadPool::new(3);
        let data: Vec<u64> = (0..40).collect(); // borrowed, not moved
        let out = pool.scoped_map(8, |i| data[i * 5..(i + 1) * 5].iter().sum::<u64>());
        let want: Vec<u64> = (0..8).map(|i| (0..40).filter(|x| x / 5 == i).sum()).collect();
        assert_eq!(out, want);
        assert_eq!(data.len(), 40, "borrow survives the scope");
        // Reuse the same pool back to back (no spawn per call).
        let out2 = pool.scoped_map(3, |i| data[i]);
        assert_eq!(out2, vec![0, 1, 2]);
        let empty: Vec<u64> = pool.scoped_map(0, |i| data[i]);
        assert!(empty.is_empty());
    }

    #[test]
    fn scoped_map_propagates_panics_after_the_scope_drains() {
        let pool = ThreadPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scoped_map(4, |i| {
                if i == 2 {
                    panic!("boom");
                }
                i
            })
        }));
        assert!(res.is_err(), "panic must cross the scope");
        // The pool must still be serviceable afterwards.
        assert_eq!(pool.scoped_map(2, |i| i + 1), vec![1, 2]);
    }
}
