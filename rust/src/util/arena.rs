//! Scratch-buffer arena for the decode hot path.
//!
//! Every decode step used to allocate a handful of short-lived `Vec`s —
//! gathered-row scratch, selection index lists, dequant temporaries —
//! all dropped before the next token. [`BufferArena`] recycles them:
//! `take_*` hands out a cleared buffer (reusing a previously recycled
//! allocation when one exists), `recycle_*` returns it to the pool. The
//! buffers keep their capacity, so after warm-up a steady-state decode
//! step performs **zero** heap allocations in the arena-covered paths —
//! asserted by the allocation counter in `benches/bench_decode_speedup`.
//!
//! Buffers are plain `Vec`s, so adopting the arena is mechanical:
//! replace `let mut v = Vec::new()` with `let mut v = take_f32()` and
//! drop-sites with `recycle_f32(v)`. Forgetting to recycle is safe —
//! the buffer is simply freed as usual and the pool re-grows on demand
//! (the audit counters make such leaks visible).
//!
//! Determinism: the arena changes only *where* buffers come from, never
//! their contents (`take_*` always returns an **empty** Vec). Token
//! streams are bitwise unaffected, which `tests/kv_quant.rs` and the
//! worker-count determinism suites re-assert over the arena-backed
//! paths.
//!
//! The convenience API ([`take_f32`] etc.) wraps one arena per thread
//! in a `thread_local`, so worker threads never contend and the pool
//! needs no locking.

use std::cell::RefCell;

/// Pools of cleared, capacity-retaining scratch buffers.
#[derive(Default)]
pub struct BufferArena {
    f32s: Vec<Vec<f32>>,
    usizes: Vec<Vec<usize>>,
    /// `take_*` calls that found the pool empty and had to allocate.
    misses: u64,
    /// Total `take_*` calls.
    takes: u64,
}

impl BufferArena {
    pub fn new() -> BufferArena {
        BufferArena::default()
    }

    /// An empty f32 buffer, reusing a recycled allocation if available.
    pub fn take_f32(&mut self) -> Vec<f32> {
        self.takes += 1;
        match self.f32s.pop() {
            Some(v) => v,
            None => {
                self.misses += 1;
                Vec::new()
            }
        }
    }

    /// Return a buffer to the pool (cleared here, capacity kept).
    pub fn recycle_f32(&mut self, mut v: Vec<f32>) {
        v.clear();
        self.f32s.push(v);
    }

    pub fn take_usize(&mut self) -> Vec<usize> {
        self.takes += 1;
        match self.usizes.pop() {
            Some(v) => v,
            None => {
                self.misses += 1;
                Vec::new()
            }
        }
    }

    pub fn recycle_usize(&mut self, mut v: Vec<usize>) {
        v.clear();
        self.usizes.push(v);
    }

    /// (takes, misses) so far — a steady-state decode loop should show
    /// `misses` flat while `takes` grows.
    pub fn counters(&self) -> (u64, u64) {
        (self.takes, self.misses)
    }
}

thread_local! {
    static ARENA: RefCell<BufferArena> = RefCell::new(BufferArena::new());
}

/// Take an empty f32 scratch buffer from this thread's arena.
pub fn take_f32() -> Vec<f32> {
    ARENA.with(|a| a.borrow_mut().take_f32())
}

/// Recycle an f32 scratch buffer into this thread's arena.
pub fn recycle_f32(v: Vec<f32>) {
    ARENA.with(|a| a.borrow_mut().recycle_f32(v));
}

/// Take an empty usize scratch buffer from this thread's arena.
pub fn take_usize() -> Vec<usize> {
    ARENA.with(|a| a.borrow_mut().take_usize())
}

/// Recycle a usize scratch buffer into this thread's arena.
pub fn recycle_usize(v: Vec<usize>) {
    ARENA.with(|a| a.borrow_mut().recycle_usize(v));
}

/// This thread's (takes, misses) counters — the bench's allocation
/// audit reads these to prove steady-state reuse.
pub fn thread_counters() -> (u64, u64) {
    ARENA.with(|a| a.borrow().counters())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_recycle_reuses_capacity() {
        let mut arena = BufferArena::new();
        let mut v = arena.take_f32();
        v.extend_from_slice(&[1.0; 100]);
        let cap = v.capacity();
        let ptr = v.as_ptr();
        arena.recycle_f32(v);
        let v2 = arena.take_f32();
        assert!(v2.is_empty(), "recycled buffer must come back cleared");
        assert_eq!(v2.capacity(), cap);
        assert_eq!(v2.as_ptr(), ptr, "same allocation, no fresh malloc");
        let (takes, misses) = arena.counters();
        assert_eq!((takes, misses), (2, 1), "second take must hit the pool");
    }

    #[test]
    fn usize_pool_is_independent() {
        let mut arena = BufferArena::new();
        let mut idx = arena.take_usize();
        idx.push(7);
        arena.recycle_usize(idx);
        let idx2 = arena.take_usize();
        assert!(idx2.is_empty());
        let (takes, misses) = arena.counters();
        assert_eq!((takes, misses), (2, 1));
    }

    #[test]
    fn thread_local_api_round_trips() {
        let mut v = take_f32();
        v.push(3.0);
        recycle_f32(v);
        let v2 = take_f32();
        assert!(v2.is_empty());
        let (takes, misses) = thread_counters();
        assert!(takes >= 2 && misses >= 1);
        recycle_f32(v2);
        let idx = take_usize();
        assert!(idx.is_empty());
        recycle_usize(idx);
    }
}
