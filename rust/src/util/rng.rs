//! Deterministic, seedable PRNG (xoshiro256**). No external deps; every
//! experiment in the repo threads an explicit seed through this type so
//! results are bit-reproducible.

/// xoshiro256** PRNG. Fast, high-quality, and deterministic across
/// platforms — all stochastic pieces of the system (sampling, synthetic
/// workloads, LSH projections, arrival processes) draw from this.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create from a seed; any seed (including 0) is valid. The seed is
    /// expanded with splitmix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent child stream (for per-head / per-request RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24BAED4963EE407))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's method with 128-bit multiply; bias is < 2^-64, fine here.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached second value not kept; the
    /// callers here value statelessness over the 2x speedup).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std, as f32.
    pub fn normal32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Exponential with rate lambda.
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Sample `k` distinct indices uniformly from [0, n) \ `excluded`,
    /// where `excluded` is a sorted slice. Uses Floyd's algorithm over the
    /// compressed range so it is O(k log k) and never scans all n.
    pub fn sample_excluding(&mut self, n: usize, k: usize, excluded: &[usize]) -> Vec<usize> {
        let m = n - excluded.len(); // size of the residual universe
        let k = k.min(m);
        let picked = self.sample_distinct(m, k);
        // Map compressed index -> original index, skipping `excluded`.
        picked.into_iter().map(|c| remap_excluding(c, excluded)).collect()
    }

    /// Floyd's algorithm: k distinct uniform draws from [0, n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut chosen = std::collections::HashSet::with_capacity(k * 2);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Map an index `c` in the compressed universe [0, n - |excluded|) back to
/// the original universe [0, n), where `excluded` is sorted ascending.
/// Solves mapped = c + #{excluded ≤ mapped} by monotone fixed-point
/// iteration with binary search — O(log|excluded|) per draw (a linear
/// scan here was the decode hot path's top cost; §Perf iteration 5).
fn remap_excluding(c: usize, excluded: &[usize]) -> usize {
    let mut mapped = c;
    loop {
        let e = excluded.partition_point(|&x| x <= mapped);
        let next = c + e;
        if next == mapped {
            return mapped;
        }
        mapped = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10);
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn sample_distinct_is_distinct_and_uniformish() {
        let mut r = Rng::new(5);
        let s = r.sample_distinct(100, 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&x| x < 100));
        // full draw = permutation of universe
        let all = r.sample_distinct(50, 50);
        let set: std::collections::HashSet<_> = all.iter().collect();
        assert_eq!(set.len(), 50);
    }

    #[test]
    fn sample_excluding_avoids_excluded() {
        let mut r = Rng::new(11);
        let excluded = vec![0, 1, 2, 50, 99];
        for _ in 0..100 {
            let s = r.sample_excluding(100, 20, &excluded);
            assert_eq!(s.len(), 20);
            for &x in &s {
                assert!(x < 100);
                assert!(!excluded.contains(&x), "drew excluded {x}");
            }
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
        }
    }

    #[test]
    fn sample_excluding_covers_whole_residual() {
        let mut r = Rng::new(13);
        let excluded = vec![2, 3, 4];
        let s = r.sample_excluding(8, 5, &excluded);
        let mut s = s.clone();
        s.sort_unstable();
        assert_eq!(s, vec![0, 1, 5, 6, 7]);
    }

    #[test]
    fn remap_excluding_basic() {
        // universe 0..6, excluded {1,3}: compressed [0,1,2,3] -> [0,2,4,5]
        let ex = vec![1, 3];
        assert_eq!(remap_excluding(0, &ex), 0);
        assert_eq!(remap_excluding(1, &ex), 2);
        assert_eq!(remap_excluding(2, &ex), 4);
        assert_eq!(remap_excluding(3, &ex), 5);
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(21);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
