//! Minimal in-repo property-testing helper (the `proptest` crate is not
//! available offline). Provides: seeded case generation, failure
//! reporting with the reproducing seed, and a light shrink over a
//! user-provided `simplify` function.

use super::rng::Rng;

/// Configuration for a property run.
pub struct Prop {
    pub cases: usize,
    pub seed: u64,
    pub name: &'static str,
}

impl Prop {
    pub fn new(name: &'static str) -> Prop {
        Prop { cases: 128, seed: 0xC0FFEE, name }
    }

    pub fn cases(mut self, n: usize) -> Prop {
        self.cases = n;
        self
    }

    pub fn seed(mut self, s: u64) -> Prop {
        self.seed = s;
        self
    }

    /// Run `check(rng)` for `cases` independent seeded cases; `check`
    /// should panic (e.g. via assert!) on failure. We catch the panic,
    /// report the case seed, and re-panic so the test fails with context.
    pub fn run(self, check: impl Fn(&mut Rng) + std::panic::RefUnwindSafe) {
        let mut meta = Rng::new(self.seed);
        for case in 0..self.cases {
            let case_seed = meta.next_u64();
            let result = std::panic::catch_unwind(|| {
                let mut rng = Rng::new(case_seed);
                check(&mut rng);
            });
            if let Err(e) = result {
                eprintln!(
                    "property '{}' failed on case {}/{} (case_seed={:#x})",
                    self.name, case, self.cases, case_seed
                );
                std::panic::resume_unwind(e);
            }
        }
    }

    /// Run a property over generated values with shrinking: `gen`
    /// produces a case, `simplify` proposes smaller variants, and
    /// `check` returns Ok(()) or Err(description).
    pub fn run_shrink<T: Clone + std::fmt::Debug>(
        self,
        gen: impl Fn(&mut Rng) -> T,
        simplify: impl Fn(&T) -> Vec<T>,
        check: impl Fn(&T) -> Result<(), String>,
    ) {
        let mut meta = Rng::new(self.seed);
        for case in 0..self.cases {
            let case_seed = meta.next_u64();
            let mut rng = Rng::new(case_seed);
            let value = gen(&mut rng);
            if let Err(first_err) = check(&value) {
                // Greedy shrink: repeatedly take the first simpler failing value.
                let mut cur = value;
                let mut err = first_err;
                'outer: loop {
                    for cand in simplify(&cur) {
                        if let Err(e) = check(&cand) {
                            cur = cand;
                            err = e;
                            continue 'outer;
                        }
                    }
                    break;
                }
                panic!(
                    "property '{}' failed (case {case}, seed {case_seed:#x}):\n  value: {:?}\n  error: {}",
                    self.name, cur, err
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        Prop::new("add-commutes").cases(64).run(|rng| {
            let a = rng.below(1000) as i64;
            let b = rng.below(1000) as i64;
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        Prop::new("always-small").cases(64).run(|rng| {
            let a = rng.below(1000);
            assert!(a < 10, "a={a}");
        });
    }

    #[test]
    fn shrink_finds_smaller_counterexample() {
        let result = std::panic::catch_unwind(|| {
            Prop::new("all-below-5").cases(32).run_shrink(
                |rng| rng.below(1000),
                |&v| if v > 0 { vec![v / 2, v - 1] } else { vec![] },
                |&v| {
                    if v < 5 {
                        Ok(())
                    } else {
                        Err(format!("{v} >= 5"))
                    }
                },
            );
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().cloned().unwrap_or_default();
        // Shrinking should drive the counterexample down to the boundary.
        assert!(msg.contains("value: 5"), "msg={msg}");
    }
}
