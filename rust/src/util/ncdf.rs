//! Normal CDF and its inverse.
//!
//! The CLT budget rule (Lemma 4.1) needs Φ⁻¹(1 - δ/2). We implement
//! W. J. Cody's double-precision rational approximation for erf/erfc
//! (~1e-16 rel. error) and Acklam's inverse-CDF approximation polished
//! with one Halley step against the accurate forward CDF.

/// Standard normal CDF Φ(x).
pub fn normal_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Error function, Cody's rational approximation (double precision).
pub fn erf(x: f64) -> f64 {
    if x.abs() <= 0.46875 {
        cody_small(x)
    } else {
        let sign = if x >= 0.0 { 1.0 } else { -1.0 };
        sign * (1.0 - cody_erfc_abs(x.abs()))
    }
}

/// Complementary error function erfc(x) = 1 - erf(x).
pub fn erfc(x: f64) -> f64 {
    if x.abs() <= 0.46875 {
        1.0 - cody_small(x)
    } else if x > 0.0 {
        cody_erfc_abs(x)
    } else {
        2.0 - cody_erfc_abs(-x)
    }
}

/// Cody regime 1: erf(x) for |x| <= 0.46875.
fn cody_small(x: f64) -> f64 {
    const A: [f64; 5] = [
        3.16112374387056560e0,
        1.13864154151050156e2,
        3.77485237685302021e2,
        3.20937758913846947e3,
        1.85777706184603153e-1,
    ];
    const B: [f64; 4] = [
        2.36012909523441209e1,
        2.44024637934444173e2,
        1.28261652607737228e3,
        2.84423683343917062e3,
    ];
    let z = x * x;
    let mut xnum = A[4] * z;
    let mut xden = z;
    for i in 0..3 {
        xnum = (xnum + A[i]) * z;
        xden = (xden + B[i]) * z;
    }
    x * (xnum + A[3]) / (xden + B[3])
}

/// Cody regimes 2–3: erfc(x) for x > 0.46875.
fn cody_erfc_abs(x: f64) -> f64 {
    debug_assert!(x > 0.0);
    if x <= 4.0 {
        const C: [f64; 9] = [
            5.64188496988670089e-1,
            8.88314979438837594e0,
            6.61191906371416295e1,
            2.98635138197400131e2,
            8.81952221241769090e2,
            1.71204761263407058e3,
            2.05107837782607147e3,
            1.23033935479799725e3,
            2.15311535474403846e-8,
        ];
        const D: [f64; 8] = [
            1.57449261107098347e1,
            1.17693950891312499e2,
            5.37181101862009858e2,
            1.62138957456669019e3,
            3.29079923573345963e3,
            4.36261909014324716e3,
            3.43936767414372164e3,
            1.23033935480374942e3,
        ];
        let mut xnum = C[8] * x;
        let mut xden = x;
        for i in 0..7 {
            xnum = (xnum + C[i]) * x;
            xden = (xden + D[i]) * x;
        }
        (-x * x).exp() * (xnum + C[7]) / (xden + D[7])
    } else {
        const P: [f64; 6] = [
            3.05326634961232344e-1,
            3.60344899949804439e-1,
            1.25781726111229246e-1,
            1.60837851487422766e-2,
            6.58749161529837803e-4,
            1.63153871373020978e-2,
        ];
        const Q: [f64; 5] = [
            2.56852019228982242e0,
            1.87295284992346047e0,
            5.27905102951428412e-1,
            6.05183413124413191e-2,
            2.33520497626869185e-3,
        ];
        if x > 26.5 {
            return 0.0; // underflows double precision anyway
        }
        let z = 1.0 / (x * x);
        let mut xnum = P[5] * z;
        let mut xden = z;
        for i in 0..4 {
            xnum = (xnum + P[i]) * z;
            xden = (xden + Q[i]) * z;
        }
        let r = z * (xnum + P[4]) / (xden + Q[4]);
        let r = (1.0 / std::f64::consts::PI.sqrt() - r) / x;
        (-x * x).exp() * r
    }
}

/// Inverse standard normal CDF Φ⁻¹(p) for p ∈ (0, 1).
///
/// Acklam's algorithm + one Halley refinement step against the accurate
/// forward CDF. Panics on p outside (0,1) in debug; clamps in release.
pub fn inv_normal_cdf(p: f64) -> f64 {
    debug_assert!(p > 0.0 && p < 1.0, "inv_normal_cdf domain: got {p}");
    let p = p.clamp(1e-300, 1.0 - 1e-16);

    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;

    let x = if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };

    // Halley refinement against the (accurate) forward CDF.
    let e = normal_cdf(x) - p;
    let u = e * (2.0 * std::f64::consts::PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn erf_known_values() {
        assert!((erf(0.0)).abs() < 1e-15);
        assert!((erf(1.0) - 0.8427007929497149).abs() < 1e-12);
        assert!((erf(-1.0) + 0.8427007929497149).abs() < 1e-12);
        assert!((erf(2.0) - 0.9953222650189527).abs() < 1e-12);
        assert!((erfc(3.0) - 2.2090496998585441e-5).abs() < 1e-15);
    }

    #[test]
    fn cdf_known_values() {
        assert!((normal_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((normal_cdf(1.0) - 0.8413447460685429).abs() < 1e-12);
        assert!((normal_cdf(-1.959963984540054) - 0.025).abs() < 1e-10);
        assert!((normal_cdf(3.0) - 0.9986501019683699).abs() < 1e-12);
    }

    #[test]
    fn inverse_known_values() {
        assert!((inv_normal_cdf(0.975) - 1.959963984540054).abs() < 1e-9);
        assert!((inv_normal_cdf(0.5)).abs() < 1e-9);
        assert!((inv_normal_cdf(0.95) - 1.6448536269514722).abs() < 1e-9);
        assert!((inv_normal_cdf(0.995) - 2.5758293035489004).abs() < 1e-9);
        assert!((inv_normal_cdf(0.025) + 1.959963984540054).abs() < 1e-9);
    }

    #[test]
    fn inverse_round_trip() {
        for i in 1..200 {
            let p = i as f64 / 200.0;
            let x = inv_normal_cdf(p);
            let back = normal_cdf(x);
            assert!((back - p).abs() < 1e-12, "p={p} x={x} back={back}");
        }
    }

    #[test]
    fn inverse_monotone() {
        let mut prev = f64::NEG_INFINITY;
        for i in 1..1000 {
            let p = i as f64 / 1000.0;
            let x = inv_normal_cdf(p);
            assert!(x > prev);
            prev = x;
        }
    }

    #[test]
    fn tails_finite() {
        assert!(inv_normal_cdf(1e-12).is_finite());
        assert!(inv_normal_cdf(1.0 - 1e-12).is_finite());
        assert!(inv_normal_cdf(1e-12) < -6.0);
        assert!(inv_normal_cdf(1.0 - 1e-12) > 6.0);
    }

    #[test]
    fn erfc_symmetry() {
        for &x in &[0.1, 0.7, 1.3, 2.4, 4.5] {
            assert!((erfc(x) + erfc(-x) - 2.0).abs() < 1e-12);
        }
    }
}
