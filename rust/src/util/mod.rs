//! Small self-contained utilities: seeded RNG, inverse normal CDF, JSON
//! writer, CLI parsing, timing, a thread pool, a scratch-buffer arena
//! and an in-repo property-testing helper. The offline build has no
//! `rand`, `serde`, `clap`, `criterion` or `proptest`, so these live
//! here.

pub mod arena;
pub mod cli;
pub mod json;
pub mod ncdf;
pub mod proptest;
pub mod rng;
pub mod threadpool;
pub mod timer;

pub use ncdf::{inv_normal_cdf, normal_cdf};
pub use rng::Rng;
pub use timer::Timer;
