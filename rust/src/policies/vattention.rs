//! vAttention (Algorithm 1): sink + window + predicted top-k heavy
//! hitters, plus a uniformly-sampled residual whose size is chosen by the
//! verified budget machinery (`crate::budget`, Algorithm 2) to meet a
//! user-specified (ε, δ) guarantee on the requested computation
//! (denominator, numerator, or full SDPA).

use super::scorers::{OracleScorer, TopkScorer};
use super::{sink_window_indices, top_indices_excluding, IndexPolicy, PolicyCtx, SizeSpec};
use crate::attention::Selection;
use crate::budget::{self, Bound, QuantSlack, Verify};
use crate::tensor::quant::KvQuantBounds;

/// Configuration for vAttention — mirrors the paper's parameterization
/// (f_s, f_l, f_t, f_b, ε, δ) plus the verified computation
/// ([`Verify`]) and concentration bound ([`Bound`]).
///
/// The symbol-by-symbol map from the paper's Algorithm 1/2 to these
/// fields (and to the `crate::budget` functions behind them) is written
/// out in `docs/GUARANTEES.md`.
#[derive(Clone, Debug)]
pub struct VAttentionConfig {
    pub sink: SizeSpec,
    pub window: SizeSpec,
    /// Heavy-hitter (predicted top-k) budget f_t.
    pub heavy: SizeSpec,
    /// Base sampling rate f_b — fraction of the residual used to estimate
    /// the budget statistics.
    pub base_rate: f64,
    pub eps: f64,
    pub delta: f64,
    pub verify: Verify,
    pub bound: Bound,
    /// Floor the adaptive budget at the base-sample size (the experiments
    /// in the paper lower-cap the computed budget by the base budget).
    pub floor_at_base: bool,
}

impl Default for VAttentionConfig {
    /// The paper's "natural config" (§5, Table 2 / App. I): 128 sink,
    /// 128 window, f_t = 0.05, f_b = 0.05, ε = δ = 0.05.
    fn default() -> Self {
        VAttentionConfig {
            sink: SizeSpec::Abs(128),
            window: SizeSpec::Abs(128),
            heavy: SizeSpec::Frac(0.05),
            base_rate: 0.05,
            eps: 0.05,
            delta: 0.05,
            verify: Verify::Sdpa,
            bound: Bound::Clt,
            floor_at_base: true,
        }
    }
}

impl VAttentionConfig {
    /// Same config under a different user contract (ε, δ). This is the
    /// per-request override the serving session applies when a request
    /// carries its own guarantee (`AttentionOpt::Verified` /
    /// `GenOptions::verified`): everything structural — sink, window,
    /// heavy-hitter budget, base rate, verified computation, bound —
    /// stays put; only the tolerance the budget machinery must certify
    /// changes.
    pub fn with_guarantee(mut self, eps: f64, delta: f64) -> Self {
        self.eps = eps;
        self.delta = delta;
        self
    }

    /// Same config with a different verified computation (denominator,
    /// numerator, or full SDPA).
    pub fn with_verify(mut self, verify: Verify) -> Self {
        self.verify = verify;
        self
    }
}

/// vAttention composed with a pluggable top-k predictor (oracle,
/// HashAttention, …). Produces a [`Selection`] with p = 1 on the
/// deterministic part and p = b/n_s on the sampled residual, plus a
/// diagnostics record ([`BudgetDecision`]) of the adaptive budget
/// decision.
///
/// For cross-step heavy-hitter reuse, wrap this policy in
/// [`crate::policies::TemporalReusePolicy`].
///
/// ```
/// use vattn::policies::{IndexPolicy, PolicyCtx, VAttentionConfig, VAttentionPolicy};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let k = Mat::randn(512, 8, 1.0, &mut rng);
/// let v = Mat::randn(512, 8, 1.0, &mut rng);
/// let q = vec![0.1; 8];
/// let mut policy =
///     VAttentionPolicy::oracle(VAttentionConfig::default().with_guarantee(0.1, 0.1));
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert!(sel.validate(512).is_ok());
/// let decision = policy.last.as_ref().unwrap();
/// assert_eq!(decision.n_fixed + decision.n_s, 512);
/// assert_eq!(sel.len(), decision.n_fixed + decision.budget);
/// ```
pub struct VAttentionPolicy {
    pub cfg: VAttentionConfig,
    pub scorer: Box<dyn TopkScorer>,
    /// Diagnostics from the most recent `select` call.
    pub last: Option<BudgetDecision>,
    /// Dequantization-error bounds of the KV store this policy selects
    /// over (`None` on exact f32 caches; refreshed by the serving
    /// session before every select via [`IndexPolicy::set_kv_quant`]).
    /// When set, the budget runs through
    /// [`crate::budget::budget_for_quant`], so the delivered (ε, δ) is
    /// inclusive of the dequantization error.
    pub kv_quant: Option<KvQuantBounds>,
}

/// Everything the budget module decided for one (head, query) — used by
/// the verification experiments (Figs. 11–18).
#[derive(Clone, Debug)]
pub struct BudgetDecision {
    pub n: usize,
    pub n_fixed: usize,
    pub n_s: usize,
    pub base_size: usize,
    pub budget: usize,
    pub sigma2_d: f64,
    pub trace_sigma_n: f64,
    pub d_hat: f64,
    pub n_hat_norm: f64,
    /// Deterministic relative slack ρ charged to ε for KV
    /// dequantization error (0 on exact f32 caches).
    pub quant_rho: f64,
}

impl VAttentionPolicy {
    pub fn new(cfg: VAttentionConfig, scorer: Box<dyn TopkScorer>) -> Self {
        VAttentionPolicy { cfg, scorer, last: None, kv_quant: None }
    }

    /// vAttention with the oracle top-k predictor.
    pub fn oracle(cfg: VAttentionConfig) -> Self {
        Self::new(cfg, Box::new(OracleScorer))
    }

    /// Everything of [`IndexPolicy::select`] downstream of the scorer:
    /// deterministic-set assembly (Algorithm 1, lines 1–4), base sample,
    /// budget (Algorithm 2), and the residual draw — driven by a
    /// caller-supplied score vector over all `n` tokens.
    ///
    /// `scores_are_logits` must be `true` only when every entry of
    /// `scores` is the *exact* query–key logit (the oracle scorer); the
    /// budget statistics are then computed from `scores` directly
    /// instead of re-scanning K. A caller holding exact logits for only
    /// a *subset* of tokens (`crate::policies::TemporalReusePolicy`'s
    /// verified-reuse fast path, which fills the rest with `-inf`) must
    /// pass `false`, so the statistics re-derive each needed logit from
    /// K — bitwise the same values, since both paths evaluate the same
    /// `tensor::dot`.
    ///
    /// `score_err` is the interval half-width the scorer declared for
    /// `scores` ([`crate::policies::ScoredLogits::err`]); when `Some`,
    /// it becomes the budget's quantization logit slack directly, so
    /// the ε the budget charges is exactly the interval the scorer
    /// advertised. `None` (a score vector that is not a scorer product,
    /// e.g. the reuse fast path's partial fill) falls back to the
    /// bounds-derived term.
    pub fn select_from_scores(
        &mut self,
        ctx: &mut PolicyCtx,
        scores: &[f32],
        scores_are_logits: bool,
        score_err: Option<f32>,
    ) -> Selection {
        let n = ctx.n();
        let cfg = &self.cfg;

        // ── Algorithm 1, lines 1–4: deterministic index set I_f ──
        let fixed = sink_window_indices(n, cfg.sink.resolve(n), cfg.window.resolve(n));
        let mut i_f = fixed;
        let top = top_indices_excluding(scores, cfg.heavy.resolve(n), &i_f);
        i_f.extend(top);
        i_f.sort_unstable();

        let n_s = n - i_f.len();
        if n_s == 0 {
            self.last = Some(BudgetDecision {
                n,
                n_fixed: i_f.len(),
                n_s: 0,
                base_size: 0,
                budget: 0,
                sigma2_d: 0.0,
                trace_sigma_n: 0.0,
                d_hat: 0.0,
                n_hat_norm: 0.0,
                quant_rho: 0.0,
            });
            return Selection::deterministic(i_f);
        }

        // ── Algorithm 2: base sample → statistics → budget ──
        // When the scorer already produced exact logits (oracle), reuse
        // them for m_ref and the stats — K is scanned exactly once per
        // select (§Perf iteration 4).
        let m_ref = if scores_are_logits {
            let m = i_f.iter().map(|&i| scores[i]).fold(f32::NEG_INFINITY, f32::max);
            if m.is_finite() {
                m
            } else {
                0.0
            }
        } else {
            self.m_ref(ctx, &i_f)
        };
        let base = budget::draw_base_sample(n, &i_f, cfg.base_rate, ctx.rng);
        let stats = if scores_are_logits {
            budget::estimate_stats_from_logits(scores, ctx.v, &i_f, &base, m_ref)
        } else {
            budget::estimate_stats(ctx.k, ctx.v, ctx.q_scaled, &i_f, &base, m_ref)
        };
        // Quantized KV: the dequantization bounds become an explicit
        // slack term — σ/range widening plus an ε reduction by the
        // deterministic bias ρ — so the delivered guarantee is
        // (ε, δ) inclusive of the quantization error (GUARANTEES.md §8).
        // The scorer's declared interval half-width, when present, IS
        // the logit term.
        let qslack = self.kv_quant.and_then(|b| {
            let mut s = QuantSlack::from_bounds(&b, ctx.q_scaled, ctx.v.cols);
            if let Some(err) = score_err {
                s.logit_err = err as f64;
            }
            (!s.is_zero()).then_some(s)
        });
        let quant_rho = qslack.as_ref().map_or(0.0, |s| s.rho(&stats, cfg.verify));
        let mut b =
            budget::budget_for_quant(&stats, cfg.verify, cfg.eps, cfg.delta, cfg.bound, qslack.as_ref());
        if cfg.floor_at_base {
            b = b.max(base.len());
        }
        b = b.min(n_s);

        self.last = Some(BudgetDecision {
            n,
            n_fixed: i_f.len(),
            n_s,
            base_size: base.len(),
            budget: b,
            sigma2_d: stats.sigma2_d,
            trace_sigma_n: stats.trace_sigma_n,
            d_hat: stats.d_hat,
            n_hat_norm: stats.n_hat_norm,
            quant_rho,
        });

        // ── Algorithm 1, lines 7–10: uniform residual sample ──
        if b == 0 {
            return Selection::deterministic(i_f);
        }
        let dyn_idx = ctx.rng.sample_excluding(n, b, &i_f);
        let p_dyn = b as f32 / n_s as f32;
        Selection::compose(i_f, dyn_idx, p_dyn)
    }

    /// Reference logit for stabilized budget statistics: the max logit
    /// over the deterministic set (heavy hitters dominate, so this keeps
    /// every exp() ≤ ~1 and the ratios well-scaled).
    fn m_ref(&self, ctx: &PolicyCtx, i_f: &[usize]) -> f32 {
        let mut m = f32::NEG_INFINITY;
        for &i in i_f {
            let l = crate::tensor::dot(ctx.k.row(i), ctx.q_scaled);
            if l > m {
                m = l;
            }
        }
        if m.is_finite() {
            m
        } else {
            0.0
        }
    }
}

impl IndexPolicy for VAttentionPolicy {
    fn name(&self) -> String {
        format!("vattention({})", self.scorer.name())
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let scored = self.scorer.score_intervals(ctx, self.kv_quant);
        let scores_are_logits = self.scorer.scores_are_logits();
        let err = (scored.err > 0.0).then_some(scored.err);
        self.select_from_scores(ctx, &scored.scores, scores_are_logits, err)
    }

    fn reset(&mut self) {
        self.scorer.reset();
        self.last = None;
    }

    fn set_kv_quant(&mut self, bounds: Option<KvQuantBounds>) {
        self.kv_quant = bounds;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::{dense_sdpa, sparse_sdpa};
    use crate::tensor::{rel_l2_error, Mat};
    use crate::util::Rng;

    fn fixture(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
        (k, v, q, rng)
    }

    fn small_cfg(eps: f64, delta: f64) -> VAttentionConfig {
        VAttentionConfig {
            sink: SizeSpec::Abs(8),
            window: SizeSpec::Abs(8),
            heavy: SizeSpec::Frac(0.05),
            base_rate: 0.05,
            eps,
            delta,
            verify: Verify::Sdpa,
            bound: Bound::Clt,
            floor_at_base: true,
        }
    }

    #[test]
    fn selection_valid_and_budget_recorded() {
        let (k, v, q, mut rng) = fixture(2000, 16, 1);
        let mut pol = VAttentionPolicy::oracle(small_cfg(0.1, 0.1));
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert!(sel.validate(2000).is_ok(), "{:?}", sel.validate(2000));
        let dec = pol.last.as_ref().unwrap();
        assert_eq!(dec.n, 2000);
        assert_eq!(dec.n_fixed + dec.n_s, 2000);
        assert!(dec.budget >= dec.base_size); // floor_at_base
        assert_eq!(sel.len(), dec.n_fixed + dec.budget);
    }

    #[test]
    fn tighter_eps_gives_bigger_budget() {
        let (k, v, q, mut rng) = fixture(4000, 16, 2);
        let budget_at = |eps: f64, rng: &mut Rng| {
            let mut cfg = small_cfg(eps, 0.1);
            cfg.floor_at_base = false;
            // Denominator guarantee: on mean-zero random values the
            // numerator guarantee saturates at n_s (correct but
            // uninformative for monotonicity).
            cfg.verify = Verify::Denominator;
            let mut pol = VAttentionPolicy::oracle(cfg);
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng, step: 0 };
            pol.select(&mut ctx);
            pol.last.unwrap().budget
        };
        let tight = budget_at(0.1, &mut rng);
        let loose = budget_at(0.5, &mut rng);
        assert!(tight > loose, "tight={tight} loose={loose}");
    }

    #[test]
    fn empirical_error_within_eps_most_of_the_time() {
        // The (ε, δ) guarantee, checked empirically: at ε=0.15, δ=0.1 the
        // attention error should exceed ε in well under ~δ+slack of trials.
        let (k, v, q, mut rng) = fixture(3000, 16, 3);
        let exact = dense_sdpa(&k, &v, &q).out;
        let mut failures = 0;
        let trials = 60;
        for t in 0..trials {
            let mut pol = VAttentionPolicy::oracle(small_cfg(0.15, 0.1));
            let mut fork = rng.fork(t as u64);
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut fork, step: 0 };
            let sel = pol.select(&mut ctx);
            let approx = sparse_sdpa(&k, &v, &q, &sel);
            if rel_l2_error(&approx, &exact) > 0.15 {
                failures += 1;
            }
        }
        // δ = 0.1 → expect ≤ ~6 failures in 60; allow generous slack for
        // the CLT approximation.
        assert!(failures <= 12, "failures={failures}/{trials}");
    }

    #[test]
    fn no_residual_degenerates_to_deterministic() {
        let (k, v, q, mut rng) = fixture(20, 8, 4);
        let mut cfg = small_cfg(0.1, 0.1);
        cfg.sink = SizeSpec::Abs(10);
        cfg.window = SizeSpec::Abs(10);
        let mut pol = VAttentionPolicy::oracle(cfg);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert_eq!(sel.len(), 20);
        assert!(sel.prob.iter().all(|&p| p == 1.0));
        assert_eq!(pol.last.as_ref().unwrap().n_s, 0);
    }

    #[test]
    fn hoeffding_budget_larger_than_clt() {
        let (k, v, q, mut rng) = fixture(4000, 16, 5);
        let budget_with = |bound: Bound, rng: &mut Rng| {
            let mut cfg = small_cfg(0.1, 0.2);
            cfg.bound = bound;
            cfg.verify = Verify::Denominator;
            cfg.floor_at_base = false;
            let mut pol = VAttentionPolicy::oracle(cfg);
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng, step: 0 };
            pol.select(&mut ctx);
            pol.last.unwrap().budget
        };
        let clt = budget_with(Bound::Clt, &mut rng);
        let hoef = budget_with(Bound::Hoeffding, &mut rng);
        assert!(hoef >= clt, "hoef={hoef} clt={clt}");
    }

    #[test]
    fn kv_quant_bounds_inflate_budget_and_record_rho() {
        let (k, v, q, mut rng) = fixture(4000, 16, 8);
        let run = |bounds: Option<KvQuantBounds>, rng: &mut Rng| {
            let mut cfg = small_cfg(0.1, 0.1);
            cfg.floor_at_base = false;
            cfg.verify = Verify::Denominator;
            let mut pol = VAttentionPolicy::oracle(cfg);
            pol.set_kv_quant(bounds);
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng, step: 0 };
            pol.select(&mut ctx);
            let dec = pol.last.unwrap();
            (dec.budget, dec.quant_rho)
        };
        let (plain, rho0) = run(None, &mut rng);
        assert_eq!(rho0, 0.0);
        let bounds = KvQuantBounds { k_scale_max: 0.02, v_scale_max: 0.02 };
        let (widened, rho) = run(Some(bounds), &mut rng);
        assert!(rho > 0.0, "quantized select must record its slack");
        assert!(
            widened >= plain,
            "quantization slack must never shrink the budget: {widened} < {plain}"
        );
        // ε smaller than the bias: the budget saturates at the residual.
        let (saturated, _) = {
            let mut cfg = small_cfg(0.1, 0.1);
            cfg.floor_at_base = false;
            cfg.verify = Verify::Denominator;
            let mut pol = VAttentionPolicy::oracle(cfg);
            pol.set_kv_quant(Some(KvQuantBounds { k_scale_max: 10.0, v_scale_max: 0.0 }));
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
            pol.select(&mut ctx);
            let dec = pol.last.unwrap();
            (dec.budget, dec.quant_rho)
        };
        let n_fixed = {
            let mut cfg = small_cfg(0.1, 0.1);
            cfg.verify = Verify::Denominator;
            let mut pol = VAttentionPolicy::oracle(cfg);
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
            pol.select(&mut ctx);
            pol.last.unwrap().n_fixed
        };
        assert_eq!(saturated, 4000 - n_fixed, "rho ≥ ε must sample the whole residual");
    }

    #[test]
    fn flat_distribution_needs_fewer_samples_than_sharp_tail() {
        // Uniform scores -> tiny variance -> budget collapses to the floor.
        let d = 16;
        let n = 4000;
        let k_flat = Mat::from_fn(n, d, |_, c| if c == 0 { 1.0 } else { 0.0 });
        let v = Mat::from_fn(n, d, |_, _| 1.0);
        let q = vec![1.0; d];
        let mut cfg = small_cfg(0.05, 0.05);
        cfg.floor_at_base = false;
        let mut pol = VAttentionPolicy::oracle(cfg);
        let mut rng = Rng::new(6);
        let mut ctx = PolicyCtx { k: &k_flat, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        pol.select(&mut ctx);
        let flat_budget = pol.last.unwrap().budget;
        assert!(flat_budget < 50, "flat budget should be tiny, got {flat_budget}");
    }
}
