//! Index-selection policies: the paper's vAttention (§4) plus every
//! baseline the evaluation compares against — StreamingLLM-style
//! sink+window, oracle top-k / top-p, uniform random sampling, the
//! oracle-top+sample hybrid of §3, MagicPig (LSH sampling), and the
//! approximate-top-k family (HashAttention, DoubleSparsity, Quest,
//! PQCache, InfLLM), plus history-based H2O and SnapKV.
//!
//! A policy maps (KV cache, query) → [`Selection`] (indices +
//! probabilities). Attention itself is computed by
//! [`crate::attention::sparse_sdpa`] over that selection; quality
//! metrics compare against [`crate::attention::dense_sdpa`].
//!
//! Cross-step *temporal reuse* of heavy-hitter selections lives in
//! [`reuse`]: [`TemporalReusePolicy`] wraps a [`VAttentionPolicy`] and
//! skips the full top-k re-score whenever a drift certificate proves
//! the cached selection is still exact (see `docs/GUARANTEES.md` §6).

pub mod heavy;
pub mod magicpig;
pub mod oracle;
pub mod reuse;
pub mod scorers;
pub mod vattention;

pub use heavy::{HeavyHitterPolicy, SinkWindowPolicy, SnapKvPolicy, H2OPolicy};
pub use magicpig::MagicPigPolicy;
pub use oracle::{HybridTopSamplePolicy, OracleTopKPolicy, OracleTopPPolicy, RandomSamplePolicy};
pub use reuse::{ReuseConfig, ReuseStats, TemporalReusePolicy};
pub use scorers::{ScoredLogits, TopkScorer};
pub use vattention::{BudgetDecision, VAttentionConfig, VAttentionPolicy};

use crate::attention::Selection;
use crate::tensor::quant::KvQuantBounds;
use crate::tensor::Mat;
use crate::util::Rng;

/// Everything a policy may look at when selecting indices for one
/// (head, query) attention computation.
pub struct PolicyCtx<'a> {
    pub k: &'a Mat,
    pub v: &'a Mat,
    /// Query pre-scaled by 1/√d.
    pub q_scaled: &'a [f32],
    pub rng: &'a mut Rng,
    /// Generation step (0 for the first sparse query); history-based
    /// policies (H2O, SnapKV) key their state off monotone steps.
    pub step: usize,
}

impl<'a> PolicyCtx<'a> {
    pub fn n(&self) -> usize {
        self.k.rows
    }
}

/// An index-selection policy. `select` may mutate internal state
/// (auxiliary caches, accumulated scores); `reset` clears per-sequence
/// state between requests — and between a preemption and its replay,
/// which is what keeps replayed token streams byte-identical.
///
/// ```
/// use vattn::policies::{IndexPolicy, PolicyCtx, SinkWindowPolicy};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let k = Mat::randn(64, 8, 1.0, &mut rng);
/// let v = Mat::randn(64, 8, 1.0, &mut rng);
/// let q = vec![0.1; 8];
/// let mut policy = SinkWindowPolicy::new(4, 8);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert_eq!(sel.len(), 12);
/// assert!(sel.validate(64).is_ok());
/// ```
pub trait IndexPolicy: Send {
    fn name(&self) -> String;
    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection;
    fn reset(&mut self) {}
    /// Cross-step reuse counters, for policies that cache selections
    /// across decode steps ([`TemporalReusePolicy`]). `None` for
    /// stateless policies; the serving session aggregates `Some`
    /// returns into [`crate::server::SessionStats`].
    fn reuse_stats(&self) -> Option<&ReuseStats> {
        None
    }
    /// Hand the policy the dequantization-error bounds of the KV rows
    /// it is about to select over (`None` for exact f32 caches). The
    /// serving session calls this before every `select` on a quantized
    /// cache; policies that certify accuracy — [`VAttentionPolicy`]'s
    /// (ε, δ) budget, [`TemporalReusePolicy`]'s drift certificate —
    /// widen their math by the bound (docs/GUARANTEES.md §8). Heuristic
    /// baselines, which promise no contract, ignore it (the default).
    fn set_kv_quant(&mut self, _bounds: Option<KvQuantBounds>) {}
}

/// Size given either as an absolute token count or a fraction of n.
#[derive(Clone, Copy, Debug)]
pub enum SizeSpec {
    Abs(usize),
    Frac(f64),
}

impl SizeSpec {
    pub fn resolve(&self, n: usize) -> usize {
        match *self {
            SizeSpec::Abs(a) => a.min(n),
            SizeSpec::Frac(f) => ((f * n as f64).floor() as usize).min(n),
        }
    }
}

/// Sink (first `sink`) + local-window (last `window`) indices, deduped
/// when they overlap; always sorted ascending.
pub fn sink_window_indices(n: usize, sink: usize, window: usize) -> Vec<usize> {
    let sink = sink.min(n);
    let win_start = n.saturating_sub(window).max(sink);
    let mut idx: Vec<usize> = (0..sink).collect();
    idx.extend(win_start..n);
    idx
}

/// Merge deterministic index groups into a sorted, deduped vector.
pub fn merge_sorted_unique(groups: &[&[usize]]) -> Vec<usize> {
    let mut all: Vec<usize> = groups.iter().flat_map(|g| g.iter().copied()).collect();
    all.sort_unstable();
    all.dedup();
    all
}

/// Indices of the `count` largest entries of `scores`, excluding the
/// sorted `excluded` set. Uses partial selection (O(n) average) instead
/// of a full sort — this is on the decode hot path.
pub fn top_indices_excluding(scores: &[f32], count: usize, excluded_sorted: &[usize]) -> Vec<usize> {
    let mut cand: Vec<u32> = Vec::with_capacity(scores.len());
    let mut ex = excluded_sorted.iter().peekable();
    for i in 0..scores.len() {
        if ex.peek() == Some(&&i) {
            ex.next();
        } else {
            cand.push(i as u32);
        }
    }
    let count = count.min(cand.len());
    if count == 0 {
        return Vec::new();
    }
    if count < cand.len() {
        cand.select_nth_unstable_by(count - 1, |&a, &b| {
            scores[b as usize]
                .partial_cmp(&scores[a as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        cand.truncate(count);
    }
    cand.into_iter().map(|i| i as usize).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_window_basic() {
        assert_eq!(sink_window_indices(10, 2, 3), vec![0, 1, 7, 8, 9]);
    }

    #[test]
    fn sink_window_overlap() {
        // window reaches into the sink region: no duplicates.
        let idx = sink_window_indices(5, 3, 4);
        assert_eq!(idx, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn sink_window_degenerate() {
        assert_eq!(sink_window_indices(3, 10, 10), vec![0, 1, 2]);
        assert_eq!(sink_window_indices(0, 2, 2), Vec::<usize>::new());
    }

    #[test]
    fn size_spec() {
        assert_eq!(SizeSpec::Abs(128).resolve(1000), 128);
        assert_eq!(SizeSpec::Abs(128).resolve(64), 64);
        assert_eq!(SizeSpec::Frac(0.1).resolve(1000), 100);
        assert_eq!(SizeSpec::Frac(2.0).resolve(10), 10);
    }

    #[test]
    fn top_indices_simple() {
        let scores = vec![0.1, 5.0, 3.0, 4.0, 0.2];
        let mut top = top_indices_excluding(&scores, 2, &[]);
        top.sort_unstable();
        assert_eq!(top, vec![1, 3]);
    }

    #[test]
    fn top_indices_respects_exclusion() {
        let scores = vec![0.1, 5.0, 3.0, 4.0, 0.2];
        let mut top = top_indices_excluding(&scores, 2, &[1, 3]);
        top.sort_unstable();
        assert_eq!(top, vec![2, 4]);
    }

    #[test]
    fn top_indices_count_larger_than_candidates() {
        let scores = vec![1.0, 2.0];
        let top = top_indices_excluding(&scores, 10, &[0]);
        assert_eq!(top, vec![1]);
    }

    #[test]
    fn merge_sorted_unique_dedups() {
        let a = vec![1, 3, 5];
        let b = vec![2, 3, 4];
        assert_eq!(merge_sorted_unique(&[&a, &b]), vec![1, 2, 3, 4, 5]);
    }
}
