//! Approximate top-k scorers: each assigns a (cheap) relevance score to
//! every cached token; a policy then keeps the `count` best. This is the
//! common abstraction behind the approximate-top-k family (App. B.3):
//!
//! * `OracleScorer`        — exact logits (the top-k gold standard);
//! * `HashSignScorer`      — HashAttention-style bit signatures compared
//!                           in Hamming space (32 bits/token/head). The
//!                           paper's signatures are *learned*; we use
//!                           random-rotation sign signatures (see
//!                           DESIGN.md §3 substitutions);
//! * `DoubleSparsityScorer`— partial-channel inner products;
//! * `QuestScorer`         — page-level min/max upper bounds;
//! * `PqScorer`            — product-quantized keys with LUT scoring;
//! * `BlockMeanScorer`     — InfLLM-style page-mean representatives.
//!
//! Scorers keep auxiliary state (signatures, codebooks, page summaries)
//! that is built incrementally as the KV cache grows — mirroring how the
//! real systems maintain their aux caches during generation.

use super::PolicyCtx;
use crate::tensor::quant::KvQuantBounds;
use crate::tensor::{dot, Mat};
use crate::util::Rng;

/// A score vector plus the half-width of the logit interval each score
/// defines when the keys were dequantized from a lossy store: for a
/// logit-exact scorer over a quantized cache, the exact
/// pre-quantization logit of token i is guaranteed to lie in
/// `[scores[i] − err, scores[i] + err]` (with `err = (max_k_scale/2)·‖q‖₁`,
/// see [`KvQuantBounds::logit_err`]). `err = 0` for exact f32 caches
/// and for scorers whose scores are not logits (their output has no
/// logit-interval interpretation; the budget stats re-derive logits
/// from K and absorb the quantization slack there instead).
pub struct ScoredLogits {
    pub scores: Vec<f32>,
    pub err: f32,
}

/// A token scorer used for approximate top-k selection.
///
/// ```
/// use vattn::policies::scorers::{OracleScorer, TopkScorer};
/// use vattn::policies::PolicyCtx;
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(64, 8, 1.0, &mut rng), Mat::randn(64, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut scorer = OracleScorer;
/// let scores =
///     scorer.score(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert_eq!(scores.len(), 64);
/// assert!(scorer.scores_are_logits()); // oracle scores ARE the exact logits
/// ```
pub trait TopkScorer: Send {
    fn name(&self) -> String;
    /// Score every token in the cache (higher = more likely top-k).
    fn score(&mut self, ctx: &mut PolicyCtx) -> Vec<f32>;
    /// Clear per-sequence auxiliary state.
    fn reset(&mut self) {}
    /// Auxiliary memory in bits per token per head (for Table-9-style
    /// accounting).
    fn aux_bits_per_token(&self) -> usize {
        0
    }
    /// True when `score` returns the *exact* query–key logits (the oracle
    /// scorer) — exact over the rows actually stored, i.e. over the
    /// dequantized mirror when the cache is quantized. Consumers
    /// (vAttention's budget path) then reuse the score vector instead
    /// of re-scanning K — a full-scan saving per select.
    fn scores_are_logits(&self) -> bool {
        false
    }

    /// [`TopkScorer::score`] plus the quantization interval: over a
    /// quantized cache (`quant = Some`), a logit-exact scorer's scores
    /// bracket the exact pre-quantization logits within
    /// `[score − err, score + err]`. This is the surface the verified
    /// stack consumes — the interval half-width feeds the budget's
    /// [`crate::budget::QuantSlack`] and the reuse certificate's prune
    /// slack.
    fn score_intervals(
        &mut self,
        ctx: &mut PolicyCtx,
        quant: Option<KvQuantBounds>,
    ) -> ScoredLogits {
        let scores = self.score(ctx);
        let err = match quant {
            Some(b) if self.scores_are_logits() => b.logit_err(ctx.q_scaled),
            _ => 0.0,
        };
        ScoredLogits { scores, err }
    }
}

/// Exact logits — the oracle.
pub struct OracleScorer;

impl TopkScorer for OracleScorer {
    fn name(&self) -> String {
        "oracle".into()
    }
    fn score(&mut self, ctx: &mut PolicyCtx) -> Vec<f32> {
        crate::attention::logits_all(ctx.k, ctx.q_scaled)
    }
    fn scores_are_logits(&self) -> bool {
        true
    }
}

/// HashAttention-style: `bits` random-hyperplane sign bits per token;
/// score = negative Hamming distance to the query signature.
pub struct HashSignScorer {
    pub bits: usize,
    planes: Option<Mat>, // bits × d random projections
    sigs: Vec<u32>,      // one 32-bit signature per cached token
    seed: u64,
}

impl HashSignScorer {
    pub fn new(bits: usize, seed: u64) -> Self {
        assert!(bits <= 32, "signature packed in u32");
        HashSignScorer { bits, planes: None, sigs: Vec::new(), seed }
    }

    fn sig_of(&self, x: &[f32]) -> u32 {
        let planes = self.planes.as_ref().unwrap();
        let mut s = 0u32;
        for b in 0..self.bits {
            if dot(planes.row(b), x) >= 0.0 {
                s |= 1 << b;
            }
        }
        s
    }

    fn sync(&mut self, k: &Mat) {
        if self.planes.is_none() {
            let mut rng = Rng::new(self.seed);
            self.planes = Some(Mat::randn(self.bits, k.cols, 1.0, &mut rng));
        }
        // If the cache was reset (shrunk), rebuild from scratch.
        if self.sigs.len() > k.rows {
            self.sigs.clear();
        }
        for i in self.sigs.len()..k.rows {
            let s = self.sig_of(k.row(i));
            self.sigs.push(s);
        }
    }
}

impl TopkScorer for HashSignScorer {
    fn name(&self) -> String {
        format!("hashattention({}b)", self.bits)
    }

    fn score(&mut self, ctx: &mut PolicyCtx) -> Vec<f32> {
        self.sync(ctx.k);
        let qs = self.sig_of(ctx.q_scaled);
        self.sigs
            .iter()
            .map(|&s| -(((s ^ qs).count_ones()) as f32))
            .collect()
    }

    fn reset(&mut self) {
        self.sigs.clear();
    }

    fn aux_bits_per_token(&self) -> usize {
        self.bits
    }
}

/// DoubleSparsity: score with only the `r` channels where |q| is largest
/// (the paper calibrates channels offline; per-query selection is the
/// natural online analogue and upper-bounds its fidelity).
pub struct DoubleSparsityScorer {
    pub channels: usize,
}

impl TopkScorer for DoubleSparsityScorer {
    fn name(&self) -> String {
        format!("double-sparsity({}ch)", self.channels)
    }

    fn score(&mut self, ctx: &mut PolicyCtx) -> Vec<f32> {
        let d = ctx.q_scaled.len();
        let r = self.channels.min(d);
        // top-r channels of |q|, in an arena-recycled index buffer (this
        // runs once per decode step; the selection itself is unchanged).
        let mut ch = crate::util::arena::take_usize();
        ch.extend(0..d);
        ch.select_nth_unstable_by(r.saturating_sub(1).min(d - 1), |&a, &b| {
            ctx.q_scaled[b]
                .abs()
                .partial_cmp(&ctx.q_scaled[a].abs())
                .unwrap()
        });
        ch.truncate(r);
        let out: Vec<f32> = (0..ctx.n())
            .map(|i| {
                let row = ctx.k.row(i);
                ch.iter().map(|&c| row[c] * ctx.q_scaled[c]).sum()
            })
            .collect();
        crate::util::arena::recycle_usize(ch);
        out
    }

    fn aux_bits_per_token(&self) -> usize {
        self.channels * 2 // paper's config: r channels at ~2 bits effective
    }
}

/// Quest: pages of `page` tokens; per page keep elementwise min/max of
/// keys; a page's (and thus each member token's) score is the upper bound
/// Σ_c max(q_c·min_c, q_c·max_c).
pub struct QuestScorer {
    pub page: usize,
    mins: Vec<Vec<f32>>, // per full page
    maxs: Vec<Vec<f32>>,
    rows_seen: usize,
}

impl QuestScorer {
    pub fn new(page: usize) -> Self {
        QuestScorer { page, mins: Vec::new(), maxs: Vec::new(), rows_seen: 0 }
    }

    fn sync(&mut self, k: &Mat) {
        if self.rows_seen > k.rows {
            self.mins.clear();
            self.maxs.clear();
            self.rows_seen = 0;
        }
        // Build summaries for complete pages only; the trailing partial
        // page is scored exactly (it is the local window anyway).
        let full_pages = k.rows / self.page;
        while self.mins.len() < full_pages {
            let p = self.mins.len();
            let lo = p * self.page;
            let mut mn = k.row(lo).to_vec();
            let mut mx = k.row(lo).to_vec();
            for i in lo + 1..lo + self.page {
                for (c, &x) in k.row(i).iter().enumerate() {
                    if x < mn[c] {
                        mn[c] = x;
                    }
                    if x > mx[c] {
                        mx[c] = x;
                    }
                }
            }
            self.mins.push(mn);
            self.maxs.push(mx);
        }
        self.rows_seen = k.rows;
    }
}

impl TopkScorer for QuestScorer {
    fn name(&self) -> String {
        format!("quest(pg={})", self.page)
    }

    fn score(&mut self, ctx: &mut PolicyCtx) -> Vec<f32> {
        self.sync(ctx.k);
        let n = ctx.n();
        let mut out = vec![0.0f32; n];
        for p in 0..self.mins.len() {
            let mut ub = 0.0f32;
            for c in 0..ctx.q_scaled.len() {
                let q = ctx.q_scaled[c];
                ub += (q * self.mins[p][c]).max(q * self.maxs[p][c]);
            }
            for i in p * self.page..(p + 1) * self.page {
                out[i] = ub;
            }
        }
        // trailing partial page: exact logits
        for i in self.mins.len() * self.page..n {
            out[i] = dot(ctx.k.row(i), ctx.q_scaled);
        }
        out
    }

    fn reset(&mut self) {
        self.mins.clear();
        self.maxs.clear();
        self.rows_seen = 0;
    }

    fn aux_bits_per_token(&self) -> usize {
        // 2 vectors of d f16s per page of 16 at d=128 ≈ 32 bits/token/head
        32
    }
}

/// PQCache: product quantization of keys. The key space is split into
/// `m` sub-spaces; each gets a `cents`-entry codebook trained online by
/// k-means over the first `train_after` cached keys; scoring is a lookup
/// table of centroid·q_sub partial dots.
pub struct PqScorer {
    pub m: usize,
    pub cents: usize,
    pub train_after: usize,
    codebooks: Option<Vec<Mat>>, // m codebooks, each cents × sub_d
    codes: Vec<u8>,              // m codes per token, flattened
    rows_seen: usize,
    seed: u64,
}

impl PqScorer {
    pub fn new(m: usize, cents: usize, seed: u64) -> Self {
        assert!(cents <= 256);
        PqScorer { m, cents, train_after: 64, codebooks: None, codes: Vec::new(), rows_seen: 0, seed }
    }

    fn train(&mut self, k: &Mat) {
        let d = k.cols;
        assert!(d % self.m == 0, "d must be divisible by m");
        let sub = d / self.m;
        let n_train = k.rows.min(4096);
        let mut rng = Rng::new(self.seed);
        let mut books = Vec::with_capacity(self.m);
        for s in 0..self.m {
            // init centroids from random training rows
            let mut cb = Mat::zeros(self.cents, sub);
            for c in 0..self.cents {
                let r = rng.below(n_train);
                cb.row_mut(c).copy_from_slice(&k.row(r)[s * sub..(s + 1) * sub]);
            }
            // a few Lloyd iterations
            for _ in 0..4 {
                let mut sums = vec![vec![0.0f64; sub]; self.cents];
                let mut counts = vec![0usize; self.cents];
                for i in 0..n_train {
                    let x = &k.row(i)[s * sub..(s + 1) * sub];
                    let c = nearest_centroid(&cb, x);
                    counts[c] += 1;
                    for (j, &xv) in x.iter().enumerate() {
                        sums[c][j] += xv as f64;
                    }
                }
                for c in 0..self.cents {
                    if counts[c] > 0 {
                        for j in 0..sub {
                            cb.set(c, j, (sums[c][j] / counts[c] as f64) as f32);
                        }
                    }
                }
            }
            books.push(cb);
        }
        self.codebooks = Some(books);
    }

    fn encode_rows(&mut self, k: &Mat) {
        let sub = k.cols / self.m;
        let books = self.codebooks.as_ref().unwrap();
        for i in self.rows_seen..k.rows {
            for s in 0..self.m {
                let x = &k.row(i)[s * sub..(s + 1) * sub];
                self.codes.push(nearest_centroid(&books[s], x) as u8);
            }
        }
        self.rows_seen = k.rows;
    }
}

fn nearest_centroid(cb: &Mat, x: &[f32]) -> usize {
    let mut best = 0;
    let mut best_d = f32::INFINITY;
    for c in 0..cb.rows {
        let row = cb.row(c);
        let mut d2 = 0.0f32;
        for (a, b) in row.iter().zip(x.iter()) {
            let t = a - b;
            d2 += t * t;
        }
        if d2 < best_d {
            best_d = d2;
            best = c;
        }
    }
    best
}

impl TopkScorer for PqScorer {
    fn name(&self) -> String {
        format!("pqcache(m={},c={})", self.m, self.cents)
    }

    fn score(&mut self, ctx: &mut PolicyCtx) -> Vec<f32> {
        if self.rows_seen > ctx.k.rows {
            self.reset();
        }
        if self.codebooks.is_none() {
            self.train(ctx.k);
        }
        self.encode_rows(ctx.k);
        let sub = ctx.k.cols / self.m;
        let books = self.codebooks.as_ref().unwrap();
        // LUT: partial dot of every centroid with the query sub-vector.
        let mut lut = vec![0.0f32; self.m * self.cents];
        for s in 0..self.m {
            let qsub = &ctx.q_scaled[s * sub..(s + 1) * sub];
            for c in 0..self.cents {
                lut[s * self.cents + c] = dot(books[s].row(c), qsub);
            }
        }
        (0..ctx.n())
            .map(|i| {
                let codes = &self.codes[i * self.m..(i + 1) * self.m];
                codes
                    .iter()
                    .enumerate()
                    .map(|(s, &c)| lut[s * self.cents + c as usize])
                    .sum()
            })
            .collect()
    }

    fn reset(&mut self) {
        self.codebooks = None;
        self.codes.clear();
        self.rows_seen = 0;
    }

    fn aux_bits_per_token(&self) -> usize {
        self.m * (self.cents as f64).log2().ceil() as usize
    }
}

/// InfLLM-style block-mean representatives: score every token with the
/// inner product of its page's mean key and the query.
pub struct BlockMeanScorer {
    pub page: usize,
    means: Vec<Vec<f32>>,
    rows_seen: usize,
}

impl BlockMeanScorer {
    pub fn new(page: usize) -> Self {
        BlockMeanScorer { page, means: Vec::new(), rows_seen: 0 }
    }
}

impl TopkScorer for BlockMeanScorer {
    fn name(&self) -> String {
        format!("infllm(pg={})", self.page)
    }

    fn score(&mut self, ctx: &mut PolicyCtx) -> Vec<f32> {
        let k = ctx.k;
        if self.rows_seen > k.rows {
            self.means.clear();
        }
        let full = k.rows / self.page;
        while self.means.len() < full {
            let p = self.means.len();
            let mut mean = vec![0.0f32; k.cols];
            for i in p * self.page..(p + 1) * self.page {
                crate::tensor::axpy(1.0 / self.page as f32, k.row(i), &mut mean);
            }
            self.means.push(mean);
        }
        self.rows_seen = k.rows;
        let n = ctx.n();
        let mut out = vec![0.0f32; n];
        for p in 0..self.means.len() {
            let s = dot(&self.means[p], ctx.q_scaled);
            for i in p * self.page..(p + 1) * self.page {
                out[i] = s;
            }
        }
        for i in full * self.page..n {
            out[i] = dot(k.row(i), ctx.q_scaled);
        }
        out
    }

    fn reset(&mut self) {
        self.means.clear();
        self.rows_seen = 0;
    }

    fn aux_bits_per_token(&self) -> usize {
        256 / self.page.max(1) // one f16 d-vector per page, d≈128
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::PolicyCtx;
    use crate::tensor::Mat;

    fn fixture(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
        (k, v, q, rng)
    }

    /// Recall of scorer-top-k against oracle-top-k.
    fn recall_at(scorer: &mut dyn TopkScorer, k: &Mat, v: &Mat, q: &[f32], rng: &mut Rng, kk: usize) -> f64 {
        let mut ctx = PolicyCtx { k, v, q_scaled: q, rng, step: 0 };
        let approx = scorer.score(&mut ctx);
        let exact = crate::attention::logits_all(k, q);
        let top_a = super::super::top_indices_excluding(&approx, kk, &[]);
        let top_e = super::super::top_indices_excluding(&exact, kk, &[]);
        let set: std::collections::HashSet<_> = top_e.into_iter().collect();
        top_a.iter().filter(|i| set.contains(i)).count() as f64 / kk as f64
    }

    #[test]
    fn oracle_scorer_recall_is_one() {
        let (k, v, q, mut rng) = fixture(400, 32, 1);
        let mut s = OracleScorer;
        assert!((recall_at(&mut s, &k, &v, &q, &mut rng, 20) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn hash_scorer_beats_random_recall() {
        let (k, v, q, mut rng) = fixture(800, 32, 2);
        let mut s = HashSignScorer::new(32, 7);
        let r = recall_at(&mut s, &k, &v, &q, &mut rng, 40);
        // Random selection would get 40/800 = 5% recall; unlearned
        // random-hyperplane signatures land well above that (the paper's
        // learned signatures do far better still — see DESIGN.md §3).
        assert!(r > 0.12, "hash recall too low: {r}");
    }

    #[test]
    fn hash_scorer_incremental_matches_batch() {
        let (k, v, q, mut rng) = fixture(100, 16, 3);
        let mut inc = HashSignScorer::new(32, 5);
        // feed first 50 rows, then all 100
        let k50 = Mat::from_vec(50, 16, k.data[..50 * 16].to_vec());
        {
            let mut ctx = PolicyCtx { k: &k50, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
            let _ = inc.score(&mut ctx);
        }
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 1 };
        let s_inc = inc.score(&mut ctx);
        let mut fresh = HashSignScorer::new(32, 5);
        let s_fresh = fresh.score(&mut ctx);
        assert_eq!(s_inc, s_fresh);
    }

    #[test]
    fn quest_scores_upper_bound_member_logits() {
        let (k, v, q, mut rng) = fixture(256, 16, 4);
        let mut s = QuestScorer::new(16);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let scores = s.score(&mut ctx);
        let exact = crate::attention::logits_all(&k, &q);
        for i in 0..256 {
            assert!(scores[i] >= exact[i] - 1e-4, "page UB violated at {i}");
        }
    }

    #[test]
    fn pq_scorer_correlates_with_exact() {
        let (k, v, q, mut rng) = fixture(600, 32, 5);
        let mut s = PqScorer::new(8, 16, 11);
        let r = recall_at(&mut s, &k, &v, &q, &mut rng, 30);
        assert!(r > 0.3, "pq recall too low: {r}");
    }

    #[test]
    fn block_mean_partial_page_exact() {
        let (k, v, q, mut rng) = fixture(70, 16, 6);
        let mut s = BlockMeanScorer::new(16);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let scores = s.score(&mut ctx);
        let exact = crate::attention::logits_all(&k, &q);
        for i in 64..70 {
            assert!((scores[i] - exact[i]).abs() < 1e-5);
        }
    }

    #[test]
    fn oracle_intervals_over_quantized_keys_contain_exact_logits() {
        use crate::tensor::quant::QuantizedMat;
        let (k, v, q, mut rng) = fixture(300, 32, 8);
        // Dequantized mirror of K, plus the store's running bounds.
        let mut qm = QuantizedMat::new(32);
        let mut k_hat = Mat::zeros(0, 32);
        for r in 0..k.rows {
            qm.push_row(k.row(r));
            qm.dequantize_row_into(r, &mut k_hat.data);
            k_hat.rows += 1;
        }
        let bounds = KvQuantBounds { k_scale_max: qm.max_scale(), v_scale_max: 0.0 };
        let mut scorer = OracleScorer;
        let mut ctx = PolicyCtx { k: &k_hat, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let scored = scorer.score_intervals(&mut ctx, Some(bounds));
        assert!(scored.err > 0.0, "quantized cache must declare a non-zero interval");
        let exact = crate::attention::logits_all(&k, &q);
        for i in 0..300 {
            // The interval bound is exact in real arithmetic; allow a
            // hair of f32 dot-accumulation noise on top.
            let gap = (scored.scores[i] - exact[i]).abs();
            assert!(
                gap <= scored.err + 1e-4,
                "token {i}: |{} - {}| = {gap} > err {}",
                scored.scores[i],
                exact[i],
                scored.err
            );
        }
        // Exact caches and non-logit scorers declare zero width.
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        assert_eq!(scorer.score_intervals(&mut ctx, None).err, 0.0);
        let mut hash = HashSignScorer::new(32, 7);
        assert_eq!(hash.score_intervals(&mut ctx, Some(bounds)).err, 0.0);
    }

    #[test]
    fn double_sparsity_full_channels_is_exact() {
        let (k, v, q, mut rng) = fixture(50, 16, 7);
        let mut s = DoubleSparsityScorer { channels: 16 };
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let scores = s.score(&mut ctx);
        let exact = crate::attention::logits_all(&k, &q);
        for i in 0..50 {
            assert!((scores[i] - exact[i]).abs() < 1e-4);
        }
    }
}
