//! Deterministic heavy-hitter policies: StreamingLLM sink+window, the
//! generic scorer-driven approximate-top-k policy (wrapping any
//! `TopkScorer`), and the history-based H2O / SnapKV baselines.

use super::scorers::TopkScorer;
use super::{sink_window_indices, top_indices_excluding, IndexPolicy, PolicyCtx, SizeSpec};
use crate::attention::Selection;

/// StreamingLLM: attention sinks + sliding window, nothing else.
///
/// ```
/// use vattn::policies::{IndexPolicy, PolicyCtx, SinkWindowPolicy};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(128, 8, 1.0, &mut rng), Mat::randn(128, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut policy = SinkWindowPolicy::new(4, 16);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert_eq!(sel.len(), 20); // 4 sink + 16 window, query-independent
/// ```
pub struct SinkWindowPolicy {
    pub sink: SizeSpec,
    pub window: SizeSpec,
}

impl SinkWindowPolicy {
    pub fn new(sink: usize, window: usize) -> Self {
        SinkWindowPolicy { sink: SizeSpec::Abs(sink), window: SizeSpec::Abs(window) }
    }
}

impl IndexPolicy for SinkWindowPolicy {
    fn name(&self) -> String {
        "streaming-llm".into()
    }
    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        Selection::deterministic(sink_window_indices(
            n,
            self.sink.resolve(n),
            self.window.resolve(n),
        ))
    }
}

/// Generic approximate-top-k policy: sink + window + the `heavy` highest
/// tokens according to a pluggable [`TopkScorer`] (HashAttention,
/// DoubleSparsity, Quest, PQCache, InfLLM, or the oracle).
/// Deterministic attention (Eq. 2) — no residual sample, no guarantee.
///
/// ```
/// use vattn::policies::scorers::OracleScorer;
/// use vattn::policies::{HeavyHitterPolicy, IndexPolicy, PolicyCtx, SizeSpec};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(256, 8, 1.0, &mut rng), Mat::randn(256, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut policy = HeavyHitterPolicy::new(Box::new(OracleScorer), SizeSpec::Abs(16));
/// policy.sink = SizeSpec::Abs(4);
/// policy.window = SizeSpec::Abs(8);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert_eq!(sel.len(), 4 + 8 + 16);
/// assert!(sel.prob.iter().all(|&p| p == 1.0)); // fully deterministic
/// ```
pub struct HeavyHitterPolicy {
    pub sink: SizeSpec,
    pub window: SizeSpec,
    pub heavy: SizeSpec,
    pub scorer: Box<dyn TopkScorer>,
}

impl HeavyHitterPolicy {
    pub fn new(scorer: Box<dyn TopkScorer>, heavy: SizeSpec) -> Self {
        HeavyHitterPolicy { sink: SizeSpec::Abs(128), window: SizeSpec::Abs(128), heavy, scorer }
    }
}

impl IndexPolicy for HeavyHitterPolicy {
    fn name(&self) -> String {
        self.scorer.name()
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        let fixed = sink_window_indices(n, self.sink.resolve(n), self.window.resolve(n));
        let scores = self.scorer.score(ctx);
        let mut idx = fixed;
        let top = top_indices_excluding(&scores, self.heavy.resolve(n), &idx);
        idx.extend(top);
        idx.sort_unstable();
        Selection::deterministic(idx)
    }

    fn reset(&mut self) {
        self.scorer.reset();
    }
}

/// H2O: heavy-hitter oracle via *accumulated* attention scores across the
/// queries seen so far. Irreversible in spirit — once a token has low
/// accumulated mass it keeps losing — which is exactly the failure mode
/// the paper calls out for multi-turn relevance shifts.
///
/// ```
/// use vattn::policies::{H2OPolicy, IndexPolicy, PolicyCtx, SizeSpec};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(300, 8, 1.0, &mut rng), Mat::randn(300, 8, 1.0, &mut rng));
/// let mut policy = H2OPolicy::new(SizeSpec::Abs(20));
/// for step in 0..2 {
///     let q = vec![0.05 * (step as f32 + 1.0); 8];
///     let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step });
///     assert!(sel.validate(300).is_ok());
/// }
/// policy.reset(); // per-sequence accumulator cleared between requests
/// ```
pub struct H2OPolicy {
    pub window: SizeSpec,
    pub heavy: SizeSpec,
    acc: Vec<f64>,
}

impl H2OPolicy {
    pub fn new(heavy: SizeSpec) -> Self {
        H2OPolicy { window: SizeSpec::Abs(128), heavy, acc: Vec::new() }
    }
}

impl IndexPolicy for H2OPolicy {
    fn name(&self) -> String {
        "h2o".into()
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        // Accumulate current query's exact attention scores into history.
        let scores = crate::attention::attention_scores(ctx.k, ctx.q_scaled);
        if self.acc.len() < n {
            self.acc.resize(n, 0.0);
        }
        for (a, &s) in self.acc.iter_mut().zip(scores.iter()) {
            *a += s as f64;
        }
        let window = sink_window_indices(n, 0, self.window.resolve(n));
        let acc32: Vec<f32> = self.acc.iter().map(|&x| x as f32).collect();
        let mut idx = window;
        let top = top_indices_excluding(&acc32, self.heavy.resolve(n), &idx);
        idx.extend(top);
        idx.sort_unstable();
        Selection::deterministic(idx)
    }

    fn reset(&mut self) {
        self.acc.clear();
    }
}

/// SnapKV: selection driven by attention pooled over an observation
/// window of the `obs_window` most recent queries.
///
/// ```
/// use vattn::policies::{IndexPolicy, PolicyCtx, SizeSpec, SnapKvPolicy};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(200, 8, 1.0, &mut rng), Mat::randn(200, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut policy = SnapKvPolicy::new(SizeSpec::Abs(16), 3);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert!(sel.validate(200).is_ok());
/// ```
pub struct SnapKvPolicy {
    pub window: SizeSpec,
    pub heavy: SizeSpec,
    pub obs_window: usize,
    recent_scores: std::collections::VecDeque<Vec<f32>>,
}

impl SnapKvPolicy {
    pub fn new(heavy: SizeSpec, obs_window: usize) -> Self {
        SnapKvPolicy {
            window: SizeSpec::Abs(128),
            heavy,
            obs_window,
            recent_scores: Default::default(),
        }
    }
}

impl IndexPolicy for SnapKvPolicy {
    fn name(&self) -> String {
        "snapkv".into()
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        let scores = crate::attention::attention_scores(ctx.k, ctx.q_scaled);
        self.recent_scores.push_back(scores);
        while self.recent_scores.len() > self.obs_window {
            self.recent_scores.pop_front();
        }
        // Average-pool scores over the observation window (ragged lengths:
        // older score vectors are shorter; missing entries count as 0).
        let mut pooled = vec![0.0f32; n];
        for s in &self.recent_scores {
            for (p, &x) in pooled.iter_mut().zip(s.iter()) {
                *p += x;
            }
        }
        let inv = 1.0 / self.recent_scores.len() as f32;
        for p in pooled.iter_mut() {
            *p *= inv;
        }
        let window = sink_window_indices(n, 0, self.window.resolve(n));
        let mut idx = window;
        let top = top_indices_excluding(&pooled, self.heavy.resolve(n), &idx);
        idx.extend(top);
        idx.sort_unstable();
        Selection::deterministic(idx)
    }

    fn reset(&mut self) {
        self.recent_scores.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::scorers::{HashSignScorer, OracleScorer};
    use crate::tensor::Mat;
    use crate::util::Rng;

    fn fixture(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
        (k, v, q, rng)
    }

    #[test]
    fn sink_window_policy_is_static() {
        let (k, v, q, mut rng) = fixture(500, 16, 1);
        let mut pol = SinkWindowPolicy::new(4, 8);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert_eq!(sel.len(), 12);
        assert!(sel.validate(500).is_ok());
    }

    #[test]
    fn heavy_policy_with_oracle_matches_oracle_topk() {
        let (k, v, q, mut rng) = fixture(600, 16, 2);
        let mut a = HeavyHitterPolicy {
            sink: SizeSpec::Abs(8),
            window: SizeSpec::Abs(8),
            heavy: SizeSpec::Abs(32),
            scorer: Box::new(OracleScorer),
        };
        let mut b = crate::policies::OracleTopKPolicy {
            sink: SizeSpec::Abs(8),
            window: SizeSpec::Abs(8),
            heavy: SizeSpec::Abs(32),
        };
        let sa = {
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
            a.select(&mut ctx)
        };
        let sb = {
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
            b.select(&mut ctx)
        };
        assert_eq!(sa.idx, sb.idx);
    }

    #[test]
    fn heavy_policy_hash_valid_and_budgeted() {
        let (k, v, q, mut rng) = fixture(512, 32, 3);
        let mut pol = HeavyHitterPolicy {
            sink: SizeSpec::Abs(4),
            window: SizeSpec::Abs(4),
            heavy: SizeSpec::Abs(50),
            scorer: Box::new(HashSignScorer::new(32, 5)),
        };
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert_eq!(sel.len(), 58);
        assert!(sel.validate(512).is_ok());
    }

    #[test]
    fn h2o_accumulates_across_queries() {
        let (k, v, _, mut rng) = fixture(300, 16, 4);
        let mut pol = H2OPolicy::new(SizeSpec::Abs(20));
        // Two different queries; accumulated mass should reflect both.
        for step in 0..2 {
            let q: Vec<f32> = (0..16).map(|_| rng.normal32(0.0, 0.25)).collect();
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step };
            let sel = pol.select(&mut ctx);
            assert!(sel.validate(300).is_ok());
        }
        assert!(pol.acc.iter().sum::<f64>() > 1.9); // ~2 queries of mass 1
        pol.reset();
        assert!(pol.acc.is_empty());
    }

    #[test]
    fn snapkv_pools_observation_window() {
        let (k, v, _, mut rng) = fixture(200, 16, 5);
        let mut pol = SnapKvPolicy::new(SizeSpec::Abs(16), 3);
        for step in 0..5 {
            let q: Vec<f32> = (0..16).map(|_| rng.normal32(0.0, 0.25)).collect();
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step };
            let sel = pol.select(&mut ctx);
            assert!(sel.validate(200).is_ok());
        }
        assert_eq!(pol.recent_scores.len(), 3); // window capped
    }
}
