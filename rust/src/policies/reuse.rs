//! Temporal heavy-hitter reuse with verified refresh.
//!
//! The decode hot path re-runs the top-k predictor from scratch every
//! step, yet heavy-hitter sets are strongly temporally correlated across
//! adjacent decode steps — the observation behind Guess-Verify-Refine
//! and SpecAttn. [`TemporalReusePolicy`] wraps a [`VAttentionPolicy`]
//! and caches the previous step's heavy-hitter selection per
//! (request, layer, head); on each subsequent step it *certifies* the
//! cached set against the current query with a cheap drift bound and
//! only re-invokes the underlying [`TopkScorer`] (a full O(n·d) scan)
//! when certification fails.
//!
//! # The drift certificate
//!
//! At the last full re-score ("refresh") the policy anchors the exact
//! logits `L0[i] = ⟨k_i, q₀⟩` for every cached token, the anchor query
//! `q₀`, and the selected heavy set `C`. For a later query `q_t`, every
//! logit is bracketed without touching K again:
//!
//! ```text
//! |⟨k_i, q_t⟩ − L0[i]| = |⟨k_i, q_t − q₀⟩| ≤ ‖k_i‖·‖q_t − q₀‖   (Cauchy–Schwarz)
//! ```
//!
//! so `⟨k_i, q_t⟩ ≤ L0[i] + ‖k_i‖·Δ` with `Δ = ‖q_t − q₀‖`. Per-token
//! key norms `‖k_i‖` are maintained incrementally. The reuse step
//! exact-scores the cached set `C` (h·d work), takes the h-th largest
//! of those logits as a threshold θ — a lower bound on the fresh top-k
//! cut — and scans the upper bounds of every other residual token
//! (O(n) work, d× cheaper than scoring). Tokens whose bound clears θ
//! ("survivors") are exact-scored and compete; everything else is
//! *provably* outside the fresh top-k. The resulting heavy set is
//! therefore **identical to what a full re-score would select** (up to
//! exact floating-point ties), which is what makes reuse-enabled token
//! streams byte-identical to reuse-disabled runs — asserted by
//! `tests/temporal_reuse.rs` and `bench_engine`.
//!
//! # Why the (ε, δ) contract is never weakened
//!
//! Certification (base sample → statistics → budget, Algorithm 2 via
//! [`crate::budget`]) is re-run on *every* step from a fresh residual
//! sample; only the heavy-set computation is reused, and the certificate
//! makes it exact. When the certificate cannot prune (query drift, cache
//! growth, age), the policy falls back to a full re-score — it never
//! serves an unverified guess. See `docs/GUARANTEES.md` §6 for the
//! full argument.
//!
//! Reuse requires a scorer whose scores are exact logits
//! ([`TopkScorer::scores_are_logits`], i.e. the oracle predictor);
//! other scorers are legal but refresh on every step (counted under
//! [`ReuseStats::refresh_unsupported`]).

use super::scorers::TopkScorer;
use super::vattention::VAttentionPolicy;
use super::{IndexPolicy, PolicyCtx};
use crate::attention::Selection;
use crate::tensor::quant::KvQuantBounds;
use crate::tensor::{dot, norm2};

/// Absolute slack added to the drift bound before a token may be pruned,
/// absorbing f32 rounding in the dot products, norms and products that
/// enter the certificate. Pruning is only ever made *more* conservative
/// by slack — a spuriously surviving token is exact-scored and loses on
/// its true logit, so correctness never depends on this constant being
/// tight.
pub const REUSE_DRIFT_SLACK_ABS: f32 = 1e-3;

/// Relative slack component, scaled by the magnitudes entering the
/// pruning comparison (see [`REUSE_DRIFT_SLACK_ABS`]).
pub const REUSE_DRIFT_SLACK_REL: f32 = 1e-4;

/// Tuning knobs for [`TemporalReusePolicy`].
#[derive(Clone, Debug)]
pub struct ReuseConfig {
    /// Steps a cached heavy set may be served before a forced full
    /// re-score (`vattn serve --reuse-max-age`). Bounds how long the
    /// anchor logits may age even when the certificate keeps passing.
    pub max_age: usize,
    /// Fraction of the cache the bound scan may rescue as survivors
    /// before reuse is abandoned for a full re-score: past this point
    /// certification costs as much as scoring.
    pub survivor_cap_frac: f64,
    /// Verified-refresh trigger from the budget machinery: when the
    /// certified sample budget (as a fraction of the residual) grows by
    /// this factor over its value at the last refresh — evidence that
    /// the residual variance, and hence the observed error bound, has
    /// drifted — the next step re-scores in full and re-anchors.
    /// `None` disables the trigger.
    pub budget_drift_factor: Option<f64>,
}

impl Default for ReuseConfig {
    fn default() -> Self {
        ReuseConfig { max_age: 32, survivor_cap_frac: 0.25, budget_drift_factor: Some(4.0) }
    }
}

/// Cross-step reuse counters. `selects == hits + refreshes()` and
/// `scorer_calls == refreshes()` are invariants: every select is either
/// served from the certificate or escalated to exactly one scorer call.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReuseStats {
    /// [`IndexPolicy::select`] calls observed.
    pub selects: u64,
    /// Selects served from the cached heavy set (certificate passed).
    pub hits: u64,
    /// Tokens outside the cached set that the certificate could not
    /// prune and therefore exact-scored (includes tokens appended since
    /// the anchor). A health metric: high survivor counts with a high
    /// hit rate mean the bound is doing real work.
    pub survivors_scored: u64,
    /// Underlying [`TopkScorer::score`] invocations (full K scans).
    pub scorer_calls: u64,
    /// Refreshes because no anchor existed (first decode step, after
    /// [`IndexPolicy::reset`] — e.g. a preemption replay — or a shrunk
    /// cache).
    pub refresh_cold: u64,
    /// Refreshes forced by [`ReuseConfig::max_age`].
    pub refresh_max_age: u64,
    /// Refreshes because query drift left too many tokens uncertified
    /// ([`ReuseConfig::survivor_cap_frac`]).
    pub refresh_drift: u64,
    /// Verified refreshes triggered by certified-budget growth
    /// ([`ReuseConfig::budget_drift_factor`]).
    pub refresh_budget: u64,
    /// Refreshes because the heavy budget outgrew the cached set (e.g.
    /// a `SizeSpec::Frac` heavy budget as n grows).
    pub refresh_grown: u64,
    /// Refreshes because the underlying scorer does not expose exact
    /// logits, so the certificate cannot apply.
    pub refresh_unsupported: u64,
}

impl ReuseStats {
    /// Total full re-scores, across all causes.
    pub fn refreshes(&self) -> u64 {
        self.refresh_cold
            + self.refresh_max_age
            + self.refresh_drift
            + self.refresh_budget
            + self.refresh_grown
            + self.refresh_unsupported
    }

    /// Fraction of selects served from the cached set.
    pub fn hit_rate(&self) -> f64 {
        if self.selects == 0 {
            0.0
        } else {
            self.hits as f64 / self.selects as f64
        }
    }

    /// How many times fewer full scans ran than a reuse-free policy
    /// would have issued (which scores once per select). ≥ 1 by
    /// construction.
    pub fn scorer_reduction(&self) -> f64 {
        if self.scorer_calls == 0 {
            1.0
        } else {
            self.selects as f64 / self.scorer_calls as f64
        }
    }

    /// Accumulate another policy's counters (per-request / per-session
    /// aggregation).
    pub fn merge(&mut self, other: &ReuseStats) {
        self.selects += other.selects;
        self.hits += other.hits;
        self.survivors_scored += other.survivors_scored;
        self.scorer_calls += other.scorer_calls;
        self.refresh_cold += other.refresh_cold;
        self.refresh_max_age += other.refresh_max_age;
        self.refresh_drift += other.refresh_drift;
        self.refresh_budget += other.refresh_budget;
        self.refresh_grown += other.refresh_grown;
        self.refresh_unsupported += other.refresh_unsupported;
    }
}

enum RefreshCause {
    Cold,
    MaxAge,
    Drift,
    Budget,
    Grown,
    Unsupported,
}

/// Everything anchored at the last full re-score. Cleared by
/// [`IndexPolicy::reset`], so a preemption replay re-certifies from a
/// cold start and replays the exact selection sequence of its first
/// run.
struct ReuseAnchor {
    /// Exact logits ⟨k_i, q₀⟩ for every token cached at anchor time
    /// (length = tokens at anchor).
    l0: Vec<f32>,
    /// Largest cache length this anchor has certified against (grows
    /// with hits; the cached heavy set may reference indices up to
    /// this). Any select at a smaller n means the cache shrank without
    /// a reset — the anchor is discarded (cold refresh).
    n_seen: usize,
    /// The anchor query (pre-scaled, like every `PolicyCtx::q_scaled`).
    q0: Vec<f32>,
    /// The cached heavy set, sorted ascending; refreshed to the served
    /// set after every hit (the "previous step's selection").
    heavy: Vec<usize>,
    /// Certified budget / residual size at anchor time, for the
    /// budget-drift trigger (0 when the anchor step had no residual).
    budget_frac0: f64,
    /// Steps served since the anchor.
    age: usize,
    /// Set when the budget-drift trigger fired; the next select
    /// re-scores in full.
    force_refresh: bool,
}

/// Cross-step index reuse around a [`VAttentionPolicy`]: serve the
/// previous step's heavy-hitter selection whenever a drift certificate
/// proves it still *is* the fresh top-k, and fall back to the wrapped
/// policy's full re-score otherwise. See the module docs for the
/// certificate and the guarantee argument.
///
/// ```
/// use vattn::policies::{
///     IndexPolicy, PolicyCtx, ReuseConfig, TemporalReusePolicy, VAttentionConfig,
///     VAttentionPolicy,
/// };
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let k = Mat::randn(512, 8, 1.0, &mut rng);
/// let v = Mat::randn(512, 8, 1.0, &mut rng);
/// let q = vec![0.1; 8];
/// let inner = VAttentionPolicy::oracle(VAttentionConfig::default().with_guarantee(0.2, 0.2));
/// let mut policy = TemporalReusePolicy::new(inner, ReuseConfig::default());
/// // First select: no anchor yet — full score ("cold" refresh).
/// let a = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// // Same query again: zero drift, the certificate passes — no scorer call.
/// let b = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 1 });
/// assert!(a.validate(512).is_ok() && b.validate(512).is_ok());
/// assert_eq!(policy.stats().scorer_calls, 1);
/// assert_eq!(policy.stats().hits, 1);
/// ```
pub struct TemporalReusePolicy {
    /// The wrapped policy; its [`VAttentionPolicy::last`] diagnostics
    /// stay live (reuse routes every step through its budget tail).
    pub inner: VAttentionPolicy,
    rcfg: ReuseConfig,
    anchor: Option<ReuseAnchor>,
    /// Incrementally maintained per-token key norms ‖k_i‖.
    norms: Vec<f32>,
    stats: ReuseStats,
}

impl TemporalReusePolicy {
    pub fn new(inner: VAttentionPolicy, rcfg: ReuseConfig) -> TemporalReusePolicy {
        TemporalReusePolicy { inner, rcfg, anchor: None, norms: Vec::new(), stats: ReuseStats::default() }
    }

    /// Cumulative reuse counters for this (request, layer, head) policy.
    pub fn stats(&self) -> &ReuseStats {
        &self.stats
    }

    fn count(&mut self, cause: &RefreshCause) {
        match cause {
            RefreshCause::Cold => self.stats.refresh_cold += 1,
            RefreshCause::MaxAge => self.stats.refresh_max_age += 1,
            RefreshCause::Drift => self.stats.refresh_drift += 1,
            RefreshCause::Budget => self.stats.refresh_budget += 1,
            RefreshCause::Grown => self.stats.refresh_grown += 1,
            RefreshCause::Unsupported => self.stats.refresh_unsupported += 1,
        }
    }

    /// Extend (or rebuild, if the cache shrank) the incremental key
    /// norms up to the current cache length.
    fn sync_norms(&mut self, k: &crate::tensor::Mat) {
        if self.norms.len() > k.rows {
            self.norms.clear();
        }
        for i in self.norms.len()..k.rows {
            self.norms.push(norm2(k.row(i)));
        }
    }

    /// Mandatory-refresh check, run before any reuse attempt. `None`
    /// means the certificate may be tried.
    fn forced_refresh(&mut self, n: usize) -> Option<RefreshCause> {
        if !self.inner.scorer.scores_are_logits() {
            return Some(RefreshCause::Unsupported);
        }
        let Some(anchor) = self.anchor.as_mut() else {
            return Some(RefreshCause::Cold);
        };
        if anchor.n_seen > n {
            // The cache shrank without a reset — treat as cold, and
            // drop the norms too: rows may be rewritten before the
            // cache regrows, and sync_norms only ever extends. (Rows
            // rewritten *without* the length ever dropping are
            // undetectable here — like every incremental scorer in
            // this crate, the policy assumes an append-only cache
            // between [`IndexPolicy::reset`] calls, which is the
            // serving session's contract.)
            self.norms.clear();
            return Some(RefreshCause::Cold);
        }
        if anchor.force_refresh {
            return Some(RefreshCause::Budget);
        }
        anchor.age += 1;
        if anchor.age > self.rcfg.max_age {
            return Some(RefreshCause::MaxAge);
        }
        None
    }

    /// The heavy part of a just-computed selection: the deterministic
    /// prefix of `sel` is I_f (sorted); drop the sink/window region and
    /// what remains is the (sorted) heavy set. Shared by the refresh
    /// and hit paths so the anchor stays consistent between them.
    fn extract_heavy(&self, sel: &Selection, sink: usize, win_start: usize) -> Vec<usize> {
        let last = self.inner.last.as_ref().expect("select_from_scores records a decision");
        sel.idx[..last.n_fixed]
            .iter()
            .copied()
            .filter(|&i| i >= sink && i < win_start)
            .collect()
    }

    /// Full re-score through the wrapped policy, then (when the scorer
    /// is logit-exact) anchor the certificate state for later steps.
    fn refresh(&mut self, ctx: &mut PolicyCtx, cause: RefreshCause) -> Selection {
        self.count(&cause);
        self.stats.scorer_calls += 1;
        let scored = self.inner.scorer.score_intervals(ctx, self.inner.kv_quant);
        let logit_exact = self.inner.scorer.scores_are_logits();
        let err = (scored.err > 0.0).then_some(scored.err);
        let scores = scored.scores;
        let sel = self.inner.select_from_scores(ctx, &scores, logit_exact, err);
        self.anchor = None;
        if logit_exact {
            let n = ctx.n();
            let cfg = &self.inner.cfg;
            let sink = cfg.sink.resolve(n);
            let win_start = n.saturating_sub(cfg.window.resolve(n)).max(sink);
            let heavy = self.extract_heavy(&sel, sink, win_start);
            let last = self.inner.last.as_ref().expect("select_from_scores records a decision");
            let budget_frac0 = if last.n_s > 0 { last.budget as f64 / last.n_s as f64 } else { 0.0 };
            self.anchor = Some(ReuseAnchor {
                l0: scores,
                n_seen: n,
                q0: ctx.q_scaled.to_vec(),
                heavy,
                budget_frac0,
                age: 0,
                force_refresh: false,
            });
        }
        sel
    }

    /// The certificate fast path. Returns the selection — provably equal
    /// to a full re-score's — or the refresh cause that prevented
    /// certification.
    fn try_reuse(&mut self, ctx: &mut PolicyCtx) -> Result<Selection, RefreshCause> {
        let n = ctx.n();
        let cfg = &self.inner.cfg;
        let sink = cfg.sink.resolve(n);
        let win_start = n.saturating_sub(cfg.window.resolve(n)).max(sink);
        let in_fixed = |i: usize| i < sink || i >= win_start;
        let h_now = cfg.heavy.resolve(n);

        let anchor = self.anchor.take().expect("forced_refresh checked the anchor");
        let n0 = anchor.l0.len();

        // Exact-score the cached heavy set; its h-th largest current
        // logit lower-bounds the fresh top-k cut.
        let mut scores = vec![f32::NEG_INFINITY; n];
        let mut c_logits: Vec<f32> = Vec::with_capacity(anchor.heavy.len());
        for &i in &anchor.heavy {
            if in_fixed(i) {
                continue; // swallowed by a grown sink/window region
            }
            let l = dot(ctx.k.row(i), ctx.q_scaled);
            scores[i] = l;
            c_logits.push(l);
        }
        if c_logits.len() < h_now {
            // The anchor is stale either way; `refresh` rebuilds it.
            return Err(RefreshCause::Grown);
        }
        let theta = if h_now == 0 {
            f32::INFINITY
        } else {
            let mut sorted = c_logits.clone();
            sorted.sort_unstable_by(|a, b| b.partial_cmp(a).unwrap_or(std::cmp::Ordering::Equal));
            sorted[h_now - 1]
        };

        // Tokens appended since the anchor have no L0 — exact-score the
        // (few) non-fixed ones unconditionally. Ones already scored as
        // cached-set members are skipped (an appended token can win a
        // heavy slot and land in `anchor.heavy` on a later hit).
        let mut scored_nonfixed = c_logits.len();
        for i in n0..n {
            if !in_fixed(i) && scores[i] == f32::NEG_INFINITY {
                scores[i] = dot(ctx.k.row(i), ctx.q_scaled);
                scored_nonfixed += 1;
            }
        }
        let new_scored = scored_nonfixed - c_logits.len();

        // Drift-bound scan over every other anchored token.
        let delta = {
            let mut d2 = 0.0f32;
            for (a, b) in ctx.q_scaled.iter().zip(anchor.q0.iter()) {
                let t = a - b;
                d2 += t * t;
            }
            d2.sqrt()
        };
        let cap = ((self.rcfg.survivor_cap_frac * n as f64) as usize).max(8);
        // Quantized-KV slack: anchor logits and current logits both live
        // in dequantized space, so the certificate is already exact
        // *there* — widening the prune threshold by 2e (e the logit
        // dequantization bound) additionally keeps every pruning
        // decision valid against the pre-quantization logits (each side
        // of the comparison moves by at most e), at slightly lower
        // pruning power. Spurious survivors are exact-scored and lose,
        // so reuse-on streams remain byte-identical to reuse-off either
        // way (docs/GUARANTEES.md §8).
        let quant_slack =
            self.inner.kv_quant.map_or(0.0, |b| 2.0 * b.logit_err(ctx.q_scaled));
        let mut survivors = 0usize;
        let mut cached = anchor.heavy.iter().peekable();
        for i in 0..n0 {
            if cached.peek() == Some(&&i) {
                cached.next();
                continue;
            }
            if in_fixed(i) {
                continue;
            }
            let reach = self.norms[i] * delta;
            let ub = anchor.l0[i] + reach;
            let slack = REUSE_DRIFT_SLACK_ABS
                + REUSE_DRIFT_SLACK_REL * (theta.abs() + anchor.l0[i].abs() + reach)
                + quant_slack;
            if ub + slack > theta {
                survivors += 1;
                if survivors > cap {
                    return Err(RefreshCause::Drift);
                }
                scores[i] = dot(ctx.k.row(i), ctx.q_scaled);
            }
        }
        scored_nonfixed += survivors;
        if scored_nonfixed < h_now {
            return Err(RefreshCause::Grown);
        }
        self.stats.survivors_scored += (survivors + new_scored) as u64;

        // Certified: the top-h of the scored candidates is the fresh
        // top-h. Route the budget/sampling tail through the wrapped
        // policy (scores_are_logits = false — the vector is only
        // partially exact, so the statistics re-derive logits from K;
        // score_err = None likewise — this vector is not a scorer
        // product, so the quantization slack re-derives from the
        // bounds, bitwise the same value a fresh re-score charges).
        let sel = self.inner.select_from_scores(ctx, &scores, false, None);
        let heavy_new = self.extract_heavy(&sel, sink, win_start);
        let mut anchor = anchor;
        anchor.heavy = heavy_new;
        anchor.n_seen = n;
        if let Some(factor) = self.rcfg.budget_drift_factor {
            let last = self.inner.last.as_ref().expect("select_from_scores records a decision");
            if last.n_s > 0 && anchor.budget_frac0 > 0.0 {
                let frac = last.budget as f64 / last.n_s as f64;
                if frac > factor * anchor.budget_frac0 {
                    anchor.force_refresh = true;
                }
            }
        }
        self.anchor = Some(anchor);
        Ok(sel)
    }
}

impl IndexPolicy for TemporalReusePolicy {
    fn name(&self) -> String {
        format!("temporal-reuse({})", self.inner.name())
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        self.stats.selects += 1;
        if let Some(cause) = self.forced_refresh(ctx.n()) {
            return self.refresh(ctx, cause);
        }
        self.sync_norms(ctx.k);
        match self.try_reuse(ctx) {
            Ok(sel) => {
                self.stats.hits += 1;
                sel
            }
            Err(cause) => self.refresh(ctx, cause),
        }
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.anchor = None;
        self.norms.clear();
    }

    fn reuse_stats(&self) -> Option<&ReuseStats> {
        Some(&self.stats)
    }

    fn set_kv_quant(&mut self, bounds: Option<KvQuantBounds>) {
        // One set of bounds drives both layers: the wrapped policy's
        // budget slack and this certificate's prune slack.
        self.inner.set_kv_quant(bounds);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::{SizeSpec, VAttentionConfig};
    use crate::tensor::Mat;
    use crate::util::Rng;

    fn vcfg(sink: usize, window: usize, heavy: SizeSpec) -> VAttentionConfig {
        VAttentionConfig {
            sink: SizeSpec::Abs(sink),
            window: SizeSpec::Abs(window),
            heavy,
            base_rate: 0.05,
            eps: 0.2,
            delta: 0.2,
            verify: crate::budget::Verify::Denominator,
            bound: crate::budget::Bound::Clt,
            floor_at_base: true,
        }
    }

    /// K with `n_heavy` planted rows strongly aligned to e0 and a weak
    /// random background: a temporally stable heavy-hitter structure.
    fn planted(n: usize, d: usize, n_heavy: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let mut k = Mat::randn(n, d, 0.1, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        for j in 0..n_heavy {
            let row = 100 + j * 3;
            for c in 0..d {
                k.set(row, c, if c == 0 { 10.0 } else { 0.0 });
            }
        }
        (k, v)
    }

    /// A slowly drifting query stream around e0.
    fn drifting_query(d: usize, step: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ (step as u64).wrapping_mul(0x9E3779B97F4A7C15));
        (0..d)
            .map(|c| if c == 0 { 1.0 } else { 0.0 } + scale * rng.normal32(0.0, 1.0))
            .collect()
    }

    #[test]
    fn reuse_selections_equal_fresh_policy_on_stable_stream() {
        let (k, v) = planted(512, 16, 8, 1);
        let cfg = vcfg(4, 8, SizeSpec::Abs(8));
        let mut fresh = VAttentionPolicy::oracle(cfg.clone());
        let mut reuse = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig { max_age: 1000, ..Default::default() },
        );
        let mut rng_a = Rng::new(7);
        let mut rng_b = Rng::new(7);
        for step in 0..32 {
            let q = drifting_query(16, step, 0.01, 3);
            let sa = fresh.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_a,
                step,
            });
            let sb = reuse.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_b,
                step,
            });
            assert_eq!(sa.idx, sb.idx, "index divergence at step {step}");
            assert_eq!(sa.prob, sb.prob, "probability divergence at step {step}");
        }
        let st = reuse.stats();
        assert_eq!(st.selects, 32);
        assert_eq!(st.scorer_calls, 1, "only the cold refresh may scan: {st:?}");
        assert_eq!(st.hits, 31);
        assert!(st.scorer_reduction() >= 2.0);
        assert_eq!(st.selects, st.hits + st.refreshes());
    }

    #[test]
    fn reuse_selections_equal_fresh_policy_under_adversarial_drift() {
        // Unstructured keys and fully random queries: the certificate
        // mostly fails, reuse degenerates to refresh-every-step — and
        // the selections still match the fresh policy exactly.
        let mut rng = Rng::new(11);
        let k = Mat::randn(400, 16, 1.0, &mut rng);
        let v = Mat::randn(400, 16, 1.0, &mut rng);
        let cfg = vcfg(8, 8, SizeSpec::Frac(0.05));
        let mut fresh = VAttentionPolicy::oracle(cfg.clone());
        let mut reuse = TemporalReusePolicy::new(VAttentionPolicy::oracle(cfg), ReuseConfig::default());
        let mut rng_a = Rng::new(13);
        let mut rng_b = Rng::new(13);
        for step in 0..20 {
            let q: Vec<f32> = {
                let mut qr = Rng::new(100 + step as u64);
                (0..16).map(|_| qr.normal32(0.0, 0.25)).collect()
            };
            let sa = fresh.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_a,
                step,
            });
            let sb = reuse.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_b,
                step,
            });
            assert_eq!(sa.idx, sb.idx, "index divergence at step {step}");
            assert_eq!(sa.prob, sb.prob, "probability divergence at step {step}");
        }
        let st = reuse.stats().clone();
        assert_eq!(st.selects, st.hits + st.refreshes());
        assert_eq!(st.scorer_calls, st.refreshes());
    }

    #[test]
    fn reuse_tracks_growing_cache() {
        // Rows appended between selects (the decode pattern): new tokens
        // are exact-scored until a refresh re-anchors them.
        let (k_full, v_full) = planted(256, 16, 6, 5);
        let cfg = vcfg(4, 16, SizeSpec::Abs(6));
        let mut fresh = VAttentionPolicy::oracle(cfg.clone());
        let mut reuse = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig { max_age: 1000, ..Default::default() },
        );
        let mut rng_a = Rng::new(17);
        let mut rng_b = Rng::new(17);
        for step in 0..32 {
            let n_t = 192 + 2 * step; // grows by 2 rows per step
            let k = Mat::from_vec(n_t, 16, k_full.data[..n_t * 16].to_vec());
            let v = Mat::from_vec(n_t, 16, v_full.data[..n_t * 16].to_vec());
            let q = drifting_query(16, step, 0.01, 23);
            let sa = fresh.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_a,
                step,
            });
            let sb = reuse.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_b,
                step,
            });
            assert_eq!(sa.idx, sb.idx, "index divergence at step {step}");
            assert_eq!(sa.prob, sb.prob, "probability divergence at step {step}");
        }
        assert!(reuse.stats().hits > 0, "{:?}", reuse.stats());
    }

    #[test]
    fn max_age_forces_refresh() {
        let (k, v) = planted(512, 16, 8, 9);
        let cfg = vcfg(4, 8, SizeSpec::Abs(8));
        let mut reuse = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig { max_age: 4, budget_drift_factor: None, ..Default::default() },
        );
        let mut rng = Rng::new(31);
        let q = drifting_query(16, 0, 0.0, 1);
        for step in 0..16 {
            reuse.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step });
        }
        let st = reuse.stats();
        assert!(st.refresh_max_age >= 2, "{st:?}");
        assert_eq!(st.selects, st.hits + st.refreshes());
    }

    #[test]
    fn adversarial_query_jump_triggers_drift_refresh() {
        let mut rng = Rng::new(41);
        let k = Mat::randn(512, 16, 1.0, &mut rng);
        let v = Mat::randn(512, 16, 1.0, &mut rng);
        let cfg = vcfg(4, 8, SizeSpec::Abs(16));
        let mut reuse = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig { budget_drift_factor: None, ..Default::default() },
        );
        let q0: Vec<f32> = (0..16).map(|c| if c == 0 { 1.0 } else { 0.0 }).collect();
        let q1: Vec<f32> = q0.iter().map(|x| -x).collect(); // 180° flip
        reuse.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q0, rng: &mut rng, step: 0 });
        reuse.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q1, rng: &mut rng, step: 1 });
        let st = reuse.stats();
        assert_eq!(st.refresh_cold, 1);
        assert_eq!(st.refresh_drift, 1, "{st:?}");
        assert_eq!(st.hits, 0);
    }

    #[test]
    fn reset_clears_anchor_and_replays_identically() {
        let (k, v) = planted(384, 16, 8, 13);
        let cfg = vcfg(4, 8, SizeSpec::Abs(8));
        let run = |policy: &mut TemporalReusePolicy| -> Vec<Vec<usize>> {
            let mut rng = Rng::new(19);
            (0..8)
                .map(|step| {
                    let q = drifting_query(16, step, 0.01, 29);
                    policy
                        .select(&mut PolicyCtx {
                            k: &k,
                            v: &v,
                            q_scaled: &q,
                            rng: &mut rng,
                            step,
                        })
                        .idx
                })
                .collect()
        };
        let mut policy = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig { max_age: 1000, ..Default::default() },
        );
        let first = run(&mut policy);
        let cold_before = policy.stats().refresh_cold;
        policy.reset();
        let replay = run(&mut policy);
        assert_eq!(first, replay, "reset must make the replay byte-identical");
        assert_eq!(policy.stats().refresh_cold, cold_before + 1, "replay restarts cold");
    }

    #[test]
    fn reuse_equals_fresh_policy_with_kv_quant_bounds_set() {
        // Same stable planted stream as above, but over a quantized
        // cache (simulated: bounds set, as the session does): the
        // certificate's extra 2e slack must not break selection
        // equality with a fresh policy carrying the same bounds — and
        // reuse must still hit.
        let (k, v) = planted(512, 16, 8, 21);
        let cfg = vcfg(4, 8, SizeSpec::Abs(8));
        let bounds = KvQuantBounds { k_scale_max: 0.01, v_scale_max: 0.01 };
        let mut fresh = VAttentionPolicy::oracle(cfg.clone());
        fresh.set_kv_quant(Some(bounds));
        let mut reuse = TemporalReusePolicy::new(
            VAttentionPolicy::oracle(cfg),
            ReuseConfig { max_age: 1000, ..Default::default() },
        );
        reuse.set_kv_quant(Some(bounds));
        let mut rng_a = Rng::new(23);
        let mut rng_b = Rng::new(23);
        for step in 0..24 {
            let q = drifting_query(16, step, 0.01, 31);
            let sa = fresh.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_a,
                step,
            });
            let sb = reuse.select(&mut PolicyCtx {
                k: &k,
                v: &v,
                q_scaled: &q,
                rng: &mut rng_b,
                step,
            });
            assert_eq!(sa.idx, sb.idx, "index divergence at step {step}");
            assert_eq!(sa.prob, sb.prob, "probability divergence at step {step}");
        }
        let st = reuse.stats();
        assert!(st.hits > 0, "planted stream must still certify under quant slack: {st:?}");
        assert!(fresh.last.as_ref().unwrap().quant_rho > 0.0, "budget must charge the slack");
    }

    #[test]
    fn unsupported_scorer_refreshes_every_step() {
        let mut rng = Rng::new(43);
        let k = Mat::randn(256, 32, 1.0, &mut rng);
        let v = Mat::randn(256, 32, 1.0, &mut rng);
        let cfg = vcfg(4, 8, SizeSpec::Abs(8));
        let inner = VAttentionPolicy::new(
            cfg,
            Box::new(crate::policies::scorers::HashSignScorer::new(32, 5)),
        );
        let mut reuse = TemporalReusePolicy::new(inner, ReuseConfig::default());
        let q = vec![0.1f32; 32];
        for step in 0..4 {
            let sel = reuse.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step });
            assert!(sel.validate(256).is_ok());
        }
        let st = reuse.stats();
        assert_eq!(st.refresh_unsupported, 4);
        assert_eq!(st.scorer_calls, 4);
        assert_eq!(st.hits, 0);
    }
}
