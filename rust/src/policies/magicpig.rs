//! MagicPig (Chen et al., 2024): LSH-sampling-based sparse attention.
//!
//! Keys are centered and lifted with the simpleLSH transform
//! x → [x, √(M² − ‖x‖²)] so inner-product search becomes angular search;
//! `l` hash tables of `k_bits` random-hyperplane bits each retrieve
//! candidate tokens, and each retrieved token carries its LSH collision
//! probability p_i = 1 − (1 − c_iᵏ)ˡ with c_i = 1 − θ_i/π, feeding the
//! importance-sampling estimator of Eq. 3.
//!
//! Two fidelity modes reproduce the Table 10 ablation:
//! * `simple_lsh = true`  — the theory-faithful version ("MagicPig-B");
//! * `simple_lsh = false` — plain angular LSH on raw keys, as in the
//!   authors' released code ("MagicPig-A").

use super::{sink_window_indices, IndexPolicy, PolicyCtx, SizeSpec};
use crate::attention::Selection;
use crate::tensor::{dot, norm2, Mat};
use crate::util::Rng;

/// MagicPig: LSH-sampled sparse attention with per-token collision
/// probabilities feeding the Eq. 3 importance weights (see the module
/// docs for the transform and the fidelity modes).
///
/// ```
/// use vattn::policies::{IndexPolicy, MagicPigPolicy, PolicyCtx, SizeSpec};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(600, 16, 1.0, &mut rng), Mat::randn(600, 16, 1.0, &mut rng));
/// let q = vec![0.1; 16];
/// let mut policy = MagicPigPolicy::new(6, 32, 3);
/// policy.sink = SizeSpec::Abs(8);
/// policy.window = SizeSpec::Abs(8);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert!(sel.validate(600).is_ok());
/// assert!(sel.len() >= 16); // anchors always present; LSH adds candidates
/// ```
pub struct MagicPigPolicy {
    pub k_bits: usize,
    pub l_tables: usize,
    pub sink: SizeSpec,
    pub window: SizeSpec,
    /// Cap on retrieved tokens (paper: random-subsample if exceeded).
    pub max_budget: Option<usize>,
    /// Use the simpleLSH MIPS transform + centering (theory-faithful).
    pub simple_lsh: bool,
    seed: u64,
    state: Option<LshState>,
}

struct LshState {
    /// l_tables × k_bits hyperplanes over the (d+1)-dim lifted space.
    planes: Vec<Mat>,
    /// Bucket maps: per table, bucket-code → token indices.
    tables: Vec<std::collections::HashMap<u64, Vec<u32>>>,
    /// Lifted, normalized key copies (needed for collision probs).
    lifted: Mat,
    rows_seen: usize,
}

impl MagicPigPolicy {
    pub fn new(k_bits: usize, l_tables: usize, seed: u64) -> Self {
        assert!(k_bits <= 64);
        MagicPigPolicy {
            k_bits,
            l_tables,
            sink: SizeSpec::Abs(128),
            window: SizeSpec::Abs(128),
            max_budget: None,
            simple_lsh: true,
            seed,
            state: None,
        }
    }

    fn build(&mut self, k: &Mat) {
        let d = k.cols;
        let n = k.rows;
        // Center keys (practical fix from the paper's App. B.5 discussion).
        let mut center = vec![0.0f32; d];
        if self.simple_lsh {
            for i in 0..n {
                crate::tensor::axpy(1.0 / n as f32, k.row(i), &mut center);
            }
        }
        let mut max_norm = 1e-6f32;
        let mut centered = Mat::zeros(n, d);
        for i in 0..n {
            let row = k.row(i);
            for c in 0..d {
                centered.set(i, c, row[c] - center[c]);
            }
            max_norm = max_norm.max(norm2(centered.row(i)));
        }
        // Lift: [x, sqrt(M^2 - |x|^2)] / M  (unit vectors).
        let mut lifted = Mat::zeros(n, d + 1);
        for i in 0..n {
            let row = centered.row(i).to_vec();
            let nrm = norm2(&row);
            let last = (max_norm * max_norm - nrm * nrm).max(0.0).sqrt();
            for c in 0..d {
                lifted.set(i, c, row[c] / max_norm);
            }
            lifted.set(i, d, last / max_norm);
        }
        let mut rng = Rng::new(self.seed);
        let planes: Vec<Mat> = (0..self.l_tables)
            .map(|_| Mat::randn(self.k_bits, d + 1, 1.0, &mut rng))
            .collect();
        let mut tables = vec![std::collections::HashMap::new(); self.l_tables];
        for i in 0..n {
            for (t, plane) in planes.iter().enumerate() {
                let code = hash_code(plane, lifted.row(i), self.k_bits);
                tables[t].entry(code).or_insert_with(Vec::new).push(i as u32);
            }
        }
        self.state = Some(LshState { planes, tables, lifted, rows_seen: n });
    }
}

fn hash_code(planes: &Mat, x: &[f32], k_bits: usize) -> u64 {
    let mut code = 0u64;
    for b in 0..k_bits {
        if dot(planes.row(b), x) >= 0.0 {
            code |= 1 << b;
        }
    }
    code
}

impl IndexPolicy for MagicPigPolicy {
    fn name(&self) -> String {
        format!(
            "magicpig(K={},L={}{})",
            self.k_bits,
            self.l_tables,
            if self.simple_lsh { "" } else { ",raw" }
        )
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        let rebuild = match &self.state {
            Some(s) => s.rows_seen != n,
            None => true,
        };
        if rebuild {
            // (Re)index — real MagicPig hashes incrementally; rebuild is
            // equivalent and only costs build time, not quality.
            self.build(ctx.k);
        }
        let st = self.state.as_ref().unwrap();
        let d = ctx.k.cols;

        // Lift the query: center is NOT subtracted from q (asymmetric
        // transform): q -> [q, 0] normalized.
        let mut qlift = vec![0.0f32; d + 1];
        let qn = norm2(ctx.q_scaled).max(1e-9);
        for c in 0..d {
            qlift[c] = ctx.q_scaled[c] / qn;
        }

        // Retrieve candidates from all tables.
        let mut seen = std::collections::HashSet::new();
        for (t, plane) in st.planes.iter().enumerate() {
            let code = hash_code(plane, &qlift, self.k_bits);
            if let Some(bucket) = st.tables[t].get(&code) {
                for &i in bucket {
                    seen.insert(i as usize);
                }
            }
        }

        let fixed = sink_window_indices(n, self.sink.resolve(n), self.window.resolve(n));
        let fixed_set: std::collections::HashSet<usize> = fixed.iter().copied().collect();
        let mut cand: Vec<usize> =
            seen.into_iter().filter(|i| !fixed_set.contains(i)).collect();
        cand.sort_unstable();

        // Random-subsample if over budget (paper's §3 ablation protocol).
        if let Some(cap) = self.max_budget {
            if cand.len() > cap {
                ctx.rng.shuffle(&mut cand);
                cand.truncate(cap);
                cand.sort_unstable();
            }
        }

        // Collision probabilities for the retained candidates.
        let mut probs = Vec::with_capacity(cand.len());
        for &i in &cand {
            let cosine = dot(st.lifted.row(i), &qlift).clamp(-1.0, 1.0);
            let theta = cosine.acos();
            let c = 1.0 - theta / std::f32::consts::PI; // per-bit agree prob
            let p_table = c.powi(self.k_bits as i32);
            let p = 1.0 - (1.0 - p_table).powi(self.l_tables as i32);
            probs.push(p.clamp(1e-6, 1.0));
        }

        let mut idx = fixed;
        let n_fixed = idx.len();
        idx.extend(cand);
        let mut prob = vec![1.0f32; n_fixed];
        prob.extend(probs);
        Selection::with_probs(idx, prob)
    }

    fn reset(&mut self) {
        self.state = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
        (k, v, q, rng)
    }

    #[test]
    fn selection_is_valid() {
        let (k, v, q, mut rng) = fixture(600, 16, 1);
        let mut pol = MagicPigPolicy::new(6, 32, 3);
        pol.sink = SizeSpec::Abs(8);
        pol.window = SizeSpec::Abs(8);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert!(sel.validate(600).is_ok(), "{:?}", sel.validate(600));
        assert!(sel.len() >= 16);
    }

    #[test]
    fn more_tables_retrieve_more() {
        let (k, v, q, mut rng) = fixture(800, 16, 2);
        let count = |l: usize, rng: &mut Rng| {
            let mut pol = MagicPigPolicy::new(8, l, 3);
            pol.sink = SizeSpec::Abs(0);
            pol.window = SizeSpec::Abs(0);
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng, step: 0 };
            pol.select(&mut ctx).len()
        };
        let few = count(4, &mut rng);
        let many = count(64, &mut rng);
        assert!(many > few, "L=64 {many} <= L=4 {few}");
    }

    #[test]
    fn collision_probs_favor_similar_keys() {
        let (mut k, v, q, mut rng) = fixture(400, 16, 3);
        // Plant token 100 aligned with q: it should get a high p if drawn.
        for c in 0..16 {
            k.set(100, c, q[c] * 30.0);
        }
        let mut pol = MagicPigPolicy::new(4, 64, 5);
        pol.sink = SizeSpec::Abs(0);
        pol.window = SizeSpec::Abs(0);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        if let Some(pos) = sel.idx.iter().position(|&i| i == 100) {
            let p_planted = sel.prob[pos];
            let mean_p: f32 = sel.prob.iter().sum::<f32>() / sel.len() as f32;
            assert!(
                p_planted >= mean_p,
                "planted p {p_planted} < mean {mean_p}"
            );
        }
    }

    #[test]
    fn budget_cap_enforced() {
        let (k, v, q, mut rng) = fixture(500, 16, 4);
        let mut pol = MagicPigPolicy::new(2, 64, 7); // coarse hash: many hits
        pol.sink = SizeSpec::Abs(4);
        pol.window = SizeSpec::Abs(4);
        pol.max_budget = Some(50);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert!(sel.len() <= 8 + 50);
        assert!(sel.validate(500).is_ok());
    }

    #[test]
    fn raw_mode_differs_from_simple_lsh() {
        let (k, v, q, mut rng) = fixture(300, 16, 5);
        let run = |simple: bool, rng: &mut Rng| {
            let mut pol = MagicPigPolicy::new(8, 16, 9);
            pol.simple_lsh = simple;
            pol.sink = SizeSpec::Abs(0);
            pol.window = SizeSpec::Abs(0);
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng, step: 0 };
            pol.select(&mut ctx).idx
        };
        let a = run(true, &mut rng);
        let b = run(false, &mut rng);
        assert_ne!(a, b); // different preprocessing -> different buckets
    }
}
