//! Oracle baselines from §3 and §5: oracle top-k (gold standard for the
//! approximate-top-k family), oracle top-p (the strongest top-based
//! baseline), uniform random sampling, and the oracle-top + sample hybrid
//! used in the Fig. 2 motivation ablation.

use super::{sink_window_indices, top_indices_excluding, IndexPolicy, PolicyCtx, SizeSpec};
use crate::attention::{attention_scores, logits_all, Selection};

/// Oracle top-k: exact query–key logits, pick the `heavy` largest plus
/// sink and window tokens. Deterministic attention (Eq. 2).
///
/// ```
/// use vattn::policies::{IndexPolicy, OracleTopKPolicy, PolicyCtx};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(600, 8, 1.0, &mut rng), Mat::randn(600, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut policy = OracleTopKPolicy::with_fraction(0.05);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert_eq!(sel.len(), 128 + 128 + 30); // sink + window + 5% of 600
/// ```
pub struct OracleTopKPolicy {
    pub sink: SizeSpec,
    pub window: SizeSpec,
    pub heavy: SizeSpec,
}

impl OracleTopKPolicy {
    /// Paper default: 128 sink + 128 window tokens, `heavy` fraction.
    pub fn with_fraction(f: f64) -> Self {
        OracleTopKPolicy { sink: SizeSpec::Abs(128), window: SizeSpec::Abs(128), heavy: SizeSpec::Frac(f) }
    }
}

impl IndexPolicy for OracleTopKPolicy {
    fn name(&self) -> String {
        "oracle-top-k".into()
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        let fixed = sink_window_indices(n, self.sink.resolve(n), self.window.resolve(n));
        let logits = logits_all(ctx.k, ctx.q_scaled);
        let heavy = self.heavy.resolve(n);
        let mut idx = fixed;
        let top = top_indices_excluding(&logits, heavy, &idx);
        idx.extend(top);
        idx.sort_unstable();
        Selection::deterministic(idx)
    }
}

/// Oracle top-p: smallest set of highest-score tokens whose cumulative
/// full-attention scores exceed `p`, plus sink/window.
///
/// ```
/// use vattn::policies::{IndexPolicy, OracleTopPPolicy, PolicyCtx};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(300, 8, 1.0, &mut rng), Mat::randn(300, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut policy = OracleTopPPolicy::new(0.9);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert!(sel.validate(300).is_ok());
/// ```
pub struct OracleTopPPolicy {
    pub sink: SizeSpec,
    pub window: SizeSpec,
    pub p: f64,
}

impl OracleTopPPolicy {
    pub fn new(p: f64) -> Self {
        OracleTopPPolicy { sink: SizeSpec::Abs(128), window: SizeSpec::Abs(128), p }
    }
}

impl IndexPolicy for OracleTopPPolicy {
    fn name(&self) -> String {
        format!("oracle-top-p({})", self.p)
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        let fixed = sink_window_indices(n, self.sink.resolve(n), self.window.resolve(n));
        let scores = attention_scores(ctx.k, ctx.q_scaled);
        // Sort all tokens by score descending; take until cumulative >= p.
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            scores[b as usize].partial_cmp(&scores[a as usize]).unwrap_or(std::cmp::Ordering::Equal)
        });
        let mut cum = 0.0f64;
        let mut chosen = Vec::new();
        for &i in &order {
            cum += scores[i as usize] as f64;
            chosen.push(i as usize);
            if cum >= self.p {
                break;
            }
        }
        let mut idx = super::merge_sorted_unique(&[&fixed, &chosen]);
        idx.dedup();
        Selection::deterministic(idx)
    }
}

/// Uniform random sampling of `budget` tokens (plus sink/window as
/// deterministic anchors), estimated with Eq. 3 importance weights.
///
/// ```
/// use vattn::policies::{IndexPolicy, PolicyCtx, RandomSamplePolicy, SizeSpec};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(1000, 8, 1.0, &mut rng), Mat::randn(1000, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut policy = RandomSamplePolicy::with_fraction(0.1);
/// policy.sink = SizeSpec::Abs(8);
/// policy.window = SizeSpec::Abs(8);
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// // 16 anchors at p = 1 plus 100 sampled tokens at p = 100 / 984.
/// assert_eq!(sel.len(), 116);
/// assert_eq!(sel.prob.iter().filter(|&&p| p < 1.0).count(), 100);
/// ```
pub struct RandomSamplePolicy {
    pub sink: SizeSpec,
    pub window: SizeSpec,
    pub budget: SizeSpec,
}

impl RandomSamplePolicy {
    pub fn with_fraction(f: f64) -> Self {
        RandomSamplePolicy { sink: SizeSpec::Abs(128), window: SizeSpec::Abs(128), budget: SizeSpec::Frac(f) }
    }

    /// Pure sampling variant (no sink/window anchors) for the Fig. 2
    /// motivation study.
    pub fn pure(f: f64) -> Self {
        RandomSamplePolicy { sink: SizeSpec::Abs(0), window: SizeSpec::Abs(0), budget: SizeSpec::Frac(f) }
    }
}

impl IndexPolicy for RandomSamplePolicy {
    fn name(&self) -> String {
        "random-sample".into()
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        let fixed = sink_window_indices(n, self.sink.resolve(n), self.window.resolve(n));
        let n_s = n - fixed.len();
        let b = self.budget.resolve(n).min(n_s);
        if n_s == 0 || b == 0 {
            return Selection::deterministic(fixed);
        }
        let sampled = ctx.rng.sample_excluding(n, b, &fixed);
        let p = b as f32 / n_s as f32;
        Selection::compose(fixed, sampled, p)
    }
}

/// The §3 hybrid: half the budget on oracle-top, half on uniform
/// sampling of the residual — the simplified precursor of vAttention.
///
/// ```
/// use vattn::policies::{HybridTopSamplePolicy, IndexPolicy, PolicyCtx};
/// use vattn::tensor::Mat;
/// use vattn::util::Rng;
///
/// let mut rng = Rng::new(0);
/// let (k, v) = (Mat::randn(1000, 8, 1.0, &mut rng), Mat::randn(1000, 8, 1.0, &mut rng));
/// let q = vec![0.1; 8];
/// let mut policy = HybridTopSamplePolicy::new(0.1); // 100-token budget
/// let sel = policy.select(&mut PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 });
/// assert_eq!(sel.len(), 100);
/// assert_eq!(sel.prob.iter().filter(|&&p| p == 1.0).count(), 50); // oracle-top half
/// ```
pub struct HybridTopSamplePolicy {
    pub budget: SizeSpec,
    /// Fraction of the budget spent on oracle-top (paper uses 0.5).
    pub top_fraction: f64,
}

impl HybridTopSamplePolicy {
    pub fn new(budget_fraction: f64) -> Self {
        HybridTopSamplePolicy { budget: SizeSpec::Frac(budget_fraction), top_fraction: 0.5 }
    }
}

impl IndexPolicy for HybridTopSamplePolicy {
    fn name(&self) -> String {
        "oracle-top+random-sample".into()
    }

    fn select(&mut self, ctx: &mut PolicyCtx) -> Selection {
        let n = ctx.n();
        let budget = self.budget.resolve(n);
        let k_top = ((budget as f64 * self.top_fraction) as usize).min(n);
        let logits = logits_all(ctx.k, ctx.q_scaled);
        let mut top = top_indices_excluding(&logits, k_top, &[]);
        top.sort_unstable();
        let n_s = n - top.len();
        let b = (budget - top.len()).min(n_s);
        if b == 0 || n_s == 0 {
            return Selection::deterministic(top);
        }
        let sampled = ctx.rng.sample_excluding(n, b, &top);
        let p = b as f32 / n_s as f32;
        Selection::compose(top, sampled, p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Mat;
    use crate::util::Rng;

    fn ctx_fixture(n: usize, d: usize, seed: u64) -> (Mat, Mat, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let k = Mat::randn(n, d, 1.0, &mut rng);
        let v = Mat::randn(n, d, 1.0, &mut rng);
        let q: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0) / (d as f32).sqrt()).collect();
        (k, v, q, rng)
    }

    #[test]
    fn oracle_topk_finds_planted_heavy_token() {
        let (mut k, v, q, mut rng) = ctx_fixture(500, 16, 1);
        // Plant token 250 to align strongly with q.
        for c in 0..16 {
            k.set(250, c, q[c] * 50.0);
        }
        let mut pol = OracleTopKPolicy {
            sink: SizeSpec::Abs(4),
            window: SizeSpec::Abs(4),
            heavy: SizeSpec::Abs(10),
        };
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert!(sel.idx.contains(&250), "planted heavy token not selected");
        assert!(sel.validate(500).is_ok());
        assert!(sel.prob.iter().all(|&p| p == 1.0));
    }

    #[test]
    fn oracle_topp_covers_mass() {
        let (k, v, q, mut rng) = ctx_fixture(300, 8, 2);
        let mut pol = OracleTopPPolicy::new(0.9);
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        let scores = attention_scores(&k, &q);
        let mass: f64 = sel.idx.iter().map(|&i| scores[i] as f64).sum();
        assert!(mass >= 0.9, "mass={mass}");
        assert!(sel.validate(300).is_ok());
    }

    #[test]
    fn topp_higher_p_selects_more() {
        let (k, v, q, mut rng) = ctx_fixture(400, 8, 3);
        let mut lo = OracleTopPPolicy::new(0.5);
        let mut hi = OracleTopPPolicy::new(0.99);
        let n_lo = {
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
            lo.select(&mut ctx).len()
        };
        let n_hi = {
            let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
            hi.select(&mut ctx).len()
        };
        assert!(n_hi >= n_lo);
    }

    #[test]
    fn random_sample_has_valid_probs_and_budget() {
        let (k, v, q, mut rng) = ctx_fixture(1000, 8, 4);
        let mut pol = RandomSamplePolicy {
            sink: SizeSpec::Abs(8),
            window: SizeSpec::Abs(8),
            budget: SizeSpec::Abs(100),
        };
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert!(sel.validate(1000).is_ok());
        assert_eq!(sel.len(), 16 + 100);
        let p_expect = 100.0 / (1000.0 - 16.0);
        let sampled_probs: Vec<f32> =
            sel.prob.iter().copied().filter(|&p| p < 1.0).collect();
        assert_eq!(sampled_probs.len(), 100);
        assert!(sampled_probs.iter().all(|&p| (p - p_expect as f32).abs() < 1e-6));
    }

    #[test]
    fn hybrid_splits_budget() {
        let (k, v, q, mut rng) = ctx_fixture(1000, 8, 5);
        let mut pol = HybridTopSamplePolicy::new(0.1); // 100 tokens
        let mut ctx = PolicyCtx { k: &k, v: &v, q_scaled: &q, rng: &mut rng, step: 0 };
        let sel = pol.select(&mut ctx);
        assert!(sel.validate(1000).is_ok());
        assert_eq!(sel.len(), 100);
        let det = sel.prob.iter().filter(|&&p| p == 1.0).count();
        assert_eq!(det, 50);
    }
}
