//! Token sampling for the generation loop: greedy or temperature.

use crate::util::Rng;

#[derive(Clone, Debug)]
pub enum Sampler {
    Greedy,
    Temperature(f32),
}

impl Sampler {
    pub fn sample(&self, logits: &[f32], rng: &mut Rng) -> u32 {
        match self {
            Sampler::Greedy => argmax(logits) as u32,
            Sampler::Temperature(t) => {
                let mut probs: Vec<f32> = logits.iter().map(|&l| l / t).collect();
                crate::tensor::softmax_inplace(&mut probs);
                let r = rng.f32();
                let mut cum = 0.0f32;
                for (i, &p) in probs.iter().enumerate() {
                    cum += p;
                    if r < cum {
                        return i as u32;
                    }
                }
                (probs.len() - 1) as u32
            }
        }
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > best_v {
            best_v = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let mut rng = Rng::new(1);
        let s = Sampler::Greedy;
        assert_eq!(s.sample(&[0.1, 3.0, 0.5], &mut rng), 1);
    }

    #[test]
    fn temperature_respects_distribution() {
        let mut rng = Rng::new(2);
        let s = Sampler::Temperature(1.0);
        // one dominant logit: should be picked almost always
        let mut hits = 0;
        for _ in 0..200 {
            if s.sample(&[0.0, 10.0, 0.0], &mut rng) == 1 {
                hits += 1;
            }
        }
        assert!(hits > 190);
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let mut rng = Rng::new(3);
        let s = Sampler::Temperature(0.01);
        for _ in 0..50 {
            assert_eq!(s.sample(&[1.0, 1.2, 0.8], &mut rng), 1);
        }
    }
}
