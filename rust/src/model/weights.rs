//! Seeded synthetic weight generation. The paper serves pretrained
//! checkpoints; this environment has none (DESIGN.md §3), so weights are
//! Gaussian with transformer-standard scales — enough to exercise every
//! compute path with realistic magnitudes and full determinism.

use super::ModelConfig;
use crate::tensor::Mat;
use crate::util::Rng;

/// One transformer layer's weights (shapes match the AOT artifacts).
pub struct LayerWeights {
    pub w_ln_attn: Vec<f32>,
    pub wq: Mat,
    pub wk: Mat,
    pub wv: Mat,
    pub wo: Mat,
    pub w_ln_ffn: Vec<f32>,
    pub w_gate: Mat,
    pub w_up: Mat,
    pub w_down: Mat,
}

pub struct Weights {
    pub layers: Vec<LayerWeights>,
    pub w_ln_f: Vec<f32>,
    /// Tied embedding / LM head, [vocab × d_model].
    pub w_emb: Mat,
}

impl Weights {
    pub fn generate(cfg: &ModelConfig, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let d = cfg.d_model;
        let f = cfg.d_ff;
        // 1/sqrt(d) init keeps activations O(1) through depth.
        let s_attn = 1.0 / (d as f32).sqrt();
        let s_ffn = 1.0 / (f as f32).sqrt();
        // GQA: K/V projections emit n_kv_heads * d_head columns.
        let d_kv = cfg.n_kv_heads * cfg.d_head();
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                w_ln_attn: ln_weight(d, &mut rng),
                wq: Mat::randn(d, d, s_attn, &mut rng),
                wk: Mat::randn(d, d_kv, s_attn, &mut rng),
                wv: Mat::randn(d, d_kv, s_attn, &mut rng),
                wo: Mat::randn(d, d, s_attn, &mut rng),
                w_ln_ffn: ln_weight(d, &mut rng),
                w_gate: Mat::randn(d, f, s_attn, &mut rng),
                w_up: Mat::randn(d, f, s_attn, &mut rng),
                w_down: Mat::randn(f, d, s_ffn, &mut rng),
            })
            .collect();
        Weights {
            layers,
            w_ln_f: ln_weight(d, &mut rng),
            w_emb: Mat::randn(cfg.vocab, d, 1.0, &mut rng),
        }
    }
}

fn ln_weight(d: usize, rng: &mut Rng) -> Vec<f32> {
    (0..d).map(|_| 1.0 + rng.normal32(0.0, 0.02)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let cfg = ModelConfig::tiny();
        let a = Weights::generate(&cfg, 3);
        let b = Weights::generate(&cfg, 3);
        assert_eq!(a.layers[0].wq.data, b.layers[0].wq.data);
        assert_eq!(a.w_emb.data, b.w_emb.data);
        let c = Weights::generate(&cfg, 4);
        assert_ne!(a.layers[0].wq.data, c.layers[0].wq.data);
    }

    #[test]
    fn shapes_match_config() {
        let cfg = ModelConfig::tiny();
        let w = Weights::generate(&cfg, 1);
        assert_eq!(w.layers.len(), cfg.n_layers);
        assert_eq!(w.layers[0].wq.rows, cfg.d_model);
        assert_eq!(w.layers[0].w_gate.cols, cfg.d_ff);
        assert_eq!(w.w_emb.rows, cfg.vocab);
    }

    #[test]
    fn ln_weights_near_one() {
        let cfg = ModelConfig::tiny();
        let w = Weights::generate(&cfg, 2);
        for &x in &w.layers[0].w_ln_attn {
            assert!((x - 1.0).abs() < 0.2);
        }
    }
}
