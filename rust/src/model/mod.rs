//! Rust-native reference transformer.
//!
//! Mirrors `python/compile/model.py` op-for-op (RMSNorm, RoPE, SwiGLU,
//! tied LM head) so the PJRT-executed artifacts can be validated against
//! a pure-rust forward pass, and so the engine has a host-side compute
//! path when PJRT is not wanted (most experiments only need attention
//! math, not the full model).

pub mod config;
pub mod sampler;
pub mod weights;

pub use config::ModelConfig;
pub use sampler::Sampler;
pub use weights::{LayerWeights, Weights};

use crate::kvcache::KvCache;
use crate::tensor::Mat;

/// Per-(layer, head) index-selection callback handed to a decode step:
/// `(layer, head, K, V, q_scaled, kv_quant_bounds) -> Selection`. The
/// K/V matrices are the cache's f32 rows (the dequantized mirror on a
/// quantized cache), and the bounds — `None` on exact f32 caches —
/// carry the dequantization error the verified policies fold into
/// their (ε, δ) budget. Lives at the model layer because every compute
/// backend ([`Model::decode_step`], the PJRT path) consumes it; the
/// serving engine re-exports it as `server::SelectFn`.
pub type SelectFn = dyn FnMut(
    usize,
    usize,
    &Mat,
    &Mat,
    &[f32],
    Option<crate::tensor::quant::KvQuantBounds>,
) -> crate::attention::Selection;

/// RMSNorm matching `model.rmsnorm` (eps = 1e-5).
pub fn rmsnorm(x: &[f32], w: &[f32]) -> Vec<f32> {
    let d = x.len();
    let ms: f32 = x.iter().map(|v| v * v).sum::<f32>() / d as f32;
    let inv = 1.0 / (ms + 1e-5).sqrt();
    x.iter().zip(w.iter()).map(|(&xv, &wv)| xv * inv * wv).collect()
}

/// Rotary phases for a position: (cos, sin), each of length d_head/2.
pub fn rope_phases(pos: usize, d_head: usize) -> (Vec<f32>, Vec<f32>) {
    let half = d_head / 2;
    let mut cos = Vec::with_capacity(half);
    let mut sin = Vec::with_capacity(half);
    for i in 0..half {
        let inv = 1.0f32 / 10000f32.powf(i as f32 / half as f32);
        let ang = pos as f32 * inv;
        cos.push(ang.cos());
        sin.push(ang.sin());
    }
    (cos, sin)
}

/// Apply RoPE in the split layout used by the python model:
/// (x1, x2) -> (x1·cos − x2·sin, x1·sin + x2·cos).
pub fn apply_rope(x: &mut [f32], cos: &[f32], sin: &[f32]) {
    let half = x.len() / 2;
    for i in 0..half {
        let a = x[i];
        let b = x[half + i];
        x[i] = a * cos[i] - b * sin[i];
        x[half + i] = a * sin[i] + b * cos[i];
    }
}

/// Per-step output of a decode step.
pub struct StepOut {
    pub logits: Vec<f32>,
    /// Mean selection density across (layer, head) for this step (1.0 for
    /// dense).
    pub mean_density: f64,
}

/// The rust-native model: weights + forward passes.
pub struct Model {
    pub cfg: ModelConfig,
    pub w: Weights,
}

impl Model {
    pub fn new(cfg: ModelConfig, seed: u64) -> Model {
        let w = Weights::generate(&cfg, seed);
        Model { cfg, w }
    }

    /// Embed a token (row of the tied embedding).
    pub fn embed(&self, token: u32) -> Vec<f32> {
        self.w.w_emb.row(token as usize % self.cfg.vocab).to_vec()
    }

    /// One dense decode step: append (k, v) for `token` at `pos` into
    /// `cache` and return logits. `select` chooses attention indices per
    /// (layer, head) — it also receives the cache's dequantization
    /// bounds (`None` on f32 storage) so verified policies can widen
    /// their budget; `None` select = dense attention.
    pub fn decode_step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        mut select: Option<&mut SelectFn>,
    ) -> StepOut {
        let cfg = &self.cfg;
        let (h, dh) = (cfg.n_heads, cfg.d_head());
        let scale = 1.0 / (dh as f32).sqrt();
        let (cos, sin) = rope_phases(pos, dh);
        let mut x = self.embed(token);
        let mut densities: Vec<f64> = Vec::new();

        for l in 0..cfg.n_layers {
            let lw = &self.w.layers[l];
            // ── attention sub-block ──
            let xn = rmsnorm(&x, &lw.w_ln_attn);
            let q_flat = Mat::from_vec(1, cfg.d_model, xn.clone()).matmul(&lw.wq);
            let k_flat = Mat::from_vec(1, cfg.d_model, xn.clone()).matmul(&lw.wk);
            let v_flat = Mat::from_vec(1, cfg.d_model, xn).matmul(&lw.wv);
            // GQA: one (k, v) append per KV head; query heads share them.
            // Head scratch rides the per-thread arena — one warm-up
            // allocation per worker, zero per step thereafter.
            let mut kh = crate::util::arena::take_f32();
            for kvh in 0..cfg.n_kv_heads {
                kh.clear();
                kh.extend_from_slice(&k_flat.data[kvh * dh..(kvh + 1) * dh]);
                let vh = &v_flat.data[kvh * dh..(kvh + 1) * dh];
                apply_rope(&mut kh, &cos, &sin);
                cache.append(l, kvh, &kh, vh);
            }
            crate::util::arena::recycle_f32(kh);
            let mut attn_concat = crate::util::arena::take_f32();
            attn_concat.resize(cfg.d_model, 0.0);
            let mut qh = crate::util::arena::take_f32();
            for head in 0..h {
                qh.clear();
                qh.extend_from_slice(&q_flat.data[head * dh..(head + 1) * dh]);
                apply_rope(&mut qh, &cos, &sin);
                for qv in qh.iter_mut() {
                    *qv *= scale;
                }
                let kv_head = cfg.kv_head_of(head);
                let (out, rows_read) = {
                    let (kc, vc) = cache.head(l, kv_head);
                    match select.as_mut() {
                        Some(f) => {
                            let qb = cache.quant_bounds(l, kv_head);
                            let sel = f(l, head, kc, vc, &qh, qb);
                            densities.push(sel.density(kc.rows));
                            (crate::attention::sparse_sdpa(kc, vc, &qh, &sel), sel.len())
                        }
                        None => {
                            densities.push(1.0);
                            (crate::attention::dense_sdpa(kc, vc, &qh).out, kc.rows)
                        }
                    }
                };
                // Charge the host-tier read traffic (K and V rows
                // touched, at the cache's physical per-row bytes).
                cache.record_selected_read(rows_read);
                attn_concat[head * dh..(head + 1) * dh].copy_from_slice(&out);
            }
            crate::util::arena::recycle_f32(qh);
            let attn_out = lw.wo.vecmat(&attn_concat);
            crate::util::arena::recycle_f32(attn_concat);
            for (xi, &ai) in x.iter_mut().zip(attn_out.iter()) {
                *xi += ai;
            }
            // ── ffn sub-block ──
            let xn = rmsnorm(&x, &lw.w_ln_ffn);
            let g = lw.w_gate.vecmat(&xn);
            let u = lw.w_up.vecmat(&xn);
            let act: Vec<f32> = g
                .iter()
                .zip(u.iter())
                .map(|(&gv, &uv)| gv / (1.0 + (-gv).exp()) * uv)
                .collect();
            let ffn_out = lw.w_down.vecmat(&act);
            for (xi, &fi) in x.iter_mut().zip(ffn_out.iter()) {
                *xi += fi;
            }
        }

        let xn = rmsnorm(&x, &self.w.w_ln_f);
        let logits = self.w.w_emb.matvec(&xn);
        let mean_density = if densities.is_empty() {
            1.0
        } else {
            densities.iter().sum::<f64>() / densities.len() as f64
        };
        StepOut { logits, mean_density }
    }

    /// Prefill: run `tokens` through the model densely, filling `cache`.
    /// Returns the logits after the last token.
    pub fn prefill(&self, tokens: &[u32], cache: &mut KvCache) -> StepOut {
        let mut last = StepOut { logits: vec![], mean_density: 1.0 };
        for (pos, &t) in tokens.iter().enumerate() {
            last = self.decode_step(t, pos, cache, None);
        }
        last
    }

    /// Parameter count (for reporting).
    pub fn param_count(&self) -> usize {
        let c = &self.cfg;
        let per_layer = 2 * c.d_model // norms
            + 4 * c.d_model * c.d_model // q,k,v,o
            + 2 * c.d_model * c.d_ff + c.d_ff * c.d_model; // gate,up,down
        c.n_layers * per_layer + c.d_model + c.vocab * c.d_model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::Selection;
    use crate::util::Rng;

    #[test]
    fn rmsnorm_matches_definition() {
        let x = vec![3.0, -4.0];
        let out = rmsnorm(&x, &[1.0, 1.0]);
        let rms = (out.iter().map(|v| v * v).sum::<f32>() / 2.0).sqrt();
        assert!((rms - 1.0).abs() < 1e-4);
    }

    #[test]
    fn rope_zero_position_is_identity() {
        let (cos, sin) = rope_phases(0, 8);
        let mut x = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];
        let orig = x.clone();
        apply_rope(&mut x, &cos, &sin);
        for (a, b) in x.iter().zip(orig.iter()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let (cos, sin) = rope_phases(13, 16);
        let mut rng = Rng::new(1);
        let mut x: Vec<f32> = (0..16).map(|_| rng.normal32(0.0, 1.0)).collect();
        let n0 = crate::tensor::norm2(&x);
        apply_rope(&mut x, &cos, &sin);
        assert!((crate::tensor::norm2(&x) - n0).abs() < 1e-4);
    }

    #[test]
    fn rope_relative_inner_product() {
        let dh = 16;
        let mut rng = Rng::new(2);
        let q: Vec<f32> = (0..dh).map(|_| rng.normal32(0.0, 1.0)).collect();
        let k: Vec<f32> = (0..dh).map(|_| rng.normal32(0.0, 1.0)).collect();
        let ip = |m: usize, n: usize| {
            let (cm, sm) = rope_phases(m, dh);
            let (cn, sn) = rope_phases(n, dh);
            let mut qq = q.clone();
            let mut kk = k.clone();
            apply_rope(&mut qq, &cm, &sm);
            apply_rope(&mut kk, &cn, &sn);
            crate::tensor::dot(&qq, &kk)
        };
        assert!((ip(5, 3) - ip(9, 7)).abs() < 1e-3);
    }

    #[test]
    fn decode_step_shapes_and_determinism() {
        let cfg = ModelConfig::tiny();
        let model = Model::new(cfg.clone(), 42);
        let mut c1 = KvCache::new(&cfg);
        let mut c2 = KvCache::new(&cfg);
        let a = model.decode_step(5, 0, &mut c1, None);
        let b = model.decode_step(5, 0, &mut c2, None);
        assert_eq!(a.logits.len(), cfg.vocab);
        assert_eq!(a.logits, b.logits);
        assert_eq!(c1.len(0), 1);
    }

    #[test]
    fn prefill_grows_cache() {
        let cfg = ModelConfig::tiny();
        let model = Model::new(cfg.clone(), 42);
        let mut cache = KvCache::new(&cfg);
        let out = model.prefill(&[1, 2, 3, 4], &mut cache);
        assert_eq!(cache.len(0), 4);
        assert_eq!(out.logits.len(), cfg.vocab);
    }

    #[test]
    fn dense_selection_equals_dense_path() {
        // A selector that picks everything must reproduce dense logits.
        let cfg = ModelConfig::tiny();
        let model = Model::new(cfg.clone(), 7);
        let mut c1 = KvCache::new(&cfg);
        let mut c2 = KvCache::new(&cfg);
        model.prefill(&[1, 2, 3], &mut c1);
        model.prefill(&[1, 2, 3], &mut c2);
        let dense = model.decode_step(4, 3, &mut c1, None);
        let mut select_all = |_l: usize,
                              _h: usize,
                              k: &Mat,
                              _v: &Mat,
                              _q: &[f32],
                              _qb: Option<crate::tensor::quant::KvQuantBounds>| {
            Selection::deterministic((0..k.rows).collect())
        };
        let sparse = model.decode_step(4, 3, &mut c2, Some(&mut select_all));
        let err = crate::tensor::rel_l2_error(&sparse.logits, &dense.logits);
        assert!(err < 1e-5, "err={err}");
        assert!((sparse.mean_density - 1.0).abs() < 1e-9);
    }

    #[test]
    fn int8_cache_decode_is_deterministic_and_exposes_bounds() {
        use crate::kvcache::KvDtype;
        let cfg = ModelConfig::tiny();
        let model = Model::new(cfg.clone(), 42);
        let mut c1 = KvCache::new_with_dtype(&cfg, KvDtype::Int8);
        let mut c2 = KvCache::new_with_dtype(&cfg, KvDtype::Int8);
        model.prefill(&[1, 2, 3], &mut c1);
        model.prefill(&[1, 2, 3], &mut c2);
        let a = model.decode_step(4, 3, &mut c1, None);
        // The select callback on a quantized cache receives Some bounds
        // with a live scale.
        let mut saw_bounds = 0usize;
        let mut select_all = |_l: usize,
                              _h: usize,
                              k: &Mat,
                              _v: &Mat,
                              _q: &[f32],
                              qb: Option<crate::tensor::quant::KvQuantBounds>| {
            let b = qb.expect("int8 cache must expose quant bounds");
            assert!(b.k_scale_max > 0.0);
            saw_bounds += 1;
            Selection::deterministic((0..k.rows).collect())
        };
        let b = model.decode_step(4, 3, &mut c2, Some(&mut select_all));
        assert_eq!(saw_bounds, cfg.n_layers * cfg.n_heads);
        // Dense and select-everything agree on the same quantized store.
        let err = crate::tensor::rel_l2_error(&b.logits, &a.logits);
        assert!(err < 1e-5, "err={err}");
        // And differ from the fp32 cache's logits (quantization is real).
        let mut cf = KvCache::new(&cfg);
        model.prefill(&[1, 2, 3], &mut cf);
        let f = model.decode_step(4, 3, &mut cf, None);
        assert_ne!(f.logits, a.logits, "int8 storage must perturb the logits");
    }

    #[test]
    fn param_count_small_is_tens_of_millions() {
        let m = Model::new(ModelConfig::small(), 1);
        let p = m.param_count();
        assert!(p > 20_000_000 && p < 60_000_000, "params={p}");
    }

    #[test]
    fn gqa_model_runs_and_shares_kv_heads() {
        let cfg = ModelConfig::tiny_gqa();
        let model = Model::new(cfg.clone(), 11);
        let mut cache = KvCache::new(&cfg);
        let out = model.prefill(&[1, 2, 3, 4, 5], &mut cache);
        assert_eq!(out.logits.len(), cfg.vocab);
        // cache has n_kv_heads slots per layer, each with 5 rows
        assert_eq!(cache.n_heads, cfg.n_kv_heads);
        assert_eq!(cache.len(0), 5);
        let (k0, _) = cache.head(0, 0);
        let (k1, _) = cache.head(0, 1);
        assert_eq!(k0.rows, 5);
        assert_ne!(k0.data, k1.data);
    }

    #[test]
    fn gqa_equals_mha_when_groups_are_one() {
        // n_kv_heads == n_heads must reproduce the plain MHA path.
        let cfg = ModelConfig::tiny();
        assert_eq!(cfg.gqa_group(), 1);
        let model = Model::new(cfg.clone(), 5);
        let mut c = KvCache::new(&cfg);
        let out = model.decode_step(9, 0, &mut c, None);
        assert!(out.logits.iter().all(|x| x.is_finite()));
    }
}
