//! Model configuration. Mirrors `python/compile/model.py::ModelConfig` —
//! the shapes must agree with the AOT artifacts the rust runtime loads.

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ModelConfig {
    pub d_model: usize,
    pub n_heads: usize,
    /// KV heads for grouped-query attention; must divide `n_heads`.
    /// Equal to `n_heads` for plain multi-head attention.
    pub n_kv_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab: usize,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Query heads per KV head (GQA group size).
    pub fn gqa_group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    /// Map a query head to its KV head.
    pub fn kv_head_of(&self, q_head: usize) -> usize {
        q_head / self.gqa_group()
    }

    /// Test-sized model (matches python `ModelConfig.tiny()`).
    pub fn tiny() -> ModelConfig {
        ModelConfig { d_model: 64, n_heads: 2, n_kv_heads: 2, n_layers: 2, d_ff: 128, vocab: 256 }
    }

    /// Tiny GQA variant: 4 query heads sharing 2 KV heads.
    pub fn tiny_gqa() -> ModelConfig {
        ModelConfig { d_model: 64, n_heads: 4, n_kv_heads: 2, n_layers: 2, d_ff: 128, vocab: 256 }
    }

    /// End-to-end serving example (~26M params; python `small()`).
    pub fn small() -> ModelConfig {
        ModelConfig { d_model: 512, n_heads: 8, n_kv_heads: 8, n_layers: 8, d_ff: 1408, vocab: 8192 }
    }

    /// Llama-3-8B *shape* (for latency extrapolation only — weights are
    /// never materialized at this size; see `sim::memory_model`). GQA:
    /// 32 query heads over 8 KV heads, like the real model.
    pub fn llama8b_shape() -> ModelConfig {
        ModelConfig {
            d_model: 4096,
            n_heads: 32,
            n_kv_heads: 8,
            n_layers: 32,
            d_ff: 14336,
            vocab: 128256,
        }
    }

    /// KV cache bytes per token (f32 here; the paper's fp16 halves this —
    /// the *ratios* Fig. 5 cares about are unaffected).
    pub fn kv_bytes_per_token(&self) -> usize {
        2 * self.n_kv_heads * self.d_head() * 4 * self.n_layers
    }

    /// Parse from a CLI name.
    pub fn by_name(name: &str) -> Option<ModelConfig> {
        match name {
            "tiny" => Some(Self::tiny()),
            "small" => Some(Self::small()),
            "llama8b-shape" => Some(Self::llama8b_shape()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heads_divide_model_dim() {
        for cfg in [ModelConfig::tiny(), ModelConfig::small(), ModelConfig::llama8b_shape()] {
            assert_eq!(cfg.d_model % cfg.n_heads, 0);
            assert!(cfg.d_head() >= 16);
        }
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(ModelConfig::by_name("tiny"), Some(ModelConfig::tiny()));
        assert!(ModelConfig::by_name("nope").is_none());
    }

    #[test]
    fn kv_bytes_llama_shape() {
        // GQA: 2 * 8 kv-heads * 128 * 4B * 32 layers = 256 KiB/token f32.
        assert_eq!(ModelConfig::llama8b_shape().kv_bytes_per_token(), 256 << 10);
    }

    #[test]
    fn gqa_head_mapping() {
        let cfg = ModelConfig::llama8b_shape();
        assert_eq!(cfg.gqa_group(), 4);
        assert_eq!(cfg.kv_head_of(0), 0);
        assert_eq!(cfg.kv_head_of(3), 0);
        assert_eq!(cfg.kv_head_of(4), 1);
        assert_eq!(cfg.kv_head_of(31), 7);
    }
}
