//! Adaptive sample-size ("budget") computation — Algorithm 2 and the
//! theory of §4 / Appendix D–E.
//!
//! Given the deterministic index set `I_f` and a small uniform *base
//! sample* of the residual tokens, we estimate the population statistics
//! (σ² of the exp-logits for the denominator, Tr(Σ) of the exp-weighted
//! value vectors for the numerator, plus D̂ and ‖N̂‖₂) and solve the CLT
//! bound of Lemma 4.1 (or the conservative Hoeffding bound of App. E) for
//! the minimum sample size `b` that yields an (ε, δ) approximation.
//!
//! All exponentials are taken relative to a reference logit `m_ref`
//! supplied by the caller; every budget formula is scale-invariant in
//! `m_ref` because it only involves ratios (σ/D, √Tr(Σ)/‖N‖).
//!
//! The written derivation — CLT vs Hoeffding, the per-computation
//! verification targets, and the symbol map from the paper's
//! Algorithm 1/2 (f_s, f_l, f_t, f_b) to
//! [`crate::policies::VAttentionConfig`] fields and the functions in
//! this module — lives in `docs/GUARANTEES.md`. Empirical (ε, δ)
//! coverage is asserted by `tests/budget_coverage.rs` (including the
//! quantized-KV sweep) and, with temporal reuse enabled,
//! `tests/temporal_reuse.rs`.
//!
//! When the KV store is quantized (`EngineConfig::kv_dtype = Int8`),
//! the deterministic dequantization error enters the contract through
//! [`QuantSlack`] / [`budget_for_quant`]: the sampling tolerance is
//! shrunk by the worst-case relative bias ρ and the spread statistics
//! are widened ([`widen_stats`]), so the delivered (ε, δ) is *inclusive
//! of* the dequantization error rather than silently on top of it.
//!
//! ```
//! use vattn::budget::{budget_for, BaseStats, Bound, Verify};
//!
//! // Statistics as `estimate_stats` would report them for a moderately
//! // concentrated residual of 1000 tokens.
//! let stats = BaseStats {
//!     n_s: 1000,
//!     sigma2_d: 0.25,
//!     trace_sigma_n: 4.0,
//!     d_hat: 2000.0,
//!     n_hat_norm: 3000.0,
//!     range_d: 3.0,
//!     range_n: 10.0,
//!     base_size: 50,
//! };
//! let clt = budget_for(&stats, Verify::Denominator, 0.05, 0.05, Bound::Clt);
//! let hoeffding = budget_for(&stats, Verify::Denominator, 0.05, 0.05, Bound::Hoeffding);
//! assert!(clt > 0 && clt <= hoeffding); // Hoeffding is the conservative recipe
//! assert!(hoeffding <= stats.n_s); // budgets never exceed the residual
//! ```

use crate::attention;
use crate::tensor::Mat;
use crate::util::{inv_normal_cdf, Rng};

/// Which computation the (ε, δ) guarantee is requested for (Algorithm 2's
/// `X` parameter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Verify {
    /// Guarantee on the denominator D only.
    Denominator,
    /// Guarantee on the numerator N only.
    Numerator,
    /// Guarantee on the full attention output N/D (Theorem 4.3).
    Sdpa,
}

/// Which concentration bound backs the budget (App. E).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bound {
    /// Central-limit-theorem based (optimistic; the paper's default).
    Clt,
    /// Hoeffding's inequality (conservative; ~2.8× larger budgets).
    Hoeffding,
}

/// Population statistics estimated from the base sample (Algorithm 2's
/// `get-stats`), all relative to the shared reference logit `m_ref`.
#[derive(Clone, Debug)]
pub struct BaseStats {
    /// Number of residual (non-deterministic) tokens n_s.
    pub n_s: usize,
    /// Sample variance of {exp(l_i - m_ref)} over the base sample.
    pub sigma2_d: f64,
    /// Sample trace of the covariance of {exp(l_i - m_ref)·v_i}.
    pub trace_sigma_n: f64,
    /// Estimated full denominator D̂ = D_f + (n_s/b₀)·Σ_base exp(l - m_ref).
    pub d_hat: f64,
    /// Estimated ‖N̂‖₂ with N̂ = N_f + (n_s/b₀)·Σ_base exp(l - m_ref)·v.
    pub n_hat_norm: f64,
    /// Max exp(l - m_ref) observed in the base sample (range proxy for
    /// Hoeffding; inflated by `HOEFFDING_RANGE_SLACK`).
    pub range_d: f64,
    /// Max ‖exp(l - m_ref)·v‖ observed in the base sample.
    pub range_n: f64,
    /// Base-sample size actually used.
    pub base_size: usize,
}

/// Multiplier applied to the base-sample max when it stands in for the
/// (unknown) population range in the Hoeffding budget. The paper treats
/// Hoeffding as the conservative recipe, so we err on the large side.
pub const HOEFFDING_RANGE_SLACK: f64 = 1.5;

/// Estimate `BaseStats` from a base sample of residual indices.
///
/// `i_f_sorted` — deterministic indices, sorted ascending (for exclusion).
/// `base_idx` — the base-sample indices (must be residual tokens).
pub fn estimate_stats(
    k: &Mat,
    v: &Mat,
    q_scaled: &[f32],
    i_f_sorted: &[usize],
    base_idx: &[usize],
    m_ref: f32,
) -> BaseStats {
    estimate_stats_impl(v, i_f_sorted, base_idx, m_ref, k.rows, |i| {
        crate::tensor::dot(k.row(i), q_scaled)
    })
}

/// `estimate_stats` over *precomputed* logits — the hot-path variant used
/// when the top-k scorer already scanned all keys (oracle predictor):
/// avoids re-touching K entirely (§Perf iteration 4).
pub fn estimate_stats_from_logits(
    logits: &[f32],
    v: &Mat,
    i_f_sorted: &[usize],
    base_idx: &[usize],
    m_ref: f32,
) -> BaseStats {
    estimate_stats_impl(v, i_f_sorted, base_idx, m_ref, logits.len(), |i| logits[i])
}

fn estimate_stats_impl(
    v: &Mat,
    i_f_sorted: &[usize],
    base_idx: &[usize],
    m_ref: f32,
    n: usize,
    logit_of: impl Fn(usize) -> f32,
) -> BaseStats {
    let n_s = n - i_f_sorted.len();
    let b0 = base_idx.len();
    let d_dim = v.cols;

    // Deterministic contributions D_f, N_f (via the logit accessor).
    let mut n_f = vec![0.0f32; d_dim];
    let mut d_f = 0.0f64;
    for &i in i_f_sorted {
        let w = (logit_of(i) - m_ref).exp();
        d_f += w as f64;
        crate::tensor::axpy(w, v.row(i), &mut n_f);
    }

    if b0 == 0 || n_s == 0 {
        // Degenerate: no residual / no sample — zero variance, exact sums.
        let n_norm = crate::tensor::norm2(&n_f) as f64;
        return BaseStats {
            n_s,
            sigma2_d: 0.0,
            trace_sigma_n: 0.0,
            d_hat: d_f,
            n_hat_norm: n_norm,
            range_d: 0.0,
            range_n: 0.0,
            base_size: 0,
        };
    }

    // Base-sample moments of r_i = exp(l_i - m_ref) (scalar) and
    // r⃗_i = exp(l_i - m_ref)·v_i (vector).
    let mut sum_w = 0.0f64;
    let mut sum_w2 = 0.0f64;
    let mut max_w = 0.0f64;
    let mut max_rn = 0.0f64;
    let mut sum_vec = vec![0.0f64; d_dim];
    let mut sum_vec2 = vec![0.0f64; d_dim];
    for &i in base_idx {
        let l = logit_of(i);
        let w = (l - m_ref).exp() as f64;
        sum_w += w;
        sum_w2 += w * w;
        max_w = max_w.max(w);
        // Vectorized column-moment pass; bitwise equal to the historical
        // interleaved loop (kept as `weighted_moments_seq_ref`, proptested
        // in tests/proptests.rs) because per-column accumulation order is
        // unchanged and the rn2 reduction stays sequential.
        let rn2 = crate::tensor::simd::weighted_moments(w, v.row(i), &mut sum_vec, &mut sum_vec2);
        max_rn = max_rn.max(rn2.sqrt());
    }
    let b0f = b0 as f64;
    let mean_w = sum_w / b0f;
    // Unbiased sample variance (guard b0 == 1).
    let sigma2_d = if b0 > 1 {
        ((sum_w2 - b0f * mean_w * mean_w) / (b0f - 1.0)).max(0.0)
    } else {
        0.0
    };
    // Tr(Σ) = Σ_c Var(r_c).
    let mut trace = 0.0f64;
    for c in 0..d_dim {
        let mean_c = sum_vec[c] / b0f;
        if b0 > 1 {
            trace += ((sum_vec2[c] - b0f * mean_c * mean_c) / (b0f - 1.0)).max(0.0);
        }
    }

    // Scale-up estimates of the residual sums.
    let d_dyn = n_s as f64 * mean_w;
    let d_hat = d_f + d_dyn;
    let mut n_hat2 = 0.0f64;
    for c in 0..d_dim {
        let n_c = n_f[c] as f64 + n_s as f64 * (sum_vec[c] / b0f);
        n_hat2 += n_c * n_c;
    }

    BaseStats {
        n_s,
        sigma2_d,
        trace_sigma_n: trace,
        d_hat,
        n_hat_norm: n_hat2.sqrt(),
        range_d: max_w * HOEFFDING_RANGE_SLACK,
        range_n: max_rn * HOEFFDING_RANGE_SLACK,
        base_size: b0,
    }
}

/// CLT budget for estimating a *scalar* sum to absolute error τ w.p. 1-δ
/// (Lemma 4.1 with d = 1): b ≥ (Φ⁻¹(1-δ/2) · n_s·σ / τ)².
pub fn clt_budget_scalar(n_s: usize, sigma: f64, tau: f64, delta: f64) -> usize {
    if sigma <= 0.0 || tau <= 0.0 || n_s == 0 {
        return 0;
    }
    let z = inv_normal_cdf(1.0 - delta / 2.0);
    let b = (z * n_s as f64 * sigma / tau).powi(2);
    ceil_budget(b, n_s)
}

/// CLT budget for a *vector* sum (Lemma 4.1): σ replaced by √Tr(Σ).
pub fn clt_budget_vector(n_s: usize, trace_sigma: f64, tau: f64, delta: f64) -> usize {
    clt_budget_scalar(n_s, trace_sigma.max(0.0).sqrt(), tau, delta)
}

/// Hoeffding budget for a sum of n_s terms bounded in [0, R], estimated by
/// a scaled sample mean: Pr(|ŝ-s| > τ) ≤ 2·exp(-2bτ²/(n_s²R²)), so
/// b ≥ n_s²·R²·ln(2/δ) / (2τ²).
pub fn hoeffding_budget(n_s: usize, range: f64, tau: f64, delta: f64) -> usize {
    if range <= 0.0 || tau <= 0.0 || n_s == 0 {
        return 0;
    }
    let b = (n_s as f64 * range).powi(2) * (2.0 / delta).ln() / (2.0 * tau * tau);
    ceil_budget(b, n_s)
}

fn ceil_budget(b: f64, n_s: usize) -> usize {
    if !b.is_finite() {
        return n_s;
    }
    (b.ceil().max(0.0) as usize).min(n_s)
}

/// Budget b_D(ε, δ) for an (ε, δ)-approximation of the denominator
/// (Corollary D.3): τ = ε·D̂.
pub fn budget_denominator(stats: &BaseStats, eps: f64, delta: f64, bound: Bound) -> usize {
    let tau = eps * stats.d_hat;
    match bound {
        Bound::Clt => clt_budget_scalar(stats.n_s, stats.sigma2_d.sqrt(), tau, delta),
        Bound::Hoeffding => hoeffding_budget(stats.n_s, stats.range_d, tau, delta),
    }
}

/// Budget b_N(ε, δ) for the numerator (Corollary D.2): τ = ε·‖N̂‖₂.
pub fn budget_numerator(stats: &BaseStats, eps: f64, delta: f64, bound: Bound) -> usize {
    let tau = eps * stats.n_hat_norm;
    match bound {
        Bound::Clt => clt_budget_vector(stats.n_s, stats.trace_sigma_n, tau, delta),
        Bound::Hoeffding => hoeffding_budget(stats.n_s, stats.range_n, tau, delta),
    }
}

/// Budget for (ε, δ)-verified SDPA (Theorem 4.3):
///   b ≥ min over ε'∈(0,ε), δ'∈(0,δ) of max(b_D(ε'/2, δ'), b_N((ε-ε')/2, δ-δ')).
/// We grid-search the (ε', δ') split — both budget formulas are closed
/// form, so a 15×7 grid costs ~100 Φ⁻¹ evaluations.
pub fn budget_sdpa(stats: &BaseStats, eps: f64, delta: f64, bound: Bound) -> usize {
    let mut best = usize::MAX;
    const EPS_GRID: usize = 15;
    const DELTA_GRID: usize = 7;
    for i in 1..EPS_GRID {
        let eps_d = eps * i as f64 / EPS_GRID as f64; // ε' for denominator
        let eps_n = eps - eps_d;
        for j in 1..DELTA_GRID {
            let delta_d = delta * j as f64 / DELTA_GRID as f64;
            let delta_n = delta - delta_d;
            let bd = budget_denominator(stats, eps_d / 2.0, delta_d, bound);
            let bn = budget_numerator(stats, eps_n / 2.0, delta_n, bound);
            best = best.min(bd.max(bn));
        }
    }
    best.min(stats.n_s)
}

/// Budget dispatch over the verified computation (Algorithm 2).
pub fn budget_for(stats: &BaseStats, verify: Verify, eps: f64, delta: f64, bound: Bound) -> usize {
    match verify {
        Verify::Denominator => budget_denominator(stats, eps, delta, bound),
        Verify::Numerator => budget_numerator(stats, eps, delta, bound),
        Verify::Sdpa => budget_sdpa(stats, eps, delta, bound),
    }
}

/// Dequantization-error bounds of a quantized KV store, as the budget
/// math consumes them. Both terms are *deterministic* worst-case bounds
/// (`tensor::quant`'s exact per-row `scale/2` guarantee pushed through
/// the dot product), so quantization spends ε only — δ is untouched,
/// because nothing random was added. Derivation: docs/GUARANTEES.md §8.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QuantSlack {
    /// Bound on |dequantized logit − exact logit|, uniform over tokens:
    /// `e = (max_k_scale / 2) · ‖q‖₁`.
    pub logit_err: f64,
    /// Bound on the L2 perturbation of any value row:
    /// `‖v̂ − v‖₂ ≤ (max_v_scale / 2) · √d`.
    pub value_norm_err: f64,
}

impl QuantSlack {
    /// The single conversion from a KV store's raw dequantization
    /// bounds to budget slack — every consumer (the serving policy, the
    /// coverage tests, the bench's coverage probe) must build its slack
    /// here so the empirical (ε, δ) checks validate exactly what the
    /// policy charges. `logit_err` may be supplied precomputed (a
    /// scorer's declared interval half-width); both spellings are
    /// [`crate::tensor::quant::KvQuantBounds::logit_err`].
    pub fn from_bounds(
        bounds: &crate::tensor::quant::KvQuantBounds,
        q_scaled: &[f32],
        d: usize,
    ) -> QuantSlack {
        QuantSlack {
            logit_err: bounds.logit_err(q_scaled) as f64,
            value_norm_err: bounds.value_err() as f64 * (d as f64).sqrt(),
        }
    }

    pub fn is_zero(&self) -> bool {
        self.logit_err == 0.0 && self.value_norm_err == 0.0
    }

    /// `e^e − 1`: every true exp-logit weight `w` sits within
    /// `[ŵ·e^{−e}, ŵ·e^{e}]` of its dequantized counterpart ŵ, i.e.
    /// within this relative factor.
    fn weight_rel(&self) -> f64 {
        self.logit_err.exp_m1()
    }

    /// Relative deterministic bias of the quantized denominator:
    /// `|D_q − D| ≤ (e^e − 1)·D`.
    pub fn rho_denominator(&self) -> f64 {
        self.weight_rel()
    }

    /// Relative deterministic bias of the quantized numerator:
    /// `‖N_q − N‖ ≤ (e^e − 1)·‖N‖ + e^e·D·e_v·√d`, expressed relative
    /// to the estimated ‖N̂‖ via the measured D̂/‖N̂‖ ratio. Infinite
    /// when ‖N̂‖ ≈ 0 (a relative guarantee is then unattainable and the
    /// budget correctly saturates at n_s).
    pub fn rho_numerator(&self, stats: &BaseStats) -> f64 {
        let wr = self.weight_rel();
        if self.value_norm_err == 0.0 {
            return wr;
        }
        if stats.n_hat_norm <= 0.0 {
            return f64::INFINITY;
        }
        wr + (1.0 + wr) * self.value_norm_err * stats.d_hat / stats.n_hat_norm
    }

    /// Total relative slack for the requested computation. For SDPA the
    /// denominator and numerator biases compose first-order, mirroring
    /// how Theorem 4.3 splits ε across the two estimates.
    pub fn rho(&self, stats: &BaseStats, verify: Verify) -> f64 {
        match verify {
            Verify::Denominator => self.rho_denominator(),
            Verify::Numerator => self.rho_numerator(stats),
            Verify::Sdpa => self.rho_denominator() + self.rho_numerator(stats),
        }
    }
}

/// Widen measured base-sample statistics to cover the pre-quantization
/// population (docs/GUARANTEES.md §8). With `e` the logit bound, every
/// true weight is `ŵ·c`, `c ∈ [e^{−e}, e^{e}]`; writing `w = ŵ + d`
/// with `|d| ≤ R̂·(e^e − 1)` gives `σ(w) ≤ σ(ŵ) + max|d|` (std is a
/// seminorm), and the Hoeffding ranges grow by the factor `e^e` (plus
/// the value-row perturbation for the vector terms). Widening is pure
/// extra conservatism on the *sampling* bound — the deterministic bias
/// is handled separately by [`budget_for_quant`]'s ε split.
pub fn widen_stats(stats: &BaseStats, slack: &QuantSlack) -> BaseStats {
    let wr = slack.weight_rel(); // e^e − 1
    let grow = 1.0 + wr; //         e^e
    let beta = stats.range_d * wr;
    let gamma = stats.range_n * wr + stats.range_d * grow * slack.value_norm_err;
    let sigma_d = stats.sigma2_d.max(0.0).sqrt() + beta;
    let sigma_n = stats.trace_sigma_n.max(0.0).sqrt() + gamma;
    BaseStats {
        sigma2_d: sigma_d * sigma_d,
        trace_sigma_n: sigma_n * sigma_n,
        range_d: stats.range_d * grow,
        range_n: stats.range_n * grow + stats.range_d * grow * slack.value_norm_err,
        ..stats.clone()
    }
}

/// [`budget_for`] with the dequantization error folded into the (ε, δ)
/// contract: the sampled estimator concentrates around the *quantized*
/// sums, which sit within a deterministic relative `ρ` of the exact
/// ones, so the sampling tolerance must satisfy
/// `ε_s·(1 + ρ) + ρ ≤ ε  ⇒  ε_s = (ε − ρ) / (1 + ρ)`,
/// evaluated over [`widen_stats`]-widened statistics. When `ρ ≥ ε` no
/// sample size can deliver the contract (the bias alone may exceed it):
/// the budget saturates at `n_s` — exact summation over the quantized
/// cache, the best any consumer of this store can do. δ is never split:
/// quantization is deterministic. `None` / zero slack reduces exactly to
/// [`budget_for`], which is the "slack term zeroed" negative control
/// `tests/budget_coverage.rs` proves unsound on adversarial rows.
pub fn budget_for_quant(
    stats: &BaseStats,
    verify: Verify,
    eps: f64,
    delta: f64,
    bound: Bound,
    slack: Option<&QuantSlack>,
) -> usize {
    let Some(s) = slack.filter(|s| !s.is_zero()) else {
        return budget_for(stats, verify, eps, delta, bound);
    };
    let rho = s.rho(stats, verify);
    if !rho.is_finite() || rho >= eps {
        return stats.n_s;
    }
    let eps_s = (eps - rho) / (1.0 + rho);
    budget_for(&widen_stats(stats, s), verify, eps_s, delta, bound)
}

/// Draw the base sample (Algorithm 2 line 1): `⌈f_b · n_s⌉` uniform
/// residual indices, excluding the deterministic set (sorted).
pub fn draw_base_sample(
    n: usize,
    i_f_sorted: &[usize],
    f_b: f64,
    rng: &mut Rng,
) -> Vec<usize> {
    let n_s = n - i_f_sorted.len();
    let b0 = ((f_b * n_s as f64).ceil() as usize).min(n_s);
    rng.sample_excluding(n, b0, i_f_sorted)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_stats() -> BaseStats {
        // Scales chosen so CLT budgets land well inside (0, n_s): a
        // moderately concentrated residual with a large estimated sum.
        BaseStats {
            n_s: 10_000,
            sigma2_d: 0.25,
            trace_sigma_n: 4.0,
            d_hat: 20_000.0,
            n_hat_norm: 30_000.0,
            range_d: 3.0,
            range_n: 10.0,
            base_size: 256,
        }
    }

    #[test]
    fn clt_matches_formula() {
        // b = (z * n_s * sigma / tau)^2 with z = Phi^-1(0.975) ≈ 1.96.
        let b = clt_budget_scalar(1000, 0.5, 50.0, 0.05);
        let z = inv_normal_cdf(0.975);
        let want = (z * 1000.0 * 0.5 / 50.0).powi(2).ceil() as usize;
        assert_eq!(b, want);
    }

    #[test]
    fn budget_monotone_in_eps_and_delta() {
        let s = toy_stats();
        for bound in [Bound::Clt, Bound::Hoeffding] {
            let tight = budget_denominator(&s, 0.01, 0.05, bound);
            let loose = budget_denominator(&s, 0.1, 0.05, bound);
            assert!(tight >= loose, "{bound:?}: eps monotonicity");
            let sure = budget_denominator(&s, 0.05, 0.01, bound);
            let unsure = budget_denominator(&s, 0.05, 0.2, bound);
            assert!(sure >= unsure, "{bound:?}: delta monotonicity");
        }
    }

    #[test]
    fn hoeffding_at_least_clt_in_practice() {
        // With matched range/σ scales, Hoeffding should be (much) more
        // conservative — the paper reports ~2.8×.
        let s = toy_stats();
        let clt = budget_denominator(&s, 0.05, 0.1, Bound::Clt);
        let hoef = budget_denominator(&s, 0.05, 0.1, Bound::Hoeffding);
        assert!(hoef > clt, "hoeffding {hoef} <= clt {clt}");
    }

    #[test]
    fn budget_capped_at_ns() {
        let s = toy_stats();
        assert!(budget_denominator(&s, 1e-6, 1e-6, Bound::Clt) <= s.n_s);
        assert!(budget_numerator(&s, 1e-6, 1e-6, Bound::Hoeffding) <= s.n_s);
    }

    #[test]
    fn sdpa_budget_at_most_worst_single_split() {
        let s = toy_stats();
        let b = budget_sdpa(&s, 0.1, 0.1, Bound::Clt);
        // An even split is a feasible point of the minimization, so the
        // optimum can't exceed it.
        let bd = budget_denominator(&s, 0.025, 0.05, Bound::Clt);
        let bn = budget_numerator(&s, 0.025, 0.05, Bound::Clt);
        assert!(b <= bd.max(bn).min(s.n_s));
        assert!(b > 0);
    }

    #[test]
    fn zero_variance_means_zero_budget() {
        let mut s = toy_stats();
        s.sigma2_d = 0.0;
        assert_eq!(budget_denominator(&s, 0.05, 0.05, Bound::Clt), 0);
    }

    #[test]
    fn estimate_stats_on_uniform_population() {
        // All keys identical -> zero variance, exact D̂.
        use crate::tensor::Mat;
        let n = 128;
        let d = 8;
        let k = Mat::from_fn(n, d, |_, c| if c == 0 { 1.0 } else { 0.0 });
        let v = Mat::from_fn(n, d, |_, c| c as f32);
        let q = vec![1.0; d];
        let i_f: Vec<usize> = (0..8).collect();
        let mut rng = Rng::new(1);
        let base = draw_base_sample(n, &i_f, 0.25, &mut rng);
        let stats = estimate_stats(&k, &v, &q, &i_f, &base, 1.0);
        assert!(stats.sigma2_d < 1e-12);
        // exact D = n * exp(1 - 1) = 128
        assert!((stats.d_hat - n as f64).abs() < 1e-3, "d_hat={}", stats.d_hat);
        assert_eq!(stats.n_s, n - 8);
    }

    #[test]
    fn estimate_stats_variance_accuracy() {
        // Known two-point logit population: check σ̂² ≈ population σ².
        use crate::tensor::Mat;
        let n = 4000;
        let d = 4;
        // half the keys give logit 0, half logit ln(3) (w = 1 or 3).
        let k = Mat::from_fn(n, d, |r, c| {
            if c == 0 {
                if r % 2 == 0 {
                    0.0
                } else {
                    3f32.ln()
                }
            } else {
                0.0
            }
        });
        let v = Mat::from_fn(n, d, |_, _| 1.0);
        let q = vec![1.0, 0.0, 0.0, 0.0];
        let i_f: Vec<usize> = vec![];
        let mut rng = Rng::new(7);
        let base = draw_base_sample(n, &i_f, 0.5, &mut rng);
        let stats = estimate_stats(&k, &v, &q, &i_f, &base, 0.0);
        // population: w ∈ {1,3} equally -> mean 2, var 1.
        assert!((stats.sigma2_d - 1.0).abs() < 0.1, "σ²={}", stats.sigma2_d);
        assert!((stats.d_hat - 2.0 * n as f64).abs() < 0.1 * n as f64);
    }

    #[test]
    fn quant_slack_zero_reduces_to_plain_budget() {
        let s = toy_stats();
        for verify in [Verify::Denominator, Verify::Numerator, Verify::Sdpa] {
            for bound in [Bound::Clt, Bound::Hoeffding] {
                let plain = budget_for(&s, verify, 0.05, 0.05, bound);
                assert_eq!(budget_for_quant(&s, verify, 0.05, 0.05, bound, None), plain);
                let zero = QuantSlack::default();
                assert_eq!(
                    budget_for_quant(&s, verify, 0.05, 0.05, bound, Some(&zero)),
                    plain
                );
            }
        }
    }

    #[test]
    fn quant_slack_inflates_budget_monotonically() {
        let s = toy_stats();
        let small = QuantSlack { logit_err: 0.005, value_norm_err: 0.0 };
        let big = QuantSlack { logit_err: 0.02, value_norm_err: 0.0 };
        for bound in [Bound::Clt, Bound::Hoeffding] {
            let b0 = budget_for_quant(&s, Verify::Denominator, 0.05, 0.1, bound, None);
            let b1 = budget_for_quant(&s, Verify::Denominator, 0.05, 0.1, bound, Some(&small));
            let b2 = budget_for_quant(&s, Verify::Denominator, 0.05, 0.1, bound, Some(&big));
            assert!(b0 <= b1 && b1 <= b2, "{bound:?}: {b0} {b1} {b2}");
            assert!(b2 <= s.n_s);
        }
        // ε consumed entirely by the bias: sample everything.
        let huge = QuantSlack { logit_err: 0.2, value_norm_err: 0.0 };
        assert_eq!(
            budget_for_quant(&s, Verify::Denominator, 0.05, 0.1, Bound::Clt, Some(&huge)),
            s.n_s
        );
    }

    #[test]
    fn widen_stats_grows_every_spread_term_and_keeps_sums() {
        let s = toy_stats();
        let slack = QuantSlack { logit_err: 0.05, value_norm_err: 0.02 };
        let w = widen_stats(&s, &slack);
        assert!(w.sigma2_d > s.sigma2_d);
        assert!(w.trace_sigma_n > s.trace_sigma_n);
        assert!(w.range_d > s.range_d);
        assert!(w.range_n > s.range_n);
        // Point estimates and sizes pass through unchanged.
        assert_eq!(w.n_s, s.n_s);
        assert_eq!(w.d_hat, s.d_hat);
        assert_eq!(w.n_hat_norm, s.n_hat_norm);
        assert_eq!(w.base_size, s.base_size);
    }

    #[test]
    fn quant_rho_composes_sdpa_and_handles_degenerate_numerator() {
        let s = toy_stats();
        let slack = QuantSlack { logit_err: 0.01, value_norm_err: 0.001 };
        let rd = slack.rho_denominator();
        let rn = slack.rho_numerator(&s);
        assert!(rd > 0.0 && rn > rd, "value term must add to the numerator bias");
        assert!((slack.rho(&s, Verify::Sdpa) - (rd + rn)).abs() < 1e-15);
        let mut degenerate = toy_stats();
        degenerate.n_hat_norm = 0.0;
        assert!(slack.rho_numerator(&degenerate).is_infinite());
        assert_eq!(
            budget_for_quant(&degenerate, Verify::Numerator, 0.1, 0.1, Bound::Clt, Some(&slack)),
            degenerate.n_s
        );
    }

    #[test]
    fn base_sample_excludes_i_f() {
        let mut rng = Rng::new(3);
        let i_f: Vec<usize> = (0..100).collect();
        let base = draw_base_sample(1000, &i_f, 0.1, &mut rng);
        assert_eq!(base.len(), 90);
        assert!(base.iter().all(|&i| i >= 100 && i < 1000));
    }
}
