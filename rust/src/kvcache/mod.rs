//! KV cache management.
//!
//! The cache is host-resident (the paper's CPU-offload deployment): the
//! L3 coordinator owns it, runs index selection over it, and ships only
//! the *gathered* rows to the device. We track per-tier byte traffic so
//! the Fig. 5 bandwidth accounting is explicit, and maintain the small
//! auxiliary caches vAttention needs (the incremental random base-sample
//! cache; approximate-top-k bit caches live inside their scorers).
//!
//! Serving-engine caches are *paged* and **demand-paged**: the engine
//! leases a request's prompt blocks from a [`BlockPool`] at admission
//! and then grows the block table one block at a time as generation
//! crosses block boundaries (`KvCache::grow`), instead of reserving the
//! worst case up front. Blocks are reference counted: requests with
//! identical prompt prefixes share physical blocks through the
//! [`PrefixCache`] radix (fork = refcount bump; a divergent write
//! promotes the block to private via [`BlockPool::cow`]). Within a
//! request, rows stay contiguous per (layer, head) slot — index
//! selection scans K linearly, so contiguity is the hot-path layout —
//! while the block table carries placement, capacity accounting and
//! admission gating, mirroring vLLM's logical/physical split.
//!
//! Rows are physically stored by a [`BlockStore`] in the cache's
//! [`KvDtype`] — plain f32 or per-row symmetric int8 (3.5–4× smaller;
//! `EngineConfig::kv_dtype` / `vattn serve --kv-quant int8`). All byte
//! accounting (block sizing, [`TierStats`] traffic, resident bytes) is
//! on the physical payload; at int8 the dequantization error is carried
//! through the (ε, δ) budget as an explicit slack term rather than
//! silently ignored — see `docs/GUARANTEES.md` §8.

pub mod paged;
pub mod prefetch;
pub mod prefix;
pub mod spill;
pub mod store;
pub mod tiered;

pub use paged::{BlockId, BlockPool, CowOutcome, PageError};
pub use prefetch::PrefetchEngine;
pub use prefix::{ChainKey, PrefixCache};
pub use spill::{SlotReader, SpillSlot, SpillStats, SpillStore};
pub use store::{BlockSnapshot, BlockStore, KvDtype, SlotRows};
pub use tiered::{TierStats, TransferModel};

use crate::model::ModelConfig;
use crate::tensor::quant::KvQuantBounds;
use crate::tensor::Mat;

/// Block size (tokens) used when a cache is built standalone, outside an
/// engine's block pool.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Per-(layer, head) append-only KV store.
pub struct KvCache {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// Physical row storage (f32 or int8 + dequantized mirror), one slot
    /// per (layer, kv-head).
    store: BlockStore,
    /// Host→device traffic accounting (physical bytes).
    pub stats: TierStats,
    /// Allocation granularity in tokens.
    block_tokens: usize,
    /// Physical blocks leased from a [`BlockPool`] (empty ⇒ standalone).
    block_table: Vec<BlockId>,
    /// Paged caches enforce the leased-capacity bound on append.
    paged: bool,
}

impl KvCache {
    /// Standalone (unpaged) cache — grows without a capacity bound. Used
    /// by experiments and tests that run outside the serving engine.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        Self::build(cfg, DEFAULT_BLOCK_TOKENS, Vec::new(), false, KvDtype::F32)
    }

    /// Standalone cache with an explicit storage dtype.
    pub fn new_with_dtype(cfg: &ModelConfig, dtype: KvDtype) -> KvCache {
        Self::build(cfg, DEFAULT_BLOCK_TOKENS, Vec::new(), false, dtype)
    }

    /// Paged f32 cache backed by blocks leased from a [`BlockPool`]. The
    /// caller (the engine) frees the table via [`KvCache::release_blocks`]
    /// when the request completes.
    pub fn paged(cfg: &ModelConfig, block_tokens: usize, blocks: Vec<BlockId>) -> KvCache {
        Self::paged_dtype(cfg, block_tokens, blocks, KvDtype::F32)
    }

    /// [`KvCache::paged`] with an explicit storage dtype (the serving
    /// session builds per-request caches in the request's resolved
    /// dtype).
    pub fn paged_dtype(
        cfg: &ModelConfig,
        block_tokens: usize,
        blocks: Vec<BlockId>,
        dtype: KvDtype,
    ) -> KvCache {
        Self::build(cfg, block_tokens.max(1), blocks, true, dtype)
    }

    fn build(
        cfg: &ModelConfig,
        block_tokens: usize,
        blocks: Vec<BlockId>,
        paged: bool,
        dtype: KvDtype,
    ) -> KvCache {
        // One slot per (layer, KV head) — query heads share KV slots
        // under grouped-query attention.
        let slots = cfg.n_layers * cfg.n_kv_heads;
        let d = cfg.d_head();
        KvCache {
            n_layers: cfg.n_layers,
            n_heads: cfg.n_kv_heads,
            d_head: d,
            store: BlockStore::new(slots, d, dtype),
            stats: TierStats::default(),
            block_tokens,
            block_table: blocks,
            paged,
        }
    }

    /// Physical storage dtype of this cache's rows.
    pub fn dtype(&self) -> KvDtype {
        self.store.dtype()
    }

    /// Physical bytes of one stored K or V row.
    pub fn row_bytes(&self) -> usize {
        self.store.row_bytes()
    }

    /// Dequantization-error bounds for a head's rows (`None` on exact
    /// f32 storage). The engine hands these to the index policies before
    /// every select so the (ε, δ) budget can absorb the quantization
    /// slack (docs/GUARANTEES.md §8).
    pub fn quant_bounds(&self, layer: usize, head: usize) -> Option<KvQuantBounds> {
        self.store.quant_bounds(self.slot(layer, head))
    }

    #[inline]
    fn slot(&self, layer: usize, head: usize) -> usize {
        layer * self.n_heads + head
    }

    /// Append one token's (k, v) rows for a head. Paged caches enforce
    /// the capacity their block table was leased for — overflowing it
    /// means the engine's admission reservation was wrong. On int8
    /// storage the rows are quantized on the way in and the write
    /// traffic is charged at the physical (post-quantization) rate.
    pub fn append(&mut self, layer: usize, head: usize, k_row: &[f32], v_row: &[f32]) {
        let s = self.slot(layer, head);
        debug_assert_eq!(k_row.len(), self.d_head);
        if self.paged {
            let cap = self.block_table.len() * self.block_tokens;
            assert!(
                self.store.rows(s) < cap,
                "paged KvCache overflow: slot ({layer}, {head}) at {} tokens, {} blocks × {} reserved",
                self.store.rows(s),
                self.block_table.len(),
                self.block_tokens
            );
        }
        self.store.append_row(s, k_row, v_row);
        self.stats.record_write(2 * self.store.row_bytes());
    }

    /// Number of cached tokens for a layer (all heads advance together).
    pub fn len(&self, layer: usize) -> usize {
        self.store.rows(self.slot(layer, 0))
    }

    pub fn is_empty(&self) -> bool {
        (0..self.store.slots()).all(|s| self.store.rows(s) == 0)
    }

    /// Borrow a head's (K, V) matrices — the f32 rows every consumer
    /// computes over (the dequantized mirror on int8 storage).
    pub fn head(&self, layer: usize, head: usize) -> (&Mat, &Mat) {
        let s = self.slot(layer, head);
        (self.store.k(s), self.store.v(s))
    }

    /// Gather selected rows into dense (b × d) buffers — the host→device
    /// transfer of the serving path. Also charges the byte traffic to
    /// `stats` (2 matrices × b rows × d floats).
    pub fn gather(&mut self, layer: usize, head: usize, idx: &[usize]) -> (Mat, Mat) {
        let mut gk = Mat::zeros(0, 0);
        let mut gv = Mat::zeros(0, 0);
        self.gather_into(layer, head, idx, &mut gk, &mut gv);
        (gk, gv)
    }

    /// [`KvCache::gather`] into caller-owned scratch buffers: `gk` / `gv`
    /// are reshaped in place (allocation reused), so a decode loop that
    /// hoists two `Mat`s pays zero allocations per (layer, head, step).
    /// Charges the same read traffic as `gather`.
    pub fn gather_into(
        &mut self,
        layer: usize,
        head: usize,
        idx: &[usize],
        gk: &mut Mat,
        gv: &mut Mat,
    ) {
        let s = self.slot(layer, head);
        let d = self.d_head;
        // clear + extend (not a zeroing resize): every row is about to
        // be overwritten, so the only work left is the memcpy itself.
        gk.rows = idx.len();
        gk.cols = d;
        gk.data.clear();
        gv.rows = idx.len();
        gv.cols = d;
        gv.data.clear();
        for &i in idx {
            gk.data.extend_from_slice(self.store.k(s).row(i));
            gv.data.extend_from_slice(self.store.v(s).row(i));
        }
        // Physical traffic: a quantized row ships its codes + scale and
        // is dequantized device-side, so the host tier moves row_bytes,
        // not the 4·d of the dequantized view.
        self.stats.record_read(2 * idx.len() * self.store.row_bytes());
    }

    /// Charge the read traffic of `rows` selected K/V row pairs touched
    /// in place (the non-gathering decode path), at the physical
    /// per-row rate of this cache's dtype.
    pub fn record_selected_read(&mut self, rows: usize) {
        self.stats.record_read(2 * rows * self.store.row_bytes());
    }

    /// Total resident bytes (physical payload; a quantized cache's
    /// dequantized mirror models the transient device tile and is not
    /// host-resident state).
    pub fn resident_bytes(&self) -> usize {
        self.store.payload_bytes()
    }

    /// Drop all cached tokens (end of a request).
    pub fn clear(&mut self) {
        self.store.clear();
    }

    /// Tokens currently cached (all slots advance together).
    pub fn tokens(&self) -> usize {
        if self.store.slots() == 0 {
            0
        } else {
            self.store.rows(0)
        }
    }

    /// Allocation granularity in tokens.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks leased to this cache.
    pub fn blocks_reserved(&self) -> usize {
        self.block_table.len()
    }

    /// The leased block table, position-ordered (block `i` backs tokens
    /// `[i·block_tokens, (i+1)·block_tokens)`).
    pub fn block_table(&self) -> &[BlockId] {
        &self.block_table
    }

    /// Extend the block table with freshly leased blocks — the
    /// demand-paging growth path: the engine allocates a block only when
    /// the next append would cross a block boundary, instead of
    /// reserving the worst case at admission.
    pub fn grow(&mut self, blocks: impl IntoIterator<Item = BlockId>) {
        self.block_table.extend(blocks);
    }

    /// Swap the physical block at table index `idx` for `id` and return
    /// the previous id — the cache side of a copy-on-write promotion
    /// (`BlockPool::cow`): the engine moved this request's reference
    /// from a shared block to a private one; row data is per-request
    /// contiguous, so only the placement changes.
    pub fn replace_block(&mut self, idx: usize, id: BlockId) -> BlockId {
        std::mem::replace(&mut self.block_table[idx], id)
    }

    /// Snapshot one *filled* block's rows across every (layer, kv-head)
    /// slot, in the cache's physical layout — quantized payloads are
    /// captured byte-for-byte, so a later [`KvCache::load_block`]
    /// reproduces the donor's store bit-exactly. Used by the prefix
    /// cache to keep shared prompt blocks alive beyond their donor.
    pub fn snapshot_block(&self, block: usize) -> BlockSnapshot {
        let lo = block * self.block_tokens;
        let hi = lo + self.block_tokens;
        assert!(hi <= self.tokens(), "snapshot of an unfilled block {block}");
        self.store.snapshot_rows(lo, hi)
    }

    /// Snapshot an arbitrary cached row range `[lo, hi)` across every
    /// slot — like [`KvCache::snapshot_block`] but without the
    /// full-block restriction, so a preemption swap-out can capture a
    /// partially filled tail block too.
    pub fn snapshot_rows(&self, lo: usize, hi: usize) -> BlockSnapshot {
        assert!(lo <= hi && hi <= self.tokens(), "snapshot range out of bounds");
        self.store.snapshot_rows(lo, hi)
    }

    /// Bulk-append one shared block's rows (as produced by
    /// [`KvCache::snapshot_block`]) — the fork's copy-in of a cached
    /// prompt prefix, replacing that block's prefill compute with a
    /// memcpy. Quantized payloads are restored byte-for-byte (never
    /// requantized), which is what keeps prefix-shared and unshared
    /// runs byte-identical. Paged caches enforce their leased capacity
    /// as in [`KvCache::append`].
    pub fn load_block(&mut self, snap: &BlockSnapshot) {
        let tokens = snap.tokens;
        if self.paged {
            let cap = self.block_table.len() * self.block_tokens;
            assert!(
                self.tokens() + tokens <= cap,
                "paged KvCache overflow on prefix load: {} + {tokens} tokens into {} blocks × {}",
                self.tokens(),
                self.block_table.len(),
                self.block_tokens
            );
        }
        self.store.load_rows(snap);
        self.stats
            .record_write(2 * self.store.slots() * tokens * self.store.row_bytes());
    }

    /// Blocks actually filled by appended tokens.
    pub fn blocks_used(&self) -> usize {
        self.tokens().div_ceil(self.block_tokens)
    }

    /// Physical block holding the cached token at `pos` (None when the
    /// position has not been appended yet).
    pub fn block_of(&self, pos: usize) -> Option<BlockId> {
        if pos >= self.tokens() {
            return None;
        }
        self.block_table.get(pos / self.block_tokens).copied()
    }

    /// Drop all cached tokens and hand the leased block table back to
    /// the caller (who returns it to the [`BlockPool`]).
    pub fn release_blocks(&mut self) -> Vec<BlockId> {
        self.clear();
        std::mem::take(&mut self.block_table)
    }
}

/// Incrementally-maintained random cache of residual token indices (the
/// paper's "small random cache ... incrementally populated and updated
/// during token generation" used for on-GPU budget estimation):
/// reservoir sampling keeps a uniform sample of all appended positions.
pub struct RandomCache {
    pub capacity: usize,
    pub indices: Vec<usize>,
    seen: usize,
}

impl RandomCache {
    pub fn new(capacity: usize) -> RandomCache {
        RandomCache { capacity, indices: Vec::with_capacity(capacity), seen: 0 }
    }

    /// Observe the next appended position; O(1) amortized reservoir step.
    pub fn observe(&mut self, pos: usize, rng: &mut crate::util::Rng) {
        self.seen += 1;
        if self.indices.len() < self.capacity {
            self.indices.push(pos);
        } else {
            let j = rng.below(self.seen);
            if j < self.capacity {
                self.indices[j] = pos;
            }
        }
    }

    pub fn seen(&self) -> usize {
        self.seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn append_and_len() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        assert!(cache.is_empty());
        let row = vec![1.0f32; c.d_head()];
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                cache.append(l, h, &row, &row);
            }
        }
        assert_eq!(cache.len(0), 1);
        assert_eq!(cache.len(1), 1);
        assert!(!cache.is_empty());
    }

    #[test]
    fn gather_returns_selected_rows_and_charges_bytes() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        for i in 0..10 {
            let row = vec![i as f32; c.d_head()];
            cache.append(0, 0, &row, &row);
        }
        let (gk, gv) = cache.gather(0, 0, &[2, 7]);
        assert_eq!(gk.rows, 2);
        assert_eq!(gk.row(0)[0], 2.0);
        assert_eq!(gv.row(1)[0], 7.0);
        assert_eq!(cache.stats.bytes_read, 2 * 2 * c.d_head() * 4);
    }

    #[test]
    fn resident_bytes_grows_linearly() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        let row = vec![0.0f32; c.d_head()];
        cache.append(0, 0, &row, &row);
        let b1 = cache.resident_bytes();
        cache.append(0, 0, &row, &row);
        assert_eq!(cache.resident_bytes(), 2 * b1);
        cache.clear();
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn paged_cache_tracks_blocks_and_releases() {
        let c = cfg();
        let mut pool = BlockPool::for_model(&c, 4, None);
        let blocks = pool.try_alloc(pool.blocks_for_tokens(10)).unwrap();
        assert_eq!(blocks.len(), 3);
        let mut cache = KvCache::paged(&c, 4, blocks);
        let row = vec![1.0f32; c.d_head()];
        for _ in 0..10 {
            for l in 0..c.n_layers {
                for h in 0..c.n_kv_heads {
                    cache.append(l, h, &row, &row);
                }
            }
        }
        assert_eq!(cache.tokens(), 10);
        assert_eq!(cache.blocks_used(), 3);
        assert_eq!(cache.blocks_reserved(), 3);
        assert!(cache.block_of(0).is_some());
        assert!(cache.block_of(11).is_none());
        let freed = cache.release_blocks();
        assert_eq!(freed.len(), 3);
        assert_eq!(cache.tokens(), 0);
        pool.free(freed).unwrap();
        assert_eq!(pool.in_use_blocks(), 0);
    }

    #[test]
    fn grow_extends_capacity_and_replace_swaps_placement() {
        let c = cfg();
        let mut pool = BlockPool::for_model(&c, 4, None);
        let lease = pool.try_alloc(1).unwrap();
        let mut cache = KvCache::paged(&c, 4, lease);
        let row = vec![1.0f32; c.d_head()];
        let fill = |cache: &mut KvCache, n: usize| {
            for _ in 0..n {
                for l in 0..c.n_layers {
                    for h in 0..c.n_kv_heads {
                        cache.append(l, h, &row, &row);
                    }
                }
            }
        };
        fill(&mut cache, 4);
        assert_eq!(cache.blocks_reserved(), 1);
        // Demand paging: lease the next block only when needed.
        cache.grow(pool.try_alloc(1).unwrap());
        fill(&mut cache, 4);
        assert_eq!(cache.tokens(), 8);
        assert_eq!(cache.blocks_reserved(), 2);
        assert_eq!(cache.block_table(), &[0, 1]);
        // CoW swap: placement changes, data does not.
        let fresh = pool.try_alloc(1).unwrap()[0];
        assert_eq!(cache.replace_block(0, fresh), 0);
        assert_eq!(cache.block_table(), &[fresh, 1]);
        assert_eq!(cache.tokens(), 8);
    }

    #[test]
    fn gather_into_reuses_scratch_and_matches_gather() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        for i in 0..10 {
            let row = vec![i as f32; c.d_head()];
            cache.append(0, 0, &row, &row);
        }
        let (gk, gv) = cache.gather(0, 0, &[1, 4, 9]);
        let mut sk = Mat::zeros(0, 0);
        let mut sv = Mat::zeros(0, 0);
        cache.gather_into(0, 0, &[1, 4, 9], &mut sk, &mut sv);
        assert_eq!(gk.data, sk.data);
        assert_eq!(gv.data, sv.data);
        // Reuse with a different shape: no stale rows, same accounting.
        let reads_before = cache.stats.reads;
        let ptr = sk.data.as_ptr();
        cache.gather_into(0, 0, &[7], &mut sk, &mut sv);
        assert_eq!(sk.rows, 1);
        assert_eq!(sk.row(0)[0], 7.0);
        assert_eq!(cache.stats.reads, reads_before + 1);
        assert_eq!(sk.data.as_ptr(), ptr, "scratch must not reallocate when shrinking");
    }

    #[test]
    fn snapshot_and_load_block_round_trip() {
        let c = cfg();
        let mut pool = BlockPool::for_model(&c, 4, None);
        let lease = pool.try_alloc(2).unwrap();
        let mut src = KvCache::paged(&c, 4, lease);
        for i in 0..8 {
            for l in 0..c.n_layers {
                for h in 0..c.n_kv_heads {
                    let row = vec![(i * 10 + l + h) as f32; c.d_head()];
                    src.append(l, h, &row, &row);
                }
            }
        }
        let s0 = src.snapshot_block(0);
        let s1 = src.snapshot_block(1);
        let lease2 = pool.try_alloc(2).unwrap();
        let mut dst = KvCache::paged(&c, 4, lease2);
        dst.load_block(&s0);
        dst.load_block(&s1);
        assert_eq!(dst.tokens(), 8);
        for l in 0..c.n_layers {
            for h in 0..c.n_kv_heads {
                let (sk, svm) = src.head(l, h);
                let (dk, dvm) = dst.head(l, h);
                assert_eq!(sk.data, dk.data);
                assert_eq!(svm.data, dvm.data);
            }
        }
    }

    #[test]
    #[should_panic(expected = "paged KvCache overflow on prefix load")]
    fn load_block_rejects_overflow() {
        let c = cfg();
        // Donor holds 8 tokens in 2 blocks; the destination leased only
        // one 4-token block, so loading both snapshots must overflow.
        let mut pool = BlockPool::for_model(&c, 4, None);
        let mut donor = KvCache::paged(&c, 4, pool.try_alloc(2).unwrap());
        let row = vec![0.0f32; c.d_head()];
        for _ in 0..8 {
            for l in 0..c.n_layers {
                for h in 0..c.n_kv_heads {
                    donor.append(l, h, &row, &row);
                }
            }
        }
        let s0 = donor.snapshot_block(0);
        let s1 = donor.snapshot_block(1);
        let mut cache = KvCache::paged(&c, 4, pool.try_alloc(1).unwrap());
        cache.load_block(&s0);
        cache.load_block(&s1);
    }

    #[test]
    #[should_panic(expected = "paged KvCache overflow")]
    fn paged_cache_rejects_overflow() {
        let c = cfg();
        let mut cache = KvCache::paged(&c, 4, vec![0]);
        let row = vec![0.0f32; c.d_head()];
        for _ in 0..5 {
            cache.append(0, 0, &row, &row);
        }
    }

    #[test]
    fn append_charges_write_traffic() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        let row = vec![0.0f32; c.d_head()];
        cache.append(0, 0, &row, &row);
        assert_eq!(cache.stats.bytes_written, 2 * c.d_head() * 4);
        assert_eq!(cache.stats.writes, 1);
    }

    #[test]
    fn int8_cache_charges_physical_bytes_on_reads_and_writes() {
        // The TierStats counters must reflect post-quantization traffic:
        // an int8 row is d codes + a 4-byte scale per matrix, not 4·d.
        let c = cfg();
        let d = c.d_head();
        let mut cache = KvCache::new_with_dtype(&c, KvDtype::Int8);
        assert_eq!(cache.dtype(), KvDtype::Int8);
        assert_eq!(cache.row_bytes(), d + 4);
        let row = vec![1.5f32; d];
        cache.append(0, 0, &row, &row);
        assert_eq!(cache.stats.bytes_written, 2 * (d + 4));
        assert_eq!(cache.stats.writes, 1);
        for _ in 0..9 {
            cache.append(0, 0, &row, &row);
        }
        let before = cache.stats.bytes_read;
        let (gk, _gv) = cache.gather(0, 0, &[0, 3, 7]);
        assert_eq!(gk.rows, 3);
        assert_eq!(cache.stats.bytes_read - before, 2 * 3 * (d + 4));
        cache.record_selected_read(5);
        assert_eq!(cache.stats.bytes_read - before, 2 * 3 * (d + 4) + 2 * 5 * (d + 4));
        // Resident bytes are physical too: ≥ 3.5x under fp32 at d = 32.
        let fp32 = KvCache::new(&c).row_bytes();
        assert!(fp32 as f64 / cache.row_bytes() as f64 >= 3.5);
        assert_eq!(cache.resident_bytes(), 10 * 2 * (d + 4));
    }

    #[test]
    fn int8_append_reads_back_within_bound_and_reports_bounds() {
        let c = cfg();
        let d = c.d_head();
        let mut cache = KvCache::new_with_dtype(&c, KvDtype::Int8);
        assert!(cache.quant_bounds(0, 0).unwrap().is_zero(), "empty slot has zero bounds");
        assert!(KvCache::new(&c).quant_bounds(0, 0).is_none(), "f32 cache has no bounds");
        let mut rng = Rng::new(9);
        let mut rows = Vec::new();
        for _ in 0..6 {
            let kr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 2.0)).collect();
            let vr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 2.0)).collect();
            cache.append(0, 0, &kr, &vr);
            rows.push((kr, vr));
        }
        let b = cache.quant_bounds(0, 0).expect("int8 bounds");
        assert!(b.k_scale_max > 0.0 && b.v_scale_max > 0.0);
        let (kc, vc) = cache.head(0, 0);
        for (r, (kr, vr)) in rows.iter().enumerate() {
            for (x, x_hat) in kr.iter().zip(kc.row(r)) {
                assert!((x - x_hat).abs() <= 0.5 * b.k_scale_max);
            }
            for (x, x_hat) in vr.iter().zip(vc.row(r)) {
                assert!((x - x_hat).abs() <= 0.5 * b.v_scale_max);
            }
        }
    }

    #[test]
    fn int8_snapshot_load_round_trip_is_bit_exact() {
        let c = cfg();
        let mut pool = BlockPool::for_model_dtype(&c, 4, None, KvDtype::Int8);
        let mut src = KvCache::paged_dtype(&c, 4, pool.try_alloc(2).unwrap(), KvDtype::Int8);
        let mut rng = Rng::new(11);
        for _ in 0..8 {
            for l in 0..c.n_layers {
                for h in 0..c.n_kv_heads {
                    let kr: Vec<f32> = (0..c.d_head()).map(|_| rng.normal32(0.0, 1.0)).collect();
                    let vr: Vec<f32> = (0..c.d_head()).map(|_| rng.normal32(0.0, 1.0)).collect();
                    src.append(l, h, &kr, &vr);
                }
            }
        }
        let s0 = src.snapshot_block(0);
        assert_eq!(s0.dtype, KvDtype::Int8);
        let s1 = src.snapshot_block(1);
        let mut dst = KvCache::paged_dtype(&c, 4, pool.try_alloc(2).unwrap(), KvDtype::Int8);
        dst.load_block(&s0);
        dst.load_block(&s1);
        assert_eq!(dst.tokens(), 8);
        for l in 0..c.n_layers {
            for h in 0..c.n_kv_heads {
                let (sk, sv) = src.head(l, h);
                let (dk, dv) = dst.head(l, h);
                // Byte-for-byte payload copy ⇒ bitwise-equal mirrors.
                assert_eq!(sk.data, dk.data);
                assert_eq!(sv.data, dv.data);
            }
        }
        // Load charges physical write traffic.
        let slots = c.n_layers * c.n_kv_heads;
        assert_eq!(dst.stats.bytes_written, 2 * slots * 8 * (c.d_head() + 4));
    }

    #[test]
    fn reservoir_is_uniformish() {
        let mut rng = Rng::new(1);
        let cap = 100;
        let n = 10_000;
        // Count how often position < 5000 is retained across trials.
        let mut lows = 0usize;
        for t in 0..50 {
            let mut rc = RandomCache::new(cap);
            let mut fork = rng.fork(t);
            for p in 0..n {
                rc.observe(p, &mut fork);
            }
            assert_eq!(rc.indices.len(), cap);
            lows += rc.indices.iter().filter(|&&p| p < n / 2).count();
        }
        let frac = lows as f64 / (50.0 * cap as f64);
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }
}
