//! KV cache management.
//!
//! The cache is host-resident (the paper's CPU-offload deployment): the
//! L3 coordinator owns it, runs index selection over it, and ships only
//! the *gathered* rows to the device. We track per-tier byte traffic so
//! the Fig. 5 bandwidth accounting is explicit, and maintain the small
//! auxiliary caches vAttention needs (the incremental random base-sample
//! cache; approximate-top-k bit caches live inside their scorers).
//!
//! Serving-engine caches are *paged* and **demand-paged**: the engine
//! leases a request's prompt blocks from a [`BlockPool`] at admission
//! and then grows the block table one block at a time as generation
//! crosses block boundaries (`KvCache::grow`), instead of reserving the
//! worst case up front. Blocks are reference counted: requests with
//! identical prompt prefixes share physical blocks through the
//! [`PrefixCache`] radix (fork = refcount bump; a divergent write
//! promotes the block to private via [`BlockPool::cow`]). Within a
//! request, rows stay contiguous per (layer, head) slot — index
//! selection scans K linearly, so contiguity is the hot-path layout —
//! while the block table carries placement, capacity accounting and
//! admission gating, mirroring vLLM's logical/physical split.

pub mod paged;
pub mod prefix;
pub mod tiered;

pub use paged::{BlockId, BlockPool, CowOutcome, PageError};
pub use prefix::{ChainKey, PrefixCache};
pub use tiered::{TierStats, TransferModel};

use crate::model::ModelConfig;
use crate::tensor::Mat;

/// Block size (tokens) used when a cache is built standalone, outside an
/// engine's block pool.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Per-(layer, head) append-only KV store.
pub struct KvCache {
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_head: usize,
    /// layers × heads, each an (n × d_head) matrix pair.
    k: Vec<Mat>,
    v: Vec<Mat>,
    /// Host→device traffic accounting.
    pub stats: TierStats,
    /// Allocation granularity in tokens.
    block_tokens: usize,
    /// Physical blocks leased from a [`BlockPool`] (empty ⇒ standalone).
    block_table: Vec<BlockId>,
    /// Paged caches enforce the leased-capacity bound on append.
    paged: bool,
}

impl KvCache {
    /// Standalone (unpaged) cache — grows without a capacity bound. Used
    /// by experiments and tests that run outside the serving engine.
    pub fn new(cfg: &ModelConfig) -> KvCache {
        Self::build(cfg, DEFAULT_BLOCK_TOKENS, Vec::new(), false)
    }

    /// Paged cache backed by blocks leased from a [`BlockPool`]. The
    /// caller (the engine) frees the table via [`KvCache::release_blocks`]
    /// when the request completes.
    pub fn paged(cfg: &ModelConfig, block_tokens: usize, blocks: Vec<BlockId>) -> KvCache {
        Self::build(cfg, block_tokens.max(1), blocks, true)
    }

    fn build(cfg: &ModelConfig, block_tokens: usize, blocks: Vec<BlockId>, paged: bool) -> KvCache {
        // One slot per (layer, KV head) — query heads share KV slots
        // under grouped-query attention.
        let slots = cfg.n_layers * cfg.n_kv_heads;
        let d = cfg.d_head();
        KvCache {
            n_layers: cfg.n_layers,
            n_heads: cfg.n_kv_heads,
            d_head: d,
            k: (0..slots).map(|_| Mat::zeros(0, d)).collect(),
            v: (0..slots).map(|_| Mat::zeros(0, d)).collect(),
            stats: TierStats::default(),
            block_tokens,
            block_table: blocks,
            paged,
        }
    }

    #[inline]
    fn slot(&self, layer: usize, head: usize) -> usize {
        layer * self.n_heads + head
    }

    /// Append one token's (k, v) rows for a head. Paged caches enforce
    /// the capacity their block table was leased for — overflowing it
    /// means the engine's admission reservation was wrong.
    pub fn append(&mut self, layer: usize, head: usize, k_row: &[f32], v_row: &[f32]) {
        let s = self.slot(layer, head);
        debug_assert_eq!(k_row.len(), self.d_head);
        if self.paged {
            let cap = self.block_table.len() * self.block_tokens;
            assert!(
                self.k[s].rows < cap,
                "paged KvCache overflow: slot ({layer}, {head}) at {} tokens, {} blocks × {} reserved",
                self.k[s].rows,
                self.block_table.len(),
                self.block_tokens
            );
        }
        self.k[s].data.extend_from_slice(k_row);
        self.k[s].rows += 1;
        self.v[s].data.extend_from_slice(v_row);
        self.v[s].rows += 1;
        self.stats.record_write(2 * self.d_head * 4);
    }

    /// Number of cached tokens for a layer (all heads advance together).
    pub fn len(&self, layer: usize) -> usize {
        self.k[self.slot(layer, 0)].rows
    }

    pub fn is_empty(&self) -> bool {
        self.k.iter().all(|m| m.rows == 0)
    }

    /// Borrow a head's (K, V) matrices.
    pub fn head(&self, layer: usize, head: usize) -> (&Mat, &Mat) {
        let s = self.slot(layer, head);
        (&self.k[s], &self.v[s])
    }

    /// Gather selected rows into dense (b × d) buffers — the host→device
    /// transfer of the serving path. Also charges the byte traffic to
    /// `stats` (2 matrices × b rows × d floats).
    pub fn gather(&mut self, layer: usize, head: usize, idx: &[usize]) -> (Mat, Mat) {
        let mut gk = Mat::zeros(0, 0);
        let mut gv = Mat::zeros(0, 0);
        self.gather_into(layer, head, idx, &mut gk, &mut gv);
        (gk, gv)
    }

    /// [`KvCache::gather`] into caller-owned scratch buffers: `gk` / `gv`
    /// are reshaped in place (allocation reused), so a decode loop that
    /// hoists two `Mat`s pays zero allocations per (layer, head, step).
    /// Charges the same read traffic as `gather`.
    pub fn gather_into(
        &mut self,
        layer: usize,
        head: usize,
        idx: &[usize],
        gk: &mut Mat,
        gv: &mut Mat,
    ) {
        let s = self.slot(layer, head);
        let d = self.d_head;
        // clear + extend (not a zeroing resize): every row is about to
        // be overwritten, so the only work left is the memcpy itself.
        gk.rows = idx.len();
        gk.cols = d;
        gk.data.clear();
        gv.rows = idx.len();
        gv.cols = d;
        gv.data.clear();
        for &i in idx {
            gk.data.extend_from_slice(self.k[s].row(i));
            gv.data.extend_from_slice(self.v[s].row(i));
        }
        self.stats.record_read(2 * idx.len() * d * 4);
    }

    /// Total resident bytes.
    pub fn resident_bytes(&self) -> usize {
        self.k
            .iter()
            .zip(self.v.iter())
            .map(|(k, v)| (k.data.len() + v.data.len()) * 4)
            .sum()
    }

    /// Drop all cached tokens (end of a request).
    pub fn clear(&mut self) {
        for m in self.k.iter_mut().chain(self.v.iter_mut()) {
            m.rows = 0;
            m.data.clear();
        }
    }

    /// Tokens currently cached (all slots advance together).
    pub fn tokens(&self) -> usize {
        self.k.first().map(|m| m.rows).unwrap_or(0)
    }

    /// Allocation granularity in tokens.
    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    /// Blocks leased to this cache.
    pub fn blocks_reserved(&self) -> usize {
        self.block_table.len()
    }

    /// The leased block table, position-ordered (block `i` backs tokens
    /// `[i·block_tokens, (i+1)·block_tokens)`).
    pub fn block_table(&self) -> &[BlockId] {
        &self.block_table
    }

    /// Extend the block table with freshly leased blocks — the
    /// demand-paging growth path: the engine allocates a block only when
    /// the next append would cross a block boundary, instead of
    /// reserving the worst case at admission.
    pub fn grow(&mut self, blocks: impl IntoIterator<Item = BlockId>) {
        self.block_table.extend(blocks);
    }

    /// Swap the physical block at table index `idx` for `id` and return
    /// the previous id — the cache side of a copy-on-write promotion
    /// (`BlockPool::cow`): the engine moved this request's reference
    /// from a shared block to a private one; row data is per-request
    /// contiguous, so only the placement changes.
    pub fn replace_block(&mut self, idx: usize, id: BlockId) -> BlockId {
        std::mem::replace(&mut self.block_table[idx], id)
    }

    /// Snapshot one *filled* block's rows: per (layer, kv-head) slot, the
    /// flat `block_tokens × d_head` K and V buffers. Used by the prefix
    /// cache to keep shared prompt blocks alive beyond their donor.
    pub fn snapshot_block(&self, block: usize) -> (Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let lo = block * self.block_tokens;
        let hi = lo + self.block_tokens;
        assert!(hi <= self.tokens(), "snapshot of an unfilled block {block}");
        let d = self.d_head;
        let mut ks = Vec::with_capacity(self.k.len());
        let mut vs = Vec::with_capacity(self.v.len());
        for s in 0..self.k.len() {
            ks.push(self.k[s].data[lo * d..hi * d].to_vec());
            vs.push(self.v[s].data[lo * d..hi * d].to_vec());
        }
        (ks, vs)
    }

    /// Bulk-append one shared block's rows (the layout produced by
    /// [`KvCache::snapshot_block`]) — the fork's copy-in of a cached
    /// prompt prefix, replacing that block's prefill compute with a
    /// memcpy. Paged caches enforce their leased capacity as in
    /// [`KvCache::append`].
    pub fn load_block(&mut self, k_slots: &[Vec<f32>], v_slots: &[Vec<f32>]) {
        assert_eq!(k_slots.len(), self.k.len(), "slot count mismatch on prefix load");
        let d = self.d_head;
        let tokens = k_slots.first().map_or(0, |b| b.len() / d);
        if self.paged {
            let cap = self.block_table.len() * self.block_tokens;
            assert!(
                self.tokens() + tokens <= cap,
                "paged KvCache overflow on prefix load: {} + {tokens} tokens into {} blocks × {}",
                self.tokens(),
                self.block_table.len(),
                self.block_tokens
            );
        }
        for (s, (kb, vb)) in k_slots.iter().zip(v_slots.iter()).enumerate() {
            debug_assert_eq!(kb.len(), tokens * d);
            self.k[s].data.extend_from_slice(kb);
            self.k[s].rows += tokens;
            self.v[s].data.extend_from_slice(vb);
            self.v[s].rows += tokens;
        }
        self.stats.record_write(2 * k_slots.len() * tokens * d * 4);
    }

    /// Blocks actually filled by appended tokens.
    pub fn blocks_used(&self) -> usize {
        self.tokens().div_ceil(self.block_tokens)
    }

    /// Physical block holding the cached token at `pos` (None when the
    /// position has not been appended yet).
    pub fn block_of(&self, pos: usize) -> Option<BlockId> {
        if pos >= self.tokens() {
            return None;
        }
        self.block_table.get(pos / self.block_tokens).copied()
    }

    /// Drop all cached tokens and hand the leased block table back to
    /// the caller (who returns it to the [`BlockPool`]).
    pub fn release_blocks(&mut self) -> Vec<BlockId> {
        self.clear();
        std::mem::take(&mut self.block_table)
    }
}

/// Incrementally-maintained random cache of residual token indices (the
/// paper's "small random cache ... incrementally populated and updated
/// during token generation" used for on-GPU budget estimation):
/// reservoir sampling keeps a uniform sample of all appended positions.
pub struct RandomCache {
    pub capacity: usize,
    pub indices: Vec<usize>,
    seen: usize,
}

impl RandomCache {
    pub fn new(capacity: usize) -> RandomCache {
        RandomCache { capacity, indices: Vec::with_capacity(capacity), seen: 0 }
    }

    /// Observe the next appended position; O(1) amortized reservoir step.
    pub fn observe(&mut self, pos: usize, rng: &mut crate::util::Rng) {
        self.seen += 1;
        if self.indices.len() < self.capacity {
            self.indices.push(pos);
        } else {
            let j = rng.below(self.seen);
            if j < self.capacity {
                self.indices[j] = pos;
            }
        }
    }

    pub fn seen(&self) -> usize {
        self.seen
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn cfg() -> ModelConfig {
        ModelConfig::tiny()
    }

    #[test]
    fn append_and_len() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        assert!(cache.is_empty());
        let row = vec![1.0f32; c.d_head()];
        for l in 0..c.n_layers {
            for h in 0..c.n_heads {
                cache.append(l, h, &row, &row);
            }
        }
        assert_eq!(cache.len(0), 1);
        assert_eq!(cache.len(1), 1);
        assert!(!cache.is_empty());
    }

    #[test]
    fn gather_returns_selected_rows_and_charges_bytes() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        for i in 0..10 {
            let row = vec![i as f32; c.d_head()];
            cache.append(0, 0, &row, &row);
        }
        let (gk, gv) = cache.gather(0, 0, &[2, 7]);
        assert_eq!(gk.rows, 2);
        assert_eq!(gk.row(0)[0], 2.0);
        assert_eq!(gv.row(1)[0], 7.0);
        assert_eq!(cache.stats.bytes_read, 2 * 2 * c.d_head() * 4);
    }

    #[test]
    fn resident_bytes_grows_linearly() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        let row = vec![0.0f32; c.d_head()];
        cache.append(0, 0, &row, &row);
        let b1 = cache.resident_bytes();
        cache.append(0, 0, &row, &row);
        assert_eq!(cache.resident_bytes(), 2 * b1);
        cache.clear();
        assert_eq!(cache.resident_bytes(), 0);
    }

    #[test]
    fn paged_cache_tracks_blocks_and_releases() {
        let c = cfg();
        let mut pool = BlockPool::for_model(&c, 4, None);
        let blocks = pool.try_alloc(pool.blocks_for_tokens(10)).unwrap();
        assert_eq!(blocks.len(), 3);
        let mut cache = KvCache::paged(&c, 4, blocks);
        let row = vec![1.0f32; c.d_head()];
        for _ in 0..10 {
            for l in 0..c.n_layers {
                for h in 0..c.n_kv_heads {
                    cache.append(l, h, &row, &row);
                }
            }
        }
        assert_eq!(cache.tokens(), 10);
        assert_eq!(cache.blocks_used(), 3);
        assert_eq!(cache.blocks_reserved(), 3);
        assert!(cache.block_of(0).is_some());
        assert!(cache.block_of(11).is_none());
        let freed = cache.release_blocks();
        assert_eq!(freed.len(), 3);
        assert_eq!(cache.tokens(), 0);
        pool.free(freed).unwrap();
        assert_eq!(pool.in_use_blocks(), 0);
    }

    #[test]
    fn grow_extends_capacity_and_replace_swaps_placement() {
        let c = cfg();
        let mut pool = BlockPool::for_model(&c, 4, None);
        let lease = pool.try_alloc(1).unwrap();
        let mut cache = KvCache::paged(&c, 4, lease);
        let row = vec![1.0f32; c.d_head()];
        let fill = |cache: &mut KvCache, n: usize| {
            for _ in 0..n {
                for l in 0..c.n_layers {
                    for h in 0..c.n_kv_heads {
                        cache.append(l, h, &row, &row);
                    }
                }
            }
        };
        fill(&mut cache, 4);
        assert_eq!(cache.blocks_reserved(), 1);
        // Demand paging: lease the next block only when needed.
        cache.grow(pool.try_alloc(1).unwrap());
        fill(&mut cache, 4);
        assert_eq!(cache.tokens(), 8);
        assert_eq!(cache.blocks_reserved(), 2);
        assert_eq!(cache.block_table(), &[0, 1]);
        // CoW swap: placement changes, data does not.
        let fresh = pool.try_alloc(1).unwrap()[0];
        assert_eq!(cache.replace_block(0, fresh), 0);
        assert_eq!(cache.block_table(), &[fresh, 1]);
        assert_eq!(cache.tokens(), 8);
    }

    #[test]
    fn gather_into_reuses_scratch_and_matches_gather() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        for i in 0..10 {
            let row = vec![i as f32; c.d_head()];
            cache.append(0, 0, &row, &row);
        }
        let (gk, gv) = cache.gather(0, 0, &[1, 4, 9]);
        let mut sk = Mat::zeros(0, 0);
        let mut sv = Mat::zeros(0, 0);
        cache.gather_into(0, 0, &[1, 4, 9], &mut sk, &mut sv);
        assert_eq!(gk.data, sk.data);
        assert_eq!(gv.data, sv.data);
        // Reuse with a different shape: no stale rows, same accounting.
        let reads_before = cache.stats.reads;
        let ptr = sk.data.as_ptr();
        cache.gather_into(0, 0, &[7], &mut sk, &mut sv);
        assert_eq!(sk.rows, 1);
        assert_eq!(sk.row(0)[0], 7.0);
        assert_eq!(cache.stats.reads, reads_before + 1);
        assert_eq!(sk.data.as_ptr(), ptr, "scratch must not reallocate when shrinking");
    }

    #[test]
    fn snapshot_and_load_block_round_trip() {
        let c = cfg();
        let mut pool = BlockPool::for_model(&c, 4, None);
        let lease = pool.try_alloc(2).unwrap();
        let mut src = KvCache::paged(&c, 4, lease);
        for i in 0..8 {
            for l in 0..c.n_layers {
                for h in 0..c.n_kv_heads {
                    let row = vec![(i * 10 + l + h) as f32; c.d_head()];
                    src.append(l, h, &row, &row);
                }
            }
        }
        let (k0, v0) = src.snapshot_block(0);
        let (k1, v1) = src.snapshot_block(1);
        let lease2 = pool.try_alloc(2).unwrap();
        let mut dst = KvCache::paged(&c, 4, lease2);
        dst.load_block(&k0, &v0);
        dst.load_block(&k1, &v1);
        assert_eq!(dst.tokens(), 8);
        for l in 0..c.n_layers {
            for h in 0..c.n_kv_heads {
                let (sk, svm) = src.head(l, h);
                let (dk, dvm) = dst.head(l, h);
                assert_eq!(sk.data, dk.data);
                assert_eq!(svm.data, dvm.data);
            }
        }
    }

    #[test]
    #[should_panic(expected = "paged KvCache overflow on prefix load")]
    fn load_block_rejects_overflow() {
        let c = cfg();
        let mut cache = KvCache::paged(&c, 4, vec![0]);
        let slots = c.n_layers * c.n_kv_heads;
        let block: Vec<Vec<f32>> = (0..slots).map(|_| vec![0.0; 8 * c.d_head()]).collect();
        cache.load_block(&block, &block);
    }

    #[test]
    #[should_panic(expected = "paged KvCache overflow")]
    fn paged_cache_rejects_overflow() {
        let c = cfg();
        let mut cache = KvCache::paged(&c, 4, vec![0]);
        let row = vec![0.0f32; c.d_head()];
        for _ in 0..5 {
            cache.append(0, 0, &row, &row);
        }
    }

    #[test]
    fn append_charges_write_traffic() {
        let c = cfg();
        let mut cache = KvCache::new(&c);
        let row = vec![0.0f32; c.d_head()];
        cache.append(0, 0, &row, &row);
        assert_eq!(cache.stats.bytes_written, 2 * c.d_head() * 4);
        assert_eq!(cache.stats.writes, 1);
    }

    #[test]
    fn reservoir_is_uniformish() {
        let mut rng = Rng::new(1);
        let cap = 100;
        let n = 10_000;
        // Count how often position < 5000 is retained across trials.
        let mut lows = 0usize;
        for t in 0..50 {
            let mut rc = RandomCache::new(cap);
            let mut fork = rng.fork(t);
            for p in 0..n {
                rc.observe(p, &mut fork);
            }
            assert_eq!(rc.indices.len(), cap);
            lows += rc.indices.iter().filter(|&&p| p < n / 2).count();
        }
        let frac = lows as f64 / (50.0 * cap as f64);
        assert!((frac - 0.5).abs() < 0.05, "frac={frac}");
    }
}
