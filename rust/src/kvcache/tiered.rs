//! Two-tier KV placement accounting + the bandwidth transfer model used
//! to extrapolate Fig. 5 to 8B-scale shapes.
//!
//! Who consumes what, so none of this looks dead:
//!
//! * [`TierStats`] rides on every [`crate::kvcache::KvCache`]
//!   (`cache.stats`): the model charges a read per gathered K/V row and
//!   a write per append, in **physical** bytes — a quantized (int8)
//!   cache charges `d + 4` bytes per row, not the `4·d` of its
//!   dequantized working view, so `kv MiB read/written` reflect what
//!   actually crosses the host tier. The counters are **phase-split**:
//!   when prefill completes, the session calls [`TierStats::end_prefill_phase`]
//!   to bank the traffic so far (prompt appends *and* prefix-fork
//!   copy-ins) into the `prefill_*` fields, so nothing is dropped —
//!   `RequestResult::kv_bytes_read` / `kv_bytes_written` keep their
//!   decode-only meaning while `kv_prefill_bytes_*` carry the prefill
//!   side; `metrics::ServeSummary` sums and prints both.
//! * The tier itself is **real** when the engine runs with a spill
//!   store (`--kv-spill PATH`): [`crate::kvcache::SpillStore`] is a
//!   file-backed cold tier that preempted blocks swap out to and back
//!   in from, byte-for-byte. [`TransferModel`] remains a *model* — no
//!   live code path sleeps on it; `sim::` and the Fig. 5 speedup
//!   experiment convert measured byte counts into projected transfer
//!   seconds for 8B-scale shapes over a PCIe-class host→device link.
//!   Treat its defaults as the paper's deployment assumption, not a
//!   measurement.

/// Byte-traffic counters for the host (CPU RAM) tier, split into a
/// banked prefill phase and the live (decode) phase.
#[derive(Clone, Debug, Default)]
pub struct TierStats {
    /// Bytes gathered/read from the host-resident cache (current phase).
    pub bytes_read: usize,
    /// Number of gather operations (current phase).
    pub reads: usize,
    /// Bytes appended into the host-resident cache (current phase).
    pub bytes_written: usize,
    /// Number of append operations (current phase).
    pub writes: usize,
    /// Bytes read during the prefill phase (banked at prefill end).
    pub prefill_bytes_read: usize,
    /// Read ops during the prefill phase.
    pub prefill_reads: usize,
    /// Bytes written during the prefill phase — prompt appends plus
    /// prefix-fork snapshot copy-ins, which a plain reset used to drop.
    pub prefill_bytes_written: usize,
    /// Write ops during the prefill phase.
    pub prefill_writes: usize,
}

impl TierStats {
    pub fn record_read(&mut self, bytes: usize) {
        self.bytes_read += bytes;
        self.reads += 1;
    }

    pub fn record_write(&mut self, bytes: usize) {
        self.bytes_written += bytes;
        self.writes += 1;
    }

    /// Bank everything recorded so far as prefill traffic and zero the
    /// live counters, which from here on accumulate decode traffic.
    /// Called by the session exactly when a request finishes prefill;
    /// idempotent in effect across preemption replays because the live
    /// counters restart from zero each time (banked totals accumulate).
    pub fn end_prefill_phase(&mut self) {
        self.prefill_bytes_read += self.bytes_read;
        self.prefill_reads += self.reads;
        self.prefill_bytes_written += self.bytes_written;
        self.prefill_writes += self.writes;
        self.bytes_read = 0;
        self.reads = 0;
        self.bytes_written = 0;
        self.writes = 0;
    }

    /// Total traffic across both phases.
    pub fn total_bytes_read(&self) -> usize {
        self.prefill_bytes_read + self.bytes_read
    }

    pub fn total_bytes_written(&self) -> usize {
        self.prefill_bytes_written + self.bytes_written
    }

    pub fn reset(&mut self) {
        *self = TierStats::default();
    }
}

/// A simple bandwidth/latency model for KV traffic: t = bytes/BW + c·ops.
/// Defaults approximate a PCIe-4.0 x16 host→GPU link (the paper's
/// CPU-offloaded serving deployment) — see DESIGN.md §3.
#[derive(Clone, Debug)]
pub struct TransferModel {
    /// Sustained bandwidth, bytes/second.
    pub bandwidth: f64,
    /// Fixed per-transfer overhead, seconds.
    pub overhead: f64,
}

impl Default for TransferModel {
    fn default() -> Self {
        TransferModel { bandwidth: 24e9, overhead: 8e-6 }
    }
}

impl TransferModel {
    pub fn transfer_time(&self, bytes: usize, ops: usize) -> f64 {
        bytes as f64 / self.bandwidth + ops as f64 * self.overhead
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_accumulate() {
        let mut s = TierStats::default();
        s.record_read(100);
        s.record_read(50);
        s.record_write(30);
        assert_eq!(s.bytes_read, 150);
        assert_eq!(s.reads, 2);
        assert_eq!(s.bytes_written, 30);
        assert_eq!(s.writes, 1);
        s.reset();
        assert_eq!(s.bytes_read, 0);
        assert_eq!(s.bytes_written, 0);
    }

    #[test]
    fn prefill_phase_banks_instead_of_dropping() {
        let mut s = TierStats::default();
        s.record_write(100); // prompt append
        s.record_read(40); // prefix-fork copy-in accounting
        s.end_prefill_phase();
        assert_eq!(s.prefill_bytes_written, 100);
        assert_eq!(s.prefill_writes, 1);
        assert_eq!(s.prefill_bytes_read, 40);
        assert_eq!(s.prefill_reads, 1);
        assert_eq!(s.bytes_written, 0, "live counters restart for decode");
        s.record_write(7);
        s.record_read(3);
        assert_eq!(s.total_bytes_written(), 107);
        assert_eq!(s.total_bytes_read(), 43);
        // A replayed prefill banks again; nothing is lost.
        s.end_prefill_phase();
        assert_eq!(s.prefill_bytes_written, 107);
        assert_eq!(s.prefill_bytes_read, 43);
    }

    #[test]
    fn transfer_time_linear_in_bytes() {
        let m = TransferModel { bandwidth: 1e9, overhead: 0.0 };
        assert!((m.transfer_time(1_000_000_000, 0) - 1.0).abs() < 1e-12);
        let t_half = m.transfer_time(500_000_000, 0);
        assert!((t_half - 0.5).abs() < 1e-12);
    }

    #[test]
    fn overhead_counts_ops() {
        let m = TransferModel { bandwidth: 1e12, overhead: 1e-5 };
        let t = m.transfer_time(0, 10);
        assert!((t - 1e-4).abs() < 1e-15);
    }
}
