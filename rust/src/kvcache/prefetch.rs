//! [`PrefetchEngine`]: the cold tier's asynchronous swap-in pipeline
//! (`--kv-prefetch`).
//!
//! PR 6 made preemption swap-out/swap-in instead of recompute replay,
//! but every swap-in still ran synchronously inside the session's
//! admission phase — a `pread` per block on the scheduler thread while
//! the worker pool sat idle. This module overlaps that data movement
//! with compute, SpecAttn-style: speculation may only *move* data,
//! never change what is selected or sampled, so every determinism and
//! (ε, δ) guarantee is untouched.
//!
//! The engine owns one dedicated IO thread (`vattn-spill-io`) and a
//! pair of channels. The session *kicks* a job the moment a suspended
//! request reaches the front window of the waiting queue — before any
//! batch slot frees — handing over the request's [`SpillSlot`]s; the IO
//! thread stages each block into a decoded [`BlockSnapshot`] buffer.
//! When admission later resumes the request, [`PrefetchEngine::wait`]
//! hands the staged buffers back — blocking only on whatever tail of
//! the job is still in flight, which is how blocking swap-in reads on
//! the scheduler thread drop to ~0 *deterministically* (the consume
//! path never races: a kicked job is either consumed in full or
//! invalidated, never half-used).
//!
//! Ownership discipline — the part every preempt/resume/cancel/drain
//! path must respect:
//!
//! - The [`crate::kvcache::SpillStore`] stays the **only** owner of
//!   slot lifecycle. The engine reads through a stat-free
//!   [`SlotReader`] (dup'd fd) and never frees, writes, or recycles a
//!   slot.
//! - A job's slots must stay live until the job is consumed
//!   ([`PrefetchEngine::wait`]) or invalidated
//!   ([`PrefetchEngine::invalidate`]). Both paths are called *before*
//!   the session frees the slots, so a staged read can race a recycle
//!   only after its job id is already dead — the engine then discards
//!   the result (torn bytes, garbage, or an IO error alike) without it
//!   ever reaching a cache.
//! - Staged bytes are decoded by the same code path as the blocking
//!   read ([`crate::kvcache::spill`]'s shared record decoder), so a
//!   resumed stream is byte-identical whether it consumed a prefetch,
//!   fell back to blocking reads, or ran with prefetch disabled.

use std::collections::{HashMap, HashSet};
use std::io;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

use super::spill::{SlotReader, SpillSlot};
use super::store::BlockSnapshot;

/// One staged read request: every cold-tier slot of one suspended
/// request, in position order.
struct Job {
    id: u64,
    slots: Vec<SpillSlot>,
}

/// The IO thread's answer: the staged snapshots, or the first error it
/// hit (the session falls back to the blocking path on `Err`).
struct Done {
    id: u64,
    result: io::Result<Vec<BlockSnapshot>>,
}

/// Owner of the `vattn-spill-io` thread. See the module docs for the
/// lifecycle contract.
pub struct PrefetchEngine {
    /// `Some` until drop; taking it closes the channel and stops the
    /// IO thread.
    tx: Option<Sender<Job>>,
    rx: Receiver<Done>,
    worker: Option<JoinHandle<()>>,
    next_id: u64,
    /// Finished jobs not yet consumed (results of earlier kicks drained
    /// while waiting on a later one).
    completed: HashMap<u64, io::Result<Vec<BlockSnapshot>>>,
    /// Jobs whose results must be discarded on arrival (cancelled or
    /// unwound requests).
    invalidated: HashSet<u64>,
}

impl PrefetchEngine {
    /// Spawn the IO thread over `reader` (obtained from
    /// `SpillStore::reader`).
    pub fn new(reader: SlotReader) -> PrefetchEngine {
        let (tx, job_rx) = channel::<Job>();
        let (done_tx, rx) = channel::<Done>();
        let worker = std::thread::Builder::new()
            .name("vattn-spill-io".into())
            .spawn(move || {
                while let Ok(job) = job_rx.recv() {
                    let result = job
                        .slots
                        .iter()
                        .map(|&slot| reader.read(slot))
                        .collect::<io::Result<Vec<_>>>();
                    if done_tx.send(Done { id: job.id, result }).is_err() {
                        break; // session gone; nothing left to stage for
                    }
                }
            })
            .expect("spawning vattn-spill-io");
        PrefetchEngine {
            tx: Some(tx),
            rx,
            worker: Some(worker),
            next_id: 0,
            completed: HashMap::new(),
            invalidated: HashSet::new(),
        }
    }

    /// Start staging `slots` and return the job id the session parks on
    /// the suspended request. The slots must stay live until this job is
    /// consumed or invalidated.
    pub fn kick(&mut self, slots: &[SpillSlot]) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let tx = self.tx.as_ref().expect("kick after drop");
        if tx.send(Job { id, slots: slots.to_vec() }).is_err() {
            // IO thread died (it never panics on IO errors, but be
            // defensive): record the job as already-failed so `wait`
            // falls back to the blocking path.
            self.completed
                .insert(id, Err(io::Error::new(io::ErrorKind::Other, "spill-io thread gone")));
        }
        id
    }

    /// Block until job `id` finishes and hand back its staged
    /// snapshots. `None` means the staged read failed (or the job was
    /// invalidated / the IO thread is gone) — the caller must fall back
    /// to the synchronous path, which re-reads the same bytes, so the
    /// outcome is identical either way. Bounded by one in-flight file
    /// read per queued job ahead of this one.
    pub fn wait(&mut self, id: u64) -> Option<Vec<BlockSnapshot>> {
        if self.invalidated.contains(&id) {
            // Stay in the invalidated set until the in-flight result
            // arrives (a later wait's drain discards it).
            return None;
        }
        loop {
            if let Some(result) = self.completed.remove(&id) {
                return result.ok();
            }
            let done = self.rx.recv().ok()?;
            if self.invalidated.remove(&done.id) {
                continue; // late result of a dead job: discard
            }
            self.completed.insert(done.id, done.result);
        }
    }

    /// Mark job `id` dead: its result (whether already staged or still
    /// in flight) will be discarded, never consumed. Called before the
    /// session frees the job's slots, so recycled-slot reads can never
    /// be mistaken for valid stages.
    pub fn invalidate(&mut self, id: u64) {
        if self.completed.remove(&id).is_none() {
            self.invalidated.insert(id);
        }
    }
}

impl Drop for PrefetchEngine {
    fn drop(&mut self) {
        drop(self.tx.take()); // closes the job channel; the thread exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::spill::SpillStore;
    use crate::kvcache::store::{BlockStore, KvDtype};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("vattn_prefetch_{}_{name}", std::process::id()))
    }

    fn filled(slots: usize, d: usize, rows: usize, dtype: KvDtype) -> BlockStore {
        let mut st = BlockStore::new(slots, d, dtype);
        for r in 0..rows {
            for s in 0..slots {
                let kr: Vec<f32> = (0..d).map(|c| (s * 100 + r * 10 + c) as f32 * 0.02).collect();
                let vr: Vec<f32> = (0..d).map(|c| (s * 55 + r * 7 + c) as f32 * -0.01).collect();
                st.append_row(s, &kr, &vr);
            }
        }
        st
    }

    #[test]
    fn staged_reads_match_blocking_reads_in_and_out_of_order() {
        let path = tmp("staged_eq");
        let (slots, d, bt) = (2, 4, 4);
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let a = filled(slots, d, bt, KvDtype::F32);
        let b = filled(slots, d, 3, KvDtype::Int8);
        let (sa, sb) = (a.snapshot_rows(0, bt), b.snapshot_rows(0, 3));
        let slot_a = store.write_block(&sa).unwrap();
        let slot_b = store.write_block(&sb).unwrap();
        let mut pf = PrefetchEngine::new(store.reader().unwrap());
        let job_a = pf.kick(&[slot_a]);
        let job_b = pf.kick(&[slot_b, slot_a]);
        // Consume out of kick order: `wait` parks job_a's result while
        // draining toward job_b.
        let staged_b = pf.wait(job_b).expect("staged");
        assert_eq!(staged_b.len(), 2);
        let staged_a = pf.wait(job_a).expect("staged");
        assert_eq!(staged_a.len(), 1);
        let blocking_a = store.read_block(slot_a).unwrap();
        let blocking_b = store.read_block(slot_b).unwrap();
        for (staged, blocking) in [
            (&staged_a[0], &blocking_a),
            (&staged_b[0], &blocking_b),
            (&staged_b[1], &blocking_a),
        ] {
            assert_eq!(staged.dtype, blocking.dtype);
            assert_eq!(staged.tokens, blocking.tokens);
            assert_eq!(staged.payload_bytes(), blocking.payload_bytes());
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn invalidated_jobs_are_never_consumed() {
        let path = tmp("invalidate");
        let (slots, d, bt) = (1, 4, 4);
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, bt, KvDtype::F32);
        let slot = store.write_block(&src.snapshot_rows(0, bt)).unwrap();
        let mut pf = PrefetchEngine::new(store.reader().unwrap());
        // Invalidate before the result is drained: wait() must refuse it
        // whether the IO thread has finished or not.
        let job = pf.kick(&[slot]);
        pf.invalidate(job);
        assert!(pf.wait(job).is_none(), "invalidated job must not be consumed");
        // A fresh job on the same slot still works — invalidation is
        // per-job, not per-slot.
        let job2 = pf.kick(&[slot]);
        assert_eq!(pf.wait(job2).expect("staged").len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
