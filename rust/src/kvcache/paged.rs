//! Paged KV-cache block allocator (vLLM-style block manager, specialized
//! to this testbed's host-resident caches).
//!
//! Memory is accounted in fixed-size *blocks* of `block_tokens` tokens;
//! one block spans every (layer, kv-head) slot of a request, so
//! `block_bytes = kv_bytes_per_token × block_tokens`. Since the
//! demand-paging redesign the pool is *reference counted*: a block is
//! leased with one reference ([`BlockPool::try_alloc`]), additional
//! owners attach with [`BlockPool::retain`] (prefix sharing: forking a
//! request onto a cached prompt prefix is a refcount bump, not a copy),
//! and [`BlockPool::free`] drops one reference — the block returns to
//! the free list only when the last owner lets go. A writer that holds
//! a *shared* block promotes it to private with [`BlockPool::cow`]
//! (copy-on-write: the old block keeps its other owners, the writer
//! gets a fresh block).
//!
//! The engine allocates blocks **on demand** — prompt blocks at
//! admission, then one block at a time as generation crosses block
//! boundaries — instead of leasing a request's worst case up front.
//! Allocation happens only in the serial phases of a scheduler tick, so
//! workers still never touch the pool and steps stay data-parallel and
//! deterministic. Freed ids return to a LIFO free list and are reused
//! before new ids are minted.

use crate::model::ModelConfig;

/// Physical block handle leased from a [`BlockPool`].
pub type BlockId = u32;

/// Misuse of the allocator — all indicate an engine bookkeeping bug.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PageError {
    /// The block was already free (refcount underflow).
    DoubleFree(BlockId),
    /// The block id was never minted by this pool.
    UnknownBlock(BlockId),
}

impl std::fmt::Display for PageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageError::DoubleFree(id) => write!(f, "double free of block {id}"),
            PageError::UnknownBlock(id) => write!(f, "unknown block {id}"),
        }
    }
}

impl std::error::Error for PageError {}

/// What a copy-on-write promotion did (see [`BlockPool::cow`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CowOutcome {
    /// The caller was the sole owner — write in place, same id.
    InPlace,
    /// The block was shared: the caller's reference moved to this fresh
    /// private block (the caller copies the payload if it keeps any).
    Copied(BlockId),
    /// The block is shared but the pool has no free block for the copy;
    /// the caller must reclaim memory (evict / preempt) and retry.
    OutOfBlocks,
}

/// Fixed-size reference-counted block allocator with a free list and a
/// capacity limit.
#[derive(Debug)]
pub struct BlockPool {
    block_tokens: usize,
    block_bytes: usize,
    /// `None` = unbounded (blocks are minted on demand).
    capacity_blocks: Option<usize>,
    /// Recycled ids, popped LIFO.
    free: Vec<BlockId>,
    /// Reference count per minted id (`0` = on the free list).
    refs: Vec<u32>,
    /// Blocks with at least one reference (each counted once however
    /// many owners it has — sharing is what makes this < Σ leases).
    in_use: usize,
    peak_in_use: usize,
    reused: u64,
    cow_copies: u64,
}

impl BlockPool {
    pub fn new(block_tokens: usize, block_bytes: usize, capacity_blocks: Option<usize>) -> BlockPool {
        BlockPool {
            block_tokens: block_tokens.max(1),
            block_bytes: block_bytes.max(1),
            capacity_blocks,
            free: Vec::new(),
            refs: Vec::new(),
            in_use: 0,
            peak_in_use: 0,
            reused: 0,
            cow_copies: 0,
        }
    }

    /// Pool sized for a model storing f32 KV rows: block bytes follow
    /// from the KV row shape, and an optional byte budget becomes a
    /// block capacity (≥ 1).
    pub fn for_model(
        cfg: &ModelConfig,
        block_tokens: usize,
        capacity_bytes: Option<usize>,
    ) -> BlockPool {
        Self::for_model_dtype(cfg, block_tokens, capacity_bytes, super::KvDtype::F32)
    }

    /// [`BlockPool::for_model`] at an explicit KV storage dtype. A
    /// quantized dtype shrinks `block_bytes`, so the same byte budget
    /// yields proportionally more blocks — which is the entire serving
    /// payoff of the int8 tier: more resident requests, fewer
    /// preemptions, same pool.
    pub fn for_model_dtype(
        cfg: &ModelConfig,
        block_tokens: usize,
        capacity_bytes: Option<usize>,
        dtype: super::KvDtype,
    ) -> BlockPool {
        let bt = block_tokens.max(1);
        let bb = (dtype.kv_bytes_per_token(cfg) * bt).max(1);
        let cap = capacity_bytes.map(|bytes| (bytes / bb).max(1));
        BlockPool::new(bt, bb, cap)
    }

    /// Blocks needed to hold `tokens` tokens (at least one).
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens).max(1)
    }

    /// Lease `n` blocks (refcount 1 each), reusing freed ids first.
    /// Returns `None` when the lease would exceed capacity (the caller's
    /// admission / growth gate — reclaim memory and retry, or wait).
    pub fn try_alloc(&mut self, n: usize) -> Option<Vec<BlockId>> {
        if let Some(cap) = self.capacity_blocks {
            if self.in_use + n > cap {
                return None;
            }
        }
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            match self.free.pop() {
                Some(id) => {
                    self.refs[id as usize] = 1;
                    self.reused += 1;
                    ids.push(id);
                }
                None => {
                    let id = self.refs.len() as BlockId;
                    self.refs.push(1);
                    ids.push(id);
                }
            }
        }
        self.in_use += n;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Some(ids)
    }

    /// Attach one more owner to a live block (prefix-sharing fork).
    /// Costs no capacity: the block is already resident.
    pub fn retain(&mut self, id: BlockId) -> Result<(), PageError> {
        match self.refs.get_mut(id as usize) {
            None => Err(PageError::UnknownBlock(id)),
            Some(0) => Err(PageError::DoubleFree(id)),
            Some(r) => {
                *r += 1;
                Ok(())
            }
        }
    }

    /// Drop one reference per id. A block returns to the free list only
    /// when its last owner frees it; freeing a free block or a foreign
    /// id is rejected instead of corrupting the pool.
    pub fn free(&mut self, ids: impl IntoIterator<Item = BlockId>) -> Result<(), PageError> {
        for id in ids {
            match self.refs.get_mut(id as usize) {
                None => return Err(PageError::UnknownBlock(id)),
                Some(0) => return Err(PageError::DoubleFree(id)),
                Some(r) => {
                    *r -= 1;
                    if *r == 0 {
                        self.free.push(id);
                        self.in_use -= 1;
                    }
                }
            }
        }
        Ok(())
    }

    /// Copy-on-write promotion: make the caller's reference to `id`
    /// privately writable. Sole owner → [`CowOutcome::InPlace`]; shared →
    /// the caller's reference moves to a fresh block
    /// ([`CowOutcome::Copied`]; the caller copies any payload it keeps),
    /// or [`CowOutcome::OutOfBlocks`] when the pool cannot host the copy.
    pub fn cow(&mut self, id: BlockId) -> Result<CowOutcome, PageError> {
        match self.refs.get(id as usize).copied() {
            None => Err(PageError::UnknownBlock(id)),
            Some(0) => Err(PageError::DoubleFree(id)),
            Some(1) => Ok(CowOutcome::InPlace),
            Some(_) => {
                let Some(fresh) = self.try_alloc(1) else {
                    return Ok(CowOutcome::OutOfBlocks);
                };
                // Detach the caller from the shared block; other owners
                // keep it alive, so this cannot free it.
                self.refs[id as usize] -= 1;
                self.cow_copies += 1;
                Ok(CowOutcome::Copied(fresh[0]))
            }
        }
    }

    /// References currently held on a block (0 for free or unknown ids).
    pub fn ref_count(&self, id: BlockId) -> u32 {
        self.refs.get(id as usize).copied().unwrap_or(0)
    }

    /// True when more than one owner holds the block.
    pub fn is_shared(&self, id: BlockId) -> bool {
        self.ref_count(id) > 1
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn capacity_blocks(&self) -> Option<usize> {
        self.capacity_blocks
    }

    /// Blocks currently resident (each counted once, however shared).
    pub fn in_use_blocks(&self) -> usize {
        self.in_use
    }

    /// Blocks still allocatable before the capacity gate refuses
    /// (`None` = unbounded).
    pub fn free_blocks(&self) -> Option<usize> {
        self.capacity_blocks.map(|cap| cap.saturating_sub(self.in_use))
    }

    /// Watermark check: can `n` blocks be allocated while leaving at
    /// least `reserve` blocks free afterwards? Always true when the pool
    /// is unbounded.
    pub fn can_alloc(&self, n: usize, reserve: usize) -> bool {
        match self.capacity_blocks {
            None => true,
            Some(cap) => self.in_use + n + reserve <= cap,
        }
    }

    /// True when no lease is outstanding (every minted block is back on
    /// the free list). The serving session debug-asserts the matching
    /// invariant whenever a tick leaves it idle: any submit/cancel/tick
    /// interleaving that drains the session must end with only
    /// prefix-cache-held blocks resident, and none at all once the
    /// prefix cache is flushed — or blocks leaked.
    pub fn is_quiescent(&self) -> bool {
        self.in_use == 0
    }

    /// Ids ever minted (leased + recycled).
    pub fn minted_blocks(&self) -> usize {
        self.refs.len()
    }

    /// Length of the recycled-id free list.
    pub fn free_list_len(&self) -> usize {
        self.free.len()
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use * self.block_bytes
    }

    /// High-water mark of resident blocks.
    pub fn peak_in_use_blocks(&self) -> usize {
        self.peak_in_use
    }

    pub fn peak_bytes_in_use(&self) -> usize {
        self.peak_in_use * self.block_bytes
    }

    /// How many leases were served from the free list (reuse, not mint).
    pub fn reuse_count(&self) -> u64 {
        self.reused
    }

    /// Copy-on-write promotions that actually copied (shared → private).
    pub fn cow_count(&self) -> u64 {
        self.cow_copies
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_mints_then_reuses_lifo() {
        let mut p = BlockPool::new(16, 1024, None);
        let a = p.try_alloc(3).unwrap();
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(p.in_use_blocks(), 3);
        p.free([1]).unwrap();
        assert_eq!(p.free_list_len(), 1);
        // freed id comes back before a new one is minted
        let b = p.try_alloc(2).unwrap();
        assert_eq!(b, vec![1, 3]);
        assert_eq!(p.minted_blocks(), 4);
        assert_eq!(p.reuse_count(), 1);
    }

    #[test]
    fn capacity_gates_allocation() {
        let mut p = BlockPool::new(16, 1024, Some(4));
        let a = p.try_alloc(3).unwrap();
        assert!(p.try_alloc(2).is_none(), "3 + 2 > 4 must refuse");
        assert_eq!(p.in_use_blocks(), 3, "refused alloc must not leak");
        let b = p.try_alloc(1).unwrap();
        assert!(p.try_alloc(1).is_none());
        p.free(a).unwrap();
        assert!(p.try_alloc(3).is_some());
        p.free(b).unwrap();
    }

    #[test]
    fn double_free_and_unknown_are_rejected() {
        let mut p = BlockPool::new(16, 1024, None);
        let a = p.try_alloc(1).unwrap();
        p.free(a.clone()).unwrap();
        assert_eq!(p.free(a), Err(PageError::DoubleFree(0)));
        assert_eq!(p.free([99]), Err(PageError::UnknownBlock(99)));
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn byte_accounting_and_peak() {
        let mut p = BlockPool::new(8, 500, None);
        let a = p.try_alloc(4).unwrap();
        assert_eq!(p.bytes_in_use(), 2000);
        p.free(a).unwrap();
        assert_eq!(p.bytes_in_use(), 0);
        assert_eq!(p.peak_bytes_in_use(), 2000);
        assert_eq!(p.peak_in_use_blocks(), 4);
    }

    #[test]
    fn quiescence_tracks_outstanding_leases() {
        let mut p = BlockPool::new(8, 128, None);
        assert!(p.is_quiescent(), "fresh pool has no leases");
        let a = p.try_alloc(2).unwrap();
        let b = p.try_alloc(1).unwrap();
        assert!(!p.is_quiescent());
        p.free(a).unwrap();
        assert!(!p.is_quiescent(), "one lease still out");
        p.free(b).unwrap();
        assert!(p.is_quiescent(), "all leases returned");
    }

    #[test]
    fn for_model_matches_kv_row_math() {
        let cfg = ModelConfig::tiny();
        let p = BlockPool::for_model(&cfg, 16, Some(4 * cfg.kv_bytes_per_token() * 16));
        assert_eq!(p.block_bytes(), cfg.kv_bytes_per_token() * 16);
        assert_eq!(p.capacity_blocks(), Some(4));
        assert_eq!(p.blocks_for_tokens(1), 1);
        assert_eq!(p.blocks_for_tokens(16), 1);
        assert_eq!(p.blocks_for_tokens(17), 2);
        assert_eq!(p.blocks_for_tokens(0), 1, "even empty requests hold one block");
    }

    #[test]
    fn for_model_dtype_quantized_pool_holds_more_blocks_per_byte() {
        let cfg = ModelConfig::tiny();
        let budget = 64 * 16 * cfg.kv_bytes_per_token();
        let fp32 = BlockPool::for_model_dtype(&cfg, 16, Some(budget), super::super::KvDtype::F32);
        let int8 = BlockPool::for_model_dtype(&cfg, 16, Some(budget), super::super::KvDtype::Int8);
        assert_eq!(fp32.capacity_blocks(), Some(64));
        let ratio = int8.capacity_blocks().unwrap() as f64 / 64.0;
        assert!(ratio >= 3.5, "int8 pool only {ratio}x the fp32 block count");
        assert!(int8.block_bytes() < fp32.block_bytes());
    }

    #[test]
    fn retain_keeps_block_alive_until_last_owner_frees() {
        let mut p = BlockPool::new(16, 1024, None);
        let a = p.try_alloc(1).unwrap();
        let id = a[0];
        p.retain(id).unwrap();
        p.retain(id).unwrap();
        assert_eq!(p.ref_count(id), 3);
        assert!(p.is_shared(id));
        assert_eq!(p.in_use_blocks(), 1, "sharing costs no capacity");
        p.free([id]).unwrap();
        p.free([id]).unwrap();
        assert_eq!(p.in_use_blocks(), 1, "two owners down, one to go");
        assert_eq!(p.free_list_len(), 0);
        p.free([id]).unwrap();
        assert!(p.is_quiescent(), "last owner frees for real");
        assert_eq!(p.free(vec![id]), Err(PageError::DoubleFree(id)));
        assert_eq!(p.retain(id), Err(PageError::DoubleFree(id)));
        assert_eq!(p.retain(42), Err(PageError::UnknownBlock(42)));
    }

    #[test]
    fn cow_in_place_when_sole_owner_copies_when_shared() {
        let mut p = BlockPool::new(16, 1024, Some(3));
        let a = p.try_alloc(1).unwrap();
        let id = a[0];
        assert_eq!(p.cow(id).unwrap(), CowOutcome::InPlace);
        p.retain(id).unwrap();
        let out = p.cow(id).unwrap();
        let CowOutcome::Copied(fresh) = out else { panic!("expected copy, got {out:?}") };
        assert_ne!(fresh, id);
        assert_eq!(p.ref_count(id), 1, "writer detached from the shared block");
        assert_eq!(p.ref_count(fresh), 1);
        assert_eq!(p.in_use_blocks(), 2);
        assert_eq!(p.cow_count(), 1);
        // Fill the pool, then a shared cow must report exhaustion.
        let b = p.try_alloc(1).unwrap();
        p.retain(fresh).unwrap();
        assert_eq!(p.cow(fresh).unwrap(), CowOutcome::OutOfBlocks);
        assert_eq!(p.ref_count(fresh), 2, "failed cow must not drop the reference");
        // Errors for dead / foreign ids.
        p.free(b.clone()).unwrap();
        assert_eq!(p.cow(b[0]), Err(PageError::DoubleFree(b[0])));
        assert_eq!(p.cow(999), Err(PageError::UnknownBlock(999)));
    }

    #[test]
    fn watermark_and_free_block_accounting() {
        let mut p = BlockPool::new(16, 1024, Some(5));
        assert_eq!(p.free_blocks(), Some(5));
        assert!(p.can_alloc(3, 2));
        assert!(!p.can_alloc(4, 2));
        let a = p.try_alloc(2).unwrap();
        assert_eq!(p.free_blocks(), Some(3));
        assert!(p.can_alloc(1, 2));
        assert!(!p.can_alloc(2, 2));
        p.free(a).unwrap();
        let unbounded = BlockPool::new(16, 1024, None);
        assert_eq!(unbounded.free_blocks(), None);
        assert!(unbounded.can_alloc(1_000_000, 1_000_000));
    }
}
