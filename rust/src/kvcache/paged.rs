//! Paged KV-cache block allocator (vLLM-style block manager, specialized
//! to this testbed's host-resident caches).
//!
//! Memory is accounted in fixed-size *blocks* of `block_tokens` tokens;
//! one block spans every (layer, kv-head) slot of a request, so
//! `block_bytes = kv_bytes_per_token × block_tokens`. The engine leases
//! a request's worst-case block count at admission (prompt + generation
//! budget — both known up front), which makes the scheduler's capacity
//! gate exact and keeps the decode hot path completely allocator-free:
//! workers never touch the pool, so steps stay data-parallel and
//! deterministic. Freed blocks return to a LIFO free list and are reused
//! before new ids are minted.

use crate::model::ModelConfig;

/// Physical block handle leased from a [`BlockPool`].
pub type BlockId = u32;

/// Misuse of the allocator — both indicate an engine bookkeeping bug.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PageError {
    /// The block was already free.
    DoubleFree(BlockId),
    /// The block id was never minted by this pool.
    UnknownBlock(BlockId),
}

impl std::fmt::Display for PageError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PageError::DoubleFree(id) => write!(f, "double free of block {id}"),
            PageError::UnknownBlock(id) => write!(f, "unknown block {id}"),
        }
    }
}

impl std::error::Error for PageError {}

/// Fixed-size block allocator with a free list and a capacity limit.
#[derive(Debug)]
pub struct BlockPool {
    block_tokens: usize,
    block_bytes: usize,
    /// `None` = unbounded (blocks are minted on demand).
    capacity_blocks: Option<usize>,
    /// Recycled ids, popped LIFO.
    free: Vec<BlockId>,
    /// Lease state per minted id (`true` = currently leased out).
    live: Vec<bool>,
    in_use: usize,
    peak_in_use: usize,
    reused: u64,
}

impl BlockPool {
    pub fn new(block_tokens: usize, block_bytes: usize, capacity_blocks: Option<usize>) -> BlockPool {
        BlockPool {
            block_tokens: block_tokens.max(1),
            block_bytes: block_bytes.max(1),
            capacity_blocks,
            free: Vec::new(),
            live: Vec::new(),
            in_use: 0,
            peak_in_use: 0,
            reused: 0,
        }
    }

    /// Pool sized for a model: block bytes follow from the KV row shape,
    /// and an optional byte budget becomes a block capacity (≥ 1).
    pub fn for_model(
        cfg: &ModelConfig,
        block_tokens: usize,
        capacity_bytes: Option<usize>,
    ) -> BlockPool {
        let bt = block_tokens.max(1);
        let bb = (cfg.kv_bytes_per_token() * bt).max(1);
        let cap = capacity_bytes.map(|bytes| (bytes / bb).max(1));
        BlockPool::new(bt, bb, cap)
    }

    /// Blocks needed to hold `tokens` tokens (at least one).
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_tokens).max(1)
    }

    /// Lease `n` blocks, reusing freed ids first. Returns `None` when the
    /// lease would exceed capacity (the caller's admission gate).
    pub fn try_alloc(&mut self, n: usize) -> Option<Vec<BlockId>> {
        if let Some(cap) = self.capacity_blocks {
            if self.in_use + n > cap {
                return None;
            }
        }
        let mut ids = Vec::with_capacity(n);
        for _ in 0..n {
            match self.free.pop() {
                Some(id) => {
                    self.live[id as usize] = true;
                    self.reused += 1;
                    ids.push(id);
                }
                None => {
                    let id = self.live.len() as BlockId;
                    self.live.push(true);
                    ids.push(id);
                }
            }
        }
        self.in_use += n;
        self.peak_in_use = self.peak_in_use.max(self.in_use);
        Some(ids)
    }

    /// Return leased blocks to the free list. Rejects double frees and
    /// foreign ids instead of corrupting the pool.
    pub fn free(&mut self, ids: impl IntoIterator<Item = BlockId>) -> Result<(), PageError> {
        for id in ids {
            match self.live.get_mut(id as usize) {
                None => return Err(PageError::UnknownBlock(id)),
                Some(slot) if !*slot => return Err(PageError::DoubleFree(id)),
                Some(slot) => {
                    *slot = false;
                    self.free.push(id);
                    self.in_use -= 1;
                }
            }
        }
        Ok(())
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn capacity_blocks(&self) -> Option<usize> {
        self.capacity_blocks
    }

    /// Blocks currently leased out.
    pub fn in_use_blocks(&self) -> usize {
        self.in_use
    }

    /// True when no lease is outstanding (every minted block is back on
    /// the free list). The serving session debug-asserts this whenever
    /// a tick leaves it idle: any submit/cancel/tick interleaving that
    /// drains the session must end quiescent, or blocks leaked.
    pub fn is_quiescent(&self) -> bool {
        self.in_use == 0
    }

    /// Ids ever minted (leased + recycled).
    pub fn minted_blocks(&self) -> usize {
        self.live.len()
    }

    /// Length of the recycled-id free list.
    pub fn free_list_len(&self) -> usize {
        self.free.len()
    }

    pub fn bytes_in_use(&self) -> usize {
        self.in_use * self.block_bytes
    }

    pub fn peak_bytes_in_use(&self) -> usize {
        self.peak_in_use * self.block_bytes
    }

    /// How many leases were served from the free list (reuse, not mint).
    pub fn reuse_count(&self) -> u64 {
        self.reused
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_mints_then_reuses_lifo() {
        let mut p = BlockPool::new(16, 1024, None);
        let a = p.try_alloc(3).unwrap();
        assert_eq!(a, vec![0, 1, 2]);
        assert_eq!(p.in_use_blocks(), 3);
        p.free([1]).unwrap();
        assert_eq!(p.free_list_len(), 1);
        // freed id comes back before a new one is minted
        let b = p.try_alloc(2).unwrap();
        assert_eq!(b, vec![1, 3]);
        assert_eq!(p.minted_blocks(), 4);
        assert_eq!(p.reuse_count(), 1);
    }

    #[test]
    fn capacity_gates_allocation() {
        let mut p = BlockPool::new(16, 1024, Some(4));
        let a = p.try_alloc(3).unwrap();
        assert!(p.try_alloc(2).is_none(), "3 + 2 > 4 must refuse");
        assert_eq!(p.in_use_blocks(), 3, "refused alloc must not leak");
        let b = p.try_alloc(1).unwrap();
        assert!(p.try_alloc(1).is_none());
        p.free(a).unwrap();
        assert!(p.try_alloc(3).is_some());
        p.free(b).unwrap();
    }

    #[test]
    fn double_free_and_unknown_are_rejected() {
        let mut p = BlockPool::new(16, 1024, None);
        let a = p.try_alloc(1).unwrap();
        p.free(a.clone()).unwrap();
        assert_eq!(p.free(a), Err(PageError::DoubleFree(0)));
        assert_eq!(p.free([99]), Err(PageError::UnknownBlock(99)));
        assert_eq!(p.in_use_blocks(), 0);
    }

    #[test]
    fn byte_accounting_and_peak() {
        let mut p = BlockPool::new(8, 500, None);
        let a = p.try_alloc(4).unwrap();
        assert_eq!(p.bytes_in_use(), 2000);
        p.free(a).unwrap();
        assert_eq!(p.bytes_in_use(), 0);
        assert_eq!(p.peak_bytes_in_use(), 2000);
    }

    #[test]
    fn quiescence_tracks_outstanding_leases() {
        let mut p = BlockPool::new(8, 128, None);
        assert!(p.is_quiescent(), "fresh pool has no leases");
        let a = p.try_alloc(2).unwrap();
        let b = p.try_alloc(1).unwrap();
        assert!(!p.is_quiescent());
        p.free(a).unwrap();
        assert!(!p.is_quiescent(), "one lease still out");
        p.free(b).unwrap();
        assert!(p.is_quiescent(), "all leases returned");
    }

    #[test]
    fn for_model_matches_kv_row_math() {
        let cfg = ModelConfig::tiny();
        let p = BlockPool::for_model(&cfg, 16, Some(4 * cfg.kv_bytes_per_token() * 16));
        assert_eq!(p.block_bytes(), cfg.kv_bytes_per_token() * 16);
        assert_eq!(p.capacity_blocks(), Some(4));
        assert_eq!(p.blocks_for_tokens(1), 1);
        assert_eq!(p.blocks_for_tokens(16), 1);
        assert_eq!(p.blocks_for_tokens(17), 2);
        assert_eq!(p.blocks_for_tokens(0), 1, "even empty requests hold one block");
    }
}
