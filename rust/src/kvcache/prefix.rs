//! Shared-prompt prefix cache: a hash-keyed radix of *full* prompt-token
//! blocks (vLLM-style automatic prefix caching, specialized to this
//! testbed's host-resident caches).
//!
//! Every cached block is keyed by the hash chain of its token history:
//! `key(i) = h(key(i-1), tokens of block i)`, so a key identifies the
//! entire prefix up to and including its block, and lookup is a walk
//! from the root — requests with identical prompt prefixes (system
//! prompts, few-shot headers) land on the same chain. A hit is a *fork*:
//! the request attaches to the cached physical blocks with a refcount
//! bump ([`BlockPool::retain`]), copies the cached K/V rows into its
//! contiguous working buffers (a host memcpy — orders of magnitude
//! cheaper than recomputing prefill), and starts prefill *after* the
//! matched tokens. The final prompt token is never matched: its forward
//! pass produces the logits that seed decoding.
//!
//! Ownership: the cache holds one pool reference per entry, so cached
//! blocks survive their donor request. Entries are evicted LRU —
//! leaf-first along the radix, and only when the cache is the sole
//! owner (eviction must actually reclaim a block) — when the session
//! runs out of pool capacity, and en masse by
//! [`PrefixCache::flush`].
//!
//! Keys are 64-bit FNV-1a over the full token chain; as in vLLM's
//! hash-based prefix cache, a collision would silently alias two
//! prefixes — with 64-bit keys this is vanishingly unlikely at testbed
//! scale and is accepted by design.

use std::collections::{HashMap, HashSet};

use super::paged::{BlockId, BlockPool, PageError};
use super::store::{BlockSnapshot, KvDtype};
use super::KvCache;

/// Hash-chain key of a cached block (identifies the whole prefix up to
/// and including that block, *and* the storage dtype it was prefilled
/// in — an int8 donor's blocks never match an f32 request's lookup, so
/// mixed-dtype sessions cannot alias payload layouts).
pub type ChainKey = u64;

/// One cached full block: its physical id (the cache holds one pool
/// reference on it) plus a snapshot of its K/V rows for copy-in. The
/// snapshot carries the donor's *physical* payload — quantized blocks
/// byte-for-byte — so forks are bit-exact replicas.
struct Entry {
    id: BlockId,
    parent: Option<ChainKey>,
    /// Live child entries in the radix (leaf = 0); evicting leaf-first
    /// keeps every resident entry reachable from the root.
    children: u32,
    /// LRU stamp; strictly increasing, so eviction order is total and
    /// deterministic.
    last_used: u64,
    /// The block's rows across every (layer, kv-head) slot, in the
    /// donor's storage layout.
    snap: BlockSnapshot,
}

/// The radix of cached prompt blocks. Owned by the serving `Session`;
/// all methods run in the serial phases of a tick, so the structure
/// needs no internal locking.
pub struct PrefixCache {
    block_tokens: usize,
    clock: u64,
    entries: HashMap<ChainKey, Entry>,
    hit_blocks: u64,
    lookup_blocks: u64,
    inserted_blocks: u64,
    evicted_blocks: u64,
}

/// FNV-1a over (storage dtype, parent key presence, parent key, block
/// tokens). The dtype tag partitions the radix: chains prefilled at
/// different KV dtypes never match each other.
fn chain_key(dtype: KvDtype, parent: Option<ChainKey>, tokens: &[u32]) -> ChainKey {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    let mut eat = |byte: u8| {
        h ^= byte as u64;
        h = h.wrapping_mul(PRIME);
    };
    eat(match dtype {
        KvDtype::F32 => 0xF3,
        KvDtype::Int8 => 0x18,
        KvDtype::Int4 => 0x14,
    });
    match parent {
        None => eat(0),
        Some(p) => {
            eat(1);
            for b in p.to_le_bytes() {
                eat(b);
            }
        }
    }
    for &t in tokens {
        for b in t.to_le_bytes() {
            eat(b);
        }
    }
    h
}

impl PrefixCache {
    pub fn new(block_tokens: usize) -> PrefixCache {
        PrefixCache {
            block_tokens: block_tokens.max(1),
            clock: 0,
            entries: HashMap::new(),
            hit_blocks: 0,
            lookup_blocks: 0,
            inserted_blocks: 0,
            evicted_blocks: 0,
        }
    }

    /// Walk the radix along `prompt` and return the matched chain keys
    /// (possibly empty). Only full blocks match, and never the block
    /// containing the final prompt token — that token's forward pass is
    /// what seeds decoding, so at least one prompt token is always
    /// recomputed. Touches the LRU stamp of every matched entry; the
    /// hit-rate counters move only through [`PrefixCache::record_use`],
    /// so a pool-stalled admission retrying its lookup every tick does
    /// not inflate them.
    pub fn lookup(&mut self, prompt: &[u32], dtype: KvDtype) -> Vec<ChainKey> {
        let bt = self.block_tokens;
        if prompt.is_empty() {
            return Vec::new();
        }
        let mut keys = Vec::new();
        let mut parent = None;
        let mut start = 0;
        while start + bt < prompt.len() {
            let key = chain_key(dtype, parent, &prompt[start..start + bt]);
            // Stamp first: a miss wastes one clock value, which keeps
            // stamps unique without overlapping entry borrows.
            self.clock += 1;
            let stamp = self.clock;
            let Some(e) = self.entries.get_mut(&key) else { break };
            e.last_used = stamp;
            keys.push(key);
            parent = Some(key);
            start += bt;
        }
        keys
    }

    /// Record one *committed* fork: `hit` of this request's `total`
    /// prompt blocks were served from the radix. Called by the session
    /// exactly once per successful admission, so the reported hit rate
    /// counts forks that actually happened.
    pub fn record_use(&mut self, hit: usize, total: usize) {
        self.hit_blocks += hit as u64;
        self.lookup_blocks += total as u64;
    }

    /// Physical block ids behind matched keys (in chain order). Only
    /// valid for keys just returned by [`PrefixCache::lookup`] with no
    /// intervening eviction — the session calls both in one serial phase.
    pub fn blocks(&self, keys: &[ChainKey]) -> Vec<BlockId> {
        keys.iter().map(|k| self.entries[k].id).collect()
    }

    /// Copy the matched blocks' K/V rows into a request's working cache
    /// (the fork's one-time memcpy; `keys` as returned by `lookup`).
    /// Quantized payloads are copied byte-for-byte — the fork's store is
    /// bit-identical to the donor's, never requantized.
    pub fn copy_into(&self, keys: &[ChainKey], cache: &mut KvCache) {
        for key in keys {
            cache.load_block(&self.entries[key].snap);
        }
    }

    /// Offer a freshly prefilled request's full prompt blocks to the
    /// radix. Blocks already cached are skipped; new entries take one
    /// pool reference on the donor's physical block and snapshot its
    /// rows (in the donor's storage dtype, which also tags the chain
    /// keys). Returns the number of blocks inserted.
    pub fn insert_chain(
        &mut self,
        prompt: &[u32],
        cache: &KvCache,
        pool: &mut BlockPool,
    ) -> Result<usize, PageError> {
        let bt = self.block_tokens;
        let dtype = cache.dtype();
        let full = prompt.len() / bt;
        let mut parent: Option<ChainKey> = None;
        let mut inserted = 0;
        for b in 0..full {
            let key = chain_key(dtype, parent, &prompt[b * bt..(b + 1) * bt]);
            if !self.entries.contains_key(&key) {
                let id = cache.block_table()[b];
                pool.retain(id)?;
                let snap = cache.snapshot_block(b);
                self.clock += 1;
                if let Some(p) = parent {
                    if let Some(pe) = self.entries.get_mut(&p) {
                        pe.children += 1;
                    }
                }
                self.entries.insert(
                    key,
                    Entry { id, parent, children: 0, last_used: self.clock, snap },
                );
                inserted += 1;
                self.inserted_blocks += 1;
            } else {
                // A re-donated chain is in active use: refresh its LRU
                // stamp. Without this, a block every request re-offers
                // still ages as "cold" and gets evicted ahead of
                // genuinely idle chains.
                self.clock += 1;
                let stamp = self.clock;
                self.entries.get_mut(&key).expect("key presence just checked").last_used = stamp;
            }
            parent = Some(key);
        }
        Ok(inserted)
    }

    /// Export every entry for persistence: `(key, parent, snapshot)`
    /// triples ordered parents-before-children, so an import replaying
    /// them in order can re-link child counts in one pass. Within each
    /// depth level the keys are sorted, making the serialized radix
    /// byte-deterministic across runs.
    pub fn export_chains(&self) -> Vec<(ChainKey, Option<ChainKey>, &BlockSnapshot)> {
        let mut out = Vec::with_capacity(self.entries.len());
        let mut emitted: HashSet<ChainKey> = HashSet::with_capacity(self.entries.len());
        while emitted.len() < self.entries.len() {
            let mut ready: Vec<ChainKey> = self
                .entries
                .iter()
                .filter(|(k, e)| {
                    !emitted.contains(*k)
                        && e.parent
                            .map_or(true, |p| emitted.contains(&p) || !self.entries.contains_key(&p))
                })
                .map(|(k, _)| *k)
                .collect();
            if ready.is_empty() {
                break; // unreachable: the radix is acyclic by construction
            }
            ready.sort_unstable();
            for k in ready {
                let e = &self.entries[&k];
                out.push((k, e.parent, &e.snap));
                emitted.insert(k);
            }
        }
        out
    }

    /// Re-create one persisted entry (warm start from a spill store's
    /// prefix file). The imported block takes a fresh pool lease — its
    /// rows live in the snapshot until a fork copies them in, exactly
    /// like a donor-inserted entry after the donor retired. Entries must
    /// arrive parents-before-children (as exported). Returns `false`
    /// when the pool has no free block — the caller stops importing and
    /// serves with a partial radix.
    pub fn import_entry(
        &mut self,
        key: ChainKey,
        parent: Option<ChainKey>,
        snap: BlockSnapshot,
        pool: &mut BlockPool,
    ) -> bool {
        if self.entries.contains_key(&key) {
            return true;
        }
        let Some(lease) = pool.try_alloc(1) else { return false };
        self.clock += 1;
        if let Some(p) = parent {
            if let Some(pe) = self.entries.get_mut(&p) {
                pe.children += 1;
            }
        }
        self.entries.insert(
            key,
            Entry { id: lease[0], parent, children: 0, last_used: self.clock, snap },
        );
        self.inserted_blocks += 1;
        true
    }

    /// Evict the least-recently-used *reclaimable* entry: a leaf whose
    /// block the cache is the sole owner of (so freeing it actually
    /// returns a block to the pool). Returns false when nothing
    /// reclaimable exists — the session falls through to preemption.
    pub fn evict_one(&mut self, pool: &mut BlockPool) -> Result<bool, PageError> {
        let victim = self
            .entries
            .iter()
            .filter(|(_, e)| e.children == 0 && pool.ref_count(e.id) == 1)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| *k);
        let Some(key) = victim else { return Ok(false) };
        let e = self.entries.remove(&key).expect("victim key just found");
        if let Some(p) = e.parent {
            if let Some(pe) = self.entries.get_mut(&p) {
                pe.children = pe.children.saturating_sub(1);
            }
        }
        pool.free([e.id])?;
        self.evicted_blocks += 1;
        Ok(true)
    }

    /// Drop every entry, returning the cache's pool references. After a
    /// flush (and with no requests in flight) the pool is quiescent.
    /// Returns the number of blocks released.
    pub fn flush(&mut self, pool: &mut BlockPool) -> Result<usize, PageError> {
        let n = self.entries.len();
        for (_, e) in self.entries.drain() {
            pool.free([e.id])?;
        }
        self.evicted_blocks += n as u64;
        Ok(n)
    }

    /// Entries resident (== pool references the cache holds; every entry
    /// holds exactly one reference on a distinct block).
    pub fn blocks_held(&self) -> usize {
        self.entries.len()
    }

    /// Prompt blocks served from the radix, over all lookups.
    pub fn hit_blocks(&self) -> u64 {
        self.hit_blocks
    }

    /// Prompt blocks presented to the radix, over all lookups.
    pub fn lookup_blocks(&self) -> u64 {
        self.lookup_blocks
    }

    /// Block-granular hit rate over all lookups (0 when never queried).
    pub fn hit_rate(&self) -> f64 {
        if self.lookup_blocks == 0 {
            0.0
        } else {
            self.hit_blocks as f64 / self.lookup_blocks as f64
        }
    }

    pub fn inserted_blocks(&self) -> u64 {
        self.inserted_blocks
    }

    pub fn evicted_blocks(&self) -> u64 {
        self.evicted_blocks
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelConfig;

    const BT: usize = 4;

    /// A paged cache filled with `tokens` recognizable rows (row i is
    /// all `base + i`), its table leased from `pool`.
    fn filled_cache(cfg: &ModelConfig, pool: &mut BlockPool, tokens: usize, base: f32) -> KvCache {
        let lease = pool.try_alloc(pool.blocks_for_tokens(tokens)).expect("alloc");
        let mut cache = KvCache::paged(cfg, BT, lease);
        for i in 0..tokens {
            let row = vec![base + i as f32; cfg.d_head()];
            for l in 0..cfg.n_layers {
                for h in 0..cfg.n_kv_heads {
                    cache.append(l, h, &row, &row);
                }
            }
        }
        cache
    }

    fn prompt(len: usize) -> Vec<u32> {
        (0..len as u32).map(|t| t * 7 % 101).collect()
    }

    #[test]
    fn chain_key_distinguishes_position_content_and_dtype() {
        let a = chain_key(KvDtype::F32, None, &[1, 2, 3, 4]);
        let b = chain_key(KvDtype::F32, None, &[1, 2, 3, 5]);
        let c = chain_key(KvDtype::F32, Some(a), &[1, 2, 3, 4]);
        let d = chain_key(KvDtype::Int8, None, &[1, 2, 3, 4]);
        assert_ne!(a, b, "content must matter");
        assert_ne!(a, c, "chain position must matter");
        assert_ne!(a, d, "storage dtype must partition the radix");
        assert_eq!(a, chain_key(KvDtype::F32, None, &[1, 2, 3, 4]), "keys are deterministic");
    }

    #[test]
    fn insert_then_lookup_matches_full_blocks_but_never_the_last_token() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model(&cfg, BT, None);
        let mut px = PrefixCache::new(BT);
        let p = prompt(10); // 2 full blocks + a 2-token tail
        let cache = filled_cache(&cfg, &mut pool, 10, 0.0);
        assert_eq!(px.insert_chain(&p, &cache, &mut pool).unwrap(), 2);
        assert_eq!(px.blocks_held(), 2);
        // Same prompt: both full blocks match.
        assert_eq!(px.lookup(&p, KvDtype::F32).len(), 2);
        // An f32 chain never serves an int8 request (layouts differ).
        assert_eq!(px.lookup(&p, KvDtype::Int8).len(), 0);
        // A prompt of exactly 8 tokens may match only block 0 — block 1
        // holds its final token, whose logits must be recomputed.
        assert_eq!(px.lookup(&p[..8], KvDtype::F32).len(), 1);
        // Diverging second block stops the chain after block 0.
        let mut q = p.clone();
        q[5] = 999;
        assert_eq!(px.lookup(&q, KvDtype::F32).len(), 1);
        // Diverging first block matches nothing.
        let mut r = p.clone();
        r[0] = 999;
        assert_eq!(px.lookup(&r, KvDtype::F32).len(), 0);
        // Lookups alone never move the hit-rate counters (stalled
        // admission retries must not inflate them) — committed forks do.
        assert_eq!(px.hit_rate(), 0.0);
        px.record_use(2, 3);
        px.record_use(1, 2);
        assert!((px.hit_rate() - 3.0 / 5.0).abs() < 1e-12);
        assert_eq!(px.hit_blocks(), 3);
        assert_eq!(px.lookup_blocks(), 5);
    }

    #[test]
    fn copy_into_reproduces_the_donor_rows_and_fork_shares_blocks() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model(&cfg, BT, None);
        let mut px = PrefixCache::new(BT);
        let p = prompt(9); // 2 full blocks
        let donor = filled_cache(&cfg, &mut pool, 9, 100.0);
        px.insert_chain(&p, &donor, &mut pool).unwrap();
        let donor_in_use = pool.in_use_blocks();

        let keys = px.lookup(&p, KvDtype::F32);
        let ids = px.blocks(&keys);
        assert_eq!(ids, donor.block_table()[..2].to_vec());
        for &id in &ids {
            pool.retain(id).unwrap(); // the fork's refcount bump
        }
        assert_eq!(pool.in_use_blocks(), donor_in_use, "sharing costs no blocks");
        let tail = pool.try_alloc(1).unwrap(); // fork's private tail block
        let mut table = ids.clone();
        table.extend(tail);
        let mut fork = KvCache::paged(&cfg, BT, table);
        px.copy_into(&keys, &mut fork);
        assert_eq!(fork.tokens(), 8);
        for l in 0..cfg.n_layers {
            for h in 0..cfg.n_kv_heads {
                let (dk, dv) = donor.head(l, h);
                let (fk, fv) = fork.head(l, h);
                assert_eq!(&dk.data[..8 * cfg.d_head()], &fk.data[..]);
                assert_eq!(&dv.data[..8 * cfg.d_head()], &fv.data[..]);
            }
        }
    }

    #[test]
    fn eviction_is_lru_leaf_first_and_skips_shared_blocks() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model(&cfg, BT, None);
        let mut px = PrefixCache::new(BT);
        let p = prompt(13); // 3 full blocks, chained 0 → 1 → 2
        let mut donor = filled_cache(&cfg, &mut pool, 13, 0.0);
        px.insert_chain(&p, &donor, &mut pool).unwrap();
        // Donor finishes: its references go away, cache keeps the blocks.
        pool.free(donor.release_blocks()).unwrap();
        assert_eq!(pool.in_use_blocks(), 3);

        // A later lookup refreshes the whole chain's LRU stamps; the
        // deepest leaf (block 2) is still the only evictable entry.
        assert_eq!(px.lookup(&p, KvDtype::F32).len(), 3);
        assert!(px.evict_one(&mut pool).unwrap());
        assert_eq!(px.blocks_held(), 2);
        assert_eq!(pool.in_use_blocks(), 2);
        // Now block 1 is the leaf; retain it as a live request would —
        // eviction must then fall through to... nothing (block 0 has a
        // child, block 1 is shared), reporting no progress.
        let keys = px.lookup(&p[..9], KvDtype::F32); // matches blocks 0, 1
        let ids = px.blocks(&keys);
        pool.retain(ids[1]).unwrap();
        assert!(!px.evict_one(&mut pool).unwrap());
        pool.free([ids[1]]).unwrap();
        assert!(px.evict_one(&mut pool).unwrap(), "sole ownership restored");
        assert_eq!(px.evicted_blocks(), 2);
    }

    #[test]
    fn int8_fork_copies_quantized_payload_byte_for_byte() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model_dtype(&cfg, BT, None, KvDtype::Int8);
        let mut px = PrefixCache::new(BT);
        let p = prompt(9); // 2 full blocks
        let lease = pool.try_alloc(pool.blocks_for_tokens(9)).unwrap();
        let mut donor = KvCache::paged_dtype(&cfg, BT, lease, KvDtype::Int8);
        let mut rng = crate::util::Rng::new(5);
        for _ in 0..9 {
            for l in 0..cfg.n_layers {
                for h in 0..cfg.n_kv_heads {
                    let kr: Vec<f32> = (0..cfg.d_head()).map(|_| rng.normal32(0.0, 1.0)).collect();
                    let vr: Vec<f32> = (0..cfg.d_head()).map(|_| rng.normal32(0.0, 1.0)).collect();
                    donor.append(l, h, &kr, &vr);
                }
            }
        }
        px.insert_chain(&p, &donor, &mut pool).unwrap();
        let keys = px.lookup(&p, KvDtype::Int8);
        assert_eq!(keys.len(), 2);
        let ids = px.blocks(&keys);
        for &id in &ids {
            pool.retain(id).unwrap();
        }
        let tail = pool.try_alloc(1).unwrap();
        let mut table = ids;
        table.extend(tail);
        let mut fork = KvCache::paged_dtype(&cfg, BT, table, KvDtype::Int8);
        px.copy_into(&keys, &mut fork);
        assert_eq!(fork.tokens(), 8);
        for l in 0..cfg.n_layers {
            for h in 0..cfg.n_kv_heads {
                let (dk, dv) = donor.head(l, h);
                let (fk, fv) = fork.head(l, h);
                // Bitwise-equal dequantized mirrors: the payload was
                // copied byte-for-byte, never requantized.
                assert_eq!(&dk.data[..8 * cfg.d_head()], &fk.data[..]);
                assert_eq!(&dv.data[..8 * cfg.d_head()], &fv.data[..]);
            }
        }
    }

    #[test]
    fn flush_returns_every_block_to_the_pool() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model(&cfg, BT, None);
        let mut px = PrefixCache::new(BT);
        let p = prompt(12);
        let mut donor = filled_cache(&cfg, &mut pool, 12, 0.0);
        px.insert_chain(&p, &donor, &mut pool).unwrap();
        pool.free(donor.release_blocks()).unwrap();
        assert!(!pool.is_quiescent());
        assert_eq!(px.flush(&mut pool).unwrap(), 3); // 12 tokens = 3 full blocks
        assert!(pool.is_quiescent());
        assert_eq!(px.blocks_held(), 0);
    }

    #[test]
    fn re_donated_chain_refreshes_lru_stamps() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model(&cfg, BT, None);
        let mut px = PrefixCache::new(BT);
        let p = prompt(9); // 2 full blocks
        let mut q = prompt(9);
        q[0] = 999; // a distinct chain
        let mut a = filled_cache(&cfg, &mut pool, 9, 0.0);
        px.insert_chain(&p, &a, &mut pool).unwrap();
        let mut b = filled_cache(&cfg, &mut pool, 9, 50.0);
        px.insert_chain(&q, &b, &mut pool).unwrap();
        // Re-donating p's chain inserts nothing but must refresh its LRU
        // stamps — it is the chain in active use.
        let mut c = filled_cache(&cfg, &mut pool, 9, 0.0);
        assert_eq!(px.insert_chain(&p, &c, &mut pool).unwrap(), 0);
        for donor in [&mut a, &mut b, &mut c] {
            pool.free(donor.release_blocks()).unwrap();
        }
        assert!(px.evict_one(&mut pool).unwrap());
        // The victim must come from the idle chain q, not the re-donated
        // p (whose leaf used to look "cold" and got evicted first).
        assert_eq!(px.lookup(&p, KvDtype::F32).len(), 2, "re-donated chain survives");
        assert_eq!(px.lookup(&q, KvDtype::F32).len(), 1, "idle chain lost its leaf");
    }

    #[test]
    fn export_orders_parents_first_and_import_rebuilds_the_radix() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model(&cfg, BT, None);
        let mut px = PrefixCache::new(BT);
        let p = prompt(13); // 3 full blocks, one chain
        let mut donor = filled_cache(&cfg, &mut pool, 13, 0.0);
        px.insert_chain(&p, &donor, &mut pool).unwrap();
        let exported: Vec<(ChainKey, Option<ChainKey>)> =
            px.export_chains().iter().map(|(k, par, _)| (*k, *par)).collect();
        assert_eq!(exported.len(), 3);
        for (i, (_, par)) in exported.iter().enumerate() {
            if let Some(par) = par {
                assert!(
                    exported[..i].iter().any(|(k, _)| k == par),
                    "parents must precede children"
                );
            }
        }
        // Warm-start a fresh cache + pool from the exported triples (a
        // single chain exports depth-by-depth, so entry i is block i;
        // the spill store round-trips snapshots byte-exactly, here we
        // take them straight from the donor).
        let mut pool2 = BlockPool::for_model(&cfg, BT, None);
        let mut px2 = PrefixCache::new(BT);
        for (i, (k, par)) in exported.iter().enumerate() {
            assert!(px2.import_entry(*k, *par, donor.snapshot_block(i), &mut pool2));
        }
        assert_eq!(px2.blocks_held(), 3);
        assert_eq!(px2.inserted_blocks(), 3);
        let keys = px2.lookup(&p, KvDtype::F32);
        assert_eq!(keys.len(), 3, "imported radix serves the original prompt");
        let ids = px2.blocks(&keys);
        for &id in &ids {
            pool2.retain(id).unwrap();
        }
        let tail = pool2.try_alloc(1).unwrap();
        let mut table = ids;
        table.extend(tail);
        let mut fork = KvCache::paged(&cfg, BT, table);
        px2.copy_into(&keys, &mut fork);
        assert_eq!(fork.tokens(), 12);
        for l in 0..cfg.n_layers {
            for h in 0..cfg.n_kv_heads {
                let (dk, dv) = donor.head(l, h);
                let (fk, fv) = fork.head(l, h);
                assert_eq!(&dk.data[..12 * cfg.d_head()], &fk.data[..]);
                assert_eq!(&dv.data[..12 * cfg.d_head()], &fv.data[..]);
            }
        }
        // Importing an already-present key is a no-op hit, not a leak.
        assert!(px2.import_entry(exported[0].0, exported[0].1, donor.snapshot_block(0), &mut pool2));
        assert_eq!(px2.blocks_held(), 3);
        pool.free(donor.release_blocks()).unwrap();
    }

    #[test]
    fn import_stops_when_the_pool_is_full() {
        let cfg = ModelConfig::tiny();
        let mut big = BlockPool::for_model(&cfg, BT, None);
        let donor = filled_cache(&cfg, &mut big, 9, 0.0);
        let p = prompt(9);
        let k1 = chain_key(KvDtype::F32, None, &p[..BT]);
        let k2 = chain_key(KvDtype::F32, Some(k1), &p[BT..2 * BT]);
        // A pool with exactly one block: the first import lands, the
        // second reports exhaustion so the caller stops gracefully.
        let mut tiny = BlockPool::for_model(&cfg, BT, Some(BT * cfg.kv_bytes_per_token()));
        let mut px = PrefixCache::new(BT);
        assert!(px.import_entry(k1, None, donor.snapshot_block(0), &mut tiny));
        assert!(!px.import_entry(k2, Some(k1), donor.snapshot_block(1), &mut tiny));
        assert_eq!(px.blocks_held(), 1);
    }

    #[test]
    fn second_donor_with_same_prefix_inserts_nothing_new() {
        let cfg = ModelConfig::tiny();
        let mut pool = BlockPool::for_model(&cfg, BT, None);
        let mut px = PrefixCache::new(BT);
        let p = prompt(9);
        let a = filled_cache(&cfg, &mut pool, 9, 0.0);
        assert_eq!(px.insert_chain(&p, &a, &mut pool).unwrap(), 2);
        let b = filled_cache(&cfg, &mut pool, 9, 0.0);
        assert_eq!(px.insert_chain(&p, &b, &mut pool).unwrap(), 0, "chain already cached");
        // A longer prompt extending the same prefix adds only its new block.
        let mut longer = prompt(9);
        longer.extend([7, 8, 9, 10]);
        let c = filled_cache(&cfg, &mut pool, 13, 0.0);
        assert_eq!(px.insert_chain(&longer, &c, &mut pool).unwrap(), 1);
        assert_eq!(px.blocks_held(), 3);
    }
}
