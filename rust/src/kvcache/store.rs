//! [`BlockStore`]: the K/V row storage behind [`crate::kvcache::KvCache`],
//! in one of three physical dtypes behind a single interface.
//!
//! * [`KvDtype::F32`] — rows stored as plain f32 (`4·d` bytes/row), the
//!   historical layout.
//! * [`KvDtype::Int8`] — rows stored as per-row symmetric int8 payloads
//!   (`d + 4` bytes/row: one code per element plus the f32 scale; see
//!   [`crate::tensor::quant`]), alongside a *dequantized f32 working
//!   mirror*.
//! * [`KvDtype::Int4`] — rows stored as bit-packed per-row symmetric
//!   int4 payloads (`⌈d/2⌉ + 4` bytes/row: two codes per byte plus the
//!   f32 scale; docs/GUARANTEES.md §9), same mirror discipline as int8
//!   with a wider ρ folded through the budget.
//!
//! The mirror is the testbed's stand-in for the transient on-device
//! dequantized tile of the paper's deployment: every downstream
//! computation (index selection, attention, the budget statistics) reads
//! the mirror — so quantization error is fully visible to the verified
//! pipeline — while everything *physical* (paged-pool block sizing,
//! [`crate::kvcache::TierStats`] byte traffic, resident bytes, prefix
//! snapshots) is accounted on the int8 payload. The bridge is exact:
//! `QuantizedMat::dot_row` is bitwise equal to dotting the mirror row
//! (proved in `tests/proptests.rs`), so mirror-side math is the math a
//! fused dequantizing kernel would produce.
//!
//! Snapshots ([`BlockStore::snapshot_rows`] / [`BlockStore::load_rows`])
//! carry the payload **byte-for-byte** — a prefix fork or CoW copy of a
//! quantized block never requantizes, so forked requests are bit-exact
//! replicas of their donors and token streams stay byte-identical
//! between shared and unshared runs (`tests/kv_quant.rs`).

use crate::model::ModelConfig;
use crate::tensor::quant::{KvQuantBounds, QuantizedMat, QuantizedMat4};
use crate::tensor::Mat;

/// Physical storage dtype of a KV cache's rows.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KvDtype {
    /// Plain f32 rows (exact).
    #[default]
    F32,
    /// Per-row symmetric int8 with power-of-two scales; dequantization
    /// error is carried through the (ε, δ) budget as an explicit slack
    /// term (docs/GUARANTEES.md §8).
    Int8,
    /// Bit-packed per-row symmetric int4 (two codes per byte) with
    /// power-of-two scales — same exact `scale/2` bound as int8 but a
    /// 16× wider scale, i.e. a wider ρ (docs/GUARANTEES.md §9).
    Int4,
}

impl KvDtype {
    /// Physical bytes of one stored K or V row of `d` elements. Int8
    /// rows carry a 4-byte f32 scale next to `d` one-byte codes; int4
    /// packs two codes per byte (`⌈d/2⌉` bytes) plus the scale.
    pub fn row_bytes(self, d: usize) -> usize {
        match self {
            KvDtype::F32 => 4 * d,
            KvDtype::Int8 => d + 4,
            KvDtype::Int4 => d.div_ceil(2) + 4,
        }
    }

    /// KV bytes per cached token for a model at this dtype (K and V
    /// rows across every layer's KV heads). At `F32` this equals
    /// [`ModelConfig::kv_bytes_per_token`].
    pub fn kv_bytes_per_token(self, cfg: &ModelConfig) -> usize {
        2 * cfg.n_kv_heads * self.row_bytes(cfg.d_head()) * cfg.n_layers
    }

    pub fn name(self) -> &'static str {
        match self {
            KvDtype::F32 => "f32",
            KvDtype::Int8 => "int8",
            KvDtype::Int4 => "int4",
        }
    }

    /// Parse a CLI spelling (`vattn serve --kv-quant int4`).
    pub fn parse(s: &str) -> Option<KvDtype> {
        match s {
            "f32" | "fp32" | "none" => Some(KvDtype::F32),
            "int8" => Some(KvDtype::Int8),
            "int4" => Some(KvDtype::Int4),
            _ => None,
        }
    }
}

/// fp32-vs-physical per-token footprint ratio (1.0 when the physical
/// bytes are zero/unpopulated). The single definition behind
/// `SessionStats::kv_compression_ratio` and
/// `metrics::PagingSummary::compression_ratio`, so the serve table,
/// `BENCH_engine.json` and stats consumers can never diverge.
pub fn compression_ratio(bytes_per_token_fp32: usize, bytes_per_token: usize) -> f64 {
    if bytes_per_token == 0 {
        1.0
    } else {
        bytes_per_token_fp32 as f64 / bytes_per_token as f64
    }
}

/// One slot's rows for one block, in that slot's physical layout.
/// Quantized payloads are raw codes + scales, copied byte-for-byte.
pub enum SlotRows {
    F32 { k: Vec<f32>, v: Vec<f32> },
    Int8 { k: Vec<i8>, k_scales: Vec<f32>, v: Vec<i8>, v_scales: Vec<f32> },
    /// Bit-packed int4: `⌈d/2⌉` bytes per row, two codes per byte.
    Int4 { k: Vec<u8>, k_scales: Vec<f32>, v: Vec<u8>, v_scales: Vec<f32> },
}

/// A full block's rows across every (layer, kv-head) slot — what the
/// prefix cache retains per entry and what a fork copies in.
pub struct BlockSnapshot {
    pub dtype: KvDtype,
    /// Tokens (rows per slot) the snapshot covers.
    pub tokens: usize,
    pub slots: Vec<SlotRows>,
}

impl BlockSnapshot {
    /// Physical payload bytes the snapshot carries — what a copy-in
    /// memcpy or a cold-tier transfer actually moves. Matches
    /// [`BlockStore::payload_bytes`] accounting: f32 rows at 4 bytes per
    /// element, int8 as one code byte per element plus a 4-byte f32
    /// scale per row.
    pub fn payload_bytes(&self) -> usize {
        self.slots
            .iter()
            .map(|s| match s {
                SlotRows::F32 { k, v } => (k.len() + v.len()) * 4,
                SlotRows::Int8 { k, k_scales, v, v_scales } => {
                    k.len() + v.len() + (k_scales.len() + v_scales.len()) * 4
                }
                SlotRows::Int4 { k, k_scales, v, v_scales } => {
                    k.len() + v.len() + (k_scales.len() + v_scales.len()) * 4
                }
            })
            .sum()
    }
}

/// Per-slot K/V storage in one dtype. Slots advance together only by
/// convention (the cache appends one row to every slot per token); the
/// store itself is per-slot append-only.
pub struct BlockStore {
    dtype: KvDtype,
    d: usize,
    /// Dequantized working rows per slot — authoritative for F32, the
    /// device-tile mirror for Int8 (see module docs).
    k: Vec<Mat>,
    v: Vec<Mat>,
    /// Physical int8 payloads (empty unless dtype is Int8).
    qk: Vec<QuantizedMat>,
    qv: Vec<QuantizedMat>,
    /// Physical bit-packed int4 payloads (empty unless dtype is Int4).
    q4k: Vec<QuantizedMat4>,
    q4v: Vec<QuantizedMat4>,
}

impl BlockStore {
    pub fn new(slots: usize, d: usize, dtype: KvDtype) -> BlockStore {
        let q8 = matches!(dtype, KvDtype::Int8);
        let q4 = matches!(dtype, KvDtype::Int4);
        BlockStore {
            dtype,
            d,
            k: (0..slots).map(|_| Mat::zeros(0, d)).collect(),
            v: (0..slots).map(|_| Mat::zeros(0, d)).collect(),
            qk: if q8 { (0..slots).map(|_| QuantizedMat::new(d)).collect() } else { Vec::new() },
            qv: if q8 { (0..slots).map(|_| QuantizedMat::new(d)).collect() } else { Vec::new() },
            q4k: if q4 { (0..slots).map(|_| QuantizedMat4::new(d)).collect() } else { Vec::new() },
            q4v: if q4 { (0..slots).map(|_| QuantizedMat4::new(d)).collect() } else { Vec::new() },
        }
    }

    pub fn dtype(&self) -> KvDtype {
        self.dtype
    }

    pub fn slots(&self) -> usize {
        self.k.len()
    }

    /// Physical bytes of one stored row (per matrix).
    pub fn row_bytes(&self) -> usize {
        self.dtype.row_bytes(self.d)
    }

    pub fn rows(&self, slot: usize) -> usize {
        self.k[slot].rows
    }

    /// The slot's K rows as the f32 matrix every consumer reads
    /// (dequantized mirror at Int8).
    pub fn k(&self, slot: usize) -> &Mat {
        &self.k[slot]
    }

    pub fn v(&self, slot: usize) -> &Mat {
        &self.v[slot]
    }

    /// Append one token's rows to a slot. At Int8 the row is quantized
    /// into the payload and the *dequantized* values — not the originals
    /// — extend the mirror, so downstream math sees exactly what the
    /// store can reproduce.
    pub fn append_row(&mut self, slot: usize, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        match self.dtype {
            KvDtype::F32 => {
                self.k[slot].data.extend_from_slice(k_row);
                self.k[slot].rows += 1;
                self.v[slot].data.extend_from_slice(v_row);
                self.v[slot].rows += 1;
            }
            KvDtype::Int8 => {
                self.qk[slot].push_row(k_row);
                let r = self.qk[slot].rows() - 1;
                self.qk[slot].dequantize_row_into(r, &mut self.k[slot].data);
                self.k[slot].rows += 1;
                self.qv[slot].push_row(v_row);
                self.qv[slot].dequantize_row_into(r, &mut self.v[slot].data);
                self.v[slot].rows += 1;
            }
            KvDtype::Int4 => {
                self.q4k[slot].push_row(k_row);
                let r = self.q4k[slot].rows() - 1;
                self.q4k[slot].dequantize_row_into(r, &mut self.k[slot].data);
                self.k[slot].rows += 1;
                self.q4v[slot].push_row(v_row);
                self.q4v[slot].dequantize_row_into(r, &mut self.v[slot].data);
                self.v[slot].rows += 1;
            }
        }
    }

    /// Dequantization-error bounds of a slot's rows (`None` for exact
    /// f32 storage). Monotone under appends, reset by `clear`.
    pub fn quant_bounds(&self, slot: usize) -> Option<KvQuantBounds> {
        match self.dtype {
            KvDtype::F32 => None,
            KvDtype::Int8 => Some(KvQuantBounds {
                k_scale_max: self.qk[slot].max_scale(),
                v_scale_max: self.qv[slot].max_scale(),
            }),
            KvDtype::Int4 => Some(KvQuantBounds {
                k_scale_max: self.q4k[slot].max_scale(),
                v_scale_max: self.q4v[slot].max_scale(),
            }),
        }
    }

    /// Physical resident bytes across all slots (payload only; the Int8
    /// mirror is the transient device tile, not host-resident state).
    pub fn payload_bytes(&self) -> usize {
        match self.dtype {
            KvDtype::F32 => self.k.iter().zip(&self.v).map(|(k, v)| (k.data.len() + v.data.len()) * 4).sum(),
            KvDtype::Int8 => self
                .qk
                .iter()
                .zip(&self.qv)
                .map(|(k, v)| k.payload_bytes() + v.payload_bytes())
                .sum(),
            KvDtype::Int4 => self
                .q4k
                .iter()
                .zip(&self.q4v)
                .map(|(k, v)| k.payload_bytes() + v.payload_bytes())
                .sum(),
        }
    }

    /// Snapshot rows [lo, hi) of every slot in physical layout —
    /// quantized payloads byte-for-byte.
    pub fn snapshot_rows(&self, lo: usize, hi: usize) -> BlockSnapshot {
        let d = self.d;
        let mut slots = Vec::with_capacity(self.k.len());
        for s in 0..self.k.len() {
            slots.push(match self.dtype {
                KvDtype::F32 => SlotRows::F32 {
                    k: self.k[s].data[lo * d..hi * d].to_vec(),
                    v: self.v[s].data[lo * d..hi * d].to_vec(),
                },
                KvDtype::Int8 => {
                    let (kc, ks) = self.qk[s].raw_rows(lo, hi);
                    let (vc, vs) = self.qv[s].raw_rows(lo, hi);
                    SlotRows::Int8 {
                        k: kc.to_vec(),
                        k_scales: ks.to_vec(),
                        v: vc.to_vec(),
                        v_scales: vs.to_vec(),
                    }
                }
                KvDtype::Int4 => {
                    let (kc, ks) = self.q4k[s].raw_rows(lo, hi);
                    let (vc, vs) = self.q4v[s].raw_rows(lo, hi);
                    SlotRows::Int4 {
                        k: kc.to_vec(),
                        k_scales: ks.to_vec(),
                        v: vc.to_vec(),
                        v_scales: vs.to_vec(),
                    }
                }
            });
        }
        BlockSnapshot { dtype: self.dtype, tokens: hi - lo, slots }
    }

    /// Bulk-append a snapshot's rows — the fork's copy-in. Quantized
    /// payloads are restored byte-for-byte and the mirror is rebuilt by
    /// dequantization, so the loaded rows are bit-identical to the
    /// donor's. Panics on a dtype or slot-count mismatch (the prefix
    /// cache keys chains by dtype, so a mismatch is an engine bug).
    pub fn load_rows(&mut self, snap: &BlockSnapshot) {
        assert_eq!(snap.dtype, self.dtype, "KV dtype mismatch on block load");
        assert_eq!(snap.slots.len(), self.k.len(), "slot count mismatch on block load");
        for (s, rows) in snap.slots.iter().enumerate() {
            match rows {
                SlotRows::F32 { k, v } => {
                    debug_assert_eq!(k.len(), snap.tokens * self.d);
                    self.k[s].data.extend_from_slice(k);
                    self.k[s].rows += snap.tokens;
                    self.v[s].data.extend_from_slice(v);
                    self.v[s].rows += snap.tokens;
                }
                SlotRows::Int8 { k, k_scales, v, v_scales } => {
                    let base = self.qk[s].rows();
                    self.qk[s].extend_raw(k, k_scales);
                    self.qv[s].extend_raw(v, v_scales);
                    for r in base..base + snap.tokens {
                        self.qk[s].dequantize_row_into(r, &mut self.k[s].data);
                        self.k[s].rows += 1;
                        self.qv[s].dequantize_row_into(r, &mut self.v[s].data);
                        self.v[s].rows += 1;
                    }
                }
                SlotRows::Int4 { k, k_scales, v, v_scales } => {
                    let base = self.q4k[s].rows();
                    self.q4k[s].extend_raw(k, k_scales);
                    self.q4v[s].extend_raw(v, v_scales);
                    for r in base..base + snap.tokens {
                        self.q4k[s].dequantize_row_into(r, &mut self.k[s].data);
                        self.k[s].rows += 1;
                        self.q4v[s].dequantize_row_into(r, &mut self.v[s].data);
                        self.v[s].rows += 1;
                    }
                }
            }
        }
    }

    pub fn clear(&mut self) {
        for m in self.k.iter_mut().chain(self.v.iter_mut()) {
            m.rows = 0;
            m.data.clear();
        }
        for q in self.qk.iter_mut().chain(self.qv.iter_mut()) {
            q.clear();
        }
        for q in self.q4k.iter_mut().chain(self.q4v.iter_mut()) {
            q.clear();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn dtype_bytes_and_parse() {
        assert_eq!(KvDtype::F32.row_bytes(32), 128);
        assert_eq!(KvDtype::Int8.row_bytes(32), 36);
        let cfg = ModelConfig::tiny();
        assert_eq!(KvDtype::F32.kv_bytes_per_token(&cfg), cfg.kv_bytes_per_token());
        // tiny: 2 kv-heads × 2 layers × 2 matrices × (32 + 4) bytes.
        assert_eq!(KvDtype::Int8.kv_bytes_per_token(&cfg), 2 * 2 * 2 * 36);
        assert_eq!(KvDtype::parse("int8"), Some(KvDtype::Int8));
        assert_eq!(KvDtype::parse("fp32"), Some(KvDtype::F32));
        assert_eq!(KvDtype::parse("f32"), Some(KvDtype::F32));
        assert_eq!(KvDtype::parse("int4"), Some(KvDtype::Int4));
        assert_eq!(KvDtype::parse("int2"), None);
        assert_eq!(KvDtype::Int8.name(), "int8");
        assert_eq!(KvDtype::Int4.name(), "int4");
        // int4 packs two codes per byte: ⌈32/2⌉ + 4 = 20 bytes/row.
        assert_eq!(KvDtype::Int4.row_bytes(32), 20);
        assert_eq!(KvDtype::Int4.row_bytes(33), 21);
        assert_eq!(KvDtype::Int4.kv_bytes_per_token(&cfg), 2 * 2 * 2 * 20);
    }

    #[test]
    fn f32_store_is_exact_and_int8_store_is_within_bounds() {
        let mut rng = Rng::new(1);
        let d = 16;
        let rows: Vec<Vec<f32>> = (0..12).map(|_| {
            (0..d).map(|_| rng.normal32(0.0, 1.5)).collect()
        }).collect();
        let mut exact = BlockStore::new(2, d, KvDtype::F32);
        let mut quant = BlockStore::new(2, d, KvDtype::Int8);
        for row in &rows {
            exact.append_row(0, row, row);
            quant.append_row(0, row, row);
        }
        assert_eq!(exact.rows(0), 12);
        assert!(exact.quant_bounds(0).is_none());
        for (r, row) in rows.iter().enumerate() {
            assert_eq!(exact.k(0).row(r), &row[..]);
        }
        let b = quant.quant_bounds(0).expect("int8 bounds");
        assert!(b.k_scale_max > 0.0);
        for (r, row) in rows.iter().enumerate() {
            for (x, x_hat) in row.iter().zip(quant.k(0).row(r)) {
                assert!((x - x_hat).abs() <= 0.5 * b.k_scale_max);
            }
        }
        // Physical accounting: int8 pays (d + 4) per row per matrix.
        assert_eq!(exact.payload_bytes(), 12 * 2 * 4 * d);
        assert_eq!(quant.payload_bytes(), 12 * 2 * (d + 4));
        assert_eq!(quant.row_bytes(), d + 4);
    }

    #[test]
    fn int8_snapshot_load_is_byte_exact() {
        let mut rng = Rng::new(2);
        let d = 8;
        let mut src = BlockStore::new(3, d, KvDtype::Int8);
        for _ in 0..10 {
            for s in 0..3 {
                let kr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
                let vr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
                src.append_row(s, &kr, &vr);
            }
        }
        let snap = src.snapshot_rows(2, 6);
        assert_eq!(snap.tokens, 4);
        assert_eq!(snap.dtype, KvDtype::Int8);
        let mut dst = BlockStore::new(3, d, KvDtype::Int8);
        dst.load_rows(&snap);
        assert_eq!(dst.rows(0), 4);
        for s in 0..3 {
            for r in 0..4 {
                // Mirror values bitwise equal to the donor's — the
                // payload round-tripped byte-for-byte.
                assert_eq!(dst.k(s).row(r), src.k(s).row(2 + r));
                assert_eq!(dst.v(s).row(r), src.v(s).row(2 + r));
            }
        }
    }

    #[test]
    fn int4_store_is_within_bounds_and_pays_packed_bytes() {
        let mut rng = Rng::new(4);
        let d = 16;
        let rows: Vec<Vec<f32>> = (0..12).map(|_| {
            (0..d).map(|_| rng.normal32(0.0, 1.5)).collect()
        }).collect();
        let mut quant = BlockStore::new(2, d, KvDtype::Int4);
        for row in &rows {
            quant.append_row(0, row, row);
        }
        let b = quant.quant_bounds(0).expect("int4 bounds");
        assert!(b.k_scale_max > 0.0);
        for (r, row) in rows.iter().enumerate() {
            for (x, x_hat) in row.iter().zip(quant.k(0).row(r)) {
                assert!((x - x_hat).abs() <= 0.5 * b.k_scale_max);
            }
        }
        // Physical accounting: int4 pays (⌈d/2⌉ + 4) per row per matrix.
        assert_eq!(quant.payload_bytes(), 12 * 2 * (d / 2 + 4));
        assert_eq!(quant.row_bytes(), d / 2 + 4);
    }

    #[test]
    fn int4_snapshot_load_is_byte_exact() {
        let mut rng = Rng::new(5);
        let d = 9; // odd head dim: padded last nibble in every row
        let mut src = BlockStore::new(3, d, KvDtype::Int4);
        for _ in 0..10 {
            for s in 0..3 {
                let kr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
                let vr: Vec<f32> = (0..d).map(|_| rng.normal32(0.0, 1.0)).collect();
                src.append_row(s, &kr, &vr);
            }
        }
        let snap = src.snapshot_rows(2, 6);
        assert_eq!(snap.tokens, 4);
        assert_eq!(snap.dtype, KvDtype::Int4);
        assert_eq!(snap.payload_bytes(), 3 * 2 * 4 * (d.div_ceil(2) + 4));
        let mut dst = BlockStore::new(3, d, KvDtype::Int4);
        dst.load_rows(&snap);
        assert_eq!(dst.rows(0), 4);
        for s in 0..3 {
            for r in 0..4 {
                assert_eq!(dst.k(s).row(r), src.k(s).row(2 + r));
                assert_eq!(dst.v(s).row(r), src.v(s).row(2 + r));
            }
        }
    }

    #[test]
    #[should_panic(expected = "KV dtype mismatch")]
    fn load_rejects_dtype_mismatch() {
        let mut f32_store = BlockStore::new(1, 4, KvDtype::F32);
        f32_store.append_row(0, &[1.0; 4], &[1.0; 4]);
        let snap = f32_store.snapshot_rows(0, 1);
        let mut int8_store = BlockStore::new(1, 4, KvDtype::Int8);
        int8_store.load_rows(&snap);
    }

    #[test]
    fn clear_resets_bounds() {
        let mut st = BlockStore::new(1, 4, KvDtype::Int8);
        st.append_row(0, &[8.0; 4], &[2.0; 4]);
        assert!(st.quant_bounds(0).unwrap().k_scale_max > 0.0);
        st.clear();
        assert_eq!(st.rows(0), 0);
        assert!(st.quant_bounds(0).unwrap().is_zero());
        assert_eq!(st.payload_bytes(), 0);
    }
}
