//! [`SpillStore`]: the file-backed cold tier behind `--kv-spill`.
//!
//! A byte-addressed, block-granular region file that [`BlockSnapshot`]s
//! spill to and swap back from. The session uses it to turn preemption
//! into swap-out/swap-in: the LIFO victim's blocks — physical payload
//! bytes, quantized blocks byte-for-byte — move to disk, and its
//! re-admission gates on reading them back instead of replaying prefill
//! from scratch. Because the snapshot round-trip is byte-exact (the same
//! guarantee prefix forks rely on, see [`crate::kvcache::store`]), a
//! swapped-in request's dequantized mirror is bit-identical to what it
//! held before preemption, so token streams stay byte-identical with
//! spill forced on vs off.
//!
//! Layout: the region file is divided into fixed-size slots of
//! `HEADER_BYTES + slots · 2 · block_tokens · 4 · d` bytes — the worst
//! case (f32) payload of one block, so mixed-dtype sessions share one
//! geometry (an int8 block's `d + 4` bytes/row always fits inside the
//! f32 slot for `d ≥ 2`). Each record is a 9-byte header (dtype tag,
//! token count, slot count) followed by the per-(layer, kv-head)-slot
//! payload in physical layout: f32 rows verbatim, int8 as codes then
//! scales, K before V. Records are written with `write_all_at` and read
//! with `read_exact_at` ([`std::os::unix::fs::FileExt`]) — no mmap, no
//! seeks shared between blocks, so the store needs no interior locking
//! beyond the session's serial tick phases.
//!
//! Slot ids are recycled LIFO through a free list, and a `live` bitmap
//! catches double-free / use-after-free at the API boundary. Traffic is
//! charged to [`SpillStats`] in **physical payload bytes** (what a real
//! NVMe tier would move), mirroring how [`crate::kvcache::TierStats`]
//! charges the host tier.
//!
//! The same store also persists the [`crate::kvcache::PrefixCache`]
//! radix: [`SpillStore::persist_prefix`] serializes the chain (keys,
//! parent links, dtype tags, snapshots) into a sibling `<path>.prefix`
//! file, and [`SpillStore::load_prefix`] lets a fresh `Session`
//! warm-start from it — the prefix cache survives process restarts.
//! The prefix file is intentionally *not* truncated by
//! [`SpillStore::open`]; only the block region is scratch space.

use std::fs::File;
use std::io;
use std::os::unix::fs::FileExt;
use std::path::{Path, PathBuf};

use super::prefix::ChainKey;
use super::store::{BlockSnapshot, KvDtype, SlotRows};

/// Record header: dtype tag (u8) + tokens (u32 LE) + slot count (u32 LE).
const HEADER_BYTES: usize = 9;
/// Dtype tags, matching the prefix radix's chain-key tag bytes.
const TAG_F32: u8 = 0xF3;
const TAG_INT8: u8 = 0x18;
const TAG_INT4: u8 = 0x14;
/// Prefix-file framing: magic, format version.
const PREFIX_MAGIC: u32 = 0x7650_7266; // "vPrf"
const PREFIX_VERSION: u32 = 1;

/// Handle to one spilled block in the region file. Obtained from
/// [`SpillStore::write_block`]; redeemed by [`SpillStore::read_block`]
/// or released by [`SpillStore::free`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpillSlot(u32);

/// Cold-tier traffic counters, charged in physical payload bytes.
///
/// `swap_in_bytes` / `swap_in_ops` count every restore regardless of
/// path, so the conservation invariant `swap_in == spill_out` holds
/// with prefetch on or off; `blocking_swap_in_ops` isolates the
/// synchronous `read_exact_at` calls issued on the scheduler thread —
/// the stalls the prefetch pipeline exists to eliminate.
#[derive(Clone, Debug, Default)]
pub struct SpillStats {
    /// Payload bytes written to the cold tier (swap-out).
    pub spill_out_bytes: usize,
    /// Block-write operations.
    pub spill_out_ops: usize,
    /// Payload bytes read back from the cold tier (swap-in).
    pub swap_in_bytes: usize,
    /// Block-read operations (blocking and prefetched alike).
    pub swap_in_ops: usize,
    /// Swap-in reads issued synchronously on the scheduler thread
    /// ([`SpillStore::read_block`]); ~0 when prefetch keeps up.
    pub blocking_swap_in_ops: usize,
    /// Blocks handed to the prefetch pipeline (queue-front kicks).
    pub prefetch_issued_ops: usize,
    /// Prefetched blocks consumed at resume instead of a blocking read.
    pub prefetch_hit_ops: usize,
    /// Prefetched blocks discarded (cancel-while-prefetching, or the
    /// staged read failed and resume fell back to the blocking path).
    pub prefetch_wasted_ops: usize,
    /// Payload bytes restored through the staged prefetch path.
    pub prefetch_bytes: usize,
}

impl SpillStats {
    /// Fraction of prefetch-issued blocks that were consumed at resume
    /// (0 when the pipeline never ran).
    pub fn prefetch_hit_rate(&self) -> f64 {
        if self.prefetch_issued_ops == 0 {
            0.0
        } else {
            self.prefetch_hit_ops as f64 / self.prefetch_issued_ops as f64
        }
    }
}

/// The file-backed cold tier. See the module docs for the layout.
pub struct SpillStore {
    file: File,
    prefix_path: PathBuf,
    block_tokens: usize,
    /// (layer, kv-head) slots per block — the `BlockStore` slot count.
    slots: usize,
    d: usize,
    /// Fixed region-file stride per block (header + worst-case payload).
    slot_bytes: usize,
    /// Recycled slot ids, LIFO.
    free: Vec<u32>,
    /// Liveness per allocated slot id (double-free / stale-read guard).
    live: Vec<bool>,
    live_count: usize,
    stats: SpillStats,
}

fn bad(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Physical payload bytes of one record at `dtype` (excludes the header).
fn payload_len(dtype: KvDtype, tokens: usize, slots: usize, d: usize) -> usize {
    slots * 2 * tokens * dtype.row_bytes(d)
}

fn encode_header(snap: &BlockSnapshot, buf: &mut Vec<u8>) {
    buf.push(match snap.dtype {
        KvDtype::F32 => TAG_F32,
        KvDtype::Int8 => TAG_INT8,
        KvDtype::Int4 => TAG_INT4,
    });
    buf.extend_from_slice(&(snap.tokens as u32).to_le_bytes());
    buf.extend_from_slice(&(snap.slots.len() as u32).to_le_bytes());
}

fn encode_payload(snap: &BlockSnapshot, buf: &mut Vec<u8>) {
    let f32s = |xs: &[f32], buf: &mut Vec<u8>| {
        for &x in xs {
            buf.extend_from_slice(&x.to_le_bytes());
        }
    };
    let i8s = |xs: &[i8], buf: &mut Vec<u8>| buf.extend(xs.iter().map(|&c| c as u8));
    for rows in &snap.slots {
        match rows {
            SlotRows::F32 { k, v } => {
                f32s(k, buf);
                f32s(v, buf);
            }
            SlotRows::Int8 { k, k_scales, v, v_scales } => {
                i8s(k, buf);
                f32s(k_scales, buf);
                i8s(v, buf);
                f32s(v_scales, buf);
            }
            SlotRows::Int4 { k, k_scales, v, v_scales } => {
                buf.extend_from_slice(k);
                f32s(k_scales, buf);
                buf.extend_from_slice(v);
                f32s(v_scales, buf);
            }
        }
    }
}

/// Little-endian cursor over a byte slice; every read is bounds-checked
/// so a truncated or corrupt record surfaces as `InvalidData`, never a
/// panic.
struct Rd<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Rd<'a> {
    fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, p: 0 }
    }

    fn take(&mut self, n: usize) -> io::Result<&'a [u8]> {
        let end = self.p.checked_add(n).filter(|&e| e <= self.b.len());
        let Some(end) = end else { return Err(bad("truncated spill record")) };
        let s = &self.b[self.p..end];
        self.p = end;
        Ok(s)
    }

    fn u8(&mut self) -> io::Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> io::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> io::Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> io::Result<Vec<f32>> {
        let raw = self.take(n * 4)?;
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn i8s(&mut self, n: usize) -> io::Result<Vec<i8>> {
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }

    fn bytes(&mut self, n: usize) -> io::Result<Vec<u8>> {
        Ok(self.take(n)?.to_vec())
    }

    fn done(&self) -> bool {
        self.p == self.b.len()
    }
}

fn decode_dtype(tag: u8) -> io::Result<KvDtype> {
    match tag {
        TAG_F32 => Ok(KvDtype::F32),
        TAG_INT8 => Ok(KvDtype::Int8),
        TAG_INT4 => Ok(KvDtype::Int4),
        t => Err(bad(format!("unknown KV dtype tag 0x{t:02x} in spill record"))),
    }
}

fn decode_payload(
    rd: &mut Rd<'_>,
    dtype: KvDtype,
    tokens: usize,
    slots: usize,
    d: usize,
) -> io::Result<BlockSnapshot> {
    let mut out = Vec::with_capacity(slots);
    for _ in 0..slots {
        out.push(match dtype {
            KvDtype::F32 => {
                SlotRows::F32 { k: rd.f32s(tokens * d)?, v: rd.f32s(tokens * d)? }
            }
            KvDtype::Int8 => SlotRows::Int8 {
                k: rd.i8s(tokens * d)?,
                k_scales: rd.f32s(tokens)?,
                v: rd.i8s(tokens * d)?,
                v_scales: rd.f32s(tokens)?,
            },
            KvDtype::Int4 => SlotRows::Int4 {
                k: rd.bytes(tokens * d.div_ceil(2))?,
                k_scales: rd.f32s(tokens)?,
                v: rd.bytes(tokens * d.div_ceil(2))?,
                v_scales: rd.f32s(tokens)?,
            },
        });
    }
    Ok(BlockSnapshot { dtype, tokens, slots: out })
}

/// Read and decode one record from the region file with positional
/// reads only — shared by the scheduler-thread [`SpillStore::read_block`]
/// and the IO-thread [`SlotReader::read`], so the two paths are
/// byte-identical by construction.
fn read_slot_record(
    file: &File,
    slot: SpillSlot,
    slot_bytes: usize,
    block_tokens: usize,
    slots: usize,
    d: usize,
) -> io::Result<BlockSnapshot> {
    let base = slot.0 as u64 * slot_bytes as u64;
    let mut header = [0u8; HEADER_BYTES];
    file.read_exact_at(&mut header, base)?;
    let mut rd = Rd::new(&header);
    let dtype = decode_dtype(rd.u8()?)?;
    let tokens = rd.u32()? as usize;
    let rec_slots = rd.u32()? as usize;
    if rec_slots != slots || tokens > block_tokens {
        return Err(bad(format!(
            "spill record geometry mismatch: {rec_slots} slots x {tokens} tokens \
             vs store {slots} x {block_tokens}"
        )));
    }
    let mut payload = vec![0u8; payload_len(dtype, tokens, rec_slots, d)];
    file.read_exact_at(&mut payload, base + HEADER_BYTES as u64)?;
    let mut rd = Rd::new(&payload);
    let snap = decode_payload(&mut rd, dtype, tokens, rec_slots, d)?;
    debug_assert!(rd.done());
    Ok(snap)
}

/// Read-only handle to the region file for the prefetch IO thread
/// ([`SpillStore::reader`]). Holds an independent `File` (dup'd fd), so
/// its positional reads never interfere with the store's writes; it
/// charges no stats and checks no liveness — the [`SpillStore`] remains
/// the single owner of slot lifecycle, and the prefetch engine discards
/// any read whose job was invalidated before consumption (so a read
/// racing a slot recycle can surface garbage or an error, but never
/// reach a cache).
pub struct SlotReader {
    file: File,
    block_tokens: usize,
    slots: usize,
    d: usize,
    slot_bytes: usize,
}

impl SlotReader {
    /// Decode the record at `slot`, byte-identical to what
    /// [`SpillStore::read_block`] would return for a live slot.
    pub fn read(&self, slot: SpillSlot) -> io::Result<BlockSnapshot> {
        read_slot_record(&self.file, slot, self.slot_bytes, self.block_tokens, self.slots, self.d)
    }
}

impl SpillStore {
    /// Open (create/truncate) the block region file at `path` for the
    /// given cache geometry. The sibling `<path>.prefix` file — the
    /// persistent prefix radix — is left untouched so it can survive
    /// across store openings (that is the whole point of persisting it).
    pub fn open(
        path: &Path,
        block_tokens: usize,
        slots: usize,
        d: usize,
    ) -> io::Result<SpillStore> {
        assert!(block_tokens > 0 && slots > 0 && d > 0, "degenerate spill geometry");
        let file = File::options()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        let mut os = path.as_os_str().to_os_string();
        os.push(".prefix");
        Ok(SpillStore {
            file,
            prefix_path: PathBuf::from(os),
            block_tokens,
            slots,
            d,
            // Worst-case (f32) payload: int8's d + 4 and int4's
            // ⌈d/2⌉ + 4 B/row both fit for d ≥ 2.
            slot_bytes: HEADER_BYTES + payload_len(KvDtype::F32, block_tokens, slots, d),
            free: Vec::new(),
            live: Vec::new(),
            live_count: 0,
            stats: SpillStats::default(),
        })
    }

    /// Spill one block snapshot to disk, returning its slot handle.
    /// Charges [`SpillStats::spill_out_bytes`] with the snapshot's
    /// physical payload bytes.
    pub fn write_block(&mut self, snap: &BlockSnapshot) -> io::Result<SpillSlot> {
        assert_eq!(snap.slots.len(), self.slots, "slot-count mismatch on spill");
        assert!(snap.tokens <= self.block_tokens, "oversized block on spill");
        let mut buf = Vec::with_capacity(HEADER_BYTES + snap.payload_bytes());
        encode_header(snap, &mut buf);
        encode_payload(snap, &mut buf);
        let id = match self.free.pop() {
            Some(id) => id,
            None => {
                self.live.push(false);
                (self.live.len() - 1) as u32
            }
        };
        self.file.write_all_at(&buf, id as u64 * self.slot_bytes as u64)?;
        self.live[id as usize] = true;
        self.live_count += 1;
        self.stats.spill_out_bytes += snap.payload_bytes();
        self.stats.spill_out_ops += 1;
        Ok(SpillSlot(id))
    }

    /// Swap one block back in, byte-for-byte, synchronously on the
    /// calling thread. The slot stays live (and re-readable) until
    /// [`SpillStore::free`] releases it, so a failed re-admission can
    /// retry. Charges [`SpillStats::swap_in_bytes`] and counts the call
    /// as a blocking read ([`SpillStats::blocking_swap_in_ops`]).
    pub fn read_block(&mut self, slot: SpillSlot) -> io::Result<BlockSnapshot> {
        let id = slot.0 as usize;
        assert!(self.live.get(id).copied().unwrap_or(false), "read of a dead spill slot");
        let snap =
            read_slot_record(&self.file, slot, self.slot_bytes, self.block_tokens, self.slots, self.d)?;
        self.stats.swap_in_bytes += snap.payload_bytes();
        self.stats.swap_in_ops += 1;
        self.stats.blocking_swap_in_ops += 1;
        Ok(snap)
    }

    /// Independent read handle over the region file for the prefetch IO
    /// thread (dup'd fd via `try_clone`).
    pub fn reader(&self) -> io::Result<SlotReader> {
        Ok(SlotReader {
            file: self.file.try_clone()?,
            block_tokens: self.block_tokens,
            slots: self.slots,
            d: self.d,
            slot_bytes: self.slot_bytes,
        })
    }

    /// Charge one staged (prefetched) block restore: the payload moved
    /// through the IO thread, so swap-in traffic is conserved
    /// (`swap_in == spill_out` still holds) while
    /// [`SpillStats::blocking_swap_in_ops`] stays untouched.
    pub fn note_prefetched_swap_in(&mut self, bytes: usize) {
        self.stats.swap_in_bytes += bytes;
        self.stats.swap_in_ops += 1;
        self.stats.prefetch_hit_ops += 1;
        self.stats.prefetch_bytes += bytes;
    }

    /// Charge `blocks` handed to the prefetch pipeline at a queue-front
    /// kick.
    pub fn note_prefetch_issued(&mut self, blocks: usize) {
        self.stats.prefetch_issued_ops += blocks;
    }

    /// Charge `blocks` whose staged reads will never be consumed
    /// (cancelled request, or a failed staged read falling back to the
    /// blocking path).
    pub fn note_prefetch_wasted(&mut self, blocks: usize) {
        self.stats.prefetch_wasted_ops += blocks;
    }

    /// Release a slot back to the free list. Panics on double-free.
    pub fn free(&mut self, slot: SpillSlot) {
        let id = slot.0 as usize;
        assert!(self.live.get(id).copied().unwrap_or(false), "double free of a spill slot");
        self.live[id] = false;
        self.live_count -= 1;
        self.free.push(slot.0);
    }

    /// Blocks currently resident in the cold tier. Zero after every
    /// suspended request has been resumed or cancelled — the leak check
    /// mirrored by the pool's quiescence invariant.
    pub fn live_blocks(&self) -> usize {
        self.live_count
    }

    pub fn stats(&self) -> &SpillStats {
        &self.stats
    }

    /// True when no cold-tier slot is live — the spill half of the
    /// session's end-of-run quiescence check (`Session::kv_quiescent`).
    pub fn is_quiescent(&self) -> bool {
        self.live_count == 0
    }

    /// Serialize the prefix radix (chain keys, parent links, snapshots)
    /// into the sibling `<path>.prefix` file, atomically replacing any
    /// previous contents. `entries` must list parents before children
    /// (see `PrefixCache::export_chains`) so [`SpillStore::load_prefix`]
    /// can re-link in one pass.
    pub fn persist_prefix(
        &self,
        entries: &[(ChainKey, Option<ChainKey>, &BlockSnapshot)],
    ) -> io::Result<()> {
        let mut buf = Vec::new();
        buf.extend_from_slice(&PREFIX_MAGIC.to_le_bytes());
        buf.extend_from_slice(&PREFIX_VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.block_tokens as u32).to_le_bytes());
        buf.extend_from_slice(&(self.slots as u32).to_le_bytes());
        buf.extend_from_slice(&(self.d as u32).to_le_bytes());
        buf.extend_from_slice(&(entries.len() as u64).to_le_bytes());
        for (key, parent, snap) in entries {
            buf.extend_from_slice(&key.to_le_bytes());
            match parent {
                None => {
                    buf.push(0);
                    buf.extend_from_slice(&0u64.to_le_bytes());
                }
                Some(p) => {
                    buf.push(1);
                    buf.extend_from_slice(&p.to_le_bytes());
                }
            }
            encode_header(snap, &mut buf);
            encode_payload(snap, &mut buf);
        }
        std::fs::write(&self.prefix_path, buf)
    }

    /// Load a previously persisted prefix radix, if one exists for this
    /// exact cache geometry. Returns `Ok(None)` when the file is absent
    /// or was written for a different geometry (a different model /
    /// block size — warm-starting from it would be wrong, not just
    /// useless); corrupt framing is an error.
    pub fn load_prefix(
        &self,
    ) -> io::Result<Option<Vec<(ChainKey, Option<ChainKey>, BlockSnapshot)>>> {
        let bytes = match std::fs::read(&self.prefix_path) {
            Ok(b) => b,
            Err(e) if e.kind() == io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(e),
        };
        let mut rd = Rd::new(&bytes);
        if rd.u32()? != PREFIX_MAGIC || rd.u32()? != PREFIX_VERSION {
            return Ok(None);
        }
        let (bt, slots, d) = (rd.u32()? as usize, rd.u32()? as usize, rd.u32()? as usize);
        if bt != self.block_tokens || slots != self.slots || d != self.d {
            return Ok(None);
        }
        let n = rd.u64()? as usize;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let key = rd.u64()?;
            let has_parent = rd.u8()?;
            let parent_raw = rd.u64()?;
            let parent = match has_parent {
                0 => None,
                1 => Some(parent_raw),
                t => return Err(bad(format!("bad parent tag {t} in prefix file"))),
            };
            let dtype = decode_dtype(rd.u8()?)?;
            let tokens = rd.u32()? as usize;
            let rec_slots = rd.u32()? as usize;
            if rec_slots != slots || tokens > bt {
                return Err(bad("prefix entry geometry mismatch"));
            }
            let snap = decode_payload(&mut rd, dtype, tokens, slots, d)?;
            out.push((key, parent, snap));
        }
        if !rd.done() {
            return Err(bad("trailing bytes in prefix file"));
        }
        Ok(Some(out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::store::BlockStore;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("vattn_spill_{}_{name}", std::process::id()))
    }

    /// Deterministic filled store: row r of slot s is a ramp keyed by
    /// (s, r, column), distinct across all of them.
    fn filled(slots: usize, d: usize, rows: usize, dtype: KvDtype) -> BlockStore {
        let mut st = BlockStore::new(slots, d, dtype);
        for r in 0..rows {
            for s in 0..slots {
                let kr: Vec<f32> =
                    (0..d).map(|c| (s * 1000 + r * 10 + c) as f32 * 0.01 - 1.5).collect();
                let vr: Vec<f32> = (0..d).map(|c| (s * 777 + r * 31 + c) as f32 * -0.02).collect();
                st.append_row(s, &kr, &vr);
            }
        }
        st
    }

    fn assert_snap_eq(a: &BlockSnapshot, b: &BlockSnapshot) {
        assert_eq!(a.dtype, b.dtype);
        assert_eq!(a.tokens, b.tokens);
        assert_eq!(a.slots.len(), b.slots.len());
        for (x, y) in a.slots.iter().zip(&b.slots) {
            match (x, y) {
                (SlotRows::F32 { k: ka, v: va }, SlotRows::F32 { k: kb, v: vb }) => {
                    // Bitwise, not approximate: the tier must be exact.
                    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(bits(ka), bits(kb));
                    assert_eq!(bits(va), bits(vb));
                }
                (
                    SlotRows::Int8 { k: ka, k_scales: ksa, v: va, v_scales: vsa },
                    SlotRows::Int8 { k: kb, k_scales: ksb, v: vb, v_scales: vsb },
                ) => {
                    assert_eq!(ka, kb);
                    assert_eq!(va, vb);
                    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(bits(ksa), bits(ksb));
                    assert_eq!(bits(vsa), bits(vsb));
                }
                (
                    SlotRows::Int4 { k: ka, k_scales: ksa, v: va, v_scales: vsa },
                    SlotRows::Int4 { k: kb, k_scales: ksb, v: vb, v_scales: vsb },
                ) => {
                    assert_eq!(ka, kb);
                    assert_eq!(va, vb);
                    let bits = |xs: &[f32]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                    assert_eq!(bits(ksa), bits(ksb));
                    assert_eq!(bits(vsa), bits(vsb));
                }
                _ => panic!("slot layout mismatch"),
            }
        }
    }

    #[test]
    fn f32_block_round_trips_byte_exact() {
        let path = tmp("f32_rt");
        let (slots, d, bt) = (4, 8, 16);
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, bt, KvDtype::F32);
        let snap = src.snapshot_rows(0, bt);
        let slot = store.write_block(&snap).unwrap();
        let back = store.read_block(slot).unwrap();
        assert_snap_eq(&snap, &back);
        assert_eq!(store.stats().spill_out_bytes, snap.payload_bytes());
        assert_eq!(store.stats().swap_in_bytes, snap.payload_bytes());
        assert_eq!(store.stats().spill_out_ops, 1);
        assert_eq!(store.stats().swap_in_ops, 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn int8_block_round_trips_byte_exact_including_partial_tail() {
        let path = tmp("int8_rt");
        let (slots, d, bt) = (2, 16, 8);
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, 5, KvDtype::Int8); // partial block: 5 < 8
        let snap = src.snapshot_rows(0, 5);
        assert_eq!(snap.payload_bytes(), slots * 2 * 5 * (d + 4));
        let slot = store.write_block(&snap).unwrap();
        let back = store.read_block(slot).unwrap();
        assert_snap_eq(&snap, &back);
        // Loading the round-tripped snapshot reproduces the donor's
        // dequantized mirror bit-for-bit.
        let mut dst = BlockStore::new(slots, d, KvDtype::Int8);
        dst.load_rows(&back);
        for s in 0..slots {
            for r in 0..5 {
                assert_eq!(dst.k(s).row(r), src.k(s).row(r));
                assert_eq!(dst.v(s).row(r), src.v(s).row(r));
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn int4_block_round_trips_byte_exact_at_odd_head_dim() {
        let path = tmp("int4_rt");
        let (slots, d, bt) = (2, 9, 8); // odd d: padded last nibble per row
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, 5, KvDtype::Int4); // partial block: 5 < 8
        let snap = src.snapshot_rows(0, 5);
        assert_eq!(snap.payload_bytes(), slots * 2 * 5 * (d.div_ceil(2) + 4));
        let slot = store.write_block(&snap).unwrap();
        let back = store.read_block(slot).unwrap();
        assert_snap_eq(&snap, &back);
        let mut dst = BlockStore::new(slots, d, KvDtype::Int4);
        dst.load_rows(&back);
        for s in 0..slots {
            for r in 0..5 {
                assert_eq!(dst.k(s).row(r), src.k(s).row(r));
                assert_eq!(dst.v(s).row(r), src.v(s).row(r));
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn slots_recycle_lifo_and_track_liveness() {
        let path = tmp("recycle");
        let (slots, d, bt) = (1, 4, 4);
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, bt, KvDtype::F32);
        let snap = src.snapshot_rows(0, bt);
        let a = store.write_block(&snap).unwrap();
        let b = store.write_block(&snap).unwrap();
        assert_ne!(a, b);
        assert_eq!(store.live_blocks(), 2);
        store.free(a);
        assert_eq!(store.live_blocks(), 1);
        let c = store.write_block(&snap).unwrap();
        assert_eq!(c, a, "freed slot is recycled");
        assert_eq!(store.live_blocks(), 2);
        store.free(b);
        store.free(c);
        assert_eq!(store.live_blocks(), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_is_caught() {
        let path = tmp("dfree");
        let mut store = SpillStore::open(&path, 4, 1, 4).unwrap();
        let src = filled(1, 4, 4, KvDtype::F32);
        let slot = store.write_block(&src.snapshot_rows(0, 4)).unwrap();
        store.free(slot);
        store.free(slot);
    }

    #[test]
    fn slot_reader_matches_blocking_read_and_charges_nothing() {
        let path = tmp("reader_eq");
        let (slots, d, bt) = (2, 8, 8);
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, 5, KvDtype::Int8);
        let snap = src.snapshot_rows(0, 5);
        let slot = store.write_block(&snap).unwrap();
        let reader = store.reader().unwrap();
        let staged = reader.read(slot).unwrap();
        assert_snap_eq(&snap, &staged);
        // The reader is stat-free: swap-in traffic is only charged when
        // the session actually consumes a restore.
        assert_eq!(store.stats().swap_in_ops, 0);
        assert_eq!(store.stats().blocking_swap_in_ops, 0);
        let blocking = store.read_block(slot).unwrap();
        assert_snap_eq(&staged, &blocking);
        assert_eq!(store.stats().swap_in_ops, 1);
        assert_eq!(store.stats().blocking_swap_in_ops, 1);
        // A staged consume conserves swap-in traffic without counting
        // as a blocking read.
        store.note_prefetched_swap_in(staged.payload_bytes());
        assert_eq!(store.stats().swap_in_ops, 2);
        assert_eq!(store.stats().blocking_swap_in_ops, 1);
        assert_eq!(store.stats().prefetch_hit_ops, 1);
        assert_eq!(store.stats().prefetch_bytes, staged.payload_bytes());
        let _ = std::fs::remove_file(&path);
    }

    /// Satellite audit: a restart with a *smaller* geometry must never
    /// import a persisted prefix written for the larger one — the
    /// header check covers every axis (block_tokens, slots, d), in both
    /// directions.
    #[test]
    fn prefix_sidecar_is_rejected_on_any_smaller_reopen_geometry() {
        let path = tmp("prefix_shrink");
        let prefix_path = {
            let mut os = path.as_os_str().to_os_string();
            os.push(".prefix");
            PathBuf::from(os)
        };
        let _ = std::fs::remove_file(&prefix_path);
        let (slots, d, bt) = (3, 6, 8);
        let store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, bt, KvDtype::F32);
        store.persist_prefix(&[(7, None, &src.snapshot_rows(0, bt))]).unwrap();
        drop(store);
        for (bt2, slots2, d2) in
            [(bt / 2, slots, d), (bt, slots - 1, d), (bt, slots, d - 1), (bt - 1, slots - 1, d)]
        {
            let shrunk = SpillStore::open(&path, bt2, slots2, d2).unwrap();
            assert!(
                shrunk.load_prefix().unwrap().is_none(),
                "smaller geometry ({bt2}, {slots2}, {d2}) must cold-start, not import"
            );
        }
        // The matching geometry still imports after all those opens
        // (each of which truncated the region file).
        let same = SpillStore::open(&path, bt, slots, d).unwrap();
        assert_eq!(same.load_prefix().unwrap().expect("matching geometry imports").len(), 1);
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&prefix_path);
    }

    /// Satellite audit: sidecar entries embed their snapshots inline and
    /// never reference region-file offsets, so a truncated (or scribbled)
    /// region can never corrupt a warm start.
    #[test]
    fn prefix_sidecar_is_self_contained_from_the_region_file() {
        let path = tmp("prefix_selfcont");
        let prefix_path = {
            let mut os = path.as_os_str().to_os_string();
            os.push(".prefix");
            PathBuf::from(os)
        };
        let _ = std::fs::remove_file(&prefix_path);
        let (slots, d, bt) = (2, 4, 4);
        let mut store = SpillStore::open(&path, bt, slots, d).unwrap();
        let src = filled(slots, d, bt, KvDtype::Int4);
        let snap = src.snapshot_rows(0, bt);
        // Populate the region so there is something to destroy.
        let _slot = store.write_block(&snap).unwrap();
        store.persist_prefix(&[(3, None, &snap)]).unwrap();
        drop(store);
        // Scribble over the whole region file out-of-band.
        std::fs::write(&path, b"garbage").unwrap();
        let store2 = SpillStore::open(&path, bt, slots, d).unwrap();
        let loaded = store2.load_prefix().unwrap().expect("sidecar survives region loss");
        assert_eq!(loaded.len(), 1);
        assert_snap_eq(&loaded[0].2, &snap);
        // A truncated *sidecar*, by contrast, is a hard error — never a
        // silent partial import.
        let bytes = std::fs::read(&prefix_path).unwrap();
        std::fs::write(&prefix_path, &bytes[..bytes.len() - 3]).unwrap();
        assert!(store2.load_prefix().is_err(), "truncated sidecar must error");
        let _ = std::fs::remove_file(&path);
        let _ = std::fs::remove_file(&prefix_path);
    }

    #[test]
    fn prefix_radix_persists_across_store_openings() {
        let path = tmp("prefix_rt");
        let prefix_path = {
            let mut os = path.as_os_str().to_os_string();
            os.push(".prefix");
            PathBuf::from(os)
        };
        let _ = std::fs::remove_file(&prefix_path);
        let (slots, d, bt) = (2, 4, 4);
        let store = SpillStore::open(&path, bt, slots, d).unwrap();
        assert!(store.load_prefix().unwrap().is_none(), "no file yet");
        let a = filled(slots, d, bt, KvDtype::F32);
        let b = filled(slots, d, bt, KvDtype::Int8);
        let (sa, sb) = (a.snapshot_rows(0, bt), b.snapshot_rows(0, bt));
        store.persist_prefix(&[(11, None, &sa), (22, Some(11), &sb)]).unwrap();
        drop(store);
        // A fresh opening truncates the block region but keeps the
        // persisted radix readable.
        let store2 = SpillStore::open(&path, bt, slots, d).unwrap();
        let loaded = store2.load_prefix().unwrap().expect("radix survives reopen");
        assert_eq!(loaded.len(), 2);
        assert_eq!((loaded[0].0, loaded[0].1), (11, None));
        assert_eq!((loaded[1].0, loaded[1].1), (22, Some(11)));
        assert_snap_eq(&loaded[0].2, &sa);
        assert_snap_eq(&loaded[1].2, &sb);
        // A store with different geometry refuses the file (None, not
        // a mis-shaped warm start).
        let other = tmp("prefix_rt_other_geom");
        let store3 = SpillStore::open(&other, bt, slots, d + 1).unwrap();
        let mut os = other.as_os_str().to_os_string();
        os.push(".prefix");
        std::fs::copy(&prefix_path, PathBuf::from(os.clone())).unwrap();
        assert!(store3.load_prefix().unwrap().is_none(), "geometry mismatch rejected");
        for p in [&path, &prefix_path, &other, &PathBuf::from(os)] {
            let _ = std::fs::remove_file(p);
        }
    }
}
