//! Event-driven serving session: the scheduler core behind the engine.
//!
//! [`Session`] owns the serving state — FIFO waiting queue, active batch,
//! demand-paged [`BlockPool`], optional [`PrefixCache`] — and exposes the
//! streaming interface real serving needs: [`Session::submit`] enqueues a
//! request and returns its [`RequestId`], [`Session::tick`] runs one
//! scheduler round and returns the [`Event`]s it produced (admissions,
//! per-token emissions, completions, preemptions, rejections — each
//! stamped with the session clock), and [`Session::cancel`] tears a
//! request down mid-flight, returning every leased KV block to the pool
//! immediately.
//!
//! **Demand paging.** Admission reserves a request's *prompt* blocks
//! only (plus a configurable headroom left free in the pool); generation
//! blocks are allocated one at a time, in the serial phase of the tick,
//! as decoding crosses block boundaries — so batch density is set by
//! what requests actually hold, not by worst-case leases. When the pool
//! runs dry the session first reclaims idle prefix-cache blocks, then
//! deterministically preempts the most-recently-admitted active request:
//! its blocks are freed, an [`Event::Preempted`] is emitted, and it is
//! requeued at the *front* of the waiting queue. Because its RNG stream
//! is a pure function of (engine seed, seed tag) and its policies are
//! reset, the re-run replays a byte-identical token stream — already
//! emitted `Token` events are suppressed, so consumers observe one
//! gapless stream per request regardless of preemption.
//!
//! **Prefix sharing.** With `EngineConfig::prefix_cache` enabled, full
//! prompt-token blocks are published to a hash-keyed radix when a
//! request finishes prefill; later requests with the same prompt prefix
//! *fork* off the cached blocks — a refcount bump in the pool plus a
//! host memcpy of the cached K/V rows — and prefill only their suffix. A
//! write into a block that is still shared promotes it to a private copy
//! first ([`BlockPool::cow`]); with full-block sharing the tail is never
//! shared, so the promotion is a guarded no-op in steady state.
//!
//! One `tick` is exactly one round of the engine's scheduling model —
//! block accounting + admission, parallel step execution across the
//! worker pool, then a deterministic merge in submission order — so the
//! per-request token streams observed through `Event::Token` are
//! byte-identical at any worker count, and `Engine::serve` /
//! `Engine::serve_open_loop` are nothing but drive-the-session loops
//! over this type.
//!
//! Heterogeneity lives on the request, not the engine: [`GenOptions`]
//! carries a per-request sampler, generation length, RNG seed, and
//! attention contract ([`AttentionOpt`]) — including a per-request
//! (ε, δ) guarantee for verified sparse attention, which is the paper's
//! deployment story: users pick their own accuracy contract at serving
//! time.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use super::engine::{AttentionMode, Backend, EngineConfig};
use super::RequestResult;
use crate::attention::Selection;
use crate::kvcache::{
    BlockId, BlockPool, CowOutcome, KvCache, KvDtype, PageError, PrefetchEngine, PrefixCache,
    SpillSlot, SpillStore, TierStats,
};
use crate::model::{ModelConfig, Sampler, StepOut};
use crate::policies::{
    IndexPolicy, PolicyCtx, ReuseConfig, ReuseStats, TemporalReusePolicy, VAttentionConfig,
    VAttentionPolicy,
};
use crate::tensor::quant::KvQuantBounds;
use crate::tensor::Mat;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

/// Identifier minted by [`Session::submit`]; stable for the lifetime of
/// the session (ids are never reused).
pub type RequestId = u64;

/// Typed errors on the serving path (replacing the stringly `anyhow`
/// errors the batch API used). Converts into `anyhow::Error` via `?`
/// where callers still speak `anyhow`.
#[derive(Debug)]
pub enum EngineError {
    /// The request's worst-case KV footprint can never fit the pool,
    /// even with every other block reclaimed (conservative: shared
    /// prefix blocks are not credited, so admission can never livelock).
    KvCapacityExceeded { needed: usize, available: usize },
    /// A byte-capped pool sizes its blocks by the engine-wide
    /// `EngineConfig::kv_dtype`; a per-request override storing *wider*
    /// rows would silently overrun the operator's byte budget (each
    /// block would physically hold more bytes than the pool charged),
    /// so it is rejected up front. Narrower overrides (int8 rows in an
    /// f32-sized pool) are admitted — they under-fill their blocks,
    /// wasting capacity but never exceeding it — and any override is
    /// fine on an uncapped pool.
    KvDtypeWiderThanPool { requested: KvDtype, pool: KvDtype },
    /// prompt + generation budget exceeds `EngineConfig::max_seq_len`.
    PromptTooLong { len: usize, max: usize },
    /// The id was never submitted, or already finished / cancelled.
    UnknownRequest(RequestId),
    /// Block-pool bookkeeping violation — an engine bug, not user error.
    Page(PageError),
    /// The compute backend failed mid-step.
    Backend(anyhow::Error),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::KvCapacityExceeded { needed, available } => write!(
                f,
                "request needs {needed} KV blocks but pool capacity is {available} blocks; \
                 raise kv_capacity_bytes or shorten the request"
            ),
            EngineError::KvDtypeWiderThanPool { requested, pool } => write!(
                f,
                "request stores {} KV rows but the byte-capped pool sizes blocks for {}; \
                 use the engine-wide kv_dtype or an uncapped pool",
                requested.name(),
                pool.name()
            ),
            EngineError::PromptTooLong { len, max } => write!(
                f,
                "prompt + generation budget is {len} tokens but max_seq_len is {max}"
            ),
            EngineError::UnknownRequest(id) => {
                write!(f, "unknown request {id} (never submitted, finished, or cancelled)")
            }
            EngineError::Page(e) => write!(f, "kv block pool: {e}"),
            EngineError::Backend(e) => write!(f, "backend: {e:#}"),
        }
    }
}

impl std::error::Error for EngineError {}

/// Per-request policy factory: builds one policy per (layer, head) for a
/// request, with access to that request's [`GenOptions`] — this is how a
/// per-request accuracy contract reaches the policy layer.
pub type PolicyFactory =
    Arc<dyn Fn(usize, usize, &GenOptions) -> Box<dyn IndexPolicy> + Send + Sync>;

/// Per-request decode-attention contract.
#[derive(Clone, Default)]
pub enum AttentionOpt {
    /// Use the session's default attention (dense unless overridden via
    /// [`Session::set_default_attention`]).
    #[default]
    Inherit,
    /// Full attention for this request.
    Dense,
    /// vAttention with this request's own config — ε and δ live inside,
    /// so two requests in the same batch can run different guarantees.
    Verified(VAttentionConfig),
    /// vAttention plus cross-step heavy-hitter reuse
    /// ([`TemporalReusePolicy`]): the per-(layer, head) top-k selection
    /// is cached across decode steps and re-scored only when the drift
    /// certificate fails, so token streams stay byte-identical to
    /// [`AttentionOpt::Verified`] while the underlying scorer runs far
    /// less often. Reuse state is reset on preemption replay and is
    /// private per request (prefix-forked requests certify
    /// independently).
    VerifiedReuse(VAttentionConfig, ReuseConfig),
    /// Arbitrary per-request policy factory.
    Custom(PolicyFactory),
}

impl std::fmt::Debug for AttentionOpt {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AttentionOpt::Inherit => write!(f, "Inherit"),
            AttentionOpt::Dense => write!(f, "Dense"),
            AttentionOpt::Verified(cfg) => {
                write!(f, "Verified(eps={}, delta={})", cfg.eps, cfg.delta)
            }
            AttentionOpt::VerifiedReuse(cfg, rcfg) => write!(
                f,
                "VerifiedReuse(eps={}, delta={}, max_age={})",
                cfg.eps, cfg.delta, rcfg.max_age
            ),
            AttentionOpt::Custom(_) => write!(f, "Custom(..)"),
        }
    }
}

/// Per-request generation options. Everything the batch engine used to
/// fix globally — sampler, attention mode, seed — is chosen here, per
/// request; `None` / `Inherit` fall back to the session defaults.
#[derive(Clone, Debug)]
pub struct GenOptions {
    /// Number of tokens to generate.
    pub gen_len: usize,
    /// Sampler override; `None` uses `EngineConfig::sampler`.
    pub sampler: Option<Sampler>,
    /// RNG stream tag; `None` derives the stream from the request id.
    /// The actual stream is forked from the session's seeded root RNG,
    /// so (engine seed, request seed) fully determine the draw sequence.
    pub seed: Option<u64>,
    /// Decode-attention contract for this request.
    pub attention: AttentionOpt,
    /// Physical KV storage dtype override; `None` inherits
    /// `EngineConfig::kv_dtype`. An int8 request's cache quantizes rows
    /// on append, and any verified attention contract it carries absorbs
    /// the dequantization error into its (ε, δ) budget automatically.
    pub kv_dtype: Option<KvDtype>,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions {
            gen_len: 16,
            sampler: None,
            seed: None,
            attention: AttentionOpt::Inherit,
            kv_dtype: None,
        }
    }
}

impl GenOptions {
    pub fn new(gen_len: usize) -> GenOptions {
        GenOptions { gen_len, ..Default::default() }
    }

    pub fn sampler(mut self, sampler: Sampler) -> Self {
        self.sampler = Some(sampler);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn attention(mut self, attention: AttentionOpt) -> Self {
        self.attention = attention;
        self
    }

    /// Store this request's KV rows in `dtype` regardless of the
    /// session default.
    pub fn kv_dtype(mut self, dtype: KvDtype) -> Self {
        self.kv_dtype = Some(dtype);
        self
    }

    /// Force full attention for this request.
    pub fn dense(self) -> Self {
        self.attention(AttentionOpt::Dense)
    }

    /// Verified sparse attention at a per-request (ε, δ) contract over
    /// the paper's natural config.
    pub fn verified(self, eps: f64, delta: f64) -> Self {
        self.attention(AttentionOpt::Verified(
            VAttentionConfig::default().with_guarantee(eps, delta),
        ))
    }

    /// Verified sparse attention with a fully custom config.
    pub fn verified_with(self, cfg: VAttentionConfig) -> Self {
        self.attention(AttentionOpt::Verified(cfg))
    }

    /// Verified sparse attention at a per-request (ε, δ) contract with
    /// cross-step heavy-hitter reuse enabled (default reuse knobs).
    pub fn verified_reuse(self, eps: f64, delta: f64) -> Self {
        self.attention(AttentionOpt::VerifiedReuse(
            VAttentionConfig::default().with_guarantee(eps, delta),
            ReuseConfig::default(),
        ))
    }

    /// Verified sparse attention with reuse, both configs custom.
    pub fn verified_reuse_with(self, cfg: VAttentionConfig, rcfg: ReuseConfig) -> Self {
        self.attention(AttentionOpt::VerifiedReuse(cfg, rcfg))
    }
}

/// A request handed to [`Session::submit`].
#[derive(Clone, Debug)]
pub struct SubmitRequest {
    pub prompt: Vec<u32>,
    /// Seconds from session start at which the request becomes visible
    /// to the scheduler (0 = immediately; used for trace replay).
    pub arrival_s: f64,
    pub opts: GenOptions,
}

impl SubmitRequest {
    pub fn new(prompt: Vec<u32>) -> SubmitRequest {
        SubmitRequest { prompt, arrival_s: 0.0, opts: GenOptions::default() }
    }

    /// Trace-replay arrival time (seconds from session start).
    pub fn arrival(mut self, arrival_s: f64) -> Self {
        self.arrival_s = arrival_s;
        self
    }

    pub fn options(mut self, opts: GenOptions) -> Self {
        self.opts = opts;
        self
    }
}

/// What one scheduler round reported. Every variant carries `t_s`, the
/// session clock (seconds since session creation) at which the event
/// was observed — the raw material for streaming TTFT/TPOT metrics
/// (`metrics::EventLog`).
#[derive(Debug)]
pub enum Event {
    /// The request moved from the waiting queue into the active batch.
    Admitted { id: RequestId, t_s: f64 },
    /// One generated token; `step` counts from 0 per request, so a
    /// request's token stream is the sequence of its `Token` events.
    Token { id: RequestId, token: u32, step: usize, t_s: f64 },
    /// The request completed; carries the same record `Engine::serve`
    /// returns (tokens, wait/TTFT/decode timings, density, KV traffic).
    Finished { id: RequestId, result: RequestResult, t_s: f64 },
    /// Pool exhaustion forced this active request back to the front of
    /// the waiting queue; its KV blocks were freed. It will be
    /// re-admitted and replay deterministically — tokens it already
    /// streamed are *not* re-emitted, so the `Token` stream stays
    /// gapless and byte-identical to an uncontended run.
    Preempted { id: RequestId, t_s: f64 },
    /// The request terminated without a result: it can never be served
    /// under the session's configuration (capacity / length validation),
    /// or the backend failed mid-flight (`EngineError::Backend`). Any
    /// leased KV blocks have already been returned to the pool.
    Rejected { id: RequestId, reason: EngineError, t_s: f64 },
}

/// Paging and scheduling counters for one session ([`Session::stats`]).
/// `bench_engine` writes these into `BENCH_engine.json` and the `serve`
/// CLI prints them after a run.
#[derive(Clone, Debug, Default)]
pub struct SessionStats {
    /// Active requests forced back to the queue by pool exhaustion.
    pub preemptions: u64,
    /// Prompt blocks served from the prefix cache (fork, not prefill).
    pub prefix_hit_blocks: u64,
    /// Prompt blocks presented to the prefix cache across all lookups.
    pub prefix_lookup_blocks: u64,
    /// Blocks currently owned by the prefix cache.
    pub prefix_blocks_held: usize,
    /// Blocks currently resident in the pool (requests + prefix cache;
    /// a shared block counts once).
    pub blocks_in_use: usize,
    /// High-water mark of resident blocks.
    pub peak_blocks_in_use: usize,
    /// Pool capacity in blocks (`None` = unbounded).
    pub capacity_blocks: Option<usize>,
    /// Copy-on-write promotions that actually copied a block.
    pub cow_copies: u64,
    /// Temporal-reuse counters aggregated across every reuse-enabled
    /// policy the session has run (live and retired requests alike);
    /// all-zero when no request used [`AttentionOpt::VerifiedReuse`].
    pub reuse: ReuseStats,
    /// Bytes spilled to the file-backed cold tier by swap-out
    /// preemptions (physical payload bytes; 0 without `--kv-spill`).
    pub spill_out_bytes: usize,
    /// Swap-out block writes to the cold tier.
    pub spill_out_ops: usize,
    /// Bytes swapped back in from the cold tier at re-admission.
    pub swap_in_bytes: usize,
    /// Swap-in block reads from the cold tier.
    pub swap_in_ops: usize,
    /// Swap-in reads issued synchronously on the scheduler thread —
    /// the stalls `--kv-prefetch` exists to remove (~0 with prefetch
    /// on; equal to `swap_in_ops` with it off).
    pub blocking_swap_in_ops: usize,
    /// Blocks handed to the async prefetch pipeline at queue-front
    /// kicks (0 without `--kv-prefetch`).
    pub prefetch_issued_ops: usize,
    /// Prefetched blocks consumed at resume instead of blocking reads.
    pub prefetch_hit_ops: usize,
    /// Prefetched blocks discarded (cancelled while staging, or the
    /// staged read failed and resume fell back to blocking reads).
    pub prefetch_wasted_ops: usize,
    /// Payload bytes restored through the staged prefetch path.
    pub prefetch_bytes: usize,
    /// Preemptions served by full recompute replay — the fallback when
    /// no spill store is configured. Always 0 with `--kv-spill`: every
    /// preemption is a swap-out there, never a replay.
    pub preemption_replays: u64,
    /// Session-default physical KV storage dtype
    /// (`EngineConfig::kv_dtype`).
    pub kv_dtype: KvDtype,
    /// Physical KV bytes per cached token at `kv_dtype`.
    pub bytes_per_token: usize,
    /// The same token's footprint at f32 — `bytes_per_token_fp32 /
    /// bytes_per_token` is the pool's compression ratio (1 at f32).
    pub bytes_per_token_fp32: usize,
}

impl SessionStats {
    /// Block-granular prefix hit rate (0 when the cache never ran).
    pub fn prefix_hit_rate(&self) -> f64 {
        if self.prefix_lookup_blocks == 0 {
            0.0
        } else {
            self.prefix_hit_blocks as f64 / self.prefix_lookup_blocks as f64
        }
    }

    /// KV compression of the session's storage dtype against f32
    /// (1.0 when storing f32, or before stats were populated).
    pub fn kv_compression_ratio(&self) -> f64 {
        crate::kvcache::store::compression_ratio(self.bytes_per_token_fp32, self.bytes_per_token)
    }

    /// Fraction of prefetch-issued blocks consumed at resume (0 when
    /// the pipeline never ran).
    pub fn prefetch_hit_rate(&self) -> f64 {
        if self.prefetch_issued_ops == 0 {
            0.0
        } else {
            self.prefetch_hit_ops as f64 / self.prefetch_issued_ops as f64
        }
    }
}

/// A submitted request waiting for admission (or re-admission after a
/// preemption). Policies are resolved at submit time (policy
/// construction is deterministic and draws no randomness) and *reset* on
/// preemption, so a re-run replays the same selections.
struct Waiting {
    id: RequestId,
    arrival_s: f64,
    prompt: Vec<u32>,
    gen_len: usize,
    sampler: Sampler,
    seed_tag: u64,
    /// Resolved physical KV dtype (request override or session default).
    kv_dtype: KvDtype,
    policies: Vec<Box<dyn IndexPolicy>>,
    /// Tokens already emitted as `Event::Token` before a preemption
    /// (0 for fresh requests); the re-run suppresses these.
    reported: usize,
    /// Queue wait recorded at a *first* admission whose token stream
    /// already started; carried so a replayed request's `RequestResult`
    /// keeps the user-visible timing of its original run.
    wait_s: Option<f64>,
    /// TTFT of the original run (0.0 until the first token streamed).
    /// In spill mode this accumulates *active* time across swap-out /
    /// swap-in cycles for requests preempted mid-prefill, so TTFT still
    /// spans admission → eventual first token with queue time excluded.
    ttft_s: f64,
    /// Present iff this request was swap-out preempted to the cold tier
    /// (spill mode): re-admission swaps its KV bytes back in and resumes
    /// exactly where it stopped instead of replaying compute.
    suspended: Option<Suspended>,
}

/// Swap-out image of a preempted request (spill mode only): everything
/// [`Active`] held that is not cheaply re-derivable. The KV payload
/// lives in the [`SpillStore`] under `slots`; RNG, sampler-visible
/// progress and policy state ride along untouched, so the resumed token
/// stream continues byte-identically — zero recompute, zero replay.
struct Suspended {
    tokens: Vec<u32>,
    next_token: u32,
    pos: usize,
    prefill_left: usize,
    step: usize,
    rng: Rng,
    /// Cached KV tokens at swap-out (= tokens the swap-in must restore).
    cached_tokens: usize,
    /// Cold-tier slots holding this request's blocks, position-ordered.
    slots: Vec<SpillSlot>,
    /// Per-request traffic counters, carried across the swap so the
    /// swap-in memcpys do not double-charge the host-tier numbers (the
    /// cold-tier traffic is charged to [`crate::kvcache::SpillStats`]).
    stats: TierStats,
    decode_s: f64,
    density_sum: f64,
    density_n: usize,
    /// In-flight staged read over `slots` (`--kv-prefetch`): set by the
    /// queue-front kick, consumed by `resume`, invalidated by `cancel`.
    /// The slots stay live until one of those happens, so the IO thread
    /// can never stage a recycled slot into this request.
    prefetch_job: Option<u64>,
}

/// One active request's serving state. Fully self-contained (cache,
/// policies, sampler, RNG), which is what makes step execution
/// data-parallel.
struct Active {
    id: RequestId,
    prompt: Vec<u32>,
    gen_len: usize,
    sampler: Sampler,
    cache: KvCache,
    policies: Vec<Box<dyn IndexPolicy>>, // L*H, empty in dense mode
    rng: Rng,
    tokens: Vec<u32>,
    /// How many of `tokens` have been emitted as `Event::Token`.
    reported: usize,
    next_token: u32,
    pos: usize,
    prefill_left: usize,
    /// Original arrival (kept across preemptions for wait accounting).
    arrival_s: f64,
    /// RNG stream tag (kept across preemptions for deterministic replay).
    seed_tag: u64,
    /// Set by `advance` in the round prefill completes; the merge phase
    /// publishes the prompt's full blocks to the prefix cache and clears
    /// it.
    just_prefilled: bool,
    started: Instant,
    wait_s: f64,
    ttft_s: f64,
    decode_s: f64,
    density_sum: f64,
    density_n: usize,
    step: usize,
}

impl Active {
    fn finished(&self) -> bool {
        self.prefill_left == 0 && self.tokens.len() >= self.gen_len
    }

    fn into_result(self) -> RequestResult {
        RequestResult {
            id: self.id,
            tokens: self.tokens,
            wait_s: self.wait_s,
            ttft_s: self.ttft_s,
            decode_s: self.decode_s,
            mean_density: if self.density_n > 0 {
                self.density_sum / self.density_n as f64
            } else {
                1.0
            },
            kv_bytes_read: self.cache.stats.bytes_read,
            kv_bytes_written: self.cache.stats.bytes_written,
            kv_prefill_bytes_read: self.cache.stats.prefill_bytes_read,
            kv_prefill_bytes_written: self.cache.stats.prefill_bytes_written,
        }
    }
}

/// The streaming scheduler core. See the module docs for the contract;
/// see `Engine` for the batch wrappers layered on top.
pub struct Session<B: Backend> {
    backend: Arc<B>,
    cfg: EngineConfig,
    mcfg: ModelConfig,
    pool: Arc<ThreadPool>,
    blocks: BlockPool,
    /// Shared-prompt radix (`EngineConfig::prefix_cache`).
    prefix: Option<PrefixCache>,
    /// File-backed cold tier (`EngineConfig::kv_spill`): preemption
    /// becomes swap-out / swap-in instead of recompute replay, and the
    /// prefix radix persists across sessions via the sibling file.
    spill: Option<SpillStore>,
    /// Async swap-in pipeline (`EngineConfig::kv_prefetch`; requires a
    /// spill store): stages suspended requests' cold-tier blocks on the
    /// `vattn-spill-io` thread while compute continues.
    prefetch: Option<PrefetchEngine>,
    preemptions: u64,
    /// Preemptions that fell back to full recompute replay (non-spill
    /// mode only; always 0 when `spill` is set).
    preemption_replays: u64,
    /// Reuse counters of requests that already left the session
    /// (finished, cancelled, rejected); live policies are added on top
    /// by [`Session::stats`].
    retired_reuse: ReuseStats,
    default_attention: AttentionOpt,
    waiting: VecDeque<Waiting>,
    active: Vec<Active>,
    /// Rejections queued at submit time, drained by the next `tick`.
    pending_events: Vec<Event>,
    /// Pristine seeded root; never advanced. Per-request streams are
    /// derived by clone-then-fork (see `Session::request_rng`).
    seed_rng: Rng,
    start: Instant,
    /// Virtual event clock (seconds), present iff
    /// `EngineConfig::virtual_clock`: `tick` advances it by a fixed
    /// quantum and idle gaps jump it to the next arrival, so the
    /// schedule — admission order included — is a pure function of the
    /// tick count instead of wall-clock timing.
    vclock: Option<f64>,
    next_id: RequestId,
}

/// Virtual seconds one `tick` advances the clock by under
/// `EngineConfig::virtual_clock`. The value only sets the granularity
/// of arrival-time quantization (a 1 kHz scheduler); determinism holds
/// for any positive constant.
const VIRTUAL_TICK_S: f64 = 1e-3;

impl<B: Backend + Send + Sync + 'static> Session<B> {
    /// Standalone session with its own worker pool.
    pub fn new(backend: B, cfg: EngineConfig) -> Session<B> {
        let pool = Arc::new(ThreadPool::new(cfg.workers.max(1)));
        Session::with_pool(Arc::new(backend), cfg, pool)
    }

    /// Session sharing an existing backend and worker pool (the
    /// `Engine::session` / `Engine::serve` path).
    pub(crate) fn with_pool(
        backend: Arc<B>,
        cfg: EngineConfig,
        pool: Arc<ThreadPool>,
    ) -> Session<B> {
        let mcfg = backend.config().clone();
        // Blocks are sized by the engine dtype: a quantized dtype turns
        // the same byte budget into proportionally more blocks.
        let mut blocks =
            BlockPool::for_model_dtype(&mcfg, cfg.block_tokens, cfg.kv_capacity_bytes, cfg.kv_dtype);
        let mut prefix = cfg.prefix_cache.then(|| PrefixCache::new(cfg.block_tokens.max(1)));
        let spill = cfg.kv_spill.as_deref().map(|path| {
            SpillStore::open(
                path,
                cfg.block_tokens.max(1),
                mcfg.n_layers * mcfg.n_kv_heads,
                mcfg.d_head(),
            )
            .unwrap_or_else(|e| panic!("opening KV spill store {}: {e}", path.display()))
        });
        // Warm start: a previous session on the same spill path may have
        // persisted its prefix radix (`flush_prefix_cache`); re-import
        // whatever fits the pool so repeated prompts fork instead of
        // re-prefilling from scratch after a process restart. Absent,
        // geometry-mismatched, or unreadable files mean a cold start.
        if let (Some(store), Some(p)) = (spill.as_ref(), prefix.as_mut()) {
            if let Ok(Some(entries)) = store.load_prefix() {
                for (key, parent, snap) in entries {
                    if !p.import_entry(key, parent, snap, &mut blocks) {
                        break; // pool full: keep the prefix that fits
                    }
                }
            }
        }
        // The prefetch pipeline reads through a dup'd fd, so it needs a
        // store to clone from; without `--kv-spill` the flag is inert.
        let prefetch = match (cfg.kv_prefetch, spill.as_ref()) {
            (true, Some(store)) => Some(PrefetchEngine::new(
                store.reader().unwrap_or_else(|e| panic!("cloning KV spill read fd: {e}")),
            )),
            _ => None,
        };
        let seed_rng = Rng::new(cfg.seed);
        let vclock = cfg.virtual_clock.then_some(0.0);
        Session {
            backend,
            cfg,
            mcfg,
            pool,
            blocks,
            prefix,
            spill,
            prefetch,
            preemptions: 0,
            preemption_replays: 0,
            retired_reuse: ReuseStats::default(),
            default_attention: AttentionOpt::Dense,
            waiting: VecDeque::new(),
            active: Vec::new(),
            pending_events: Vec::new(),
            seed_rng,
            start: Instant::now(),
            vclock,
            next_id: 0,
        }
    }

    /// Attention applied to requests that submit `AttentionOpt::Inherit`.
    /// `Inherit` here means dense.
    pub fn set_default_attention(&mut self, attention: AttentionOpt) {
        self.default_attention = attention;
    }

    /// Seconds since the session was created (the event clock). Under
    /// `EngineConfig::virtual_clock` this reads the tick-driven virtual
    /// clock instead of the wall clock.
    pub fn now_s(&self) -> f64 {
        match self.vclock {
            Some(t) => t,
            None => self.start.elapsed().as_secs_f64(),
        }
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn active_len(&self) -> usize {
        self.active.len()
    }

    /// Requests submitted but not yet finished, cancelled, or rejected.
    pub fn outstanding(&self) -> usize {
        self.waiting.len() + self.active.len()
    }

    /// True when a `tick` would have nothing to do: no queued work and
    /// no pending events. The drive loop's termination condition.
    pub fn is_idle(&self) -> bool {
        self.waiting.is_empty() && self.active.is_empty() && self.pending_events.is_empty()
    }

    /// KV blocks currently resident: leased to active requests plus
    /// retained by the prefix cache (shared blocks count once). Once the
    /// session drains, only prefix-cache blocks remain, and
    /// [`Session::flush_prefix_cache`] brings this to zero — the no-leak
    /// invariant the cancellation tests assert.
    pub fn kv_blocks_in_use(&self) -> usize {
        self.blocks.in_use_blocks()
    }

    /// Blocks currently owned by the prefix cache (0 when disabled).
    pub fn prefix_blocks_held(&self) -> usize {
        self.prefix.as_ref().map_or(0, |p| p.blocks_held())
    }

    /// Drop every prefix-cache entry, returning its blocks to the pool.
    /// Returns the number of blocks released. With no requests in
    /// flight, the pool is quiescent afterwards.
    ///
    /// With a spill store configured, the radix is first serialized to
    /// the persistent sibling file (`<spill-path>.prefix`), so a fresh
    /// session opened on the same path warm-starts from it — cached
    /// prefixes survive process restarts.
    pub fn flush_prefix_cache(&mut self) -> Result<usize, EngineError> {
        match self.prefix.as_mut() {
            Some(p) => {
                if let Some(store) = self.spill.as_ref() {
                    store
                        .persist_prefix(&p.export_chains())
                        .map_err(|e| EngineError::Backend(e.into()))?;
                }
                p.flush(&mut self.blocks).map_err(EngineError::Page)
            }
            None => Ok(0),
        }
    }

    /// Blocks currently resident in the cold tier (`None` without a
    /// spill store). Zero once every suspended request has been resumed
    /// or cancelled — the cold-tier side of the no-leak invariant.
    pub fn spill_live_blocks(&self) -> Option<usize> {
        self.spill.as_ref().map(|s| s.live_blocks())
    }

    /// Oracle-grade quiescence: every pool block has been returned and
    /// no cold-tier slot is live. After draining all requests and
    /// [`Session::flush_prefix_cache`], a session that does not satisfy
    /// this has leaked KV somewhere — the scenario-matrix harness
    /// asserts it at the end of every run.
    pub fn kv_quiescent(&self) -> bool {
        self.blocks.is_quiescent() && self.spill.as_ref().map_or(true, |s| s.is_quiescent())
    }

    /// Paging / scheduling counters (cumulative since session creation).
    pub fn stats(&self) -> SessionStats {
        let mut reuse = self.retired_reuse.clone();
        for a in &self.active {
            merge_reuse(&mut reuse, &a.policies);
        }
        for w in &self.waiting {
            merge_reuse(&mut reuse, &w.policies);
        }
        SessionStats {
            preemptions: self.preemptions,
            prefix_hit_blocks: self.prefix.as_ref().map_or(0, |p| p.hit_blocks()),
            prefix_lookup_blocks: self.prefix.as_ref().map_or(0, |p| p.lookup_blocks()),
            prefix_blocks_held: self.prefix_blocks_held(),
            blocks_in_use: self.blocks.in_use_blocks(),
            peak_blocks_in_use: self.blocks.peak_in_use_blocks(),
            capacity_blocks: self.blocks.capacity_blocks(),
            cow_copies: self.blocks.cow_count(),
            reuse,
            spill_out_bytes: self.spill.as_ref().map_or(0, |s| s.stats().spill_out_bytes),
            spill_out_ops: self.spill.as_ref().map_or(0, |s| s.stats().spill_out_ops),
            swap_in_bytes: self.spill.as_ref().map_or(0, |s| s.stats().swap_in_bytes),
            swap_in_ops: self.spill.as_ref().map_or(0, |s| s.stats().swap_in_ops),
            blocking_swap_in_ops: self.spill.as_ref().map_or(0, |s| s.stats().blocking_swap_in_ops),
            prefetch_issued_ops: self.spill.as_ref().map_or(0, |s| s.stats().prefetch_issued_ops),
            prefetch_hit_ops: self.spill.as_ref().map_or(0, |s| s.stats().prefetch_hit_ops),
            prefetch_wasted_ops: self.spill.as_ref().map_or(0, |s| s.stats().prefetch_wasted_ops),
            prefetch_bytes: self.spill.as_ref().map_or(0, |s| s.stats().prefetch_bytes),
            preemption_replays: self.preemption_replays,
            kv_dtype: self.cfg.kv_dtype,
            bytes_per_token: self.cfg.kv_dtype.kv_bytes_per_token(&self.mcfg),
            bytes_per_token_fp32: KvDtype::F32.kv_bytes_per_token(&self.mcfg),
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Enqueue a request and return its id. Never fails: a request that
    /// can never be served yields an `Event::Rejected` on the next
    /// [`Session::tick`] instead, so the id is always valid to observe.
    pub fn submit(&mut self, req: SubmitRequest) -> RequestId {
        let policies = self.resolve_policies(&req.opts);
        self.enqueue(req, policies)
    }

    /// Like [`Session::submit`], but surface a validation failure
    /// synchronously instead of queueing an `Event::Rejected` for the
    /// next tick. The network front-end needs the distinction: an HTTP
    /// status line must be chosen *before* the response starts
    /// streaming, so capacity/length rejections map to 429/400 up front
    /// while mid-flight failures still arrive as stream events. On `Ok`
    /// the request is queued exactly as `submit` would queue it.
    pub fn submit_validated(&mut self, req: SubmitRequest) -> Result<RequestId, EngineError> {
        self.validate(&req)?;
        let policies = self.resolve_policies(&req.opts);
        Ok(self.enqueue(req, policies))
    }

    /// Legacy path for `Engine::serve`: resolve attention from the
    /// engine-global [`AttentionMode`] instead of the request options.
    pub(crate) fn submit_with_mode(
        &mut self,
        req: SubmitRequest,
        mode: &AttentionMode,
    ) -> RequestId {
        let policies = match mode {
            AttentionMode::Dense => Vec::new(),
            AttentionMode::Sparse(factory) => self.policy_grid(|l, h| factory(l, h)),
        };
        self.enqueue(req, policies)
    }

    /// Remove a request, wherever it is. An active request's leased KV
    /// blocks return to the pool immediately; a waiting request simply
    /// leaves the queue (it never held blocks). Finished, rejected,
    /// already-cancelled, or never-submitted ids yield `UnknownRequest`.
    pub fn cancel(&mut self, id: RequestId) -> Result<(), EngineError> {
        if let Some(pos) = self.waiting.iter().position(|w| w.id == id) {
            let mut w = self.waiting.remove(pos).expect("position was in range");
            merge_reuse(&mut self.retired_reuse, &w.policies);
            // A suspended request owns cold-tier slots, not pool blocks.
            if let Some(sus) = w.suspended.take() {
                // Cancel-while-prefetching unwind: kill the staged job
                // *before* freeing its slots, so a read racing the
                // recycle below is discarded instead of consumed.
                if let Some(job) = sus.prefetch_job {
                    self.prefetch
                        .as_mut()
                        .expect("prefetch job without a prefetch engine")
                        .invalidate(job);
                }
                let store =
                    self.spill.as_mut().expect("suspended request without a spill store");
                if sus.prefetch_job.is_some() {
                    store.note_prefetch_wasted(sus.slots.len());
                }
                for slot in sus.slots {
                    store.free(slot);
                }
            }
            return Ok(());
        }
        if let Some(pos) = self.active.iter().position(|a| a.id == id) {
            let mut a = self.active.remove(pos);
            merge_reuse(&mut self.retired_reuse, &a.policies);
            let lease = a.cache.release_blocks();
            self.blocks.free(lease).map_err(EngineError::Page)?;
            return Ok(());
        }
        Err(EngineError::UnknownRequest(id))
    }

    /// Run one scheduler round and return the events it produced, in
    /// deterministic order: queued rejections first, then preemptions
    /// (block accounting for the active batch), then admissions, then
    /// per-request `Token` / `Finished` events in submission order.
    ///
    /// Failures are isolated per request: a backend error terminates
    /// only the request it hit (its KV blocks return to the pool and a
    /// `Rejected` event carries the `EngineError::Backend` reason); the
    /// rest of the batch keeps streaming. `tick` itself only errors on
    /// block-pool bookkeeping violations, which are engine bugs.
    ///
    /// When nothing is active and the queue's head has not arrived yet
    /// (trace replay), the call sleeps for at most 20 ms so drive loops
    /// do not spin; interactive sessions (arrival 0) never sleep.
    pub fn tick(&mut self) -> Result<Vec<Event>, EngineError> {
        let mut events = std::mem::take(&mut self.pending_events);
        if let Some(t) = self.vclock.as_mut() {
            *t += VIRTUAL_TICK_S;
        }
        let now = self.now_s();

        // ── phase 0: queue-front prefetch kick — start staging the
        // cold-tier blocks of suspended requests near the queue front
        // *before* any batch slot frees, so the IO overlaps this tick's
        // compute instead of stalling a later admission.
        self.kick_prefetch();

        // ── phase 1: demand-paged block accounting (serial — workers
        // never touch the pool). May preempt on exhaustion.
        self.ensure_block_capacity(&mut events, now)?;

        // ── phase 2: admission (FIFO; arrival-, batch- and KV-gated) ──
        self.admit_waiting(&mut events, now)?;

        if self.active.is_empty() {
            if let Some(front) = self.waiting.front() {
                // Trace-replay idle gap: nothing runnable until the next
                // arrival. The virtual clock jumps straight to it (the
                // next tick admits); the wall clock sleeps it off.
                let arrival = front.arrival_s;
                if let Some(t) = self.vclock.as_mut() {
                    if arrival > *t {
                        *t = arrival;
                    }
                } else {
                    let gap = arrival - self.now_s();
                    if gap > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(gap.min(0.02)));
                    }
                }
            }
            return Ok(events);
        }

        // ── phase 3: fan the batch's steps out across the pool ──
        // The Active rides alongside the step result so a failing
        // request still comes back (its block lease must be returned,
        // not dropped on a worker thread).
        let batch: Vec<Active> = std::mem::take(&mut self.active);
        let backend = Arc::clone(&self.backend);
        let prefill_chunk = self.cfg.prefill_chunk.max(1);
        let stepped: Vec<(Active, Result<(), EngineError>)> =
            self.pool.map(batch, move |mut a| {
                let res = advance(&*backend, prefill_chunk, &mut a);
                (a, res)
            });

        // ── phase 4: deterministic merge, in submission order ──
        let t_s = self.now_s();
        for (mut a, res) in stepped {
            if let Err(reason) = res {
                // Per-request failure isolation: a backend error kills
                // this request (blocks returned, `Rejected` emitted) and
                // no one else — the session stays serviceable.
                merge_reuse(&mut self.retired_reuse, &a.policies);
                let lease = a.cache.release_blocks();
                self.blocks.free(lease).map_err(EngineError::Page)?;
                events.push(Event::Rejected { id: a.id, reason, t_s });
                continue;
            }
            if a.just_prefilled {
                // Publish the freshly computed full prompt blocks so
                // later identical prefixes fork instead of recomputing.
                a.just_prefilled = false;
                if let Some(p) = self.prefix.as_mut() {
                    p.insert_chain(&a.prompt, &a.cache, &mut self.blocks)
                        .map_err(EngineError::Page)?;
                }
            }
            while a.reported < a.tokens.len() {
                events.push(Event::Token {
                    id: a.id,
                    token: a.tokens[a.reported],
                    step: a.reported,
                    t_s,
                });
                a.reported += 1;
            }
            if a.finished() {
                merge_reuse(&mut self.retired_reuse, &a.policies);
                let lease = a.cache.release_blocks();
                self.blocks.free(lease).map_err(EngineError::Page)?;
                let id = a.id;
                events.push(Event::Finished { id, result: a.into_result(), t_s });
            } else {
                self.active.push(a);
            }
        }
        debug_assert!(
            !(self.waiting.is_empty() && self.active.is_empty())
                || self.blocks.in_use_blocks() == self.prefix_blocks_held(),
            "idle session must hold only prefix-cache blocks"
        );
        Ok(events)
    }

    /// Phase-1 worker: give every active request the blocks its next
    /// round of appends needs (a prefill chunk or one decode token),
    /// promoting any still-shared write-target block to private first.
    /// On pool exhaustion: reclaim idle prefix-cache blocks, then
    /// preempt the most-recently-admitted active request (LIFO — the
    /// deterministic victim rule) and retry.
    fn ensure_block_capacity(
        &mut self,
        events: &mut Vec<Event>,
        now: f64,
    ) -> Result<(), EngineError> {
        let chunk = self.cfg.prefill_chunk.max(1);
        let mut i = 0;
        'requests: while i < self.active.len() {
            let a = &self.active[i];
            let appends = if a.prefill_left > 0 { a.prefill_left.min(chunk) } else { 1 };
            loop {
                if self.prepare_for_appends(i, appends)? {
                    i += 1;
                    continue 'requests;
                }
                // Exhausted even after eviction: preempt. Every active
                // request owns ≥ 1 private block (the final prompt token
                // is never shared), so each preemption makes progress.
                let victim = self.pick_victim();
                let self_preempted = victim == i;
                self.preempt(victim, events, now)?;
                if self_preempted {
                    // `i` now indexes the next request (or the end).
                    continue 'requests;
                }
                if victim < i {
                    i -= 1;
                }
            }
        }
        Ok(())
    }

    /// Make request `i` safe to append `appends` tokens: CoW-promote any
    /// shared block in the write range, then grow the block table on
    /// demand. Returns false when the pool cannot cover it even after
    /// evicting idle prefix blocks (the caller preempts).
    fn prepare_for_appends(&mut self, i: usize, appends: usize) -> Result<bool, EngineError> {
        let bt = self.cfg.block_tokens.max(1);
        let tokens = self.active[i].cache.tokens();
        let target = tokens + appends;
        // Copy-on-write guard over the blocks this round writes into.
        // Full-block prefix sharing never shares the writable tail, so
        // this is a safety net, not a steady-state path.
        let write_lo = tokens / bt;
        let write_hi = (target - 1) / bt;
        let mut idx = write_lo;
        while idx <= write_hi && idx < self.active[i].cache.blocks_reserved() {
            let id = self.active[i].cache.block_table()[idx];
            if self.blocks.is_shared(id) {
                loop {
                    match self.blocks.cow(id).map_err(EngineError::Page)? {
                        CowOutcome::InPlace => break,
                        CowOutcome::Copied(fresh) => {
                            self.active[i].cache.replace_block(idx, fresh);
                            break;
                        }
                        CowOutcome::OutOfBlocks => {
                            if !self.evict_prefix_block()? {
                                return Ok(false);
                            }
                        }
                    }
                }
            }
            idx += 1;
        }
        // Demand growth: lease exactly the blocks the new tokens need.
        let need = self
            .blocks
            .blocks_for_tokens(target)
            .saturating_sub(self.active[i].cache.blocks_reserved());
        if need == 0 {
            return Ok(true);
        }
        loop {
            if let Some(ids) = self.blocks.try_alloc(need) {
                self.active[i].cache.grow(ids);
                return Ok(true);
            }
            if !self.evict_prefix_block()? {
                return Ok(false);
            }
        }
    }

    /// Reclaim one idle prefix-cache block (LRU leaf the cache solely
    /// owns). False when nothing is reclaimable.
    fn evict_prefix_block(&mut self) -> Result<bool, EngineError> {
        match self.prefix.as_mut() {
            Some(p) => p.evict_one(&mut self.blocks).map_err(EngineError::Page),
            None => Ok(false),
        }
    }

    /// Deterministic preemption victim for pool exhaustion.
    ///
    /// Replay mode keeps the pure LIFO rule (most recently admitted).
    /// Spill mode refines it with a dtype-aware policy: among the active
    /// requests, prefer the narrowest KV dtype — int4, then int8, then
    /// f32 — because at equal freed pool blocks a quantized victim moves
    /// 4–7.5x fewer cold-tier bytes in each swap direction. Ties
    /// (including the uniform-dtype common case) resolve to the highest
    /// index, i.e. strict LIFO, so the policy is inert unless per-request
    /// dtypes actually differ — and it is always deterministic, because
    /// dtype is request state, not timing.
    fn pick_victim(&self) -> usize {
        let last = self.active.len() - 1;
        if self.spill.is_none() {
            return last;
        }
        fn width_rank(d: KvDtype) -> u8 {
            match d {
                KvDtype::Int4 => 0,
                KvDtype::Int8 => 1,
                KvDtype::F32 => 2,
            }
        }
        let mut best = last;
        for i in (0..self.active.len()).rev() {
            if width_rank(self.active[i].cache.dtype())
                < width_rank(self.active[best].cache.dtype())
            {
                best = i;
            }
        }
        best
    }

    /// Phase-0 worker: start staged cold-tier reads for suspended
    /// requests inside the front window of the waiting queue (depth
    /// `kv_prefetch_depth`), so their bytes are in host buffers before a
    /// batch slot frees. Idempotent per suspension — a request is kicked
    /// at most once while it waits (`prefetch_job` marks it), and the
    /// job is consumed by [`Session::resume`] or invalidated by
    /// [`Session::cancel`] before its slots are recycled.
    fn kick_prefetch(&mut self) {
        let Some(pf) = self.prefetch.as_mut() else { return };
        let store = self.spill.as_mut().expect("prefetch without a spill store");
        let depth = self.cfg.kv_prefetch_depth.max(1);
        for w in self.waiting.iter_mut().take(depth) {
            if let Some(sus) = w.suspended.as_mut() {
                if sus.prefetch_job.is_none() && !sus.slots.is_empty() {
                    sus.prefetch_job = Some(pf.kick(&sus.slots));
                    store.note_prefetch_issued(sus.slots.len());
                }
            }
        }
    }

    /// Deterministic preemption of active request `idx` (always the most
    /// recently admitted), requeued at the *front* of the waiting queue.
    ///
    /// **Spill mode** (`--kv-spill`): the victim's physical KV bytes —
    /// every filled block, quantized payloads byte-for-byte — are
    /// written to the file-backed cold tier, and its RNG, policies and
    /// progress are parked in a [`Suspended`] image. Re-admission swaps
    /// the bytes back in and continues; nothing is recomputed.
    ///
    /// **Replay mode** (no spill store): the blocks are dropped, the
    /// policies reset, and the re-run re-derives the same RNG stream
    /// from (engine seed, seed tag), so the replayed token stream is
    /// byte-identical; `reported` rides along so already-emitted tokens
    /// are not re-emitted.
    fn preempt(&mut self, idx: usize, events: &mut Vec<Event>, now: f64) -> Result<(), EngineError> {
        let mut a = self.active.remove(idx);
        let kv_dtype = a.cache.dtype();
        if let Some(store) = self.spill.as_mut() {
            // Swap out: spill every filled block (the tail may be
            // partial), then return the whole lease to the pool.
            let bt = self.cfg.block_tokens.max(1);
            let cached = a.cache.tokens();
            let mut slots = Vec::with_capacity(a.cache.blocks_used());
            let mut write_err: Option<std::io::Error> = None;
            for b in 0..a.cache.blocks_used() {
                let snap = a.cache.snapshot_rows(b * bt, ((b + 1) * bt).min(cached));
                match store.write_block(&snap) {
                    Ok(slot) => slots.push(slot),
                    Err(e) => {
                        write_err = Some(e);
                        break;
                    }
                }
            }
            if let Some(e) = write_err {
                // Unwritable cold tier mid-swap-out: unwind so nothing
                // leaks — slots already written go back to the store,
                // the victim's lease back to the pool — and terminate
                // only the victim (the same per-request fault isolation
                // as a backend step error), never the whole tick.
                for slot in slots {
                    store.free(slot);
                }
                merge_reuse(&mut self.retired_reuse, &a.policies);
                let lease = a.cache.release_blocks();
                self.blocks.free(lease).map_err(EngineError::Page)?;
                events.push(Event::Rejected {
                    id: a.id,
                    reason: EngineError::Backend(e.into()),
                    t_s: now,
                });
                return Ok(());
            }
            let lease = a.cache.release_blocks();
            self.blocks.free(lease).map_err(EngineError::Page)?;
            self.preemptions += 1;
            events.push(Event::Preempted { id: a.id, t_s: now });
            let streamed = a.reported > 0;
            self.waiting.push_front(Waiting {
                id: a.id,
                arrival_s: a.arrival_s,
                prompt: a.prompt,
                gen_len: a.gen_len,
                sampler: a.sampler,
                seed_tag: a.seed_tag,
                kv_dtype,
                // Policy state is *preserved* (not reset): the resumed
                // run continues, it does not replay.
                policies: a.policies,
                reported: a.reported,
                // The original queue wait is final — the request never
                // re-runs its admission path from scratch.
                wait_s: Some(a.wait_s),
                // Mid-prefill victims accumulate active time so the
                // eventual TTFT spans all their prefill segments.
                ttft_s: if streamed {
                    a.ttft_s
                } else {
                    a.ttft_s + a.started.elapsed().as_secs_f64()
                },
                suspended: Some(Suspended {
                    tokens: a.tokens,
                    next_token: a.next_token,
                    pos: a.pos,
                    prefill_left: a.prefill_left,
                    step: a.step,
                    rng: a.rng,
                    cached_tokens: cached,
                    slots,
                    stats: a.cache.stats.clone(),
                    decode_s: a.decode_s,
                    density_sum: a.density_sum,
                    density_n: a.density_n,
                    prefetch_job: None,
                }),
            });
            // The victim is now at the queue front: if nothing is ahead
            // of it, it is the very next admission candidate, so start
            // staging its blocks immediately — the read overlaps the
            // rest of this tick's compute instead of stalling resume.
            if let (Some(pf), Some(front)) = (self.prefetch.as_mut(), self.waiting.front_mut()) {
                if let Some(sus) = front.suspended.as_mut() {
                    if sus.prefetch_job.is_none() && !sus.slots.is_empty() {
                        sus.prefetch_job = Some(pf.kick(&sus.slots));
                        self.spill
                            .as_mut()
                            .expect("prefetch without a spill store")
                            .note_prefetch_issued(sus.slots.len());
                    }
                }
            }
            return Ok(());
        }
        self.preemption_replays += 1;
        let lease = a.cache.release_blocks();
        self.blocks.free(lease).map_err(EngineError::Page)?;
        for p in a.policies.iter_mut() {
            p.reset();
        }
        self.preemptions += 1;
        events.push(Event::Preempted { id: a.id, t_s: now });
        // Timing carries over only once the stream has started: the
        // original wait/TTFT are what the user observed. A request
        // preempted mid-prefill instead re-measures at re-admission, so
        // wait + TTFT still spans arrival → (eventual) first token.
        let streamed = a.reported > 0;
        self.waiting.push_front(Waiting {
            id: a.id,
            arrival_s: a.arrival_s,
            prompt: a.prompt,
            gen_len: a.gen_len,
            sampler: a.sampler,
            seed_tag: a.seed_tag,
            kv_dtype,
            policies: a.policies,
            reported: a.reported,
            wait_s: streamed.then_some(a.wait_s),
            ttft_s: if streamed { a.ttft_s } else { 0.0 },
            suspended: None,
        });
        Ok(())
    }

    /// Phase-2 worker: FIFO admission, gated by batch capacity, arrival
    /// time, and the pool — a request needs its *prompt* blocks (minus
    /// any prefix-cache hit) plus `kv_headroom_blocks` left free; the
    /// headroom is waived when the batch is empty so it can never starve
    /// the session.
    fn admit_waiting(&mut self, events: &mut Vec<Event>, now: f64) -> Result<(), EngineError> {
        let bt = self.cfg.block_tokens.max(1);
        let max_batch = self.cfg.max_batch.max(1);
        while self.active.len() < max_batch {
            match self.waiting.front() {
                None => break,
                Some(front) if front.arrival_s > now => break,
                Some(_) => {}
            }
            let w = self.waiting.pop_front().expect("front was Some");
            // Suspended (swap-out-preempted) requests bypass the prefix
            // path entirely: they re-lease exactly the blocks they held
            // and swap their own bytes back in from the cold tier.
            if let Some(sus) = w.suspended.as_ref() {
                let need = sus.slots.len();
                let reserve =
                    if self.active.is_empty() { 0 } else { self.cfg.kv_headroom_blocks };
                let lease = loop {
                    if self.blocks.can_alloc(need, reserve) {
                        if let Some(l) = self.blocks.try_alloc(need) {
                            break Some(l);
                        }
                    }
                    if !self.evict_prefix_block()? {
                        break None;
                    }
                };
                let Some(lease) = lease else {
                    debug_assert!(
                        !self.active.is_empty(),
                        "swap-in stalled with an empty batch despite making progress at preemption"
                    );
                    self.waiting.push_front(w);
                    break;
                };
                events.push(Event::Admitted { id: w.id, t_s: now });
                let wid = w.id;
                match self.resume(w, lease, now) {
                    Ok(active) => self.active.push(active),
                    // resume() already unwound the lease and cold-tier
                    // slots; an unreadable region file terminates only
                    // this request (it used to fail the whole tick and
                    // silently drop the request with no event).
                    Err(reason) => {
                        events.push(Event::Rejected { id: wid, reason, t_s: now })
                    }
                }
                continue;
            }
            // Prefix fork: attach to matched blocks (refcount bump)
            // before any eviction below could reclaim them. Chains are
            // keyed by dtype, so an f32 request never forks an int8
            // donor's payload (or vice versa).
            let matched = match self.prefix.as_mut() {
                Some(p) => p.lookup(&w.prompt, w.kv_dtype),
                None => Vec::new(),
            };
            let matched_ids = match self.prefix.as_ref() {
                Some(p) => p.blocks(&matched),
                None => Vec::new(),
            };
            for &id in &matched_ids {
                self.blocks.retain(id).map_err(EngineError::Page)?;
            }
            let prompt_blocks = self.blocks.blocks_for_tokens(w.prompt.len());
            let need = prompt_blocks - matched_ids.len();
            let reserve = if self.active.is_empty() { 0 } else { self.cfg.kv_headroom_blocks };
            let lease = loop {
                if self.blocks.can_alloc(need, reserve) {
                    if let Some(l) = self.blocks.try_alloc(need) {
                        break Some(l);
                    }
                }
                if !self.evict_prefix_block()? {
                    break None;
                }
            };
            let Some(lease) = lease else {
                // Head-of-line waits for a completion; undo the fork.
                self.blocks.free(matched_ids).map_err(EngineError::Page)?;
                debug_assert!(
                    !self.active.is_empty(),
                    "admission stalled with an empty batch despite submit validation"
                );
                self.waiting.push_front(w);
                break;
            };
            events.push(Event::Admitted { id: w.id, t_s: now });
            if let Some(p) = self.prefix.as_mut() {
                // Commit the hit-rate sample now that the fork is real
                // (stalled retries must not inflate the counters).
                p.record_use(matched.len(), prompt_blocks);
            }
            let mut table = matched_ids;
            table.extend(lease);
            let matched_tokens = matched.len() * bt;
            let mut active = self.admit(w, table, matched_tokens, now);
            if let Some(p) = self.prefix.as_ref() {
                // The fork's one-time memcpy of the shared prefix rows.
                p.copy_into(&matched, &mut active.cache);
            }
            self.active.push(active);
        }
        Ok(())
    }

    /// Resolve a request's attention contract into per-(layer, head)
    /// policies. Empty vector = dense.
    fn resolve_policies(&self, opts: &GenOptions) -> Vec<Box<dyn IndexPolicy>> {
        let att = match &opts.attention {
            AttentionOpt::Inherit => &self.default_attention,
            other => other,
        };
        match att {
            AttentionOpt::Inherit | AttentionOpt::Dense => Vec::new(),
            AttentionOpt::Verified(vcfg) => {
                self.policy_grid(|_l, _h| Box::new(VAttentionPolicy::oracle(vcfg.clone())))
            }
            AttentionOpt::VerifiedReuse(vcfg, rcfg) => self.policy_grid(|_l, _h| {
                Box::new(TemporalReusePolicy::new(
                    VAttentionPolicy::oracle(vcfg.clone()),
                    rcfg.clone(),
                ))
            }),
            AttentionOpt::Custom(factory) => self.policy_grid(|l, h| factory(l, h, opts)),
        }
    }

    fn policy_grid(
        &self,
        mut mk: impl FnMut(usize, usize) -> Box<dyn IndexPolicy>,
    ) -> Vec<Box<dyn IndexPolicy>> {
        let mut v = Vec::with_capacity(self.mcfg.n_layers * self.mcfg.n_heads);
        for l in 0..self.mcfg.n_layers {
            for h in 0..self.mcfg.n_heads {
                v.push(mk(l, h));
            }
        }
        v
    }

    /// Submit-time validation, shared by [`Session::submit`] (which
    /// queues failures as `Event::Rejected`) and
    /// [`Session::submit_validated`] (which returns them to the caller).
    fn validate(&self, req: &SubmitRequest) -> Result<(), EngineError> {
        let total = req.prompt.len() + req.opts.gen_len;
        let kv_dtype = req.opts.kv_dtype.unwrap_or(self.cfg.kv_dtype);
        if let Some(max) = self.cfg.max_seq_len {
            if total > max {
                return Err(EngineError::PromptTooLong { len: total, max });
            }
        }
        if self.cfg.kv_capacity_bytes.is_some() {
            // Block accounting is in engine-dtype blocks; a request
            // storing wider rows would overrun the byte budget while
            // the pool believes it fits — reject instead of lying.
            let d = self.mcfg.d_head();
            if kv_dtype.row_bytes(d) > self.cfg.kv_dtype.row_bytes(d) {
                return Err(EngineError::KvDtypeWiderThanPool {
                    requested: kv_dtype,
                    pool: self.cfg.kv_dtype,
                });
            }
        }
        // Worst-case validation stays conservative under demand
        // paging: a request whose full footprint cannot fit even an
        // otherwise-empty pool would preempt-livelock once admitted,
        // so it is rejected up front (prefix sharing is not
        // credited — entries may be evicted at any time).
        if let Some(cap) = self.blocks.capacity_blocks() {
            let needed = self.blocks.blocks_for_tokens(total);
            if needed > cap {
                return Err(EngineError::KvCapacityExceeded { needed, available: cap });
            }
        }
        Ok(())
    }

    fn enqueue(&mut self, req: SubmitRequest, policies: Vec<Box<dyn IndexPolicy>>) -> RequestId {
        let id = self.next_id;
        self.next_id += 1;
        if let Err(reason) = self.validate(&req) {
            let t_s = self.now_s();
            self.pending_events.push(Event::Rejected { id, reason, t_s });
            return id;
        }
        let SubmitRequest { prompt, arrival_s, opts } = req;
        let kv_dtype = opts.kv_dtype.unwrap_or(self.cfg.kv_dtype);

        let sampler = opts.sampler.unwrap_or_else(|| self.cfg.sampler.clone());
        let seed_tag = opts.seed.unwrap_or(id);
        self.waiting.push_back(Waiting {
            id,
            arrival_s,
            prompt,
            gen_len: opts.gen_len,
            sampler,
            seed_tag,
            kv_dtype,
            policies,
            reported: 0,
            wait_s: None,
            ttft_s: 0.0,
            suspended: None,
        });
        id
    }

    /// Per-request RNG stream, a pure function of (engine seed, request
    /// seed tag): the root is cloned before forking so no shared state
    /// advances. This is what makes `GenOptions::seed` a real contract —
    /// the stream does not depend on admission order, batch composition,
    /// what was cancelled before this request ran, or whether the
    /// request was preempted and replayed.
    fn request_rng(&self, tag: u64) -> Rng {
        let mut root = self.seed_rng.clone();
        root.fork(tag)
    }

    /// Re-admit a suspended request: swap its KV bytes back in from the
    /// cold tier block by block, free the cold-tier slots, and rebuild
    /// the active state exactly where swap-out parked it — no prefill or
    /// decode is replayed, and RNG / sampler / policy state continue, so
    /// the resumed stream is byte-identical to an uncontended run.
    fn resume(
        &mut self,
        mut w: Waiting,
        lease: Vec<BlockId>,
        now: f64,
    ) -> Result<Active, EngineError> {
        let mut sus = w.suspended.take().expect("resume of a non-suspended request");
        // Consume-or-fallback: if a queue-front kick staged this
        // request's blocks, wait for that job — the overlap already
        // happened, so the wait covers only whatever tail is still in
        // flight — and load the staged snapshots. A miss (staged read
        // failed, or the IO thread is gone) falls back to the blocking
        // path below, which re-reads the same bytes through the same
        // record decoder, so the resumed stream is byte-identical
        // either way.
        let had_job = sus.prefetch_job.is_some();
        let staged = sus.prefetch_job.take().and_then(|job| {
            self.prefetch.as_mut().expect("prefetch job without a prefetch engine").wait(job)
        });
        let store = self.spill.as_mut().expect("suspended request without a spill store");
        let mut cache =
            KvCache::paged_dtype(&self.mcfg, self.cfg.block_tokens.max(1), lease, w.kv_dtype);
        if let Some(snaps) = staged {
            debug_assert_eq!(snaps.len(), sus.slots.len(), "staged job covers every slot");
            for snap in &snaps {
                // `load_block` cannot fail for a correctly-sized lease
                // (the snapshots were decoded and geometry-checked by
                // the IO thread), so this arm has no unwind path.
                cache.load_block(snap);
                store.note_prefetched_swap_in(snap.payload_bytes());
            }
        } else {
            if had_job {
                // The kick was charged as issued but its stage was
                // never consumed.
                store.note_prefetch_wasted(sus.slots.len());
            }
            for &slot in &sus.slots {
                match store.read_block(slot) {
                    Ok(snap) => cache.load_block(&snap),
                    Err(e) => {
                        // Unreadable region file: unwind so nothing leaks —
                        // every cold-tier slot (read ones stay live until
                        // freed) and the fresh lease go back, then surface
                        // the IO error as a backend failure.
                        for &s in &sus.slots {
                            store.free(s);
                        }
                        let l = cache.release_blocks();
                        self.blocks.free(l).map_err(EngineError::Page)?;
                        // The request is terminating, not resuming: bank its
                        // reuse counters like every other retirement path.
                        merge_reuse(&mut self.retired_reuse, &w.policies);
                        return Err(EngineError::Backend(e.into()));
                    }
                }
            }
        }
        for &slot in &sus.slots {
            store.free(slot);
        }
        debug_assert_eq!(cache.tokens(), sus.cached_tokens, "swap-in must restore every token");
        // Swap-in memcpys must not double-charge the per-request host
        // counters; restore them as if the request was never preempted
        // (the cold-tier traffic is charged to the spill store's stats).
        cache.stats = sus.stats;
        Ok(Active {
            id: w.id,
            gen_len: w.gen_len,
            sampler: w.sampler,
            cache,
            policies: w.policies,
            rng: sus.rng,
            tokens: sus.tokens,
            reported: w.reported,
            next_token: sus.next_token,
            pos: sus.pos,
            prefill_left: sus.prefill_left,
            prompt: w.prompt,
            arrival_s: w.arrival_s,
            seed_tag: w.seed_tag,
            just_prefilled: false,
            started: Instant::now(),
            wait_s: w.wait_s.unwrap_or((now - w.arrival_s).max(0.0)),
            ttft_s: w.ttft_s,
            decode_s: sus.decode_s,
            density_sum: sus.density_sum,
            density_n: sus.density_n,
            step: sus.step,
        })
    }

    /// Build the active-state for an admitted request. `matched_tokens`
    /// prompt tokens are already covered by shared prefix blocks (the
    /// caller copies their rows in); prefill resumes after them.
    fn admit(&self, w: Waiting, table: Vec<BlockId>, matched_tokens: usize, now: f64) -> Active {
        let prefill_left = w.prompt.len() - matched_tokens;
        let first = *w.prompt.get(matched_tokens).unwrap_or(&0);
        Active {
            id: w.id,
            gen_len: w.gen_len,
            sampler: w.sampler,
            cache: KvCache::paged_dtype(&self.mcfg, self.cfg.block_tokens.max(1), table, w.kv_dtype),
            policies: w.policies,
            rng: self.request_rng(w.seed_tag),
            tokens: Vec::new(),
            reported: w.reported,
            next_token: first,
            pos: matched_tokens,
            prefill_left,
            prompt: w.prompt,
            arrival_s: w.arrival_s,
            seed_tag: w.seed_tag,
            just_prefilled: false,
            started: Instant::now(),
            wait_s: w.wait_s.unwrap_or((now - w.arrival_s).max(0.0)),
            ttft_s: w.ttft_s,
            decode_s: 0.0,
            density_sum: 0.0,
            density_n: 0,
            step: 0,
        }
    }
}

/// Fold the reuse counters of a request's policies into an accumulator
/// (used when a request retires and again for live requests in
/// [`Session::stats`]).
fn merge_reuse(dst: &mut ReuseStats, policies: &[Box<dyn IndexPolicy>]) {
    for p in policies {
        if let Some(s) = p.reuse_stats() {
            dst.merge(s);
        }
    }
}

/// Advance one request by one scheduler round: up to `prefill_chunk`
/// prompt tokens while prefilling (dense, Setup B: context via full
/// attention), or exactly one decode step (sparse per policy). Runs on a
/// worker thread; touches only this request's state (phase 1 already
/// leased every block this round's appends need).
fn advance<B: Backend>(
    backend: &B,
    prefill_chunk: usize,
    a: &mut Active,
) -> Result<(), EngineError> {
    let n_heads = backend.config().n_heads;
    let t0 = Instant::now();
    let out: StepOut;
    if a.prefill_left > 0 {
        let take = a.prefill_left.min(prefill_chunk);
        let mut last: Option<StepOut> = None;
        for _ in 0..take {
            let tok = a.prompt[a.pos];
            last = Some(
                backend.step(tok, a.pos, &mut a.cache, None).map_err(EngineError::Backend)?,
            );
            a.prefill_left -= 1;
            a.pos += 1;
        }
        if a.prefill_left > 0 {
            return Ok(()); // still prefilling: nothing to sample yet
        }
        if a.reported == 0 {
            // Accumulate: a swap-in-resumed request adds this segment to
            // the active time banked at swap-out (fresh requests start
            // from 0.0, so this is plain assignment for them). A replay
            // (reported > 0) re-runs prefill, but the user saw their
            // first token long ago — keep that TTFT.
            a.ttft_s += a.started.elapsed().as_secs_f64();
        }
        // Bank prefill traffic (prompt appends + prefix-fork copy-ins)
        // instead of resetting it away: the live counters restart for
        // decode, and the banked side surfaces as `kv_prefill_bytes_*`.
        a.cache.stats.end_prefill_phase();
        a.just_prefilled = true; // merge phase publishes prompt blocks
        out = last.expect("prefill_chunk >= 1");
    } else {
        let sparse = !a.policies.is_empty();
        let policies = &mut a.policies;
        let rng = &mut a.rng;
        let step = a.step;
        let mut select = |l: usize,
                          h: usize,
                          k: &Mat,
                          v: &Mat,
                          q: &[f32],
                          qb: Option<KvQuantBounds>|
         -> Selection {
            let policy = &mut policies[l * n_heads + h];
            // Quantized caches report their dequantization bounds every
            // step (they grow with appended rows); verified policies
            // fold them into the (ε, δ) budget and the reuse
            // certificate before selecting.
            policy.set_kv_quant(qb);
            let mut ctx = PolicyCtx { k, v, q_scaled: q, rng: &mut *rng, step };
            policy.select(&mut ctx)
        };
        let sel_opt: Option<&mut crate::server::SelectFn> =
            if sparse { Some(&mut select) } else { None };
        let stepped = backend
            .step(a.next_token, a.pos, &mut a.cache, sel_opt)
            .map_err(EngineError::Backend)?;
        a.decode_s += t0.elapsed().as_secs_f64();
        a.pos += 1;
        a.step += 1;
        a.density_sum += stepped.mean_density;
        a.density_n += 1;
        out = stepped;
    }
    // Sample the next token once the prompt is fully ingested. The
    // sampler consumes this request's private RNG, so the draw sequence
    // is identical no matter how rounds are scheduled across workers.
    let tok = a.sampler.sample(&out.logits, &mut a.rng);
    if a.tokens.len() < a.gen_len && (a.step > 0 || a.pos == a.prompt.len()) {
        // The token just generated becomes the next input.
        a.tokens.push(tok);
        a.next_token = tok;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::Model;
    use crate::policies::SizeSpec;

    fn tiny_session(cfg: EngineConfig) -> Session<Model> {
        Session::new(Model::new(ModelConfig::tiny(), 42), cfg)
    }

    fn prompt(len: usize, salt: u32) -> Vec<u32> {
        (0..len as u32).map(|t| (t * 13 + salt) % 250).collect()
    }

    /// Drive to idle, collecting all events.
    fn drain(session: &mut Session<Model>) -> Vec<Event> {
        let mut evs = Vec::new();
        while !session.is_idle() {
            evs.extend(session.tick().expect("tick"));
        }
        evs
    }

    #[test]
    fn submit_tick_emits_admitted_tokens_finished() {
        let mut s = tiny_session(EngineConfig::default());
        let id = s.submit(SubmitRequest::new(prompt(12, 1)).options(GenOptions::new(5)));
        let evs = drain(&mut s);
        let mut tokens = Vec::new();
        let mut admitted = false;
        let mut finished = None;
        let mut last_t = 0.0;
        for ev in evs {
            match ev {
                Event::Admitted { id: i, t_s } => {
                    assert_eq!(i, id);
                    admitted = true;
                    last_t = t_s;
                }
                Event::Token { id: i, token, step, t_s } => {
                    assert_eq!(i, id);
                    assert_eq!(step, tokens.len());
                    assert!(t_s >= last_t);
                    last_t = t_s;
                    tokens.push(token);
                }
                Event::Finished { id: i, result, .. } => {
                    assert_eq!(i, id);
                    finished = Some(result);
                }
                Event::Preempted { .. } => panic!("unbounded pool must not preempt"),
                Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
            }
        }
        assert!(admitted);
        let result = finished.expect("finished event");
        assert_eq!(result.tokens.len(), 5);
        assert_eq!(result.tokens, tokens, "Token events must replay the result stream");
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    #[test]
    fn per_request_sampler_and_seed_are_isolated() {
        // Two identical prompts with different samplers in one batch:
        // the greedy one must match a solo greedy run exactly.
        let solo = {
            let mut s = tiny_session(EngineConfig::default());
            s.submit(SubmitRequest::new(prompt(10, 3)).options(GenOptions::new(6)));
            drain(&mut s)
                .into_iter()
                .find_map(|e| match e {
                    Event::Finished { result, .. } => Some(result.tokens),
                    _ => None,
                })
                .unwrap()
        };
        let mut s = tiny_session(EngineConfig::default());
        let greedy = s.submit(SubmitRequest::new(prompt(10, 3)).options(GenOptions::new(6)));
        let hot = s.submit(
            SubmitRequest::new(prompt(10, 3))
                .options(GenOptions::new(6).sampler(Sampler::Temperature(2.0)).seed(999)),
        );
        let mut results = std::collections::BTreeMap::new();
        for ev in drain(&mut s) {
            if let Event::Finished { id, result, .. } = ev {
                results.insert(id, result.tokens);
            }
        }
        assert_eq!(results[&greedy], solo, "sampler override must not perturb neighbors");
        assert_eq!(results[&hot].len(), 6);
    }

    #[test]
    fn oversized_request_is_rejected_as_event() {
        let mcfg = ModelConfig::tiny();
        let cfg = EngineConfig::builder()
            .block_tokens(16)
            .kv_capacity_bytes(16 * mcfg.kv_bytes_per_token())
            .build();
        let mut s = tiny_session(cfg);
        let ok = s.submit(SubmitRequest::new(prompt(6, 0)).options(GenOptions::new(3)));
        let doomed = s.submit(SubmitRequest::new(prompt(40, 0)).options(GenOptions::new(8)));
        let evs = drain(&mut s);
        let rejected: Vec<_> = evs
            .iter()
            .filter_map(|e| match e {
                Event::Rejected { id, reason, .. } => Some((*id, format!("{reason}"))),
                _ => None,
            })
            .collect();
        assert_eq!(rejected.len(), 1);
        assert_eq!(rejected[0].0, doomed);
        assert!(rejected[0].1.contains("KV blocks"), "{}", rejected[0].1);
        assert!(
            evs.iter().any(
                |e| matches!(e, Event::Finished { id, result, .. } if *id == ok && result.tokens.len() == 3)
            ),
            "the serveable request must still complete"
        );
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    #[test]
    fn max_seq_len_rejects_with_prompt_too_long() {
        let cfg = EngineConfig::builder().max_seq_len(16).build();
        let mut s = tiny_session(cfg);
        let id = s.submit(SubmitRequest::new(prompt(20, 0)).options(GenOptions::new(4)));
        let evs = s.tick().unwrap();
        assert!(matches!(
            &evs[..],
            [Event::Rejected { id: i, reason: EngineError::PromptTooLong { len: 24, max: 16 }, .. }]
                if *i == id
        ));
        assert!(s.is_idle());
    }

    #[test]
    fn submit_validated_surfaces_rejections_synchronously() {
        let mcfg = ModelConfig::tiny();
        let cfg = EngineConfig::builder()
            .max_seq_len(16)
            .block_tokens(16)
            .kv_capacity_bytes(16 * mcfg.kv_bytes_per_token())
            .build();
        let mut s = tiny_session(cfg);
        assert!(matches!(
            s.submit_validated(SubmitRequest::new(prompt(20, 0)).options(GenOptions::new(4))),
            Err(EngineError::PromptTooLong { len: 24, max: 16 })
        ));
        assert!(matches!(
            s.submit_validated(SubmitRequest::new(prompt(6, 0)).options(GenOptions::new(10))),
            Err(EngineError::KvCapacityExceeded { .. })
        ));
        // No Rejected events were queued, and ids were not handed out
        // for the failures: the next accepted request gets a fresh id
        // and streams normally.
        let id = s
            .submit_validated(SubmitRequest::new(prompt(6, 0)).options(GenOptions::new(3)))
            .expect("serveable request");
        let evs = drain(&mut s);
        assert!(
            !evs.iter().any(|e| matches!(e, Event::Rejected { .. })),
            "synchronous validation must not double-report as events"
        );
        assert!(evs
            .iter()
            .any(|e| matches!(e, Event::Finished { id: i, result, .. } if *i == id && result.tokens.len() == 3)));
    }

    #[test]
    fn cancel_waiting_and_active_and_unknown() {
        let cfg = EngineConfig::builder().max_batch(1).build();
        let mut s = tiny_session(cfg);
        let a = s.submit(SubmitRequest::new(prompt(8, 1)).options(GenOptions::new(40)));
        let b = s.submit(SubmitRequest::new(prompt(8, 2)).options(GenOptions::new(4)));
        s.tick().unwrap(); // admits only `a` (max_batch 1); `b` waits
        assert_eq!(s.active_len(), 1);
        assert_eq!(s.waiting_len(), 1);
        let held = s.kv_blocks_in_use();
        assert!(held > 0);
        s.cancel(b).expect("cancel waiting");
        s.cancel(a).expect("cancel active");
        assert_eq!(s.kv_blocks_in_use(), 0, "cancel must return the active lease");
        assert!(matches!(s.cancel(a), Err(EngineError::UnknownRequest(_))));
        assert!(matches!(s.cancel(77), Err(EngineError::UnknownRequest(77))));
        assert!(s.is_idle());
    }

    #[test]
    fn verified_override_runs_sparser_than_dense_neighbor() {
        let mut s = tiny_session(EngineConfig::default());
        let vcfg = VAttentionConfig {
            sink: SizeSpec::Abs(4),
            window: SizeSpec::Abs(8),
            heavy: SizeSpec::Frac(0.05),
            verify: crate::budget::Verify::Denominator,
            ..Default::default()
        }
        .with_guarantee(0.2, 0.2);
        let dense = s.submit(SubmitRequest::new(prompt(192, 5)).options(GenOptions::new(8)));
        let sparse =
            s.submit(SubmitRequest::new(prompt(192, 5)).options(GenOptions::new(8).verified_with(vcfg)));
        let mut results = std::collections::BTreeMap::new();
        for ev in drain(&mut s) {
            if let Event::Finished { id, result, .. } = ev {
                results.insert(id, result);
            }
        }
        assert!((results[&dense].mean_density - 1.0).abs() < 1e-9);
        assert!(results[&sparse].mean_density < 1.0);
        assert!(results[&sparse].kv_bytes_read < results[&dense].kv_bytes_read);
    }

    #[test]
    fn verified_reuse_streams_match_verified_and_aggregate_stats() {
        let vcfg = VAttentionConfig {
            sink: SizeSpec::Abs(4),
            window: SizeSpec::Abs(8),
            heavy: SizeSpec::Frac(0.05),
            verify: crate::budget::Verify::Denominator,
            ..Default::default()
        }
        .with_guarantee(0.2, 0.2);
        let run = |reuse: bool| {
            let mut s = tiny_session(EngineConfig::default());
            let opts = GenOptions::new(8);
            let opts = if reuse {
                opts.verified_reuse_with(vcfg.clone(), crate::policies::ReuseConfig::default())
            } else {
                opts.verified_with(vcfg.clone())
            };
            s.submit(SubmitRequest::new(prompt(192, 5)).options(opts));
            let mut tokens = Vec::new();
            for ev in drain(&mut s) {
                if let Event::Finished { result, .. } = ev {
                    tokens = result.tokens;
                }
            }
            (tokens, s.stats().reuse)
        };
        let (plain_tokens, plain_reuse) = run(false);
        let (reuse_tokens, reuse_stats) = run(true);
        assert_eq!(plain_tokens.len(), 8);
        assert_eq!(
            plain_tokens, reuse_tokens,
            "temporal reuse must not change the token stream"
        );
        // The stats survive the request retiring (aggregated at finish).
        assert_eq!(plain_reuse.selects, 0, "plain vattention reports no reuse counters");
        // 8 tokens = 1 from prefill logits + 7 policy-driven decode steps.
        let mcfg = ModelConfig::tiny();
        assert_eq!(
            reuse_stats.selects,
            7 * (mcfg.n_layers * mcfg.n_heads) as u64,
            "one select per decode step per (layer, head): {reuse_stats:?}"
        );
        assert_eq!(reuse_stats.selects, reuse_stats.hits + reuse_stats.refreshes());
        assert_eq!(reuse_stats.scorer_calls, reuse_stats.refreshes());
    }

    #[test]
    fn verified_reuse_cancel_keeps_counters() {
        let mut s = tiny_session(EngineConfig::default());
        let id = s.submit(
            SubmitRequest::new(prompt(64, 3)).options(GenOptions::new(40).verified_reuse(0.2, 0.2)),
        );
        // A few ticks so decode selects actually run, then cancel.
        for _ in 0..6 {
            s.tick().unwrap();
        }
        let before = s.stats().reuse;
        s.cancel(id).expect("cancel active");
        let after = s.stats().reuse;
        assert!(before.selects > 0, "decode steps must have selected: {before:?}");
        assert_eq!(before, after, "cancel must retire, not drop, the counters");
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    #[test]
    fn session_default_attention_applies_to_inherit() {
        let mut s = tiny_session(EngineConfig::default());
        let vcfg = VAttentionConfig {
            sink: SizeSpec::Abs(4),
            window: SizeSpec::Abs(8),
            heavy: SizeSpec::Frac(0.05),
            verify: crate::budget::Verify::Denominator,
            ..Default::default()
        }
        .with_guarantee(0.2, 0.2);
        s.set_default_attention(AttentionOpt::Verified(vcfg));
        let inherit = s.submit(SubmitRequest::new(prompt(192, 6)).options(GenOptions::new(6)));
        let dense =
            s.submit(SubmitRequest::new(prompt(192, 6)).options(GenOptions::new(6).dense()));
        let mut results = std::collections::BTreeMap::new();
        for ev in drain(&mut s) {
            if let Event::Finished { id, result, .. } = ev {
                results.insert(id, result);
            }
        }
        assert!(results[&inherit].mean_density < 1.0, "inherit must pick up the default");
        assert!((results[&dense].mean_density - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kv_dtype_override_matches_engine_wide_int8_and_reports_compression() {
        let run = |cfg: EngineConfig, opts: GenOptions| {
            let mut s = tiny_session(cfg);
            s.submit(SubmitRequest::new(prompt(24, 7)).options(opts));
            let mut out = None;
            for ev in drain(&mut s) {
                if let Event::Finished { result, .. } = ev {
                    out = Some(result);
                }
            }
            (out.expect("finished"), s.stats())
        };
        let (r_f32, st_f32) = run(EngineConfig::default(), GenOptions::new(6));
        let (r_override, _) = run(
            EngineConfig::default(),
            GenOptions::new(6).kv_dtype(KvDtype::Int8),
        );
        let (r_engine, st_int8) = run(
            EngineConfig::builder().kv_dtype(KvDtype::Int8).build(),
            GenOptions::new(6),
        );
        // Per-request override ≡ engine-wide dtype for the same request.
        assert_eq!(r_override.tokens, r_engine.tokens);
        assert_eq!(r_override.kv_bytes_read, r_engine.kv_bytes_read);
        // Physical traffic shrinks by the row compression (dense decode
        // touches the same row count either way).
        assert!(
            r_override.kv_bytes_read < r_f32.kv_bytes_read,
            "int8 {} !< f32 {}",
            r_override.kv_bytes_read,
            r_f32.kv_bytes_read
        );
        // Stats surface the dtype and the ≥ 3.5x bytes-per-token ratio.
        assert_eq!(st_f32.kv_dtype, KvDtype::F32);
        assert!((st_f32.kv_compression_ratio() - 1.0).abs() < 1e-12);
        assert_eq!(st_int8.kv_dtype, KvDtype::Int8);
        assert!(st_int8.kv_compression_ratio() >= 3.5, "{}", st_int8.kv_compression_ratio());
        assert_eq!(st_int8.bytes_per_token_fp32, ModelConfig::tiny().kv_bytes_per_token());
    }

    #[test]
    fn demand_paging_grows_blocks_with_generation() {
        // 4-token blocks, 4-token prompt, 12 generated tokens: the
        // request is admitted holding 1 block and must end holding 4 —
        // without any up-front worst-case lease.
        let cfg = EngineConfig::builder().block_tokens(4).build();
        let mut s = tiny_session(cfg);
        s.submit(SubmitRequest::new(prompt(4, 1)).options(GenOptions::new(12)));
        s.tick().unwrap(); // admission + prefill
        assert_eq!(s.kv_blocks_in_use(), 1, "admission leases prompt blocks only");
        let mut peak = 0;
        while !s.is_idle() {
            s.tick().unwrap();
            peak = peak.max(s.kv_blocks_in_use());
        }
        assert_eq!(peak, 4, "16 tokens at block 4 = 4 blocks, grown on demand");
        assert_eq!(s.kv_blocks_in_use(), 0);
        assert_eq!(s.stats().preemptions, 0);
    }

    #[test]
    fn headroom_delays_admission_but_everything_completes() {
        // Pool of 4 blocks, 1-block requests, headroom 2: at most two
        // requests may be resident at once (2 held + 2 reserve), even
        // though max_batch would allow four.
        let mcfg = ModelConfig::tiny();
        let cfg = EngineConfig::builder()
            .max_batch(4)
            .block_tokens(16)
            .kv_capacity_bytes(4 * 16 * mcfg.kv_bytes_per_token())
            .kv_headroom_blocks(2)
            .build();
        let mut s = tiny_session(cfg);
        for i in 0..4u32 {
            s.submit(SubmitRequest::new(prompt(6, i)).options(GenOptions::new(3)));
        }
        let mut max_active = 0;
        let mut finished = 0;
        while !s.is_idle() {
            for ev in s.tick().unwrap() {
                if let Event::Finished { .. } = ev {
                    finished += 1;
                }
            }
            max_active = max_active.max(s.active_len());
        }
        assert_eq!(finished, 4, "headroom must not starve anyone");
        assert!(max_active <= 2, "headroom of 2 in a 4-block pool caps residency at 2");
        assert_eq!(s.kv_blocks_in_use(), 0);
    }

    #[test]
    fn pool_exhaustion_preempts_lifo_and_replays_identically() {
        // Two long-generation requests in a pool that cannot hold both
        // to completion: the later-admitted one must be preempted
        // (Event::Preempted), re-run, and still produce exactly the
        // stream an uncontended run produces.
        let mcfg = ModelConfig::tiny();
        let contended = EngineConfig::builder()
            .max_batch(2)
            .block_tokens(4)
            .kv_capacity_bytes(7 * 4 * mcfg.kv_bytes_per_token()) // 7 blocks < 2 × 5
            .build();
        let free = EngineConfig::builder().max_batch(2).block_tokens(4).build();
        let run = |cfg: EngineConfig| {
            let mut s = tiny_session(cfg);
            let a = s.submit(SubmitRequest::new(prompt(8, 1)).options(GenOptions::new(12)));
            let b = s.submit(SubmitRequest::new(prompt(8, 2)).options(GenOptions::new(12)));
            let mut streams: std::collections::BTreeMap<u64, Vec<u32>> = Default::default();
            let mut preempted = Vec::new();
            for ev in drain(&mut s) {
                match ev {
                    Event::Token { id, token, step, .. } => {
                        let st = streams.entry(id).or_default();
                        assert_eq!(st.len(), step, "stream must stay gapless across preemption");
                        st.push(token);
                    }
                    Event::Preempted { id, .. } => preempted.push(id),
                    Event::Finished { id, result, .. } => {
                        assert_eq!(result.tokens, streams[&id], "events must replay the result");
                    }
                    Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                    Event::Admitted { .. } => {}
                }
            }
            assert_eq!(s.kv_blocks_in_use(), 0);
            ((streams[&a].clone(), streams[&b].clone()), preempted, s.stats().preemptions)
        };
        let (free_streams, no_preempts, n0) = run(free);
        assert!(no_preempts.is_empty());
        assert_eq!(n0, 0);
        let (contended_streams, preempts, n1) = run(contended);
        assert!(!preempts.is_empty(), "7 < 10 worst-case blocks must force preemption");
        assert!(n1 > 0);
        // LIFO victim rule: the most recently admitted request (b, id 1)
        // is always the first victim.
        assert_eq!(preempts[0], 1);
        assert_eq!(
            free_streams, contended_streams,
            "preempted replay must be byte-identical to the uncontended run"
        );
    }

    #[test]
    fn prefix_cache_forks_identical_prompts_and_flushes_clean() {
        let cfg = EngineConfig::builder().block_tokens(4).prefix_cache(true).build();
        let mut s = tiny_session(cfg);
        let p = prompt(16, 9);
        let a = s.submit(SubmitRequest::new(p.clone()).options(GenOptions::new(4)));
        let mut results = std::collections::BTreeMap::new();
        for ev in drain(&mut s) {
            if let Event::Finished { id, result, .. } = ev {
                results.insert(id, result.tokens);
            }
        }
        assert!(s.prefix_blocks_held() > 0, "prompt blocks published after prefill");
        let hits_before = s.stats().prefix_hit_blocks;
        // Same prompt again: forks off the cached prefix...
        let b = s.submit(SubmitRequest::new(p).options(GenOptions::new(4)));
        for ev in drain(&mut s) {
            if let Event::Finished { id, result, .. } = ev {
                results.insert(id, result.tokens);
            }
        }
        assert!(s.stats().prefix_hit_blocks > hits_before, "second run must hit the radix");
        // ...and must produce the same greedy stream (same model, same
        // prompt, same engine seed tagging by id? — ids differ, but
        // greedy sampling is RNG-free, so streams must match exactly).
        assert_eq!(results[&a], results[&b], "forked prefill must not change tokens");
        // Cache retains blocks past quiescence until flushed.
        assert!(s.is_idle());
        assert_eq!(s.kv_blocks_in_use(), s.prefix_blocks_held());
        let released = s.flush_prefix_cache().unwrap();
        assert!(released > 0);
        assert_eq!(s.kv_blocks_in_use(), 0, "flushed idle session is quiescent");
        assert!(s.stats().prefix_hit_rate() > 0.0);
    }

    fn tmp_spill(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("vattn-session-{name}-{}.spill", std::process::id()));
        p
    }

    fn rm_spill(path: &std::path::Path) {
        let _ = std::fs::remove_file(path);
        let mut os = path.as_os_str().to_os_string();
        os.push(".prefix");
        let _ = std::fs::remove_file(std::path::PathBuf::from(os));
    }

    #[test]
    fn spill_preemption_swaps_in_without_replay_and_streams_match() {
        // Same over-committed pool as the replay test (7 blocks < 2 × 5
        // worst case), but with a spill store: the LIFO victim's bytes
        // move to disk and back instead of being recomputed, and the
        // streams still match the unconstrained run byte for byte.
        let path = tmp_spill("preempt");
        let mcfg = ModelConfig::tiny();
        let contended = EngineConfig::builder()
            .max_batch(2)
            .block_tokens(4)
            .kv_capacity_bytes(7 * 4 * mcfg.kv_bytes_per_token())
            .kv_spill(&path)
            .build();
        let free = EngineConfig::builder().max_batch(2).block_tokens(4).build();
        let run = |cfg: EngineConfig| {
            let mut s = tiny_session(cfg);
            let a = s.submit(SubmitRequest::new(prompt(8, 1)).options(GenOptions::new(12)));
            let b = s.submit(SubmitRequest::new(prompt(8, 2)).options(GenOptions::new(12)));
            let mut streams: std::collections::BTreeMap<u64, Vec<u32>> = Default::default();
            for ev in drain(&mut s) {
                match ev {
                    Event::Token { id, token, step, .. } => {
                        let st = streams.entry(id).or_default();
                        assert_eq!(st.len(), step, "stream must stay gapless across swap-out");
                        st.push(token);
                    }
                    Event::Rejected { reason, .. } => panic!("unexpected rejection: {reason}"),
                    _ => {}
                }
            }
            assert_eq!(s.kv_blocks_in_use(), 0);
            ((streams[&a].clone(), streams[&b].clone()), s.stats(), s.spill_live_blocks())
        };
        let (free_streams, free_stats, no_spill) = run(free);
        assert_eq!(free_stats.preemptions, 0);
        assert_eq!(no_spill, None, "no spill store unless configured");
        let (spill_streams, stats, live) = run(contended);
        assert!(stats.preemptions > 0, "7 < 10 worst-case blocks must force preemption");
        assert_eq!(stats.preemption_replays, 0, "spill mode never replays compute");
        assert!(stats.spill_out_bytes > 0, "the victim's payload must hit the cold tier");
        assert!(stats.spill_out_ops > 0);
        assert_eq!(
            stats.swap_in_bytes, stats.spill_out_bytes,
            "everything spilled swaps back in exactly once"
        );
        assert_eq!(stats.swap_in_ops, stats.spill_out_ops);
        assert_eq!(live, Some(0), "no orphaned cold-tier blocks after the drain");
        assert_eq!(
            free_streams, spill_streams,
            "swap-in resume must be byte-identical to the uncontended run"
        );
        rm_spill(&path);
    }

    #[test]
    fn cancelling_a_suspended_request_frees_its_cold_tier_slots() {
        let path = tmp_spill("cancel");
        let mcfg = ModelConfig::tiny();
        let cfg = EngineConfig::builder()
            .max_batch(2)
            .block_tokens(4)
            .kv_capacity_bytes(7 * 4 * mcfg.kv_bytes_per_token())
            .kv_spill(&path)
            .build();
        let mut s = tiny_session(cfg);
        // `a` grows to all 7 pool blocks (8 prompt + 20 gen tokens at
        // 4/block), so the LIFO victim `b` (≥ 2 prompt blocks) is
        // guaranteed to be swapped out AND unable to re-admit while `a`
        // is at ≥ 6 blocks: at most 1 block is free then, fewer than b's
        // suspended slot count. The cancel-while-suspended state is
        // therefore reached deterministically, not by scheduling luck.
        let a = s.submit(SubmitRequest::new(prompt(8, 1)).options(GenOptions::new(20)));
        let b = s.submit(SubmitRequest::new(prompt(8, 2)).options(GenOptions::new(20)));
        // Tick until the victim is parked in the waiting queue suspended.
        let mut preempted = false;
        while !(preempted && s.waiting_len() > 0) {
            assert!(!s.is_idle(), "b must still be suspended when a finishes its growth");
            for ev in s.tick().unwrap() {
                if matches!(ev, Event::Preempted { id, .. } if id == b) {
                    preempted = true;
                }
            }
        }
        assert!(s.spill_live_blocks().unwrap() > 0, "suspended b owns cold-tier blocks");
        s.cancel(b).expect("cancel suspended");
        assert_eq!(
            s.spill_live_blocks(),
            Some(0),
            "cancelling a suspended request must free its cold-tier slots"
        );
        assert!(
            matches!(s.cancel(b), Err(EngineError::UnknownRequest(_))),
            "double cancel is UnknownRequest"
        );
        // `a` runs to completion untouched; nothing leaks in either tier.
        let evs = drain(&mut s);
        assert!(evs
            .iter()
            .any(|e| matches!(e, Event::Finished { id, result, .. } if *id == a && result.tokens.len() == 20)));
        assert_eq!(s.kv_blocks_in_use(), 0);
        assert_eq!(s.spill_live_blocks(), Some(0));
        rm_spill(&path);
    }

    #[test]
    fn prefetch_overlaps_swap_in_and_streams_stay_byte_identical() {
        // The async staging pipeline must be invisible in outputs: token
        // streams identical across {no spill, spill, spill+prefetch},
        // while the prefetch run retires every swap-in from staged
        // buffers — zero blocking cold-tier reads on the scheduler
        // thread (the queue-front kick fires at preemption, strictly
        // before the resume that consumes it).
        let mcfg = ModelConfig::tiny();
        let free = EngineConfig::builder().max_batch(2).block_tokens(4).build();
        let contended = |path: &std::path::Path, prefetch: bool| {
            EngineConfig::builder()
                .max_batch(2)
                .block_tokens(4)
                .kv_capacity_bytes(7 * 4 * mcfg.kv_bytes_per_token())
                .kv_spill(path)
                .kv_prefetch(prefetch)
                .build()
        };
        let run = |cfg: EngineConfig| {
            let mut s = tiny_session(cfg);
            let a = s.submit(SubmitRequest::new(prompt(8, 1)).options(GenOptions::new(12)));
            let b = s.submit(SubmitRequest::new(prompt(8, 2)).options(GenOptions::new(12)));
            let mut streams: std::collections::BTreeMap<u64, Vec<u32>> = Default::default();
            for ev in drain(&mut s) {
                if let Event::Token { id, token, .. } = ev {
                    streams.entry(id).or_default().push(token);
                }
            }
            assert_eq!(s.kv_blocks_in_use(), 0);
            ((streams[&a].clone(), streams[&b].clone()), s.stats(), s.spill_live_blocks())
        };
        let (free_streams, ..) = run(free);
        let off_path = tmp_spill("prefetch-off");
        let on_path = tmp_spill("prefetch-on");
        let (off_streams, off_stats, off_live) = run(contended(&off_path, false));
        let (on_streams, on_stats, on_live) = run(contended(&on_path, true));
        assert!(on_stats.preemptions > 0, "7 < 10 worst-case blocks must force preemption");
        assert_eq!(on_stats.preemption_replays, 0, "spill mode never replays compute");
        assert_eq!(free_streams, off_streams);
        assert_eq!(on_streams, free_streams, "prefetch must not change a single byte");
        // Prefetch off: every swap-in is a blocking scheduler-thread
        // read; nothing is ever issued to a staging engine.
        assert_eq!(off_stats.blocking_swap_in_ops, off_stats.swap_in_ops);
        assert_eq!(off_stats.prefetch_issued_ops, 0);
        // Prefetch on: the queue-front kick stages every suspended
        // request before its batch slot frees, so the blocking fallback
        // never runs and every stage is consumed.
        assert_eq!(on_stats.blocking_swap_in_ops, 0, "all swap-ins come from staged buffers");
        assert!(on_stats.prefetch_issued_ops > 0);
        assert_eq!(on_stats.prefetch_hit_ops, on_stats.prefetch_issued_ops);
        assert_eq!(on_stats.prefetch_wasted_ops, 0);
        assert!((on_stats.prefetch_hit_rate() - 1.0).abs() < 1e-12);
        // Conservation: the staging path must not change swap totals.
        assert_eq!(on_stats.swap_in_bytes, on_stats.spill_out_bytes);
        assert_eq!(on_stats.swap_in_ops, on_stats.spill_out_ops);
        assert_eq!(on_stats.prefetch_bytes, on_stats.swap_in_bytes);
        assert_eq!(off_live, Some(0));
        assert_eq!(on_live, Some(0), "no orphaned cold-tier blocks after the drain");
        rm_spill(&off_path);
        rm_spill(&on_path);
    }

    #[test]
    fn cancelling_a_prefetching_request_invalidates_the_staged_job() {
        // Cancel-while-prefetching unwind: the staged job is killed
        // before its slots recycle, the stage is charged as waste, and
        // neither tier leaks. Same deterministic geometry as
        // `cancelling_a_suspended_request_frees_its_cold_tier_slots`.
        let path = tmp_spill("prefetch-cancel");
        let mcfg = ModelConfig::tiny();
        let cfg = EngineConfig::builder()
            .max_batch(2)
            .block_tokens(4)
            .kv_capacity_bytes(7 * 4 * mcfg.kv_bytes_per_token())
            .kv_spill(&path)
            .kv_prefetch(true)
            .build();
        let mut s = tiny_session(cfg);
        let a = s.submit(SubmitRequest::new(prompt(8, 1)).options(GenOptions::new(20)));
        let b = s.submit(SubmitRequest::new(prompt(8, 2)).options(GenOptions::new(20)));
        let mut preempted = false;
        while !(preempted && s.waiting_len() > 0) {
            assert!(!s.is_idle(), "b must still be suspended when a finishes its growth");
            for ev in s.tick().unwrap() {
                if matches!(ev, Event::Preempted { id, .. } if id == b) {
                    preempted = true;
                }
            }
        }
        let mid = s.stats();
        assert!(
            mid.prefetch_issued_ops > mid.prefetch_hit_ops,
            "the live suspension's staged job must be kicked and still unconsumed"
        );
        s.cancel(b).expect("cancel suspended");
        assert_eq!(
            s.spill_live_blocks(),
            Some(0),
            "cancelling a prefetching request must free its cold-tier slots"
        );
        let st = s.stats();
        assert!(st.prefetch_wasted_ops > 0, "the dead stage is charged as waste");
        assert_eq!(
            st.prefetch_hit_ops + st.prefetch_wasted_ops,
            st.prefetch_issued_ops,
            "every issued block is either consumed or charged as waste"
        );
        // `a` runs to completion untouched; nothing leaks in either tier.
        let evs = drain(&mut s);
        assert!(evs
            .iter()
            .any(|e| matches!(e, Event::Finished { id, result, .. } if *id == a && result.tokens.len() == 20)));
        assert_eq!(s.kv_blocks_in_use(), 0);
        assert_eq!(s.spill_live_blocks(), Some(0));
        rm_spill(&path);
    }

    #[test]
    fn spill_victim_policy_prefers_quantized_blocks_over_lifo() {
        // Mixed-dtype batch under exhaustion: pure LIFO would evict `b`
        // (most recently admitted, f32), but the dtype-aware spill
        // policy picks `a` (int8) — the same freed pool blocks cost ~4x
        // fewer cold-tier bytes per transfer. Streams stay
        // byte-identical to the uncontended run, because *which* victim
        // spills never leaks into token selection.
        let path = tmp_spill("victim-dtype");
        let mcfg = ModelConfig::tiny();
        let contended = EngineConfig::builder()
            .max_batch(2)
            .block_tokens(4)
            .kv_capacity_bytes(7 * 4 * mcfg.kv_bytes_per_token())
            .kv_spill(&path)
            .build();
        let free = EngineConfig::builder().max_batch(2).block_tokens(4).build();
        let run = |cfg: EngineConfig| {
            let mut s = tiny_session(cfg);
            let a = s.submit(
                SubmitRequest::new(prompt(8, 1))
                    .options(GenOptions::new(12).kv_dtype(KvDtype::Int8)),
            );
            let b = s.submit(SubmitRequest::new(prompt(8, 2)).options(GenOptions::new(12)));
            let mut victims = Vec::new();
            let mut streams: std::collections::BTreeMap<u64, Vec<u32>> = Default::default();
            for ev in drain(&mut s) {
                match ev {
                    Event::Token { id, token, .. } => {
                        streams.entry(id).or_default().push(token);
                    }
                    Event::Preempted { id, .. } => victims.push(id),
                    _ => {}
                }
            }
            assert_eq!(s.kv_blocks_in_use(), 0);
            ((streams[&a].clone(), streams[&b].clone()), a, victims)
        };
        let (free_streams, _, no_preempts) = run(free);
        assert!(no_preempts.is_empty());
        let (spill_streams, a, victims) = run(contended);
        assert!(!victims.is_empty(), "7 < 10 worst-case blocks must force preemption");
        assert!(
            victims.iter().all(|&v| v == a),
            "the int8 request must always be the spill victim, not the LIFO pick"
        );
        assert_eq!(free_streams, spill_streams, "victim choice must not change a single byte");
        rm_spill(&path);
    }

    #[test]
    fn prefix_store_persists_and_warm_starts_a_fresh_session() {
        let path = tmp_spill("warmstart");
        rm_spill(&path); // stale state from a previous run would skew it
        let cfg = || {
            EngineConfig::builder()
                .block_tokens(4)
                .prefix_cache(true)
                .kv_spill(&path)
                .build()
        };
        let p = prompt(16, 9);
        let first = {
            let mut s = tiny_session(cfg());
            let id = s.submit(SubmitRequest::new(p.clone()).options(GenOptions::new(4)));
            let mut tokens = Vec::new();
            for ev in drain(&mut s) {
                if let Event::Finished { id: i, result, .. } = ev {
                    assert_eq!(i, id);
                    tokens = result.tokens;
                }
            }
            assert!(s.prefix_blocks_held() > 0);
            // Persists the radix to `<path>.prefix`, then drops it.
            assert!(s.flush_prefix_cache().unwrap() > 0);
            assert_eq!(s.kv_blocks_in_use(), 0);
            tokens
        };
        // A *fresh* session on the same spill path (process-restart
        // stand-in) warm-starts the radix from disk: the same prompt
        // forks instead of re-prefilling, and the stream is unchanged.
        let mut s2 = tiny_session(cfg());
        assert!(
            s2.prefix_blocks_held() > 0,
            "warm start must re-import the persisted radix"
        );
        let id2 = s2.submit(SubmitRequest::new(p).options(GenOptions::new(4)));
        let mut tokens2 = Vec::new();
        for ev in drain(&mut s2) {
            if let Event::Finished { id, result, .. } = ev {
                assert_eq!(id, id2);
                tokens2 = result.tokens;
            }
        }
        let st = s2.stats();
        assert!(st.prefix_hit_blocks > 0, "restarted session must hit the persisted radix");
        assert!(st.prefix_hit_rate() > 0.0);
        assert_eq!(first, tokens2, "warm-started fork must not change tokens");
        s2.flush_prefix_cache().unwrap();
        assert_eq!(s2.kv_blocks_in_use(), 0);
        rm_spill(&path);
    }

    #[test]
    fn prefill_traffic_is_banked_not_dropped() {
        let mut s = tiny_session(EngineConfig::default());
        s.submit(SubmitRequest::new(prompt(12, 3)).options(GenOptions::new(4)));
        let mut result = None;
        for ev in drain(&mut s) {
            if let Event::Finished { result: r, .. } = ev {
                result = Some(r);
            }
        }
        let r = result.expect("finished");
        let mcfg = ModelConfig::tiny();
        // Prefill appends 12 prompt tokens' K/V rows across every
        // (layer, kv-head) slot — traffic a plain counter reset used to
        // drop on the floor.
        assert_eq!(
            r.kv_prefill_bytes_written,
            12 * mcfg.kv_bytes_per_token(),
            "banked prefill writes must cover the whole prompt"
        );
        assert!(r.kv_bytes_written > 0, "decode writes stay decode-only");
        assert!(
            r.kv_bytes_written < r.kv_prefill_bytes_written,
            "4 decode tokens must write less than the 12-token prefill"
        );
    }
}
