//! Network serving front-end: a dependency-free streaming HTTP server
//! over [`std::net::TcpListener`] whose routes map onto the session
//! API through the sharded [`Router`].
//!
//! Routes:
//!
//! * `POST /v1/generate` — submit a request; the response is a chunked
//!   `application/x-ndjson` stream: one `{"id":N}` hello line, one
//!   `{"step":S,"token":T}` line per generated token, and a terminal
//!   `{"done":true,"n":K}` (or error / cancelled) line. Validation and
//!   load-shed failures never commit a 200: the first [`StreamEvent`]
//!   decides the status line (429 + `Retry-After` for retriable
//!   capacity rejections, 400 for request defects, 503 while
//!   draining).
//! * `DELETE /v1/requests/{id}` — cancel by global id (200 / 404).
//! * `GET /v1/stats` — per-shard and aggregate counters plus a
//!   [`PagingSummary`] per shard, as JSON.
//! * `GET /healthz` — liveness probe.
//!
//! Token chunks contain no timestamps, so a request's streamed body is
//! a deterministic byte sequence — the loopback determinism test
//! compares it against a direct [`crate::server::Session`] run.
//!
//! Client disconnects are detected at the first failed chunk write;
//! the handler then cancels the request through the router so its KV
//! lease (and any cold-tier slots) return immediately, rather than
//! waiting for the stream to finish into a dead socket.

use std::io::{self, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::metrics::PagingSummary;
use crate::server::engine::Backend;
use crate::server::http::{read_request, write_response, ChunkedWriter, Request};
use crate::server::router::{ErrorInfo, GlobalId, Router, RouterConfig, ShardStats, StreamEvent};
use crate::server::session::GenOptions;
use crate::util::json::Json;

/// Handle to a running server: the bound address, the router, the
/// accept thread, and every live connection handler. Dropping the
/// handle shuts the server down gracefully ([`NetServer::shutdown`]).
pub struct NetServer {
    addr: SocketAddr,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    accept: Mutex<Option<JoinHandle<()>>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `listen` (e.g. `"127.0.0.1:0"` for an ephemeral port),
    /// build the sharded router, and start accepting connections.
    pub fn start<B: Backend + Send + Sync + 'static>(
        backend: Arc<B>,
        listen: &str,
        cfg: RouterConfig,
    ) -> io::Result<NetServer> {
        let listener = TcpListener::bind(listen)?;
        let addr = listener.local_addr()?;
        // Non-blocking accept so the loop can poll the stop flag.
        listener.set_nonblocking(true)?;
        let router = Arc::new(Router::new(backend, cfg));
        let stop = Arc::new(AtomicBool::new(false));
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let router = Arc::clone(&router);
            let stop = Arc::clone(&stop);
            let handlers = Arc::clone(&handlers);
            std::thread::Builder::new()
                .name("vattn-accept".into())
                .spawn(move || accept_loop(listener, router, stop, handlers))
                .map_err(|e| io::Error::new(io::ErrorKind::Other, e))?
        };
        Ok(NetServer { addr, router, stop, accept: Mutex::new(Some(accept)), handlers })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The router behind the listener (tests inspect shard state).
    pub fn router(&self) -> &Arc<Router> {
        &self.router
    }

    /// Point-in-time per-shard stats.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.router.shard_stats()
    }

    /// Graceful shutdown: stop accepting, drain every shard (in-flight
    /// requests finish streaming; new ones get 503), join all handler
    /// threads, and return each shard's final [`ShardStats`].
    /// Idempotent.
    pub fn shutdown(&self) -> Vec<ShardStats> {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept.lock().expect("accept lock").take() {
            let _ = h.join();
        }
        // Drain shards first: handlers blocked on stream events need
        // the terminal events the drain produces before they can exit.
        let stats = self.router.shutdown();
        let handles: Vec<JoinHandle<()>> =
            self.handlers.lock().expect("handlers lock").drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        stats
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(
    listener: TcpListener,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
) {
    let mut next_conn = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let router = Arc::clone(&router);
                let stop = Arc::clone(&stop);
                // Small stacks: the bench opens 1000+ concurrent
                // connections and handlers only parse + format.
                let spawned = std::thread::Builder::new()
                    .name(format!("vattn-conn-{next_conn}"))
                    .stack_size(256 * 1024)
                    .spawn(move || {
                        let _ = handle_connection(stream, &router, &stop);
                    });
                next_conn += 1;
                if let Ok(h) = spawned {
                    handlers.lock().expect("handlers lock").push(h);
                }
            }
            // No pending connection (or transient error): poll again.
            Err(_) => std::thread::sleep(Duration::from_millis(2)),
        }
    }
}

/// Serve keep-alive requests on one connection until the client closes
/// it, asks for `Connection: close`, or the server is stopping.
fn handle_connection(stream: TcpStream, router: &Router, stop: &AtomicBool) -> io::Result<()> {
    let _ = stream.set_nodelay(true);
    // Short read timeout so idle keep-alive connections notice the
    // stop flag; a bounded write timeout so a stalled client reads as
    // a disconnect instead of pinning the handler forever.
    stream.set_read_timeout(Some(Duration::from_millis(50)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    loop {
        let mut reader = &stream;
        let req =
            match read_request(&mut reader, |partial| partial || !stop.load(Ordering::SeqCst)) {
                Ok(Some(req)) => req,
                Ok(None) => return Ok(()), // clean close or stopping while idle
                Err(e) if crate::server::http::is_body_too_large(&e) => {
                    // The head parsed fine, so the client can still be
                    // told why before the socket closes (the unread
                    // body bytes make keep-alive unsafe afterwards).
                    let mut writer = &stream;
                    let _ = error_response(
                        &mut writer,
                        413,
                        "payload_too_large",
                        &e.to_string(),
                        false,
                    );
                    return Err(e);
                }
                Err(e) => return Err(e),
            };
        let close = req.wants_close();
        let mut writer = &stream;
        route_request(&req, &mut writer, router)?;
        if close {
            return Ok(());
        }
    }
}

fn route_request<W: Write>(req: &Request, w: &mut W, router: &Router) -> io::Result<()> {
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/generate") => handle_generate(req, w, router),
        ("DELETE", path) if path.starts_with("/v1/requests/") => handle_cancel(path, w, router),
        ("GET", "/v1/stats") => {
            let body = stats_json(&router.shard_stats()).to_string();
            write_response(w, 200, "application/json", &[], body.as_bytes())
        }
        ("GET", "/healthz") => write_response(w, 200, "application/json", &[], b"{\"ok\":true}"),
        _ => error_response(w, 404, "not_found", "no such route", false),
    }
}

fn handle_generate<W: Write>(req: &Request, w: &mut W, router: &Router) -> io::Result<()> {
    let body = String::from_utf8_lossy(&req.body);
    let (prompt, opts) = match parse_generate(&body) {
        Ok(parsed) => parsed,
        Err(msg) => return error_response(w, 400, "bad_request", &msg, false),
    };
    let (id, rx) = router.submit(prompt, opts);
    // The first event decides the status line; nothing is written to
    // the socket until the shard accepts or rejects.
    match rx.recv() {
        Ok(StreamEvent::Accepted { .. }) => {}
        Ok(StreamEvent::Rejected { error, .. }) => return rejection_response(w, &error),
        Ok(_) | Err(_) => {
            return error_response(w, 500, "backend_error", "stream broke before acceptance", false)
        }
    }
    let mut cw = ChunkedWriter::start(&mut *w, 200, "application/x-ndjson", &[])?;
    if let Err(e) = cw.chunk(format!("{{\"id\":{id}}}\n").as_bytes()) {
        router.disconnect(id);
        return Err(e);
    }
    loop {
        match rx.recv() {
            Ok(StreamEvent::Token { step, token, .. }) => {
                let line = format!("{{\"step\":{step},\"token\":{token}}}\n");
                if let Err(e) = cw.chunk(line.as_bytes()) {
                    // Client hung up mid-stream: cancel so the KV
                    // lease and any cold-tier slots return now.
                    router.disconnect(id);
                    return Err(e);
                }
            }
            Ok(StreamEvent::Finished { result, .. }) => {
                let line = format!("{{\"done\":true,\"n\":{}}}\n", result.tokens.len());
                let _ = cw.chunk(line.as_bytes());
                return cw.finish();
            }
            Ok(StreamEvent::Failed { error, .. }) => {
                let line = Json::obj()
                    .field("error", Json::str(&*error.message))
                    .field("kind", Json::str(error.kind.name()))
                    .to_string();
                let _ = cw.chunk(format!("{line}\n").as_bytes());
                return cw.finish();
            }
            Ok(StreamEvent::Cancelled { .. }) => {
                let _ = cw.chunk(b"{\"cancelled\":true}\n");
                return cw.finish();
            }
            Ok(StreamEvent::Accepted { .. }) | Ok(StreamEvent::Rejected { .. }) => {}
            Err(_) => return cw.finish(), // shard died; end the stream
        }
    }
}

fn handle_cancel<W: Write>(path: &str, w: &mut W, router: &Router) -> io::Result<()> {
    let id_str = &path["/v1/requests/".len()..];
    let id: GlobalId = match id_str.parse() {
        Ok(v) => v,
        Err(_) => {
            return error_response(w, 400, "bad_request", "request id must be an integer", false)
        }
    };
    if router.cancel(id) {
        let body = format!("{{\"cancelled\":{id}}}");
        write_response(w, 200, "application/json", &[], body.as_bytes())
    } else {
        error_response(w, 404, "unknown_request", &format!("unknown request {id}"), false)
    }
}

/// Parse a `POST /v1/generate` body:
/// `{"prompt":[u32...], "gen_len":N, "seed":S?, "mode":"dense"|"verified"|"verified_reuse", "eps":E?, "delta":D?}`.
fn parse_generate(body: &str) -> Result<(Vec<u32>, GenOptions), String> {
    let j = Json::parse(body).map_err(|e| format!("invalid JSON: {e}"))?;
    let arr = j
        .get("prompt")
        .ok_or("missing field: prompt")?
        .as_arr()
        .ok_or("prompt must be an array of token ids")?;
    if arr.is_empty() {
        return Err("prompt must be non-empty".into());
    }
    let mut prompt = Vec::with_capacity(arr.len());
    for t in arr {
        let v = t.as_u64().ok_or("prompt tokens must be non-negative integers")?;
        if v > u32::MAX as u64 {
            return Err("prompt token out of u32 range".into());
        }
        prompt.push(v as u32);
    }
    let gen_len = match j.get("gen_len") {
        Some(v) => v.as_usize().ok_or("gen_len must be a non-negative integer")?,
        None => 16,
    };
    let mut opts = GenOptions::new(gen_len);
    if let Some(seed) = j.get("seed") {
        opts = opts.seed(seed.as_u64().ok_or("seed must be a non-negative integer")?);
    }
    let eps = match j.get("eps") {
        Some(v) => v.as_f64().ok_or("eps must be a number")?,
        None => 0.05,
    };
    let delta = match j.get("delta") {
        Some(v) => v.as_f64().ok_or("delta must be a number")?,
        None => 0.05,
    };
    match j.get("mode").map(|m| m.as_str().ok_or("mode must be a string")).transpose()? {
        None | Some("dense") => {}
        Some("verified") => opts = opts.verified(eps, delta),
        Some("verified_reuse") => opts = opts.verified_reuse(eps, delta),
        Some(other) => return Err(format!("unknown mode {other:?}")),
    }
    Ok((prompt, opts))
}

fn error_body(kind: &str, message: &str, retriable: bool) -> Vec<u8> {
    Json::obj()
        .field(
            "error",
            Json::obj()
                .field("kind", Json::str(kind))
                .field("message", Json::str(message))
                .field("retriable", Json::Bool(retriable)),
        )
        .to_string()
        .into_bytes()
}

fn error_response<W: Write>(
    w: &mut W,
    status: u16,
    kind: &str,
    message: &str,
    retriable: bool,
) -> io::Result<()> {
    let body = error_body(kind, message, retriable);
    let headers: &[(&str, &str)] = if retriable { &[("Retry-After", "1")] } else { &[] };
    write_response(w, status, "application/json", headers, &body)
}

/// Map a typed shard rejection onto its HTTP status (429/400/404/503,
/// with `Retry-After` on retriable capacity rejections).
fn rejection_response<W: Write>(w: &mut W, error: &ErrorInfo) -> io::Result<()> {
    let retriable = error.kind.retriable();
    let body = error_body(error.kind.name(), &error.message, retriable);
    let headers: &[(&str, &str)] = if retriable { &[("Retry-After", "1")] } else { &[] };
    write_response(w, error.kind.http_status(), "application/json", headers, &body)
}

/// `GET /v1/stats` body: per-shard counters + paging summary, plus the
/// aggregate across shards.
fn stats_json(stats: &[ShardStats]) -> Json {
    let received: u64 = stats.iter().map(|s| s.received).sum();
    let shed: u64 = stats.iter().map(|s| s.shed).sum();
    let agg = Json::obj()
        .field("received", Json::num(received as f64))
        .field("submitted", Json::num(stats.iter().map(|s| s.submitted).sum::<u64>() as f64))
        .field("shed", Json::num(shed as f64))
        .field("rejected", Json::num(stats.iter().map(|s| s.rejected).sum::<u64>() as f64))
        .field("completed", Json::num(stats.iter().map(|s| s.completed).sum::<u64>() as f64))
        .field("failed", Json::num(stats.iter().map(|s| s.failed).sum::<u64>() as f64))
        .field("cancelled", Json::num(stats.iter().map(|s| s.cancelled).sum::<u64>() as f64))
        .field(
            "disconnected",
            Json::num(stats.iter().map(|s| s.disconnected).sum::<u64>() as f64),
        )
        .field("outstanding", Json::num(stats.iter().map(|s| s.outstanding).sum::<usize>() as f64))
        .field(
            "shed_rate",
            Json::num(if received > 0 { shed as f64 / received as f64 } else { 0.0 }),
        );
    Json::obj().field("shards", Json::arr(stats.iter().map(shard_json))).field("aggregate", agg)
}

fn shard_json(s: &ShardStats) -> Json {
    let paging = PagingSummary::from(&s.session);
    Json::obj()
        .field("shard", Json::num(s.shard as f64))
        .field("received", Json::num(s.received as f64))
        .field("submitted", Json::num(s.submitted as f64))
        .field("shed", Json::num(s.shed as f64))
        .field("rejected", Json::num(s.rejected as f64))
        .field("completed", Json::num(s.completed as f64))
        .field("failed", Json::num(s.failed as f64))
        .field("cancelled", Json::num(s.cancelled as f64))
        .field("disconnected", Json::num(s.disconnected as f64))
        .field("outstanding", Json::num(s.outstanding as f64))
        .field("waiting", Json::num(s.waiting as f64))
        .field("active", Json::num(s.active as f64))
        .field("kv_blocks_in_use", Json::num(s.kv_blocks_in_use as f64))
        .field("prefix_blocks_held", Json::num(s.prefix_blocks_held as f64))
        .field(
            "spill_live_blocks",
            match s.spill_live_blocks {
                Some(n) => Json::num(n as f64),
                None => Json::Null,
            },
        )
        .field(
            "paging",
            Json::obj()
                .field("prefix_hit_rate", Json::num(paging.prefix_hit_rate))
                .field("preemptions", Json::num(paging.preemptions as f64))
                .field("preemption_replays", Json::num(paging.preemption_replays as f64))
                .field("spill_out_bytes", Json::num(paging.spill_out_bytes as f64))
                .field("swap_in_bytes", Json::num(paging.swap_in_bytes as f64))
                .field("blocking_swap_in_ops", Json::num(paging.blocking_swap_in_ops as f64))
                .field("prefetch_hit_rate", Json::num(paging.prefetch_hit_rate()))
                .field("swap_in_overlap_rate", Json::num(paging.swap_in_overlap_rate()))
                .field("peak_blocks_in_use", Json::num(paging.peak_blocks_in_use as f64))
                .field("kv_dtype", Json::str(paging.kv_dtype.name())),
        )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::session::AttentionOpt;

    #[test]
    fn parse_generate_accepts_minimal_and_full_bodies() {
        let (prompt, opts) = parse_generate(r#"{"prompt":[1,2,3]}"#).expect("minimal");
        assert_eq!(prompt, vec![1, 2, 3]);
        assert_eq!(opts.gen_len, 16);
        assert!(opts.seed.is_none());

        let (prompt, opts) = parse_generate(
            r#"{"prompt":[5,6],"gen_len":4,"seed":9,"mode":"verified","eps":0.1,"delta":0.2}"#,
        )
        .expect("full");
        assert_eq!(prompt, vec![5, 6]);
        assert_eq!(opts.gen_len, 4);
        assert_eq!(opts.seed, Some(9));
        assert!(!matches!(opts.attention, AttentionOpt::Inherit));
    }

    #[test]
    fn parse_generate_rejects_defects() {
        assert!(parse_generate("").is_err());
        assert!(parse_generate("{}").is_err());
        assert!(parse_generate(r#"{"prompt":[]}"#).is_err());
        assert!(parse_generate(r#"{"prompt":[1.5]}"#).is_err());
        assert!(parse_generate(r#"{"prompt":[-3]}"#).is_err());
        assert!(parse_generate(r#"{"prompt":[1],"gen_len":-2}"#).is_err());
        assert!(parse_generate(r#"{"prompt":[1],"mode":"warp"}"#).is_err());
        assert!(parse_generate(r#"{"prompt":[4294967296]}"#).is_err());
    }

    #[test]
    fn stats_json_aggregates_shard_counters() {
        let mut a = ShardStats { shard: 0, ..ShardStats::default() };
        a.received = 10;
        a.shed = 2;
        a.completed = 8;
        let mut b = ShardStats { shard: 1, ..ShardStats::default() };
        b.received = 6;
        b.completed = 6;
        let j = stats_json(&[a, b]);
        let parsed = Json::parse(&j.to_string()).expect("roundtrip");
        let agg = parsed.get("aggregate").expect("aggregate");
        assert_eq!(agg.get("received").and_then(Json::as_usize), Some(16));
        assert_eq!(agg.get("shed").and_then(Json::as_usize), Some(2));
        let rate = agg.get("shed_rate").and_then(Json::as_f64).expect("shed_rate");
        assert!((rate - 2.0 / 16.0).abs() < 1e-12);
        assert_eq!(parsed.get("shards").and_then(Json::as_arr).map(|s| s.len()), Some(2));
    }

    #[test]
    fn error_body_is_parseable_json() {
        let body = error_body("shard_queue_full", "shard 3 is full (64 waiting)", true);
        let parsed = Json::parse(std::str::from_utf8(&body).unwrap()).expect("parse");
        let err = parsed.get("error").expect("error");
        assert_eq!(err.get("kind").and_then(Json::as_str), Some("shard_queue_full"));
        assert_eq!(err.get("retriable").and_then(Json::as_bool), Some(true));
    }
}
