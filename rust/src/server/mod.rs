//! The serving coordinator: request admission, continuous batching, and
//! the generation loop, generic over the compute backend (rust-native
//! model or the PJRT artifact path).
//!
//! Responsibilities mirror a vLLM-style router specialized to the
//! paper's deployment: every request's KV cache is host-resident and
//! backed by *demand-paged* blocks from the engine's reference-counted
//! allocator — prompt blocks at admission (shared with other requests
//! through the prefix cache where prompts coincide), generation blocks
//! one at a time as decoding crosses block boundaries, and
//! deterministic LIFO preemption when the pool runs dry; every decode
//! step runs index selection per (layer, head) through the configured
//! policy; attention reads only the selected rows. Step execution fans
//! out across a worker pool (requests are data-parallel within a
//! scheduler round) and merges deterministically, so token streams are
//! byte-identical at any worker count.
//!
//! Two entry points share one scheduler:
//!
//! * **Streaming** — [`Session`]: `submit` / `cancel` / `tick`, with
//!   per-request [`GenOptions`] (sampler, generation length, seed, and
//!   an attention contract including per-request (ε, δ)) and typed
//!   [`EngineError`]s. Each `tick` emits [`Event`]s as they happen.
//! * **Batch** — [`Engine::serve`] / [`Engine::serve_open_loop`]: thin
//!   drive-the-session loops that return `Vec<RequestResult>` at the
//!   end, kept for experiments, benches and tests.

pub mod engine;
pub mod http;
pub mod net;
pub mod router;
pub mod session;

pub use engine::{
    AttentionMode, Backend, BatchPolicyFactory, Engine, EngineConfig, EngineConfigBuilder,
    SelectFn,
};
pub use net::NetServer;
pub use router::{ErrorInfo, ErrorKind, GlobalId, Router, RouterConfig, ShardStats, StreamEvent};
pub use session::{
    AttentionOpt, EngineError, Event, GenOptions, PolicyFactory, RequestId, Session, SessionStats,
    SubmitRequest,
};

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub gen_len: usize,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, gen_len: usize) -> Request {
        Request { id, prompt, gen_len }
    }
}

/// A request with an arrival time, for open-loop (trace-driven) serving.
#[derive(Clone, Debug)]
pub struct ArrivingRequest {
    /// Seconds from trace start at which the request becomes visible to
    /// the scheduler.
    pub arrival_s: f64,
    pub req: Request,
}

impl ArrivingRequest {
    /// A request that is already queued at t = 0 (closed-loop serving).
    pub fn immediate(req: Request) -> ArrivingRequest {
        ArrivingRequest { arrival_s: 0.0, req }
    }

    pub fn at(arrival_s: f64, req: Request) -> ArrivingRequest {
        ArrivingRequest { arrival_s, req }
    }
}

/// Completion record with serving metrics.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Queue wait before admission (arrival → first prefill), seconds.
    pub wait_s: f64,
    /// Time to first token measured from admission (prefill), seconds.
    pub ttft_s: f64,
    /// Total decode wall-clock, seconds.
    pub decode_s: f64,
    /// Mean attention density over all decode steps.
    pub mean_density: f64,
    /// Bytes of KV gathered from the host tier during decode.
    pub kv_bytes_read: usize,
    /// Bytes of KV appended into the host tier during decode. The
    /// per-request counters are phase-split when prefill completes
    /// (`TierStats::end_prefill_phase`), so this keeps its decode-only
    /// meaning while nothing is dropped: prefill traffic is banked into
    /// the `kv_prefill_bytes_*` fields instead of being reset away.
    pub kv_bytes_written: usize,
    /// Bytes of KV gathered during the prefill phase (prefix-fork
    /// copy-in accounting rides here too).
    pub kv_prefill_bytes_read: usize,
    /// Bytes of KV appended during the prefill phase — prompt appends
    /// that a plain counter reset used to drop from every summary.
    pub kv_prefill_bytes_written: usize,
}

impl RequestResult {
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.tokens.len() as f64 / self.decode_s
        } else {
            0.0
        }
    }

    /// Mean time per output token (TPOT), seconds. The first token comes
    /// out of prefill (counted in TTFT), so decode time is divided over
    /// the remaining `tokens - 1` steps, per the usual convention.
    pub fn tpot_s(&self) -> f64 {
        if self.tokens.len() <= 1 {
            0.0
        } else {
            self.decode_s / (self.tokens.len() - 1) as f64
        }
    }

    /// Time to first token measured from *arrival* (queue wait included)
    /// — the user-visible TTFT under open-loop load.
    pub fn ttft_from_arrival_s(&self) -> f64 {
        self.wait_s + self.ttft_s
    }
}
