//! The serving coordinator: request admission, continuous batching, and
//! the generation loop, generic over the compute backend (rust-native
//! model or the PJRT artifact path).
//!
//! Responsibilities mirror a vLLM-style router specialized to the
//! paper's deployment: the KV cache is host-resident per request; every
//! decode step runs index selection per (layer, head) through the
//! configured policy; attention reads only the selected rows.

pub mod engine;

pub use engine::{AttentionMode, Engine, EngineConfig, PolicyFactory};

/// An inference request.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u32>,
    pub gen_len: usize,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u32>, gen_len: usize) -> Request {
        Request { id, prompt, gen_len }
    }
}

/// Completion record with serving metrics.
#[derive(Clone, Debug)]
pub struct RequestResult {
    pub id: u64,
    pub tokens: Vec<u32>,
    /// Time to first token (prefill), seconds.
    pub ttft_s: f64,
    /// Total decode wall-clock, seconds.
    pub decode_s: f64,
    /// Mean attention density over all decode steps.
    pub mean_density: f64,
    /// Bytes of KV gathered from the host tier during decode.
    pub kv_bytes_read: usize,
}

impl RequestResult {
    pub fn decode_tokens_per_s(&self) -> f64 {
        if self.decode_s > 0.0 {
            self.tokens.len() as f64 / self.decode_s
        } else {
            0.0
        }
    }
}
