//! Hand-rolled HTTP/1.1 framing for the network front-end. The build is
//! offline (no hyper/axum), so this implements exactly the subset
//! [`crate::server::net`] speaks: request-line + header parsing with
//! `Content-Length` bodies on the way in, fixed-length or
//! chunked-transfer responses on the way out. No pipelining, no
//! `Transfer-Encoding` on requests, no HTTP/2 — clients that need more
//! belong behind a real proxy.

use std::io::{self, Read, Write};

/// Largest accepted request head (request line + headers).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Largest accepted request body. Generous for token-id prompts: a
/// 128k-token prompt serializes to well under 1 MiB of JSON digits.
pub const MAX_BODY_BYTES: usize = 8 * 1024 * 1024;

/// One parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// Path as sent (query strings are not split off; the API has none).
    pub path: String,
    /// Header (name, value) pairs; names lower-cased at parse time.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// Case-insensitive header lookup (name must be given lower-case).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// True when the client asked to close the connection after this
    /// exchange (HTTP/1.1 defaults to keep-alive).
    pub fn wants_close(&self) -> bool {
        self.header("connection").map(|v| v.eq_ignore_ascii_case("close")).unwrap_or(false)
    }
}

/// Read and parse one request off the stream.
///
/// Returns `Ok(None)` on a clean close: EOF before any request bytes,
/// or the reader giving up while idle. `keep_waiting(have_partial)` is
/// consulted whenever the underlying read times out (`WouldBlock` /
/// `TimedOut` on a socket with a read timeout): return `false` to stop
/// waiting — the connection handler uses this to poll a shutdown flag
/// between keep-alive requests without holding the accept loop open
/// forever.
pub fn read_request<R: Read>(
    r: &mut R,
    mut keep_waiting: impl FnMut(bool) -> bool,
) -> io::Result<Option<Request>> {
    // ── head: accumulate until the blank line ──
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(end) = find_head_end(&buf) {
            break end;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("request head exceeds {MAX_HEAD_BYTES} bytes"),
            ));
        }
        match r.read(&mut chunk) {
            Ok(0) => {
                if buf.is_empty() {
                    return Ok(None); // clean close between requests
                }
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-request-head",
                ));
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                if !keep_waiting(!buf.is_empty()) {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    };

    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-utf8 request head"))?;
    let mut lines = head.split("\r\n");
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ');
    let method = parts.next().unwrap_or("").to_string();
    let path = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if method.is_empty() || path.is_empty() || !version.starts_with("HTTP/1.") {
        return Err(io::Error::new(
            io::ErrorKind::InvalidData,
            format!("malformed request line '{request_line}'"),
        ));
    }
    let mut headers = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let (k, v) = line.split_once(':').ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, format!("malformed header '{line}'"))
        })?;
        headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
    }

    // ── body: exactly Content-Length bytes (0 when absent) ──
    let content_len = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .map(|(_, v)| {
            v.parse::<usize>().map_err(|_| {
                io::Error::new(io::ErrorKind::InvalidData, format!("bad content-length '{v}'"))
            })
        })
        .transpose()?
        .unwrap_or(0);
    if content_len > MAX_BODY_BYTES {
        // Reject before reserving a byte: `content_len` is untrusted
        // client input, and sizing a buffer from it would let one
        // request head commit the server to an arbitrary allocation.
        // The typed payload lets the connection handler answer 413
        // (the head parsed fine) instead of just dropping the socket.
        return Err(io::Error::new(io::ErrorKind::InvalidData, BodyTooLarge { content_len }));
    }
    let mut body = buf[head_end + 4..].to_vec();
    while body.len() < content_len {
        match r.read(&mut chunk) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed mid-request-body",
                ))
            }
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if matches!(e.kind(), io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut) => {
                // Mid-body stalls keep waiting: the head already
                // committed the client to sending `content_len` bytes.
                if !keep_waiting(true) {
                    return Ok(None);
                }
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    body.truncate(content_len); // no pipelining: drop any excess bytes
    Ok(Some(Request { method, path, headers, body }))
}

fn find_head_end(buf: &[u8]) -> Option<usize> {
    buf.windows(4).position(|w| w == b"\r\n\r\n")
}

/// Marker payload for a `Content-Length` beyond [`MAX_BODY_BYTES`]. The
/// request head parsed fine, so unlike every other parse failure the
/// handler can still send a response (`413 Payload Too Large`) before
/// closing the connection.
#[derive(Debug)]
pub struct BodyTooLarge {
    pub content_len: usize,
}

impl std::fmt::Display for BodyTooLarge {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "request body of {} bytes exceeds {MAX_BODY_BYTES}", self.content_len)
    }
}

impl std::error::Error for BodyTooLarge {}

/// True when `e` is [`read_request`]'s oversized-body rejection.
pub fn is_body_too_large(e: &io::Error) -> bool {
    e.get_ref().map_or(false, |inner| inner.is::<BodyTooLarge>())
}

/// Canonical reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (status + headers + body).
pub fn write_response<W: Write>(
    w: &mut W,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
) -> io::Result<()> {
    let mut head = format!(
        "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\n",
        reason(status),
        body.len()
    );
    for (k, v) in extra_headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str("\r\n");
    w.write_all(head.as_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// Streaming response writer: `Transfer-Encoding: chunked`, one flush
/// per chunk so each token reaches the client as soon as the scheduler
/// emits it. Dropping without [`ChunkedWriter::finish`] leaves the
/// stream unterminated — exactly what a client should see when its
/// request died mid-flight.
pub struct ChunkedWriter<W: Write> {
    w: W,
}

impl<W: Write> ChunkedWriter<W> {
    /// Send the status line + headers and switch to chunked framing.
    pub fn start(
        mut w: W,
        status: u16,
        content_type: &str,
        extra_headers: &[(&str, &str)],
    ) -> io::Result<ChunkedWriter<W>> {
        let mut head = format!(
            "HTTP/1.1 {status} {}\r\nContent-Type: {content_type}\r\nTransfer-Encoding: chunked\r\n",
            reason(status)
        );
        for (k, v) in extra_headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.flush()?;
        Ok(ChunkedWriter { w })
    }

    /// Write one chunk (empty input is skipped: a zero-length chunk is
    /// the terminator in this framing).
    pub fn chunk(&mut self, data: &[u8]) -> io::Result<()> {
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream (`0\r\n\r\n`).
    pub fn finish(mut self) -> io::Result<()> {
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// Client-side helper for the loopback tests/bench: read one full
/// response off the stream, decoding chunked framing when present.
/// Returns (status, headers, body). Requires the server to either send
/// `Content-Length` or chunked framing (this server always does one or
/// the other).
pub fn read_response<R: Read>(r: &mut R) -> io::Result<(u16, Vec<(String, String)>, Vec<u8>)> {
    let mut buf: Vec<u8> = Vec::with_capacity(1024);
    let mut chunk = [0u8; 4096];
    let head_end = loop {
        if let Some(end) = find_head_end(&buf) {
            break end;
        }
        match r.read(&mut chunk) {
            Ok(0) => {
                return Err(io::Error::new(
                    io::ErrorKind::UnexpectedEof,
                    "connection closed before response head",
                ))
            }
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    };
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-utf8 response head"))?;
    let mut lines = head.split("\r\n");
    let status_line = lines.next().unwrap_or("");
    let status: u16 = status_line
        .split(' ')
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| {
            io::Error::new(io::ErrorKind::InvalidData, format!("bad status line '{status_line}'"))
        })?;
    let mut headers = Vec::new();
    for line in lines {
        if let Some((k, v)) = line.split_once(':') {
            headers.push((k.trim().to_ascii_lowercase(), v.trim().to_string()));
        }
    }
    let mut rest = buf[head_end + 4..].to_vec();
    let mut read_more = |rest: &mut Vec<u8>| -> io::Result<bool> {
        match r.read(&mut chunk) {
            Ok(0) => Ok(false),
            Ok(n) => {
                rest.extend_from_slice(&chunk[..n]);
                Ok(true)
            }
            Err(e) if e.kind() == io::ErrorKind::Interrupted => Ok(true),
            Err(e) => Err(e),
        }
    };
    let chunked = headers
        .iter()
        .any(|(k, v)| k == "transfer-encoding" && v.eq_ignore_ascii_case("chunked"));
    if chunked {
        // Decode chunks until the zero-length terminator.
        let mut body = Vec::new();
        let mut pos = 0usize;
        loop {
            // chunk-size line
            let line_end = loop {
                if let Some(off) = rest[pos..].windows(2).position(|w| w == b"\r\n") {
                    break pos + off;
                }
                if !read_more(&mut rest)? {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed mid-chunk-size",
                    ));
                }
            };
            let size_str = std::str::from_utf8(&rest[pos..line_end])
                .map_err(|_| io::Error::new(io::ErrorKind::InvalidData, "non-utf8 chunk size"))?;
            let size = usize::from_str_radix(size_str.trim(), 16).map_err(|_| {
                io::Error::new(io::ErrorKind::InvalidData, format!("bad chunk size '{size_str}'"))
            })?;
            let data_start = line_end + 2;
            while rest.len() < data_start + size + 2 {
                if !read_more(&mut rest)? {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed mid-chunk",
                    ));
                }
            }
            if size == 0 {
                return Ok((status, headers, body));
            }
            body.extend_from_slice(&rest[data_start..data_start + size]);
            pos = data_start + size + 2; // skip the chunk's trailing CRLF
        }
    }
    let content_len = headers
        .iter()
        .find(|(k, _)| k == "content-length")
        .and_then(|(_, v)| v.parse::<usize>().ok())
        .unwrap_or(0);
    while rest.len() < content_len {
        if !read_more(&mut rest)? {
            return Err(io::Error::new(
                io::ErrorKind::UnexpectedEof,
                "connection closed mid-response-body",
            ));
        }
    }
    rest.truncate(content_len);
    Ok((status, headers, rest))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parses_post_with_body() {
        let raw = b"POST /v1/generate HTTP/1.1\r\nHost: x\r\nContent-Length: 11\r\nContent-Type: application/json\r\n\r\n{\"a\":[1,2]}";
        let req = read_request(&mut Cursor::new(&raw[..]), |_| true).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/generate");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body, b"{\"a\":[1,2]}");
        assert!(!req.wants_close());
    }

    #[test]
    fn parses_get_without_body_and_connection_close() {
        let raw = b"GET /v1/stats HTTP/1.1\r\nConnection: close\r\n\r\n";
        let req = read_request(&mut Cursor::new(&raw[..]), |_| true).unwrap().unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(req.wants_close());
    }

    #[test]
    fn clean_eof_is_none_but_truncation_errors() {
        let req = read_request(&mut Cursor::new(&b""[..]), |_| true).unwrap();
        assert!(req.is_none());
        let err = read_request(&mut Cursor::new(&b"GET / HT"[..]), |_| true).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        let raw = b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort";
        let err = read_request(&mut Cursor::new(&raw[..]), |_| true).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn rejects_malformed_heads() {
        for raw in [
            &b"NOT-HTTP\r\n\r\n"[..],
            &b"GET / SPDY/3\r\n\r\n"[..],
            &b"GET / HTTP/1.1\r\nbroken header line\r\n\r\n"[..],
            &b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n"[..],
        ] {
            let err = read_request(&mut Cursor::new(raw), |_| true).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{raw:?}");
        }
    }

    #[test]
    fn oversized_body_is_rejected_up_front() {
        // One past the cap: rejected before any body byte is read (or
        // allocated), with the typed payload the 413 path keys on.
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        let err = read_request(&mut Cursor::new(raw.as_bytes()), |_| true).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(is_body_too_large(&err), "oversized body must carry the 413 marker: {err}");
        assert!(err.to_string().contains(&(MAX_BODY_BYTES + 1).to_string()), "{err}");
        // Exactly at the cap: the head is accepted — the parse then
        // fails only because this stream never delivers the body
        // (UnexpectedEof, not the 413 marker).
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {MAX_BODY_BYTES}\r\n\r\n");
        let err = read_request(&mut Cursor::new(raw.as_bytes()), |_| true).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::UnexpectedEof);
        assert!(!is_body_too_large(&err));
        // A giant Content-Length must not have reserved memory up
        // front: a ludicrous value parses (usize) and still rejects
        // cleanly instead of aborting on allocation.
        let raw = format!("POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n", usize::MAX);
        let err = read_request(&mut Cursor::new(raw.as_bytes()), |_| true).unwrap_err();
        assert!(is_body_too_large(&err));
    }

    #[test]
    fn fixed_response_roundtrips() {
        let mut out = Vec::new();
        write_response(&mut out, 429, "application/json", &[("Retry-After", "1")], b"{}")
            .unwrap();
        let (status, headers, body) = read_response(&mut Cursor::new(&out[..])).unwrap();
        assert_eq!(status, 429);
        assert_eq!(body, b"{}");
        assert!(headers.iter().any(|(k, v)| k == "retry-after" && v == "1"));
        assert!(String::from_utf8_lossy(&out).contains("429 Too Many Requests"));
    }

    #[test]
    fn chunked_response_roundtrips() {
        let mut out = Vec::new();
        let mut cw = ChunkedWriter::start(&mut out, 200, "application/jsonl", &[]).unwrap();
        cw.chunk(b"{\"id\":0}\n").unwrap();
        cw.chunk(b"").unwrap(); // skipped, not a terminator
        cw.chunk(b"{\"token\":17}\n").unwrap();
        cw.finish().unwrap();
        let (status, headers, body) = read_response(&mut Cursor::new(&out[..])).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, b"{\"id\":0}\n{\"token\":17}\n");
        assert!(headers
            .iter()
            .any(|(k, v)| k == "transfer-encoding" && v == "chunked"));
    }

    /// A reader that yields its script one fragment at a time with
    /// simulated timeouts in between — the keep-alive poll path.
    struct Stuttering<'a> {
        parts: Vec<&'a [u8]>,
        next: usize,
        timeout_first: bool,
    }

    impl<'a> Read for Stuttering<'a> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            if self.timeout_first {
                self.timeout_first = false;
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "stall"));
            }
            if self.next >= self.parts.len() {
                return Ok(0);
            }
            self.timeout_first = true;
            let p = self.parts[self.next];
            self.next += 1;
            buf[..p.len()].copy_from_slice(p);
            Ok(p.len())
        }
    }

    #[test]
    fn survives_fragmented_reads_with_timeouts() {
        let mut r = Stuttering {
            parts: vec![b"POST / HT", b"TP/1.1\r\nContent-Length", b": 4\r\n\r\nbo", b"dy!"],
            next: 0,
            timeout_first: true,
        };
        let mut waits = 0;
        let req = read_request(&mut r, |partial| {
            waits += 1;
            assert!(waits == 1 || partial, "after the first fragment we are mid-request");
            true
        })
        .unwrap()
        .unwrap();
        assert_eq!(req.body, b"body");
        assert!(waits >= 3);
    }

    #[test]
    fn idle_timeout_gives_clean_none() {
        let mut r = Stuttering { parts: vec![], next: 0, timeout_first: true };
        let req = read_request(&mut r, |partial| {
            assert!(!partial);
            false // handler saw the shutdown flag
        })
        .unwrap();
        assert!(req.is_none());
    }
}
