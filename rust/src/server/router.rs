//! Sharded multi-session router behind the network front-end.
//!
//! A [`Router`] owns N independent [`Session`]s, each driven by its own
//! tick thread, and maps incoming requests onto them:
//!
//! * **Deterministic routing** — the FNV-1a hash of the prompt's first
//!   prefix-block of tokens picks the shard, so requests sharing a
//!   prefix land on the shard whose radix cache already holds it
//!   (same chaining idiom as `kvcache::prefix`). Prompts shorter than
//!   one block carry no shareable prefix and fall back to the
//!   least-loaded shard.
//! * **Bounded admission** — each shard sheds load once its waiting
//!   queue reaches the configured depth, replying with a typed
//!   retriable rejection ([`ErrorKind::ShardQueueFull`], HTTP 429)
//!   instead of queueing unboundedly.
//! * **Disconnect-cancel** — when a subscriber's event channel is
//!   dropped (client hung up), the shard cancels the request on the
//!   next token so its KV lease and any cold-tier slots are returned.
//! * **Graceful drain** — [`Router::shutdown`] tells every shard to
//!   finish in-flight requests (rejecting new ones with
//!   [`ErrorKind::ShuttingDown`]), persist its prefix radix when a
//!   spill store is configured, and report final [`ShardStats`].
//!
//! Determinism across shard and worker counts: the router assigns each
//! request a global id and pins it as the RNG seed tag
//! (`GenOptions::seed`) unless the caller already set one. Because a
//! request's sample stream is a pure function of (engine seed, seed
//! tag) and every shard shares the engine seed, the token stream for a
//! given request is byte-identical whether it is served by 1 shard or
//! 8, with 1 worker or 8 — the property `tests/net_serving.rs` checks
//! end-to-end through loopback sockets.

use std::collections::HashMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::server::engine::{Backend, EngineConfig};
use crate::server::session::{
    EngineError, Event, GenOptions, RequestId, Session, SessionStats, SubmitRequest,
};
use crate::server::RequestResult;
use crate::util::threadpool::ThreadPool;

/// Router-wide request id, unique across shards (and the RNG seed tag
/// pinned on the request unless the client chose its own).
pub type GlobalId = u64;

/// Coarse error class crossing the shard-thread boundary.
/// [`EngineError`] itself is neither `Clone` nor `Send`-friendly to
/// serialize (it may hold `anyhow` payloads), so shard threads ship
/// this owned descriptor instead; the HTTP layer maps it to a status.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ErrorKind {
    /// The target shard's admission queue is at capacity (load-shed).
    ShardQueueFull,
    /// The request can never fit the shard's KV pool.
    KvCapacityExceeded,
    /// Per-request KV dtype wider than the byte-capped pool's.
    KvDtypeWiderThanPool,
    /// prompt + generation budget exceeds `max_seq_len`.
    PromptTooLong,
    /// The id was never submitted, or already finished / cancelled.
    UnknownRequest,
    /// The server is draining; retry against a fresh instance.
    ShuttingDown,
    /// Block-pool bookkeeping violation — an engine bug.
    Page,
    /// The compute backend failed mid-step.
    Backend,
}

impl ErrorKind {
    /// HTTP status the front-end returns for this class.
    pub fn http_status(self) -> u16 {
        match self {
            ErrorKind::ShardQueueFull | ErrorKind::KvCapacityExceeded => 429,
            ErrorKind::KvDtypeWiderThanPool | ErrorKind::PromptTooLong => 400,
            ErrorKind::UnknownRequest => 404,
            ErrorKind::ShuttingDown => 503,
            ErrorKind::Page | ErrorKind::Backend => 500,
        }
    }

    /// Whether the client may retry the identical request and expect it
    /// to eventually succeed (transient capacity, not a request defect).
    pub fn retriable(self) -> bool {
        matches!(
            self,
            ErrorKind::ShardQueueFull | ErrorKind::KvCapacityExceeded | ErrorKind::ShuttingDown
        )
    }

    /// Stable machine-readable name used in JSON error bodies.
    pub fn name(self) -> &'static str {
        match self {
            ErrorKind::ShardQueueFull => "shard_queue_full",
            ErrorKind::KvCapacityExceeded => "kv_capacity_exceeded",
            ErrorKind::KvDtypeWiderThanPool => "kv_dtype_wider_than_pool",
            ErrorKind::PromptTooLong => "prompt_too_long",
            ErrorKind::UnknownRequest => "unknown_request",
            ErrorKind::ShuttingDown => "shutting_down",
            ErrorKind::Page => "page_error",
            ErrorKind::Backend => "backend_error",
        }
    }
}

/// Owned, clonable error descriptor: class + rendered message.
#[derive(Clone, Debug)]
pub struct ErrorInfo {
    pub kind: ErrorKind,
    pub message: String,
}

impl ErrorInfo {
    pub fn new(kind: ErrorKind, message: impl Into<String>) -> ErrorInfo {
        ErrorInfo { kind, message: message.into() }
    }
}

impl From<&EngineError> for ErrorInfo {
    fn from(e: &EngineError) -> ErrorInfo {
        let kind = match e {
            EngineError::KvCapacityExceeded { .. } => ErrorKind::KvCapacityExceeded,
            EngineError::KvDtypeWiderThanPool { .. } => ErrorKind::KvDtypeWiderThanPool,
            EngineError::PromptTooLong { .. } => ErrorKind::PromptTooLong,
            EngineError::UnknownRequest(_) => ErrorKind::UnknownRequest,
            EngineError::Page(_) => ErrorKind::Page,
            EngineError::Backend(_) => ErrorKind::Backend,
        };
        ErrorInfo { kind, message: format!("{e}") }
    }
}

/// Per-request stream events delivered to the submitter's channel.
///
/// Protocol: exactly one of `Accepted` or `Rejected` arrives first.
/// After `Accepted`, zero or more `Token`s are followed by exactly one
/// terminal event (`Finished`, `Failed`, or `Cancelled`). The HTTP
/// handler picks its status line from the first event, so validation
/// and load-shed never commit a 200.
#[derive(Debug)]
pub enum StreamEvent {
    /// The shard queued the request; streaming will follow.
    Accepted { id: GlobalId },
    /// Validation or load-shed rejection before any streaming.
    Rejected { id: GlobalId, error: ErrorInfo },
    /// One generated token (`step` counts from 0 per request).
    Token { id: GlobalId, step: usize, token: u32 },
    /// Completion record with serving metrics.
    Finished { id: GlobalId, result: RequestResult },
    /// The request died after acceptance (e.g. backend failure).
    Failed { id: GlobalId, error: ErrorInfo },
    /// The request was cancelled (client request or disconnect).
    Cancelled { id: GlobalId },
}

/// Point-in-time counters for one shard, reported by its tick thread.
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    /// Requests routed to this shard (accepted + shed + rejected).
    pub received: u64,
    /// Requests accepted into the admission queue.
    pub submitted: u64,
    /// Requests shed because the waiting queue was at capacity.
    pub shed: u64,
    /// Requests rejected synchronously by validation (never queued).
    pub rejected: u64,
    /// Requests that streamed to a `Finished` terminal.
    pub completed: u64,
    /// Accepted requests that died mid-flight (backend failure).
    pub failed: u64,
    /// Explicit cancels (`DELETE /v1/requests/{id}`).
    pub cancelled: u64,
    /// Auto-cancels after the subscriber's channel was dropped.
    pub disconnected: u64,
    /// Live requests (waiting + active) at report time.
    pub outstanding: usize,
    pub waiting: usize,
    pub active: usize,
    pub kv_blocks_in_use: usize,
    pub prefix_blocks_held: usize,
    /// Live cold-tier blocks (`None` without a spill store).
    pub spill_live_blocks: Option<usize>,
    /// Full engine counters for `GET /v1/stats`.
    pub session: SessionStats,
}

/// Router configuration: shard count, per-shard admission depth, and
/// the [`EngineConfig`] every shard is built from. When the engine
/// config carries a `kv_spill` path, shard `i` opens `<path>.shard<i>`
/// (the spill store truncates its region file on open, so shards must
/// not share one).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    pub shards: usize,
    /// Waiting-queue depth per shard at which new arrivals are shed.
    pub queue_depth: usize,
    pub engine: EngineConfig,
}

impl RouterConfig {
    pub fn new(engine: EngineConfig) -> RouterConfig {
        RouterConfig { shards: 1, queue_depth: 64, engine }
    }

    pub fn shards(mut self, n: usize) -> Self {
        self.shards = n.max(1);
        self
    }

    pub fn queue_depth(mut self, d: usize) -> Self {
        self.queue_depth = d.max(1);
        self
    }
}

enum Command {
    Submit { global: GlobalId, prompt: Vec<u32>, opts: GenOptions, events: Sender<StreamEvent> },
    /// `disconnect` distinguishes client hang-ups (counted as
    /// `disconnected`) from explicit API cancels (`cancelled`).
    Cancel { global: GlobalId, disconnect: bool, reply: Sender<bool> },
    Stats { reply: Sender<ShardStats> },
    /// Finish in-flight work, persist the prefix radix, report final
    /// stats, and exit the tick thread.
    Drain { reply: Sender<ShardStats> },
}

struct ShardHandle {
    tx: Sender<Command>,
    /// Router-visible live-request count for least-loaded fallback:
    /// incremented at submit, decremented by the shard thread on every
    /// terminal outcome (shed, reject, finish, fail, cancel,
    /// disconnect).
    outstanding: Arc<AtomicI64>,
    thread: Mutex<Option<JoinHandle<()>>>,
}

/// Shards traffic across N tick-threaded [`Session`]s; see the module
/// docs for routing, shedding, and drain semantics.
pub struct Router {
    shards: Vec<ShardHandle>,
    next_id: AtomicU64,
    /// Prefix-block width used for routing (engine `block_tokens`).
    block_tokens: usize,
}

/// FNV-1a over a token slice — the same constants `kvcache::prefix`
/// chains block keys with, so "same first block" implies "same shard".
fn fnv1a_tokens(tokens: &[u32]) -> u64 {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = OFFSET;
    for t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

impl Router {
    /// Builds the shards and starts one tick thread per shard. All
    /// shards share the backend (`Arc`) but own their KV pool, prefix
    /// cache, spill store, and worker pool.
    pub fn new<B: Backend + Send + Sync + 'static>(backend: Arc<B>, cfg: RouterConfig) -> Router {
        let n = cfg.shards.max(1);
        let queue_depth = cfg.queue_depth.max(1);
        let block_tokens = cfg.engine.block_tokens.max(1);
        let mut shards = Vec::with_capacity(n);
        for i in 0..n {
            let mut ecfg = cfg.engine.clone();
            if let Some(path) = ecfg.kv_spill.take() {
                ecfg.kv_spill = Some(format!("{}.shard{i}", path.display()).into());
            }
            let pool = Arc::new(ThreadPool::new(ecfg.workers.max(1)));
            let session = Session::with_pool(Arc::clone(&backend), ecfg, pool);
            let (tx, rx) = channel();
            let outstanding = Arc::new(AtomicI64::new(0));
            let counter = Arc::clone(&outstanding);
            let thread = std::thread::Builder::new()
                .name(format!("vattn-shard-{i}"))
                .spawn(move || shard_loop(i, session, rx, counter, queue_depth))
                .expect("spawn shard tick thread");
            shards.push(ShardHandle { tx, outstanding, thread: Mutex::new(Some(thread)) });
        }
        Router { shards, next_id: AtomicU64::new(0), block_tokens }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard a prompt routes to: FNV-1a of its first prefix block, or
    /// the least-loaded shard (lowest index on ties) when the prompt is
    /// shorter than one block.
    pub fn route(&self, prompt: &[u32]) -> usize {
        let n = self.shards.len();
        if n == 1 {
            return 0;
        }
        if prompt.len() >= self.block_tokens {
            (fnv1a_tokens(&prompt[..self.block_tokens]) % n as u64) as usize
        } else {
            self.shards
                .iter()
                .enumerate()
                .min_by_key(|(i, s)| (s.outstanding.load(Ordering::SeqCst), *i))
                .map(|(i, _)| i)
                .unwrap_or(0)
        }
    }

    /// Routes and submits a request; the returned channel carries the
    /// [`StreamEvent`] protocol. Dropping the receiver mid-stream makes
    /// the shard cancel the request (disconnect-cancel).
    pub fn submit(&self, prompt: Vec<u32>, mut opts: GenOptions) -> (GlobalId, Receiver<StreamEvent>) {
        let global = self.next_id.fetch_add(1, Ordering::SeqCst);
        let shard = self.route(&prompt);
        // Pin the RNG stream to the global id so the token stream does
        // not depend on per-shard submission order (per-shard request
        // ids differ across shard counts; global ids do not).
        if opts.seed.is_none() {
            opts.seed = Some(global);
        }
        let (tx, rx) = channel();
        self.shards[shard].outstanding.fetch_add(1, Ordering::SeqCst);
        let cmd = Command::Submit { global, prompt, opts, events: tx.clone() };
        if self.shards[shard].tx.send(cmd).is_err() {
            // Shard thread already exited (shutdown race).
            self.shards[shard].outstanding.fetch_sub(1, Ordering::SeqCst);
            let _ = tx.send(StreamEvent::Rejected {
                id: global,
                error: ErrorInfo::new(ErrorKind::ShuttingDown, "server is shutting down"),
            });
        }
        (global, rx)
    }

    /// Cancels a request by global id. The router does not track which
    /// shard holds an id (that would need cross-thread cleanup on every
    /// terminal event), so the cancel is broadcast; shard counts are
    /// small. Returns whether any shard knew the id.
    pub fn cancel(&self, global: GlobalId) -> bool {
        self.cancel_inner(global, false)
    }

    /// Cancel after a client hang-up: same lease-returning path as
    /// [`Router::cancel`], but accounted as a disconnect in
    /// [`ShardStats`].
    pub fn disconnect(&self, global: GlobalId) -> bool {
        self.cancel_inner(global, true)
    }

    fn cancel_inner(&self, global: GlobalId, disconnect: bool) -> bool {
        let mut found = false;
        for shard in &self.shards {
            let (tx, rx) = channel();
            if shard.tx.send(Command::Cancel { global, disconnect, reply: tx }).is_ok() {
                if let Ok(hit) = rx.recv() {
                    found |= hit;
                }
            }
        }
        found
    }

    /// Point-in-time stats from every shard (index-ordered).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let (tx, rx) = channel();
                if shard.tx.send(Command::Stats { reply: tx }).is_ok() {
                    if let Ok(stats) = rx.recv() {
                        return stats;
                    }
                }
                ShardStats { shard: i, ..ShardStats::default() }
            })
            .collect()
    }

    /// Graceful drain: every shard finishes its in-flight requests
    /// (shedding new arrivals with [`ErrorKind::ShuttingDown`]),
    /// persists its prefix radix if a spill store is configured, and
    /// exits. Returns each shard's final stats. Idempotent — a second
    /// call returns default stats for already-stopped shards.
    pub fn shutdown(&self) -> Vec<ShardStats> {
        let mut pending = Vec::with_capacity(self.shards.len());
        for (i, shard) in self.shards.iter().enumerate() {
            let (tx, rx) = channel();
            let sent = shard.tx.send(Command::Drain { reply: tx }).is_ok();
            pending.push((i, sent, rx));
        }
        let mut all = Vec::with_capacity(pending.len());
        for (i, sent, rx) in pending {
            let stats = if sent {
                rx.recv().unwrap_or_else(|_| ShardStats { shard: i, ..ShardStats::default() })
            } else {
                ShardStats { shard: i, ..ShardStats::default() }
            };
            all.push(stats);
        }
        for shard in &self.shards {
            let handle = shard.thread.lock().expect("shard thread lock").take();
            if let Some(handle) = handle {
                let _ = handle.join();
            }
        }
        all
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        // Best-effort drain so dropping a router never strands shard
        // threads; explicit `shutdown()` is the path that reports stats.
        self.shutdown();
    }
}

/// One shard's tick loop: pump commands without blocking, tick the
/// session while it has work, dispatch events to subscribers, park on
/// the command channel when idle.
fn shard_loop<B: Backend + Send + Sync + 'static>(
    shard: usize,
    mut session: Session<B>,
    rx: Receiver<Command>,
    outstanding: Arc<AtomicI64>,
    queue_depth: usize,
) {
    // session request id -> (global id, subscriber).
    let mut subs: HashMap<RequestId, (GlobalId, Sender<StreamEvent>)> = HashMap::new();
    let mut by_global: HashMap<GlobalId, RequestId> = HashMap::new();
    let mut stats = ShardStats { shard, ..ShardStats::default() };
    let mut draining = false;
    let mut drain_reply: Option<Sender<ShardStats>> = None;
    let mut rx_open = true;

    let mut handle = |cmd: Command,
                      session: &mut Session<B>,
                      subs: &mut HashMap<RequestId, (GlobalId, Sender<StreamEvent>)>,
                      by_global: &mut HashMap<GlobalId, RequestId>,
                      stats: &mut ShardStats,
                      draining: &mut bool,
                      drain_reply: &mut Option<Sender<ShardStats>>| {
        match cmd {
            Command::Submit { global, prompt, opts, events } => {
                stats.received += 1;
                if *draining {
                    stats.rejected += 1;
                    outstanding.fetch_sub(1, Ordering::SeqCst);
                    let _ = events.send(StreamEvent::Rejected {
                        id: global,
                        error: ErrorInfo::new(ErrorKind::ShuttingDown, "server is shutting down"),
                    });
                } else if session.waiting_len() >= queue_depth {
                    stats.shed += 1;
                    outstanding.fetch_sub(1, Ordering::SeqCst);
                    let _ = events.send(StreamEvent::Rejected {
                        id: global,
                        error: ErrorInfo::new(
                            ErrorKind::ShardQueueFull,
                            format!(
                                "shard {shard} admission queue is full ({queue_depth} waiting)"
                            ),
                        ),
                    });
                } else {
                    match session.submit_validated(SubmitRequest::new(prompt).options(opts)) {
                        Ok(rid) => {
                            stats.submitted += 1;
                            let _ = events.send(StreamEvent::Accepted { id: global });
                            subs.insert(rid, (global, events));
                            by_global.insert(global, rid);
                        }
                        Err(e) => {
                            stats.rejected += 1;
                            outstanding.fetch_sub(1, Ordering::SeqCst);
                            let _ = events.send(StreamEvent::Rejected {
                                id: global,
                                error: ErrorInfo::from(&e),
                            });
                        }
                    }
                }
            }
            Command::Cancel { global, disconnect, reply } => {
                let found = match by_global.get(&global).copied() {
                    Some(rid) => {
                        let ok = session.cancel(rid).is_ok();
                        if let Some((gid, tx)) = unregister(rid, subs, by_global, &outstanding) {
                            let _ = tx.send(StreamEvent::Cancelled { id: gid });
                            if disconnect {
                                stats.disconnected += 1;
                            } else {
                                stats.cancelled += 1;
                            }
                        }
                        ok
                    }
                    // Unknown id, already terminal, or already
                    // unregistered by a racing disconnect: no counter
                    // adjustment — its slot was released exactly once
                    // when the maps were emptied.
                    None => false,
                };
                let _ = reply.send(found);
            }
            Command::Stats { reply } => {
                let _ = reply.send(snapshot(&stats, session));
            }
            Command::Drain { reply } => {
                *draining = true;
                *drain_reply = Some(reply);
            }
        }
    };

    loop {
        // 1. Pump every queued command without blocking.
        while rx_open {
            match rx.try_recv() {
                Ok(cmd) => handle(
                    cmd,
                    &mut session,
                    &mut subs,
                    &mut by_global,
                    &mut stats,
                    &mut draining,
                    &mut drain_reply,
                ),
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // Router dropped without shutdown(): drain silently.
                    rx_open = false;
                    draining = true;
                }
            }
        }

        // 2. Drained and idle: persist the radix, report, exit.
        if draining && session.is_idle() {
            let _ = session.flush_prefix_cache();
            if let Some(reply) = drain_reply.take() {
                let _ = reply.send(snapshot(&stats, &session));
            }
            return;
        }

        // 3. Idle with no work: park on the command channel.
        if session.is_idle() {
            match rx.recv_timeout(Duration::from_millis(10)) {
                Ok(cmd) => handle(
                    cmd,
                    &mut session,
                    &mut subs,
                    &mut by_global,
                    &mut stats,
                    &mut draining,
                    &mut drain_reply,
                ),
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => {
                    rx_open = false;
                    draining = true;
                }
            }
            continue;
        }

        // 4. Tick and dispatch.
        match session.tick() {
            Ok(events) => {
                for ev in events {
                    dispatch(ev, &mut session, &mut subs, &mut by_global, &mut stats, &outstanding);
                }
            }
            Err(e) => {
                // Engine invariant violation: fail every subscriber
                // loudly, then panic so shutdown()'s join surfaces it.
                let info = ErrorInfo::new(ErrorKind::Page, format!("{e}"));
                let rids: Vec<RequestId> = subs.keys().copied().collect();
                for rid in rids {
                    if let Some((gid, tx)) = unregister(rid, &mut subs, &mut by_global, &outstanding)
                    {
                        let _ = tx.send(StreamEvent::Failed { id: gid, error: info.clone() });
                    }
                }
                if let Some(reply) = drain_reply.take() {
                    let _ = reply.send(snapshot(&stats, &session));
                }
                panic!("shard {shard} tick failed: {}", info.message);
            }
        }
    }
}

fn snapshot<B: Backend + Send + Sync + 'static>(
    counters: &ShardStats,
    session: &Session<B>,
) -> ShardStats {
    let mut s = counters.clone();
    s.outstanding = session.outstanding();
    s.waiting = session.waiting_len();
    s.active = session.active_len();
    s.kv_blocks_in_use = session.kv_blocks_in_use();
    s.prefix_blocks_held = session.prefix_blocks_held();
    s.spill_live_blocks = session.spill_live_blocks();
    s.session = session.stats();
    s
}

/// Release one registered request: remove its `subs`/`by_global` pair
/// and decrement the router-visible `outstanding` counter, as a single
/// structural operation. This is the ONLY place a *registered*
/// request's counter slot is released (the submit-time reject paths
/// decrement before registration, which is mutually exclusive with
/// this by construction), so no interleaving of disconnect-detection,
/// explicit cancel, and terminal events can decrement twice for one
/// request — whichever path runs second finds the maps already empty
/// and does nothing.
fn unregister(
    rid: RequestId,
    subs: &mut HashMap<RequestId, (GlobalId, Sender<StreamEvent>)>,
    by_global: &mut HashMap<GlobalId, RequestId>,
    outstanding: &AtomicI64,
) -> Option<(GlobalId, Sender<StreamEvent>)> {
    let entry = subs.remove(&rid);
    if let Some((gid, _)) = &entry {
        by_global.remove(gid);
        outstanding.fetch_sub(1, Ordering::SeqCst);
    }
    entry
}

fn dispatch<B: Backend + Send + Sync + 'static>(
    ev: Event,
    session: &mut Session<B>,
    subs: &mut HashMap<RequestId, (GlobalId, Sender<StreamEvent>)>,
    by_global: &mut HashMap<GlobalId, RequestId>,
    stats: &mut ShardStats,
    outstanding: &AtomicI64,
) {
    match ev {
        Event::Admitted { .. } | Event::Preempted { .. } => {}
        Event::Token { id, token, step, .. } => {
            let dead = match subs.get(&id) {
                Some((gid, tx)) => {
                    tx.send(StreamEvent::Token { id: *gid, step, token }).is_err()
                }
                None => false,
            };
            if dead {
                // Subscriber hung up without an explicit cancel:
                // cancel now so the KV lease (and any cold-tier
                // slots) return immediately.
                if unregister(id, subs, by_global, outstanding).is_some() {
                    stats.disconnected += 1;
                }
                let _ = session.cancel(id);
            }
        }
        Event::Finished { id, result, .. } => {
            if let Some((gid, tx)) = unregister(id, subs, by_global, outstanding) {
                let _ = tx.send(StreamEvent::Finished { id: gid, result });
                stats.completed += 1;
            }
        }
        Event::Rejected { id, reason, .. } => {
            if let Some((gid, tx)) = unregister(id, subs, by_global, outstanding) {
                let _ = tx.send(StreamEvent::Failed { id: gid, error: ErrorInfo::from(&reason) });
                stats.failed += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{Model, ModelConfig};

    fn router(shards: usize, depth: usize, cfg: EngineConfig) -> Router {
        let backend = Arc::new(Model::new(ModelConfig::tiny(), 42));
        Router::new(backend, RouterConfig::new(cfg).shards(shards).queue_depth(depth))
    }

    fn prompt(len: usize, salt: u32) -> Vec<u32> {
        (0..len as u32).map(|t| (t * 13 + salt) % 250).collect()
    }

    /// Collect the full stream for one request (blocking).
    fn collect(rx: &Receiver<StreamEvent>) -> (Vec<u32>, Option<StreamEvent>) {
        let mut tokens = Vec::new();
        loop {
            match rx.recv() {
                Ok(StreamEvent::Accepted { .. }) => {}
                Ok(StreamEvent::Token { token, step, .. }) => {
                    assert_eq!(step, tokens.len(), "gapless stream");
                    tokens.push(token);
                }
                Ok(term) => return (tokens, Some(term)),
                Err(_) => return (tokens, None),
            }
        }
    }

    #[test]
    fn routing_is_deterministic_and_prefix_stable() {
        let r = router(4, 8, EngineConfig::default());
        let p = prompt(64, 7);
        let shard = r.route(&p);
        assert_eq!(shard, r.route(&p));
        // Same first block, different tail: same shard (radix locality).
        let bt = r.block_tokens;
        let mut q = p[..bt].to_vec();
        q.extend(prompt(32, 99));
        assert_eq!(shard, r.route(&q));
        // Short prompts fall back to least-loaded (shard 0 when idle).
        assert_eq!(0, r.route(&prompt(1, 3)));
        r.shutdown();
    }

    #[test]
    fn submit_streams_and_finishes() {
        let r = router(2, 8, EngineConfig::default());
        let (id, rx) = r.submit(prompt(12, 1), GenOptions::new(5));
        let (tokens, term) = collect(&rx);
        assert_eq!(tokens.len(), 5);
        match term {
            Some(StreamEvent::Finished { id: gid, result }) => {
                assert_eq!(gid, id);
                assert_eq!(result.tokens, tokens);
            }
            other => panic!("expected Finished, got {other:?}"),
        }
        let stats = r.shutdown();
        let completed: u64 = stats.iter().map(|s| s.completed).sum();
        assert_eq!(completed, 1);
    }

    #[test]
    fn streams_match_across_shard_counts() {
        let mut streams = Vec::new();
        for shards in [1usize, 3] {
            let r = router(shards, 64, EngineConfig::default());
            let mut rxs = Vec::new();
            for i in 0..6u32 {
                // Explicit seed: identity must not depend on submit order.
                let (_, rx) = r.submit(prompt(20, i), GenOptions::new(6).seed(1000 + i as u64));
                rxs.push(rx);
            }
            let run: Vec<Vec<u32>> = rxs.iter().map(|rx| collect(rx).0).collect();
            streams.push(run);
            r.shutdown();
        }
        assert_eq!(streams[0], streams[1], "token streams differ across shard counts");
    }

    #[test]
    fn overfull_queue_sheds_with_retriable_429() {
        // Single shard, tiny queue: a burst must shed, not stall.
        let cfg = EngineConfig::builder().max_batch(1).build();
        let r = router(1, 2, cfg);
        let mut rxs = Vec::new();
        for i in 0..12u32 {
            let (_, rx) = r.submit(prompt(16, i), GenOptions::new(4));
            rxs.push(rx);
        }
        let mut finished = 0u32;
        let mut shed = 0u32;
        for rx in &rxs {
            // First event decides the status.
            match rx.recv().expect("first event") {
                StreamEvent::Accepted { .. } => {
                    let (_, term) = collect(rx);
                    assert!(matches!(term, Some(StreamEvent::Finished { .. })));
                    finished += 1;
                }
                StreamEvent::Rejected { error, .. } => {
                    assert_eq!(error.kind, ErrorKind::ShardQueueFull);
                    assert_eq!(error.kind.http_status(), 429);
                    assert!(error.kind.retriable());
                    shed += 1;
                }
                other => panic!("unexpected first event {other:?}"),
            }
        }
        assert_eq!(finished + shed, 12);
        assert!(shed > 0, "burst of 12 into depth-2 queue must shed");
        let stats = r.shutdown();
        assert_eq!(stats[0].shed as u32, shed);
        assert_eq!(stats[0].completed as u32, finished);
        assert_eq!(stats[0].kv_blocks_in_use, 0);
    }

    #[test]
    fn validation_rejections_are_first_events() {
        let cfg = EngineConfig::builder().max_seq_len(16).build();
        let r = router(1, 8, cfg);
        let (_, rx) = r.submit(prompt(20, 1), GenOptions::new(8));
        match rx.recv().expect("first event") {
            StreamEvent::Rejected { error, .. } => {
                assert_eq!(error.kind, ErrorKind::PromptTooLong);
                assert_eq!(error.kind.http_status(), 400);
                assert!(!error.kind.retriable());
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
        r.shutdown();
    }

    #[test]
    fn cancel_mid_stream_returns_lease() {
        let r = router(1, 8, EngineConfig::default());
        let (id, rx) = r.submit(prompt(12, 1), GenOptions::new(400));
        // Wait for streaming to start so the request is live.
        loop {
            match rx.recv().expect("event") {
                StreamEvent::Token { .. } => break,
                StreamEvent::Accepted { .. } => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(r.cancel(id), "live request must be cancellable");
        assert!(!r.cancel(id), "second cancel must miss");
        // Drain the stream: terminal must be Cancelled.
        let (_, term) = collect(&rx);
        assert!(matches!(term, Some(StreamEvent::Cancelled { .. })), "got {term:?}");
        let stats = r.shutdown();
        assert_eq!(stats[0].cancelled, 1);
        assert_eq!(stats[0].kv_blocks_in_use, 0, "cancel must return the KV lease");
    }

    #[test]
    fn dropped_receiver_triggers_disconnect_cancel() {
        let r = router(1, 8, EngineConfig::default());
        let (_, rx) = r.submit(prompt(12, 1), GenOptions::new(400));
        // Receive one token, then hang up.
        loop {
            match rx.recv().expect("event") {
                StreamEvent::Token { .. } => break,
                StreamEvent::Accepted { .. } => {}
                other => panic!("unexpected {other:?}"),
            }
        }
        drop(rx);
        // The shard notices on its next token send and cancels.
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        loop {
            let s = &r.shard_stats()[0];
            if s.disconnected == 1 && s.kv_blocks_in_use == 0 {
                break;
            }
            assert!(std::time::Instant::now() < deadline, "disconnect-cancel never fired: {s:?}");
            std::thread::sleep(Duration::from_millis(2));
        }
        r.shutdown();
    }

    #[test]
    fn disconnect_storm_settles_outstanding_to_exactly_zero() {
        // A storm of client hang-ups racing explicit cancels and
        // terminal events. The router-visible `outstanding` counters
        // must return to exactly 0 — a double decrement on any
        // disconnect/cancel/terminal interleaving would drive a counter
        // negative and skew least-loaded routing for every later short
        // prompt.
        let cfg = EngineConfig::builder().max_batch(4).build();
        let r = router(2, 64, cfg);
        let total = 32u64;
        for round in 0..4u32 {
            let mut keep = Vec::new();
            for i in 0..8u32 {
                let (id, rx) =
                    r.submit(prompt(16, round * 31 + i), GenOptions::new(24));
                if i % 2 == 0 {
                    // Hang up as soon as streaming starts...
                    loop {
                        match rx.recv().expect("event") {
                            StreamEvent::Token { .. } => break,
                            StreamEvent::Accepted { .. } => {}
                            other => panic!("unexpected {other:?}"),
                        }
                    }
                    drop(rx);
                    // ...and half of those also race an explicit cancel
                    // against the shard's own dead-subscriber sweep.
                    if i % 4 == 0 {
                        let _ = r.cancel(id);
                    }
                } else {
                    keep.push(rx);
                }
            }
            for rx in keep {
                let (toks, term) = collect(&rx);
                assert_eq!(toks.len(), 24);
                assert!(matches!(term, Some(StreamEvent::Finished { .. })));
            }
        }
        // Wait for every shard to notice its dead subscribers.
        let deadline = std::time::Instant::now() + Duration::from_secs(30);
        loop {
            let counters: Vec<i64> =
                r.shards.iter().map(|s| s.outstanding.load(Ordering::SeqCst)).collect();
            let stats = r.shard_stats();
            if counters.iter().all(|&c| c == 0)
                && stats.iter().all(|s| s.outstanding == 0 && s.kv_blocks_in_use == 0)
            {
                break;
            }
            assert!(
                std::time::Instant::now() < deadline,
                "outstanding never settled: counters={counters:?}"
            );
            std::thread::sleep(Duration::from_millis(2));
        }
        // Exactly zero — not merely "eventually non-positive".
        for (i, s) in r.shards.iter().enumerate() {
            assert_eq!(s.outstanding.load(Ordering::SeqCst), 0, "shard {i} counter skewed");
        }
        let stats = r.shutdown();
        // Every submission resolves exactly once across the terminal
        // counters (a fast finisher may beat its client's hang-up, so
        // the completed/disconnected split is racy — the total is not).
        let resolved: u64 = stats
            .iter()
            .map(|s| s.completed + s.failed + s.cancelled + s.disconnected + s.shed + s.rejected)
            .sum();
        assert_eq!(resolved, total, "each request must resolve exactly once: {stats:?}");
        assert!(
            stats.iter().map(|s| s.cancelled + s.disconnected).sum::<u64>() > 0,
            "the storm must actually exercise the disconnect path"
        );
    }

    #[test]
    fn shutdown_drains_in_flight_and_rejects_new() {
        let r = router(2, 8, EngineConfig::default());
        let (_, rx) = r.submit(prompt(12, 1), GenOptions::new(6));
        let stats = r.shutdown();
        // In-flight request finished during drain.
        let (tokens, term) = collect(&rx);
        assert_eq!(tokens.len(), 6);
        assert!(matches!(term, Some(StreamEvent::Finished { .. })));
        assert_eq!(stats.iter().map(|s| s.completed).sum::<u64>(), 1);
        // Post-shutdown submits are rejected as shutting_down.
        let (_, rx2) = r.submit(prompt(12, 2), GenOptions::new(4));
        match rx2.recv().expect("rejection") {
            StreamEvent::Rejected { error, .. } => {
                assert_eq!(error.kind, ErrorKind::ShuttingDown);
                assert_eq!(error.kind.http_status(), 503);
            }
            other => panic!("expected Rejected, got {other:?}"),
        }
    }
}
