//! The generation engine: batch serving wrappers over the event-driven
//! [`Session`] core (see `session.rs` for the scheduler itself).
//!
//! Scheduling model (vLLM-style, specialized to this testbed), as four
//! phases per scheduler round (= one `Session::tick`):
//!
//! 1. **Block accounting** — every active request is handed, on demand,
//!    the blocks its next round of appends needs (demand paging); pool
//!    exhaustion reclaims idle prefix-cache blocks first and then
//!    deterministically preempts the most-recently-admitted request.
//!    This runs serially, so workers never touch the allocator.
//! 2. **Admission** — FIFO over the waiting queue, gated by batch
//!    capacity (`max_batch`), arrival time (open-loop traces), and the
//!    paged-KV block pool: a request is admitted when its *prompt*
//!    blocks (minus any shared-prefix hit) fit alongside the configured
//!    headroom — generation blocks arrive later via phase 1, which is
//!    what lets batch density exceed worst-case reservations.
//! 3. **Step execution** — every active request advances one step (a
//!    prefill chunk, or one decode token). Each request owns its
//!    `KvCache`, policies, sampler and `Rng`, so steps are
//!    data-parallel: they fan out across the engine's
//!    `util::ThreadPool`.
//! 4. **Merge** — results return in submission order; completed
//!    requests free their blocks and their slot, freshly prefilled
//!    prompts publish their full blocks to the prefix cache, and the
//!    queue backfills. Because per-request state never crosses requests
//!    and merge order is fixed, token streams are byte-identical at any
//!    worker count — including across preemptions, whose re-runs replay
//!    deterministically.
//!
//! `Engine::serve` and `Engine::serve_open_loop` submit a whole batch
//! into a fresh session and drive `tick` to completion — there is no
//! second scheduling loop. Streaming callers use [`Engine::session`]
//! (or `Session::new`) directly and consume token events as they land.

use std::sync::Arc;

use anyhow::Result;

use super::session::{Event, GenOptions, RequestId, Session, SubmitRequest};
use super::{ArrivingRequest, Request, RequestResult};
use crate::kvcache::{KvCache, KvDtype};
use crate::model::{Model, ModelConfig, Sampler, StepOut};
use crate::policies::IndexPolicy;
use crate::util::threadpool::ThreadPool;

pub use crate::model::SelectFn;

/// Compute backend abstraction: the rust-native model or the PJRT path.
pub trait Backend {
    fn config(&self) -> &ModelConfig;
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut SelectFn>,
    ) -> Result<StepOut>;
}

impl Backend for Model {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut SelectFn>,
    ) -> Result<StepOut> {
        Ok(self.decode_step(token, pos, cache, select))
    }
}

impl Backend for crate::runtime::PjrtModel {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut SelectFn>,
    ) -> Result<StepOut> {
        self.decode_step(token, pos, cache, select)
    }
}

/// Engine-global policy factory: one fresh policy per (layer, head) for
/// each admitted request, with no per-request context. The batch-mode
/// (`AttentionMode`) counterpart of the session's options-aware
/// `server::PolicyFactory`.
pub type BatchPolicyFactory = Box<dyn Fn(usize, usize) -> Box<dyn IndexPolicy>>;

/// How decode attention is computed for a whole batch call. Requests
/// submitted through a [`Session`] choose per request instead
/// (`GenOptions` / `AttentionOpt`).
pub enum AttentionMode {
    Dense,
    Sparse(BatchPolicyFactory),
}

#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Maximum concurrently active requests.
    pub max_batch: usize,
    /// Default sampler; requests may override via `GenOptions::sampler`.
    pub sampler: Sampler,
    pub seed: u64,
    /// Worker threads for the step-execution phase. 1 = sequential.
    pub workers: usize,
    /// Prompt tokens a prefilling request may ingest per round.
    pub prefill_chunk: usize,
    /// Paged-KV allocation granularity (tokens per block).
    pub block_tokens: usize,
    /// Engine-wide KV memory budget. Admission reserves a request's
    /// *prompt* blocks only; generation blocks are demand-paged, and
    /// exhaustion triggers deterministic preemption. `None` = unbounded.
    pub kv_capacity_bytes: Option<usize>,
    /// Blocks the admission gate keeps free as growth headroom (waived
    /// when the batch is empty). Larger values trade batch density for
    /// fewer preemptions.
    pub kv_headroom_blocks: usize,
    /// Share identical prompt prefixes across requests through the
    /// hash-keyed prefix radix (`kvcache::PrefixCache`): matching full
    /// prompt blocks are forked (refcount bump + row memcpy) instead of
    /// recomputed and re-stored per request.
    pub prefix_cache: bool,
    /// Reject requests whose prompt + generation budget exceeds this
    /// (`EngineError::PromptTooLong`). `None` = unlimited.
    pub max_seq_len: Option<usize>,
    /// Physical KV storage dtype (`vattn serve --kv-quant int8`). At
    /// [`KvDtype::Int8`] the pool's blocks shrink 3.5–4×, so the same
    /// `kv_capacity_bytes` holds proportionally more tokens — more
    /// resident requests and fewer preemptions — while the
    /// dequantization error is charged to every verified request's
    /// (ε, δ) budget as an explicit slack term. Requests may override
    /// per request via `GenOptions::kv_dtype`; the pool sizes its
    /// blocks by *this* engine-wide dtype, so on a byte-capped pool an
    /// override storing wider rows is rejected
    /// (`EngineError::KvDtypeWiderThanPool`) rather than silently
    /// overrunning the budget, while narrower overrides under-fill
    /// their blocks (per-request `TierStats` byte traffic is always
    /// physical to that request).
    pub kv_dtype: KvDtype,
    /// File-backed cold tier for preempted KV (`vattn serve --kv-spill
    /// PATH`). When set, pool exhaustion *spills* the LIFO victim's
    /// blocks to this region file instead of dropping them: re-admission
    /// swaps the bytes back in (no prefill/decode replay), RNG and
    /// policy state are preserved, and token streams stay byte-identical
    /// to an unconstrained run. The session also persists its prefix
    /// cache to `PATH.prefix` on [`crate::server::Session::flush_prefix_cache`],
    /// so a fresh session on the same path warm-starts the radix across
    /// process restarts. `None` = preemption falls back to deterministic
    /// replay (the original behavior).
    pub kv_spill: Option<std::path::PathBuf>,
    /// Overlap cold-tier swap-in with compute (`vattn serve
    /// --kv-prefetch`; requires `kv_spill`, ignored without it). A
    /// dedicated `vattn-spill-io` thread starts reading a swap-out
    /// victim's slots the moment its request reaches the front window
    /// of the waiting queue — before a batch slot frees — into staged
    /// snapshots; re-admission then consumes the staged buffers instead
    /// of issuing blocking reads on the scheduler thread. Streams are
    /// byte-identical prefetch on vs off at any worker count (the
    /// staged path decodes the same bytes through the same code), so
    /// this is purely a stall-removal knob.
    pub kv_prefetch: bool,
    /// How many waiting-queue entries from the front the prefetch kick
    /// scans each tick. Depth 1 stages only the imminent re-admission;
    /// deeper windows hide more IO behind compute at the cost of staged
    /// buffers that may be wasted if a request is cancelled first.
    pub kv_prefetch_depth: usize,
    /// Drive the session's event clock virtually instead of from the
    /// wall clock: each `tick` advances a fixed quantum, and an idle
    /// gap before the next queued arrival *jumps* the clock to that
    /// arrival instead of sleeping. Admission of open-loop traces
    /// (Poisson / bursty arrivals) then becomes a pure function of the
    /// tick count, which is what lets the scenario fuzz matrix re-run
    /// an arrival-timed workload and demand byte-identical schedules.
    /// Event timestamps and latency metrics are in virtual seconds
    /// under this mode, so throughput/TTFT numbers are not wall-clock
    /// comparable.
    pub virtual_clock: bool,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 4,
            sampler: Sampler::Greedy,
            seed: 0,
            workers: 1,
            prefill_chunk: 32,
            block_tokens: 16,
            kv_capacity_bytes: None,
            kv_headroom_blocks: 0,
            prefix_cache: false,
            max_seq_len: None,
            kv_dtype: KvDtype::F32,
            kv_spill: None,
            kv_prefetch: false,
            kv_prefetch_depth: 2,
            virtual_clock: false,
        }
    }
}

impl EngineConfig {
    /// Fluent construction; fields not set keep their defaults.
    pub fn builder() -> EngineConfigBuilder {
        EngineConfigBuilder { cfg: EngineConfig::default() }
    }
}

/// Builder for [`EngineConfig`] (`EngineConfig::builder()`).
#[derive(Clone, Debug)]
pub struct EngineConfigBuilder {
    cfg: EngineConfig,
}

impl EngineConfigBuilder {
    pub fn max_batch(mut self, v: usize) -> Self {
        self.cfg.max_batch = v;
        self
    }

    pub fn sampler(mut self, v: Sampler) -> Self {
        self.cfg.sampler = v;
        self
    }

    pub fn seed(mut self, v: u64) -> Self {
        self.cfg.seed = v;
        self
    }

    pub fn workers(mut self, v: usize) -> Self {
        self.cfg.workers = v;
        self
    }

    pub fn prefill_chunk(mut self, v: usize) -> Self {
        self.cfg.prefill_chunk = v;
        self
    }

    pub fn block_tokens(mut self, v: usize) -> Self {
        self.cfg.block_tokens = v;
        self
    }

    pub fn kv_capacity_bytes(mut self, v: usize) -> Self {
        self.cfg.kv_capacity_bytes = Some(v);
        self
    }

    pub fn kv_headroom_blocks(mut self, v: usize) -> Self {
        self.cfg.kv_headroom_blocks = v;
        self
    }

    pub fn prefix_cache(mut self, v: bool) -> Self {
        self.cfg.prefix_cache = v;
        self
    }

    pub fn max_seq_len(mut self, v: usize) -> Self {
        self.cfg.max_seq_len = Some(v);
        self
    }

    pub fn kv_dtype(mut self, v: KvDtype) -> Self {
        self.cfg.kv_dtype = v;
        self
    }

    pub fn kv_spill(mut self, v: impl Into<std::path::PathBuf>) -> Self {
        self.cfg.kv_spill = Some(v.into());
        self
    }

    pub fn kv_prefetch(mut self, v: bool) -> Self {
        self.cfg.kv_prefetch = v;
        self
    }

    pub fn kv_prefetch_depth(mut self, v: usize) -> Self {
        self.cfg.kv_prefetch_depth = v;
        self
    }

    pub fn virtual_clock(mut self, v: bool) -> Self {
        self.cfg.virtual_clock = v;
        self
    }

    pub fn build(self) -> EngineConfig {
        self.cfg
    }
}

pub struct Engine<B: Backend> {
    pub backend: Arc<B>,
    pub cfg: EngineConfig,
    pool: Arc<ThreadPool>,
}

impl<B: Backend + Send + Sync + 'static> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig) -> Engine<B> {
        let pool = Arc::new(ThreadPool::new(cfg.workers.max(1)));
        Engine { backend: Arc::new(backend), cfg, pool }
    }

    /// Step-execution worker threads.
    pub fn workers(&self) -> usize {
        self.pool.num_workers()
    }

    /// Open a streaming session sharing this engine's backend, config
    /// and worker pool. Requests default to dense attention and the
    /// engine's sampler; override per request via `GenOptions`, or
    /// session-wide via `Session::set_default_attention`.
    pub fn session(&self) -> Session<B> {
        Session::with_pool(Arc::clone(&self.backend), self.cfg.clone(), Arc::clone(&self.pool))
    }

    /// Serve a batch of requests to completion with continuous batching
    /// (closed loop: everything is queued at t = 0).
    pub fn serve(&self, requests: Vec<Request>, mode: &AttentionMode) -> Result<Vec<RequestResult>> {
        let arriving = requests.into_iter().map(ArrivingRequest::immediate).collect();
        self.serve_arrivals(arriving, mode)
    }

    /// Serve an open-loop trace: requests become visible to the
    /// scheduler at their arrival times (e.g. Poisson arrivals from
    /// `workloads::traces`), so queueing delay is measured for real.
    pub fn serve_open_loop(
        &self,
        mut requests: Vec<ArrivingRequest>,
        mode: &AttentionMode,
    ) -> Result<Vec<RequestResult>> {
        requests.sort_by(|a, b| {
            a.arrival_s
                .partial_cmp(&b.arrival_s)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.req.id.cmp(&b.req.id))
        });
        self.serve_arrivals(requests, mode)
    }

    /// The batch wrappers' shared drive loop: submit everything into a
    /// fresh [`Session`], tick it dry, surface the first rejection as a
    /// typed error, and return results keyed by the caller's ids.
    fn serve_arrivals(
        &self,
        requests: Vec<ArrivingRequest>,
        mode: &AttentionMode,
    ) -> Result<Vec<RequestResult>> {
        let mut session = self.session();
        // Session ids are minted 0.. in submission order; remember the
        // caller's ids so results come back under them. The caller id
        // also tags the per-request RNG stream, so a request's draws
        // depend only on (engine seed, its own id), not on batch
        // composition.
        let mut caller_ids: Vec<u64> = Vec::with_capacity(requests.len());
        for ArrivingRequest { arrival_s, req } in requests {
            caller_ids.push(req.id);
            let sub = SubmitRequest::new(req.prompt)
                .arrival(arrival_s)
                .options(GenOptions::new(req.gen_len).seed(req.id));
            let sid: RequestId = session.submit_with_mode(sub, mode);
            debug_assert_eq!(sid as usize + 1, caller_ids.len());
        }
        let mut done: Vec<RequestResult> = Vec::new();
        while !session.is_idle() {
            for ev in session.tick()? {
                match ev {
                    Event::Finished { result, .. } => done.push(result),
                    Event::Rejected { reason, .. } => return Err(anyhow::Error::from(reason)),
                    // Preempted requests re-run deterministically and
                    // finish later; nothing to record here.
                    Event::Admitted { .. } | Event::Token { .. } | Event::Preempted { .. } => {}
                }
            }
        }
        for r in &mut done {
            r.id = caller_ids[r.id as usize];
        }
        done.sort_by_key(|r| r.id);
        Ok(done)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::{SizeSpec, VAttentionConfig, VAttentionPolicy};

    fn tiny_engine() -> Engine<Model> {
        let cfg = ModelConfig::tiny();
        Engine::new(Model::new(cfg, 42), EngineConfig::default())
    }

    fn reqs(n: usize, prompt_len: usize, gen_len: usize) -> Vec<Request> {
        (0..n as u64)
            .map(|i| {
                let prompt: Vec<u32> =
                    (0..prompt_len as u32).map(|t| (i as u32 * 7 + t) % 250).collect();
                Request::new(i, prompt, gen_len)
            })
            .collect()
    }

    #[test]
    fn serves_all_requests_dense() {
        let eng = tiny_engine();
        let results = eng.serve(reqs(6, 12, 5), &AttentionMode::Dense).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.tokens.len(), 5);
            assert!((r.mean_density - 1.0).abs() < 1e-9);
            assert!(r.ttft_s >= 0.0);
            assert!(r.wait_s >= 0.0);
        }
        // FIFO ids preserved in output ordering
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn greedy_dense_is_deterministic() {
        let eng = tiny_engine();
        let a = eng.serve(reqs(2, 10, 6), &AttentionMode::Dense).unwrap();
        let b = eng.serve(reqs(2, 10, 6), &AttentionMode::Dense).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn worker_count_does_not_change_tokens() {
        let run = |workers: usize| {
            let eng = Engine::new(
                Model::new(ModelConfig::tiny(), 42),
                EngineConfig { workers, max_batch: 3, ..Default::default() },
            );
            eng.serve(reqs(7, 9, 5), &AttentionMode::Dense).unwrap()
        };
        let seq = run(1);
        let par = run(4);
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn sparse_mode_reads_fewer_bytes() {
        let eng = tiny_engine();
        let mk_mode = || -> AttentionMode {
            AttentionMode::Sparse(Box::new(|_l, _h| {
                let mut cfg = VAttentionConfig::default();
                cfg.sink = SizeSpec::Abs(4);
                cfg.window = SizeSpec::Abs(8);
                cfg.heavy = SizeSpec::Frac(0.05);
                // Random-weight tiny models have unstructured values, so
                // the full-SDPA guarantee correctly saturates at dense —
                // use the denominator guarantee at a moderate tolerance
                // to exercise genuine sparsity here (cf. Fig. 10).
                cfg.verify = crate::budget::Verify::Denominator;
                cfg.eps = 0.2;
                cfg.delta = 0.2;
                Box::new(VAttentionPolicy::oracle(cfg))
            }))
        };
        // Long prompt so sparsity has room.
        let dense = eng.serve(reqs(1, 192, 8), &AttentionMode::Dense).unwrap();
        let sparse = eng.serve(reqs(1, 192, 8), &mk_mode()).unwrap();
        assert!(sparse[0].mean_density < 1.0);
        assert!(sparse[0].kv_bytes_read < dense[0].kv_bytes_read);
        assert_eq!(sparse[0].tokens.len(), 8);
    }

    #[test]
    fn batch_capacity_respected_and_all_complete() {
        let eng = Engine::new(
            Model::new(ModelConfig::tiny(), 1),
            EngineConfig { max_batch: 2, ..Default::default() },
        );
        let results = eng.serve(reqs(7, 6, 3), &AttentionMode::Dense).unwrap();
        assert_eq!(results.len(), 7);
        assert!(results.iter().all(|r| r.tokens.len() == 3));
    }

    #[test]
    fn kv_capacity_limits_admission_without_changing_tokens() {
        let cfg = ModelConfig::tiny();
        // Room for exactly two requests' worst case (16 tokens → 1 block).
        let capped = Engine::new(
            Model::new(cfg.clone(), 1),
            EngineConfig {
                max_batch: 4,
                block_tokens: 16,
                kv_capacity_bytes: Some(2 * 16 * cfg.kv_bytes_per_token()),
                ..Default::default()
            },
        );
        let free = Engine::new(
            Model::new(cfg, 1),
            EngineConfig { max_batch: 4, block_tokens: 16, ..Default::default() },
        );
        let a = capped.serve(reqs(5, 10, 4), &AttentionMode::Dense).unwrap();
        let b = free.serve(reqs(5, 10, 4), &AttentionMode::Dense).unwrap();
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "capacity gating must not change outputs");
        }
    }

    #[test]
    fn oversized_request_is_rejected_not_deadlocked() {
        let cfg = ModelConfig::tiny();
        let eng = Engine::new(
            Model::new(cfg.clone(), 1),
            EngineConfig {
                block_tokens: 16,
                kv_capacity_bytes: Some(16 * cfg.kv_bytes_per_token()),
                ..Default::default()
            },
        );
        // 40 + 8 tokens → 3 blocks, but the pool holds 1.
        let err = eng.serve(reqs(1, 40, 8), &AttentionMode::Dense).unwrap_err();
        assert!(format!("{err}").contains("KV blocks"), "{err}");
    }

    #[test]
    fn empty_request_list_ok() {
        let eng = tiny_engine();
        assert!(eng.serve(vec![], &AttentionMode::Dense).unwrap().is_empty());
    }

    #[test]
    fn builder_covers_every_knob() {
        let cfg = EngineConfig::builder()
            .max_batch(7)
            .sampler(Sampler::Temperature(0.5))
            .seed(9)
            .workers(3)
            .prefill_chunk(8)
            .block_tokens(32)
            .kv_capacity_bytes(1 << 20)
            .kv_headroom_blocks(4)
            .prefix_cache(true)
            .max_seq_len(4096)
            .kv_dtype(KvDtype::Int8)
            .kv_spill("/tmp/kv.spill")
            .kv_prefetch(true)
            .kv_prefetch_depth(3)
            .virtual_clock(true)
            .build();
        assert_eq!(cfg.max_batch, 7);
        assert!(matches!(cfg.sampler, Sampler::Temperature(t) if (t - 0.5).abs() < 1e-9));
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.workers, 3);
        assert_eq!(cfg.prefill_chunk, 8);
        assert_eq!(cfg.block_tokens, 32);
        assert_eq!(cfg.kv_capacity_bytes, Some(1 << 20));
        assert_eq!(cfg.kv_headroom_blocks, 4);
        assert!(cfg.prefix_cache);
        assert_eq!(cfg.max_seq_len, Some(4096));
        assert_eq!(cfg.kv_dtype, KvDtype::Int8);
        assert_eq!(cfg.kv_spill.as_deref(), Some(std::path::Path::new("/tmp/kv.spill")));
        assert!(cfg.kv_prefetch);
        assert_eq!(cfg.kv_prefetch_depth, 3);
        assert!(cfg.virtual_clock);
    }

    #[test]
    fn engine_session_streams_the_same_tokens_as_serve() {
        let eng = tiny_engine();
        let served = eng.serve(reqs(3, 10, 4), &AttentionMode::Dense).unwrap();
        let mut session = eng.session();
        for r in reqs(3, 10, 4) {
            session.submit(
                SubmitRequest::new(r.prompt).options(GenOptions::new(r.gen_len).seed(r.id)),
            );
        }
        let mut streamed: Vec<Vec<u32>> = vec![Vec::new(); 3];
        while !session.is_idle() {
            for ev in session.tick().unwrap() {
                if let Event::Token { id, token, .. } = ev {
                    streamed[id as usize].push(token);
                }
            }
        }
        for (r, s) in served.iter().zip(streamed.iter()) {
            assert_eq!(&r.tokens, s, "request {} diverged between serve and session", r.id);
        }
    }
}
