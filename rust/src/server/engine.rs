//! The generation engine: parallel continuous batching over a compute
//! backend, with a paged KV cache.
//!
//! Scheduling model (vLLM-style, specialized to this testbed), as three
//! phases per scheduler round:
//!
//! 1. **Admission** — FIFO over the waiting queue, gated by batch
//!    capacity (`max_batch`), arrival time (open-loop traces), and the
//!    paged-KV block pool: a request is admitted only when its
//!    worst-case block count (prompt + generation budget, both known up
//!    front) can be leased. Reserving worst-case at admission keeps the
//!    decode hot path allocator-free and the capacity gate exact.
//! 2. **Step execution** — every active request advances one step (a
//!    prefill chunk, or one decode token). Each request owns its
//!    `KvCache`, policies and `Rng`, so steps are data-parallel: they
//!    fan out across the engine's `util::ThreadPool`.
//! 3. **Merge** — results return in submission order; completed
//!    requests free their blocks and their slot, and the queue
//!    backfills. Because per-request state never crosses requests and
//!    merge order is fixed, token streams are byte-identical at any
//!    worker count.

use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use super::{ArrivingRequest, Request, RequestResult};
use crate::attention::Selection;
use crate::kvcache::{BlockId, BlockPool, KvCache};
use crate::model::{Model, ModelConfig, Sampler, StepOut};
use crate::policies::{IndexPolicy, PolicyCtx};
use crate::tensor::Mat;
use crate::util::threadpool::ThreadPool;
use crate::util::Rng;

/// Compute backend abstraction: the rust-native model or the PJRT path.
pub trait Backend {
    fn config(&self) -> &ModelConfig;
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection>,
    ) -> Result<StepOut>;
}

impl Backend for Model {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection>,
    ) -> Result<StepOut> {
        Ok(self.decode_step(token, pos, cache, select))
    }
}

impl Backend for crate::runtime::PjrtModel {
    fn config(&self) -> &ModelConfig {
        &self.cfg
    }
    fn step(
        &self,
        token: u32,
        pos: usize,
        cache: &mut KvCache,
        select: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection>,
    ) -> Result<StepOut> {
        self.decode_step(token, pos, cache, select)
    }
}

/// Creates a fresh policy per (layer, head) for each admitted request.
pub type PolicyFactory = Box<dyn Fn(usize, usize) -> Box<dyn IndexPolicy>>;

/// How decode attention is computed.
pub enum AttentionMode {
    Dense,
    Sparse(PolicyFactory),
}

pub struct EngineConfig {
    /// Maximum concurrently active requests.
    pub max_batch: usize,
    pub sampler: Sampler,
    pub seed: u64,
    /// Worker threads for the step-execution phase. 1 = sequential.
    pub workers: usize,
    /// Prompt tokens a prefilling request may ingest per round.
    pub prefill_chunk: usize,
    /// Paged-KV allocation granularity (tokens per block).
    pub block_tokens: usize,
    /// Engine-wide KV memory budget; admission stalls when the paged
    /// pool cannot cover a request's worst case. `None` = unbounded.
    pub kv_capacity_bytes: Option<usize>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 4,
            sampler: Sampler::Greedy,
            seed: 0,
            workers: 1,
            prefill_chunk: 32,
            block_tokens: 16,
            kv_capacity_bytes: None,
        }
    }
}

/// One active request's serving state. Fully self-contained (cache,
/// policies, RNG), which is what makes step execution data-parallel.
struct Active {
    req: Request,
    cache: KvCache,
    policies: Vec<Box<dyn IndexPolicy>>, // L*H, empty in dense mode
    rng: Rng,
    tokens: Vec<u32>,
    next_token: u32,
    pos: usize,
    prefill_left: usize,
    started: Instant,
    wait_s: f64,
    ttft_s: f64,
    decode_s: f64,
    density_sum: f64,
    density_n: usize,
    step: usize,
}

impl Active {
    fn finished(&self) -> bool {
        self.prefill_left == 0 && self.tokens.len() >= self.req.gen_len
    }

    fn into_result(self) -> RequestResult {
        RequestResult {
            id: self.req.id,
            tokens: self.tokens,
            wait_s: self.wait_s,
            ttft_s: self.ttft_s,
            decode_s: self.decode_s,
            mean_density: if self.density_n > 0 {
                self.density_sum / self.density_n as f64
            } else {
                1.0
            },
            kv_bytes_read: self.cache.stats.bytes_read,
        }
    }
}

pub struct Engine<B: Backend> {
    pub backend: Arc<B>,
    pub cfg: EngineConfig,
    pool: ThreadPool,
}

impl<B: Backend + Send + Sync + 'static> Engine<B> {
    pub fn new(backend: B, cfg: EngineConfig) -> Engine<B> {
        let pool = ThreadPool::new(cfg.workers.max(1));
        Engine { backend: Arc::new(backend), cfg, pool }
    }

    /// Step-execution worker threads.
    pub fn workers(&self) -> usize {
        self.pool.num_workers()
    }

    /// Serve a batch of requests to completion with continuous batching
    /// (closed loop: everything is queued at t = 0).
    pub fn serve(&self, requests: Vec<Request>, mode: &AttentionMode) -> Result<Vec<RequestResult>> {
        let arriving = requests.into_iter().map(ArrivingRequest::immediate).collect();
        self.serve_arrivals(arriving, mode)
    }

    /// Serve an open-loop trace: requests become visible to the
    /// scheduler at their arrival times (e.g. Poisson arrivals from
    /// `workloads::traces`), so queueing delay is measured for real.
    pub fn serve_open_loop(
        &self,
        mut requests: Vec<ArrivingRequest>,
        mode: &AttentionMode,
    ) -> Result<Vec<RequestResult>> {
        requests.sort_by(|a, b| {
            a.arrival_s
                .partial_cmp(&b.arrival_s)
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.req.id.cmp(&b.req.id))
        });
        self.serve_arrivals(requests, mode)
    }

    fn serve_arrivals(
        &self,
        requests: Vec<ArrivingRequest>,
        mode: &AttentionMode,
    ) -> Result<Vec<RequestResult>> {
        let mcfg = self.backend.config().clone();
        let max_batch = self.cfg.max_batch.max(1);
        let mut blocks =
            BlockPool::for_model(&mcfg, self.cfg.block_tokens, self.cfg.kv_capacity_bytes);
        // Fail fast on unsatisfiable requests: a worst case beyond total
        // pool capacity could never be admitted, and discovering that
        // mid-run would discard every already-completed result.
        if let Some(cap) = blocks.capacity_blocks() {
            for ar in &requests {
                let needed = blocks.blocks_for_tokens(ar.req.prompt.len() + ar.req.gen_len);
                if needed > cap {
                    bail!(
                        "request {} needs {needed} KV blocks but pool capacity is {cap} \
                         blocks ({} bytes/block); raise kv_capacity_bytes or shorten the request",
                        ar.req.id,
                        blocks.block_bytes()
                    );
                }
            }
        }
        let mut waiting: VecDeque<ArrivingRequest> = requests.into();
        let mut active: Vec<Active> = Vec::new();
        let mut done: Vec<RequestResult> = Vec::new();
        let mut seed_rng = Rng::new(self.cfg.seed);
        let start = Instant::now();

        loop {
            // ── phase 1: admission (FIFO; arrival-, batch- and KV-gated) ──
            let now = start.elapsed().as_secs_f64();
            while active.len() < max_batch {
                let Some(front) = waiting.front() else { break };
                if front.arrival_s > now {
                    break;
                }
                let needed =
                    blocks.blocks_for_tokens(front.req.prompt.len() + front.req.gen_len);
                let Some(lease) = blocks.try_alloc(needed) else {
                    // Upfront validation guarantees `needed` fits total
                    // capacity, so some active request holds the missing
                    // blocks: head-of-line waits for a completion.
                    debug_assert!(
                        !active.is_empty(),
                        "admission stalled with an empty batch despite capacity validation"
                    );
                    break;
                };
                let ar = waiting.pop_front().expect("front() was Some");
                active.push(self.admit(ar, lease, mode, &mcfg, &mut seed_rng, now));
            }

            if active.is_empty() {
                let Some(front) = waiting.front() else { break };
                // Open-loop idle gap: nothing runnable until the next arrival.
                let gap = front.arrival_s - start.elapsed().as_secs_f64();
                if gap > 0.0 {
                    std::thread::sleep(std::time::Duration::from_secs_f64(gap.min(0.02)));
                }
                continue;
            }

            // ── phase 2: fan the batch's steps out across the pool ──
            let batch: Vec<Active> = std::mem::take(&mut active);
            let backend = Arc::clone(&self.backend);
            let sampler = self.cfg.sampler.clone();
            let prefill_chunk = self.cfg.prefill_chunk.max(1);
            let stepped: Vec<Result<Active>> = self.pool.map(batch, move |mut a| {
                advance(&*backend, &sampler, prefill_chunk, &mut a).map(|_| a)
            });

            // ── phase 3: deterministic merge, in submission order ──
            for res in stepped {
                let mut a = res?;
                if a.finished() {
                    let lease = a.cache.release_blocks();
                    blocks.free(lease).map_err(|e| anyhow!("kv block pool: {e}"))?;
                    done.push(a.into_result());
                } else {
                    active.push(a);
                }
            }
        }
        done.sort_by_key(|r| r.id);
        Ok(done)
    }

    fn admit(
        &self,
        ar: ArrivingRequest,
        lease: Vec<BlockId>,
        mode: &AttentionMode,
        mcfg: &ModelConfig,
        seed_rng: &mut Rng,
        now: f64,
    ) -> Active {
        let ArrivingRequest { arrival_s, req } = ar;
        let policies = match mode {
            AttentionMode::Dense => Vec::new(),
            AttentionMode::Sparse(factory) => {
                let mut v = Vec::with_capacity(mcfg.n_layers * mcfg.n_heads);
                for l in 0..mcfg.n_layers {
                    for h in 0..mcfg.n_heads {
                        v.push(factory(l, h));
                    }
                }
                v
            }
        };
        let first = *req.prompt.first().unwrap_or(&0);
        Active {
            prefill_left: req.prompt.len(),
            cache: KvCache::paged(mcfg, self.cfg.block_tokens.max(1), lease),
            policies,
            rng: seed_rng.fork(req.id),
            tokens: Vec::new(),
            next_token: first,
            pos: 0,
            started: Instant::now(),
            wait_s: (now - arrival_s).max(0.0),
            ttft_s: 0.0,
            decode_s: 0.0,
            density_sum: 0.0,
            density_n: 0,
            step: 0,
            req,
        }
    }
}

/// Advance one request by one scheduler round: up to `prefill_chunk`
/// prompt tokens while prefilling (dense, Setup B: context via full
/// attention), or exactly one decode step (sparse per policy). Runs on a
/// worker thread; touches only this request's state.
fn advance<B: Backend>(
    backend: &B,
    sampler: &Sampler,
    prefill_chunk: usize,
    a: &mut Active,
) -> Result<()> {
    let n_heads = backend.config().n_heads;
    let t0 = Instant::now();
    let out: StepOut;
    if a.prefill_left > 0 {
        let take = a.prefill_left.min(prefill_chunk);
        let mut last: Option<StepOut> = None;
        for _ in 0..take {
            let tok = a.req.prompt[a.pos];
            last = Some(backend.step(tok, a.pos, &mut a.cache, None)?);
            a.prefill_left -= 1;
            a.pos += 1;
        }
        if a.prefill_left > 0 {
            return Ok(()); // still prefilling: nothing to sample yet
        }
        a.ttft_s = a.started.elapsed().as_secs_f64();
        a.cache.stats.reset(); // count decode traffic only
        out = last.expect("prefill_chunk >= 1");
    } else {
        let sparse = !a.policies.is_empty();
        let policies = &mut a.policies;
        let rng = &mut a.rng;
        let step = a.step;
        let mut select = |l: usize, h: usize, k: &Mat, v: &Mat, q: &[f32]| -> Selection {
            let mut ctx = PolicyCtx { k, v, q_scaled: q, rng: &mut *rng, step };
            policies[l * n_heads + h].select(&mut ctx)
        };
        let sel_opt: Option<&mut dyn FnMut(usize, usize, &Mat, &Mat, &[f32]) -> Selection> =
            if sparse { Some(&mut select) } else { None };
        let stepped = backend.step(a.next_token, a.pos, &mut a.cache, sel_opt)?;
        a.decode_s += t0.elapsed().as_secs_f64();
        a.pos += 1;
        a.step += 1;
        a.density_sum += stepped.mean_density;
        a.density_n += 1;
        out = stepped;
    }
    // Sample the next token once the prompt is fully ingested. The
    // sampler consumes this request's private RNG, so the draw sequence
    // is identical no matter how rounds are scheduled across workers.
    let tok = sampler.sample(&out.logits, &mut a.rng);
    if a.tokens.len() < a.req.gen_len && (a.step > 0 || a.pos == a.req.prompt.len()) {
        // The token just generated becomes the next input.
        a.tokens.push(tok);
        a.next_token = tok;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policies::{SizeSpec, VAttentionConfig, VAttentionPolicy};

    fn tiny_engine() -> Engine<Model> {
        let cfg = ModelConfig::tiny();
        Engine::new(Model::new(cfg, 42), EngineConfig::default())
    }

    fn reqs(n: usize, prompt_len: usize, gen_len: usize) -> Vec<Request> {
        (0..n as u64)
            .map(|i| {
                let prompt: Vec<u32> =
                    (0..prompt_len as u32).map(|t| (i as u32 * 7 + t) % 250).collect();
                Request::new(i, prompt, gen_len)
            })
            .collect()
    }

    #[test]
    fn serves_all_requests_dense() {
        let eng = tiny_engine();
        let results = eng.serve(reqs(6, 12, 5), &AttentionMode::Dense).unwrap();
        assert_eq!(results.len(), 6);
        for r in &results {
            assert_eq!(r.tokens.len(), 5);
            assert!((r.mean_density - 1.0).abs() < 1e-9);
            assert!(r.ttft_s >= 0.0);
            assert!(r.wait_s >= 0.0);
        }
        // FIFO ids preserved in output ordering
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn greedy_dense_is_deterministic() {
        let eng = tiny_engine();
        let a = eng.serve(reqs(2, 10, 6), &AttentionMode::Dense).unwrap();
        let b = eng.serve(reqs(2, 10, 6), &AttentionMode::Dense).unwrap();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens);
        }
    }

    #[test]
    fn worker_count_does_not_change_tokens() {
        let run = |workers: usize| {
            let eng = Engine::new(
                Model::new(ModelConfig::tiny(), 42),
                EngineConfig { workers, max_batch: 3, ..Default::default() },
            );
            eng.serve(reqs(7, 9, 5), &AttentionMode::Dense).unwrap()
        };
        let seq = run(1);
        let par = run(4);
        for (a, b) in seq.iter().zip(par.iter()) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens);
        }
    }

    #[test]
    fn sparse_mode_reads_fewer_bytes() {
        let eng = tiny_engine();
        let mk_mode = || -> AttentionMode {
            AttentionMode::Sparse(Box::new(|_l, _h| {
                let mut cfg = VAttentionConfig::default();
                cfg.sink = SizeSpec::Abs(4);
                cfg.window = SizeSpec::Abs(8);
                cfg.heavy = SizeSpec::Frac(0.05);
                // Random-weight tiny models have unstructured values, so
                // the full-SDPA guarantee correctly saturates at dense —
                // use the denominator guarantee at a moderate tolerance
                // to exercise genuine sparsity here (cf. Fig. 10).
                cfg.verify = crate::budget::Verify::Denominator;
                cfg.eps = 0.2;
                cfg.delta = 0.2;
                Box::new(VAttentionPolicy::oracle(cfg))
            }))
        };
        // Long prompt so sparsity has room.
        let dense = eng.serve(reqs(1, 192, 8), &AttentionMode::Dense).unwrap();
        let sparse = eng.serve(reqs(1, 192, 8), &mk_mode()).unwrap();
        assert!(sparse[0].mean_density < 1.0);
        assert!(sparse[0].kv_bytes_read < dense[0].kv_bytes_read);
        assert_eq!(sparse[0].tokens.len(), 8);
    }

    #[test]
    fn batch_capacity_respected_and_all_complete() {
        let eng = Engine::new(
            Model::new(ModelConfig::tiny(), 1),
            EngineConfig { max_batch: 2, ..Default::default() },
        );
        let results = eng.serve(reqs(7, 6, 3), &AttentionMode::Dense).unwrap();
        assert_eq!(results.len(), 7);
        assert!(results.iter().all(|r| r.tokens.len() == 3));
    }

    #[test]
    fn kv_capacity_limits_admission_without_changing_tokens() {
        let cfg = ModelConfig::tiny();
        // Room for exactly two requests' worst case (16 tokens → 1 block).
        let capped = Engine::new(
            Model::new(cfg.clone(), 1),
            EngineConfig {
                max_batch: 4,
                block_tokens: 16,
                kv_capacity_bytes: Some(2 * 16 * cfg.kv_bytes_per_token()),
                ..Default::default()
            },
        );
        let free = Engine::new(
            Model::new(cfg, 1),
            EngineConfig { max_batch: 4, block_tokens: 16, ..Default::default() },
        );
        let a = capped.serve(reqs(5, 10, 4), &AttentionMode::Dense).unwrap();
        let b = free.serve(reqs(5, 10, 4), &AttentionMode::Dense).unwrap();
        assert_eq!(a.len(), 5);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "capacity gating must not change outputs");
        }
    }

    #[test]
    fn oversized_request_is_rejected_not_deadlocked() {
        let cfg = ModelConfig::tiny();
        let eng = Engine::new(
            Model::new(cfg.clone(), 1),
            EngineConfig {
                block_tokens: 16,
                kv_capacity_bytes: Some(16 * cfg.kv_bytes_per_token()),
                ..Default::default()
            },
        );
        // 40 + 8 tokens → 3 blocks, but the pool holds 1.
        let err = eng.serve(reqs(1, 40, 8), &AttentionMode::Dense).unwrap_err();
        assert!(format!("{err}").contains("KV blocks"), "{err}");
    }

    #[test]
    fn empty_request_list_ok() {
        let eng = tiny_engine();
        assert!(eng.serve(vec![], &AttentionMode::Dense).unwrap().is_empty());
    }
}
